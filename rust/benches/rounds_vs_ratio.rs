//! E2 ("Figure 1") — approximation ratio vs number of thresholds t for
//! Algorithm 5: the measured series must dominate the proven
//! `1 − (1 − 1/(t+1))^t` curve and approach `1 − 1/e`.
//!
//! Two instance families: planted-dense coverage (OPT known exactly) and
//! clustered facility location (ratio vs greedy). Also prints the
//! OPT-guessing variant (2t+2 rounds) to show ε costs memory, not rounds.

use mrsub::algorithms::multi_round::MultiRound;
use mrsub::coordinator::run_experiment;
use mrsub::core::{threshold_bound, ONE_MINUS_1_E};
use mrsub::mapreduce::ClusterConfig;
use mrsub::workload::facility::FacilityGen;
use mrsub::workload::planted::PlantedCoverageGen;
use mrsub::workload::WorkloadGen;

fn main() {
    let k = 30;
    println!("== E2: ratio vs t for Algorithm 5 (k={k}) ==");
    println!("bound(t) = 1-(1-1/(t+1))^t -> 1-1/e = {ONE_MINUS_1_E:.4}\n");

    let planted = PlantedCoverageGen::dense(k, 6_000, 15_000).generate(5);
    let opt = planted.known_opt.unwrap();
    let facility = FacilityGen::clustered(3_000, 800, 10).generate(5);

    println!(
        "{:>3} {:>7} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "t", "rounds", "planted", "facility", "guess(2t+2)", "bound", "ok"
    );
    for t in 1..=8 {
        let cfg = ClusterConfig { seed: 9, ..ClusterConfig::default() };
        let r_planted = run_experiment(&planted, &MultiRound::known(t, opt), k, &cfg).unwrap();
        let r_fac = run_experiment(&facility, &MultiRound::guessing(t, 0.2), k, &cfg).unwrap();
        let r_guess = run_experiment(&planted, &MultiRound::guessing(t, 0.2), k, &cfg).unwrap();
        let bound = threshold_bound(t);
        let ok = r_planted.ratio >= bound - 1e-9 && r_guess.ratio >= bound * (1.0 - 0.2) - 1e-9;
        println!(
            "{:>3} {:>7} {:>12.4} {:>12.4} {:>12.4} {:>12.4} {:>10}",
            t,
            r_planted.rounds,
            r_planted.ratio,
            r_fac.ratio,
            r_guess.ratio,
            bound,
            if ok { "yes" } else { "NO" }
        );
    }
    println!("\nexpected shape: planted column ≥ bound for every t; series rises toward");
    println!("1-1/e as t grows; the guessing variant stays within (1-eps) of the known-");
    println!("OPT one while adding exactly 2 rounds (t=1 row: 4 rounds vs 2).");
}
