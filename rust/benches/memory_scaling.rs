//! E4 ("Figure 3") — the MRC memory envelopes of Lemmas 2 and 6:
//!
//! * sample size concentrates at `4·√(nk)` (Chernoff),
//! * elements received by the central machine stay `O(√(nk))` for
//!   Algorithm 4 and `O((1/ε)·√(nk)·log k)` for the OPT-free combined
//!   algorithm,
//! * per-machine residency stays `O(√(nk))`,
//!
//! as n sweeps over two orders of magnitude at fixed k. Columns are
//! normalized by √(nk) so the paper's claim reads as "columns flat in n".

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::greedy::lazy_greedy;
use mrsub::algorithms::two_round::TwoRoundKnownOpt;
use mrsub::coordinator::run_experiment;
use mrsub::mapreduce::ClusterConfig;
use mrsub::workload::coverage::CoverageGen;
use mrsub::workload::WorkloadGen;

fn main() {
    let k = 25;
    let eps = 0.1;
    println!("== E4: memory scaling at fixed k={k} (columns normalized by √(nk)) ==\n");
    println!(
        "{:>8} {:>8} {:>9} {:>11} {:>11} {:>12} {:>12}",
        "n", "√(nk)", "machines", "sample/√nk", "alg4-C/√nk", "comb-C/√nk", "mach-mem/√nk"
    );
    for n in [4_000usize, 8_000, 16_000, 32_000, 64_000, 128_000, 256_000] {
        let inst = CoverageGen::new(n, n / 3, 8).generate(7);
        let cfg = ClusterConfig { seed: 7, ..ClusterConfig::default() };
        let bound = (n as f64 * k as f64).sqrt();

        let opt_est = lazy_greedy(&inst.oracle, k).value;
        let alg4 = run_experiment(&inst, &TwoRoundKnownOpt::new(opt_est), k, &cfg).unwrap();
        let comb = run_experiment(&inst, &CombinedTwoRound::new(eps), k, &cfg).unwrap();

        println!(
            "{:>8} {:>8.0} {:>9} {:>11.2} {:>11.2} {:>12.2} {:>12.2}",
            n,
            bound,
            alg4.metrics.machines,
            alg4.metrics.sample_size as f64 / bound,
            alg4.peak_central_recv as f64 / bound,
            comb.peak_central_recv as f64 / bound,
            comb.peak_machine_memory as f64 / bound,
        );
    }
    println!("\nexpected shape (paper): sample/√nk ≈ 4.0 flat (Alg 3 with p = 4√(k/n));");
    println!("alg4-C/√nk bounded by a small constant flat in n (Lemma 2); comb-C/√nk");
    println!("bounded by O((1/ε)·log k) flat in n (Lemma 6); machine memory likewise");
    println!("O(√nk) once n/m ≈ √(nk) dominates the shard term.");
}
