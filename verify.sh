#!/usr/bin/env bash
# Tier-1 verification entry point (documented in ROADMAP.md).
#
#   ./verify.sh              build + test + fmt + clippy
#   ./verify.sh fast         build + test only
#   ./verify.sh conformance  backend-conformance matrix, single-threaded
#                            (stable worker-process counts for the
#                            shared-nothing process backend). Set
#                            MRSUB_CONFORMANCE_TRANSPORT=pipe|uds|uds+arena|tcp
#                            to run one transport shard of the process-
#                            backend matrix (the CI strategy.matrix does
#                            this to parallelize; Serial/Rayon always run)
#   ./verify.sh chaos        seeded elasticity chaos harness, single-
#                            threaded: 64+ generated kill/respawn/
#                            late-join/steal schedules across every
#                            transport, each compared round-by-round
#                            against the Serial reference; failing seeds
#                            land in target/chaos-failures.txt (uploaded
#                            as a CI artifact) and replay via
#                            MRSUB_CHAOS_SCHEDULES=<seed>
#   ./verify.sh ci           full (superset of fast) + conformance, then
#                            an `mrsub bench` smoke whose JSON report is
#                            validated against the committed bench-report
#                            schema (written to BENCH_smoke.json — the CI
#                            pipeline uploads it as an artifact)
#   ./verify.sh bench-diff   run a bench matching the committed
#                            BENCH_baseline.json axes and gate batched
#                            throughput + per-round IPC bytes against it
#                            (>15% regression fails — the committed
#                            baseline is armed, i.e. not marked
#                            provisional; diff lands in BENCH_diff.json)
#   ./verify.sh serve-smoke  end-to-end `mrsub serve` exercise: start a
#                            daemon on a warm process pool, submit two
#                            concurrent jobs plus a resubmission, compare
#                            selections/values against a standalone-path
#                            daemon (bit-identity at the CLI level), then
#                            drain via `mrsub submit --shutdown` and fail
#                            on leaked worker processes
#   ./verify.sh lint         `mrsub check-invariants` over the repo tree:
#                            wire-drift fingerprint vs WIRE_VERSION,
#                            determinism hazards, unsafe hygiene + budgets,
#                            pragma discipline (docs/ARCHITECTURE.md,
#                            "Enforced invariants")
#   ./verify.sh miri         nightly Miri over the arena layout and wire
#                            codec tests (the cfg(miri)-clean subset)
#   ./verify.sh asan         nightly AddressSanitizer over the arena
#                            lifecycle, pool, and process-backend tests,
#                            plus the arena conformance subset
#   ./verify.sh tsan         nightly ThreadSanitizer over the pool and
#                            the ProcessPool reader-thread/pipelined-join
#                            paths
#
# The default build is offline-clean (no crates.io deps, `xla` feature off).
set -euo pipefail
cd "$(dirname "$0")"

mode="${1:-full}"

# Fail if #[ignore]d tests silently accumulate: an ignored test is a
# disabled assertion, and disabling one must be a visible, justified act.
# Annotate the same line with `// ALLOW-IGNORE: <reason>` to allow one.
#
# Same discipline for #[allow(dead_code)] across all of rust/src/: a
# dead-code allow is exactly how stranded code hides through refactors.
# Justify one with `// ALLOW-DEAD: <reason>` on the same line.
#
# These greps are the fast pre-build approximation (the attribute at the
# start of a line; occurrences inside string literals — e.g. the lint
# engine's own fixtures — don't start lines). The comment/literal-aware
# authority is the same pair of lints inside `mrsub check-invariants`
# (./verify.sh lint), which also accepts `// LINT-ALLOW:` pragmas.
check_ignores() {
    local found
    found=$(grep -rnE '^[[:space:]]*#\[ignore' rust/ examples/ 2>/dev/null | grep -v 'ALLOW-IGNORE' || true)
    if [ -n "$found" ]; then
        echo "verify: FAIL — #[ignore]d tests without an ALLOW-IGNORE justification:"
        echo "$found"
        exit 1
    fi
    found=$(grep -rnE '^[[:space:]]*#\[allow\(dead_code' rust/src/ 2>/dev/null | grep -v 'ALLOW-DEAD' || true)
    if [ -n "$found" ]; then
        echo "verify: FAIL — #[allow(dead_code)] in rust/src/ without an ALLOW-DEAD justification:"
        echo "$found"
        exit 1
    fi
}

case "$mode" in
    conformance)
        check_ignores
        cargo build --release
        if [ -n "${MRSUB_CONFORMANCE_TRANSPORT:-}" ]; then
            echo "verify: conformance shard — transport ${MRSUB_CONFORMANCE_TRANSPORT}"
        fi
        cargo test --test backend_conformance -- --test-threads=1
        ;;
    chaos)
        check_ignores
        cargo build --release
        # stale failure artifacts would masquerade as this run's output.
        rm -f rust/target/chaos-failures.txt target/chaos-failures.txt
        cargo test --test elastic_chaos -- --test-threads=1
        ;;
    fast)
        check_ignores
        cargo build --release
        cargo test -q
        ;;
    full)
        check_ignores
        cargo build --release
        cargo test -q
        cargo fmt --check
        cargo clippy --all-targets -- -D warnings
        # docs are CI-enforced: broken intra-doc links and missing docs
        # (lib.rs carries #![warn(missing_docs)]) fail the build.
        RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
        ;;
    lint)
        check_ignores
        cargo build --release
        ./target/release/mrsub check-invariants
        ;;
    miri)
        # Miri cannot execute the arena's memfd/mmap/sendmsg FFI, so those
        # paths are cfg'd out (rust/src/mapreduce/arena.rs gates them on
        # `not(miri)`); what runs is the platform-independent subset — the
        # arena word-layout/validation tests and the wire codec suite (at
        # its reduced interpreted case budget). Leak checking is off
        # because arena mappings are deliberately process-lifetime.
        MIRIFLAGS="-Zmiri-ignore-leaks" \
            cargo +nightly miri test --lib -- mapreduce::arena mapreduce::wire
        ;;
    asan)
        # AddressSanitizer needs a rebuilt std (-Zbuild-std, rust-src
        # component). Covers the arena lifecycle (memfd build/map/leak),
        # the thread-pool slot writer, the ProcessPool unit tests, and the
        # arena conformance subset end to end.
        RUSTFLAGS="-Zsanitizer=address" \
            cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
            --lib -- mapreduce::arena util::pool mapreduce::process
        RUSTFLAGS="-Zsanitizer=address" \
            cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
            --test backend_conformance -- --test-threads=1 arena
        ;;
    tsan)
        # ThreadSanitizer over the lock-free pool (work-stealing cursor,
        # SendPtr slot writes, spin-join) and the ProcessPool
        # reader-thread/pipelined-join paths.
        RUSTFLAGS="-Zsanitizer=thread" \
            cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
            --lib -- util::pool mapreduce::process
        ;;
    ci)
        # `full` is a strict superset of `fast` (build + tests + lints),
        # so ci = full + conformance + the invariant lints + bench smoke.
        "$0" full
        "$0" conformance
        "$0" lint
        # Bench smoke: tiny sizes, one oracle family, serial vs the
        # shared-nothing process backend — enough to (a) keep the report
        # schema honest against the committed fixture and (b) seed the
        # BENCH_*.json perf trajectory as a per-commit CI artifact.
        # the algorithm axis covers the low-adaptivity sweep (dash) and a
        # matroid-constrained randomized-partition run alongside the
        # classic combined algorithm, so the smoke exercises every report
        # shape the v4 schema freezes.
        echo "verify: ci bench smoke"
        ./target/release/mrsub bench --n 256 --k 8 --iters 2 \
            --families coverage --backends serial,process:2 \
            --algorithms combined,dash,randgreedi-matroid \
            --sizes 300x6 --output BENCH_smoke.json
        MRSUB_BENCH_REPORT="$PWD/BENCH_smoke.json" \
            cargo test --test bench_report_schema
        ;;
    bench-diff)
        check_ignores
        cargo build --release
        # Match the committed baseline's sweep axes (families × backends ×
        # sizes) so every baseline row finds a current-row partner; rows
        # missing on either side are notes, not gates.
        echo "verify: bench-diff against BENCH_baseline.json"
        ./target/release/mrsub bench --n 4096 --k 32 --iters 3 --seed 11 \
            --families coverage,modular \
            --backends serial,process:2@uds,process:2@uds+arena \
            --sizes 8000x20 --output BENCH_current.json
        ./target/release/mrsub bench-diff \
            --baseline BENCH_baseline.json --current BENCH_current.json \
            --tolerance 0.15 --output BENCH_diff.json
        ;;
    serve-smoke)
        check_ignores
        cargo build --release
        echo "verify: serve smoke (daemon vs standalone bit-identity, clean shutdown)"
        mrsub=./target/release/mrsub
        tmp=$(mktemp -d)
        # Two daemons on ephemeral ports: one with the warm shared-nothing
        # pool under test, one on the in-process standalone path as the
        # one-shot reference (its jobs run plain run_experiment, no pool).
        "$mrsub" serve --bind 127.0.0.1:0 --backend process:2@uds >"$tmp/warm.log" 2>&1 &
        warm_pid=$!
        "$mrsub" serve --bind 127.0.0.1:0 --backend serial >"$tmp/solo.log" 2>&1 &
        solo_pid=$!
        trap 'kill "$warm_pid" "$solo_pid" 2>/dev/null || true; rm -rf "$tmp"' EXIT

        wait_addr() { # $1: daemon log; prints the scraped bind address
            local addr="" i
            for i in $(seq 1 100); do
                addr=$(sed -n 's/^mrsub serve: listening on //p' "$1" | head -n1)
                if [ -n "$addr" ]; then echo "$addr"; return 0; fi
                sleep 0.1
            done
            echo "verify: FAIL — daemon never bound ($1):" >&2
            cat "$1" >&2
            return 1
        }
        warm=$(wait_addr "$tmp/warm.log")
        solo=$(wait_addr "$tmp/solo.log")

        # two concurrent jobs share the warm pool (spawned on the first)...
        "$mrsub" submit --connect "$warm" --family coverage --n 2000 --k 12 --seed 7 \
            --algorithm combined:0.1 --output "$tmp/warm1.json" &
        j1=$!
        "$mrsub" submit --connect "$warm" --family modular --n 1024 --k 8 --seed 9 \
            --algorithm randgreedi --output "$tmp/warm2.json" &
        j2=$!
        wait "$j1"
        wait "$j2"
        # ...and a resubmission attaches to the already-warm workers.
        "$mrsub" submit --connect "$warm" --family coverage --n 2000 --k 12 --seed 7 \
            --algorithm combined:0.1 --output "$tmp/warm1_again.json"
        # one-shot equivalents on the standalone-path daemon.
        "$mrsub" submit --connect "$solo" --family coverage --n 2000 --k 12 --seed 7 \
            --algorithm combined:0.1 --output "$tmp/solo1.json"
        "$mrsub" submit --connect "$solo" --family modular --n 1024 --k 8 --seed 9 \
            --algorithm randgreedi --output "$tmp/solo2.json"

        python3 - "$tmp" <<'PYEOF'
import json, sys
tmp = sys.argv[1]
def result(name):
    with open(f"{tmp}/{name}.json") as f:
        rec = json.load(f)
    return rec["selection"], rec["value"]
for served, reference in [("warm1", "solo1"), ("warm2", "solo2"), ("warm1_again", "warm1")]:
    s, r = result(served), result(reference)
    assert s == r, f"{served} diverged from {reference}: {s} vs {r}"
print("serve smoke: selections and values bit-identical")
PYEOF

        "$mrsub" submit --connect "$warm" --shutdown
        "$mrsub" submit --connect "$solo" --shutdown
        wait "$warm_pid"
        wait "$solo_pid"
        # the daemons are gone; the warm pool's workers must be too.
        for i in $(seq 1 50); do
            pgrep -f "release/mrsub worker" >/dev/null 2>&1 || break
            sleep 0.1
        done
        if pgrep -f "release/mrsub worker" >/dev/null 2>&1; then
            echo "verify: FAIL — leaked worker processes after daemon shutdown:" >&2
            pgrep -af "release/mrsub worker" >&2 || true
            exit 1
        fi
        ;;
    *)
        echo "usage: ./verify.sh [fast|conformance|chaos|ci|bench-diff|serve-smoke|lint|miri|asan|tsan]" >&2
        exit 2
        ;;
esac

echo "verify: OK ($mode)"
