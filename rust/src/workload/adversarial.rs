//! Workload wrapper around the Theorem-4 adversarial oracle, so the
//! tightness experiment (E3) flows through the same `Instance` plumbing as
//! every other workload.

use super::{Instance, WorkloadGen};
use crate::oracle::adversarial::AdversarialOracle;

/// Theorem-4 hard instance against `t` thresholds at cardinality `k`.
#[derive(Debug, Clone, Copy)]
pub struct AdversarialGen {
    /// Number of thresholds the instance is hard for.
    pub t: usize,
    /// Cardinality constraint (also the number of optimal elements).
    pub k: usize,
}

impl AdversarialGen {
    /// New hard-instance generator.
    pub fn new(t: usize, k: usize) -> Self {
        AdversarialGen { t, k }
    }

    /// Build the concrete oracle (deterministic; no randomness involved).
    pub fn build(&self) -> AdversarialOracle {
        AdversarialOracle::hard_instance(self.t, self.k)
    }
}

impl WorkloadGen for AdversarialGen {
    fn generate(&self, _seed: u64) -> Instance {
        let oracle = self.build();
        let opt = oracle.known_opt();
        let name = format!("adversarial(t={},k={})", self.t, self.k);
        Instance::new(name, std::sync::Arc::new(oracle))
            .with_opt(opt, self.k)
            .with_spec(crate::oracle::spec::OracleSpec::Adversarial { t: self.t, k: self.k })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instance_carries_exact_opt() {
        let inst = AdversarialGen::new(3, 12).generate(0);
        assert_eq!(inst.known_opt, Some(12.0));
        assert_eq!(inst.planted_k, Some(12));
        assert!(inst.name.contains("t=3"));
    }
}
