//! Value-oracle abstraction for monotone submodular functions.
//!
//! Every algorithm in the paper interacts with `f` exclusively through
//! marginal queries `f_G(e) = f(G ∪ {e}) − f(G)`, so the central abstraction
//! is an *incremental evaluation state* ([`OracleState`]): it carries the
//! current set `G`, answers marginals in the family's natural incremental
//! complexity (e.g. O(deg) for coverage instead of O(|G|·deg)), and supports
//! O(1)-amortized insertion.
//!
//! [`Oracle`] is the immutable instance: the data defining `f` plus a
//! factory for fresh states. Oracles keep their data behind `Arc` so states
//! are `'static` and cheap to fan out across simulated machines (rayon).

use crate::core::ElementId;

pub mod adversarial;
pub mod concave;
pub mod counting;
pub mod coverage;
pub mod cut;
pub mod facility;
pub mod hlo;
pub mod modular;

pub use counting::CountingOracle;

/// A monotone submodular instance `f : 2^V -> R_{>=0}` with `V = 0..n`.
pub trait Oracle: Send + Sync {
    /// Ground-set size `n = |V|`.
    fn ground_size(&self) -> usize;

    /// Fresh evaluation state positioned at `G = ∅`.
    fn state(&self) -> Box<dyn OracleState>;

    /// `f(S)` evaluated from scratch (default: replay into a fresh state).
    fn value(&self, set: &[ElementId]) -> f64 {
        let mut st = self.state();
        for &e in set {
            st.insert(e);
        }
        st.value()
    }

    /// Singleton value `f({e})`.
    fn singleton(&self, e: ElementId) -> f64 {
        self.state().marginal(e)
    }

    /// A cheap upper bound on `OPT_k` used by tests and OPT-guessing:
    /// `k · max_e f({e})` (valid for any monotone submodular `f`).
    fn opt_upper_bound(&self, k: usize) -> f64 {
        let st = self.state();
        let mut best: f64 = 0.0;
        for e in 0..self.ground_size() as ElementId {
            best = best.max(st.marginal(e));
        }
        best * k as f64
    }
}

/// Incremental evaluation state: the current set `G`, its value, and
/// marginal queries against it.
///
/// `Sync` is required so a single frozen state (e.g. the shared `G₀` of
/// Algorithm 4) can serve read-only marginal queries from all simulated
/// machines in parallel.
pub trait OracleState: Send + Sync {
    /// `f(G)` for the current set.
    fn value(&self) -> f64;

    /// Marginal gain `f_G(e)`. Must return 0 for `e ∈ G` (idempotence).
    fn marginal(&self, e: ElementId) -> f64;

    /// Add `e` to `G`. Inserting an element twice is a no-op.
    fn insert(&mut self, e: ElementId);

    /// The current set `G` in insertion order.
    fn selected(&self) -> &[ElementId];

    /// Deep copy (used when an algorithm forks a partial solution across
    /// guesses or simulated machines).
    fn clone_state(&self) -> Box<dyn OracleState>;

    /// Batched marginals — the hot path of ThresholdFilter. The default
    /// loops over [`OracleState::marginal`]; accelerated oracles (PJRT)
    /// override it with a single device call per block.
    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(es.len(), out.len());
        for (o, &e) in out.iter_mut().zip(es) {
            *o = self.marginal(e);
        }
    }

    /// Number of selected elements (convenience).
    fn len(&self) -> usize {
        self.selected().len()
    }

    /// True iff `G = ∅`.
    fn is_empty(&self) -> bool {
        self.selected().is_empty()
    }
}

impl<T: Oracle + ?Sized> Oracle for std::sync::Arc<T> {
    fn ground_size(&self) -> usize {
        (**self).ground_size()
    }
    fn state(&self) -> Box<dyn OracleState> {
        (**self).state()
    }
    fn value(&self, set: &[ElementId]) -> f64 {
        (**self).value(set)
    }
    fn singleton(&self, e: ElementId) -> f64 {
        (**self).singleton(e)
    }
    fn opt_upper_bound(&self, k: usize) -> f64 {
        (**self).opt_upper_bound(k)
    }
}

impl<T: Oracle + ?Sized> Oracle for &T {
    fn ground_size(&self) -> usize {
        (**self).ground_size()
    }
    fn state(&self) -> Box<dyn OracleState> {
        (**self).state()
    }
    fn value(&self, set: &[ElementId]) -> f64 {
        (**self).value(set)
    }
    fn singleton(&self, e: ElementId) -> f64 {
        (**self).singleton(e)
    }
    fn opt_upper_bound(&self, k: usize) -> f64 {
        (**self).opt_upper_bound(k)
    }
}

/// Shared helper: track selection order + membership for states.
#[derive(Debug, Clone, Default)]
pub(crate) struct Selection {
    order: Vec<ElementId>,
    member: Vec<bool>,
}

impl Selection {
    pub fn new(n: usize) -> Self {
        Selection { order: Vec::new(), member: vec![false; n] }
    }

    /// Returns true if `e` was newly inserted.
    pub fn insert(&mut self, e: ElementId) -> bool {
        let i = e as usize;
        if self.member[i] {
            return false;
        }
        self.member[i] = true;
        self.order.push(e);
        true
    }

    pub fn contains(&self, e: ElementId) -> bool {
        self.member[e as usize]
    }

    pub fn order(&self) -> &[ElementId] {
        &self.order
    }
}

#[cfg(test)]
pub(crate) mod axioms {
    //! Reusable oracle-axiom checks shared by per-family tests and proptest
    //! suites: monotonicity, submodularity, idempotence, state/scratch
    //! consistency.

    use super::*;
    use crate::util::rng::Rng;

    /// Check the four oracle axioms on random chains A ⊆ B and probes e.
    pub fn check_axioms(oracle: &dyn Oracle, seed: u64, trials: usize) {
        let n = oracle.ground_size();
        assert!(n >= 3, "axiom check needs n >= 3");
        let mut rng = Rng::seed_from_u64(seed);
        let ids: Vec<ElementId> = (0..n as ElementId).collect();
        for trial in 0..trials {
            let mut perm = ids.clone();
            rng.shuffle(&mut perm);
            let b_len = rng.gen_range(1..n.min(24) + 1);
            let a_len = rng.gen_range(0..b_len);
            let (b_set, rest) = perm.split_at(b_len);
            let a_set = &b_set[..a_len];

            let mut st_a = oracle.state();
            for &e in a_set {
                st_a.insert(e);
            }
            let mut st_b = oracle.state();
            for &e in b_set {
                st_b.insert(e);
            }

            // monotone: values non-negative and non-decreasing along chain.
            assert!(st_a.value() >= -1e-9, "f must be non-negative");
            assert!(
                st_b.value() >= st_a.value() - 1e-9,
                "monotonicity violated: f(B)={} < f(A)={} (trial {trial})",
                st_b.value(),
                st_a.value()
            );

            // probe elements outside B.
            for &e in rest.iter().take(8) {
                let ma = st_a.marginal(e);
                let mb = st_b.marginal(e);
                assert!(mb >= -1e-9, "marginal must be non-negative (monotone f)");
                assert!(
                    ma >= mb - 1e-6 * (1.0 + ma.abs()),
                    "submodularity violated at e={e}: f_A(e)={ma} < f_B(e)={mb} (trial {trial})"
                );
                // marginal consistency: inserting e yields exactly value + marginal.
                let mut st_a2 = st_a.clone_state();
                st_a2.insert(e);
                let err = (st_a2.value() - (st_a.value() + ma)).abs();
                assert!(
                    err <= 1e-6 * (1.0 + st_a2.value().abs()),
                    "insert/marginal mismatch: {err}"
                );
            }

            // idempotence: marginal of a member is 0, re-insert is a no-op.
            if let Some(&e) = b_set.first() {
                assert!(st_b.marginal(e).abs() <= 1e-9, "member marginal must be 0");
                let v = st_b.value();
                st_b.insert(e);
                assert!((st_b.value() - v).abs() <= 1e-12, "re-insert changed value");
            }

            // scratch evaluation agrees with incremental state.
            let direct = oracle.value(b_set);
            let mut st = oracle.state();
            for &e in b_set {
                st.insert(e);
            }
            assert!(
                (direct - st.value()).abs() <= 1e-6 * (1.0 + direct.abs()),
                "value() vs state mismatch: {direct} vs {}",
                st.value()
            );

            // batch marginals agree with scalar marginals.
            let probes: Vec<ElementId> = rest.iter().take(8).copied().collect();
            let mut batch = vec![0.0; probes.len()];
            st_a.marginals(&probes, &mut batch);
            for (i, &e) in probes.iter().enumerate() {
                assert!(
                    (batch[i] - st_a.marginal(e)).abs() <= 1e-6,
                    "batch marginal mismatch at {e}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_insert_dedups_and_orders() {
        let mut s = Selection::new(5);
        assert!(s.insert(3));
        assert!(s.insert(1));
        assert!(!s.insert(3));
        assert!(s.contains(3));
        assert!(!s.contains(0));
        assert_eq!(s.order(), &[3, 1]);
    }
}
