//! Versioned wire format for the shared-nothing process backend.
//!
//! The coordinator and its worker processes (`mrsub worker`) speak a
//! length-prefixed, checksummed binary framing over a byte stream — a
//! stdin/stdout pipe, a Unix-domain socket, or a TCP connection (the
//! transport is chosen by [`crate::mapreduce::transport::Transport`]; the
//! framing below is byte-identical on all of them):
//!
//! ```text
//! [magic "MRSB"][version u16 LE][len u32 LE][payload…][fnv1a-32 LE]
//! ```
//!
//! Every frame is validated on receipt — magic, protocol version, a hard
//! length cap (`max_frame`, config-driven), and an FNV-1a checksum over the
//! payload — and every validation failure surfaces as a typed
//! [`WireError`], never a panic: a corrupted or truncated stream from a
//! dying worker must degrade into a structured coordinator error (the
//! contract `tests/backend_conformance.rs` fault-injects against).
//!
//! **Versioning rule:** any change to the frame header, to a message tag,
//! or to the byte layout of an existing message bumps [`WIRE_VERSION`].
//! Coordinator and worker are always the same binary (the worker is a
//! re-exec of `current_exe`), so no cross-version compatibility shims are
//! kept; the version field exists to *detect* accidental mixed-binary
//! deployments, which fail the `Ready` handshake with a clear error.
//!
//! Payloads are encoded with the hand-rolled [`Enc`]/[`Dec`] codec (the
//! offline workspace carries no serde/bincode): little-endian fixed-width
//! integers, `f64` as IEEE bit patterns (exact round-trip — the process
//! backend's bit-identical-selection contract depends on it), and
//! length-prefixed sequences with remaining-byte sanity checks so a
//! malformed length can never trigger an over-allocation.

use std::fmt;
use std::io::{Read, Write};

use crate::core::{Constraint, ElementId};
use crate::mapreduce::CommSize;
use crate::oracle::spec::OracleSpec;

/// Protocol version; bump on any layout or message change (see module docs).
///
/// v2: connect-time [`FromWorker::Hello`] handshake (required by the
/// socket transports, spoken on pipes too), plus the
/// [`RoundTask::PruneSample`] / [`TaskReply::Pruned`] pair that moves
/// Sample&Prune's pruning round worker-side.
///
/// v3: [`RoundTask::AdoptMachines`] — the elastic-pool recovery message
/// that reships a dead worker's machines (shards + store-mutating replay
/// history + the in-flight task) onto a surviving worker.
///
/// v4: the zero-copy shard arena (`process:N@uds+arena`,
/// [`crate::mapreduce::arena`]). [`WorkerInit`] and
/// [`RoundTask::AdoptMachines`] carry an `arena` flag; when set, shard
/// and sample payloads are *elided* from the frame — workers read them
/// from the fd-passed memfd mapping by global machine id instead.
///
/// v5: multi-tenant serving (`mrsub serve`). Workers gain per-job state:
/// [`ToWorker::Attach`] installs a job-keyed runtime next to the ones
/// already held (where [`ToWorker::Init`] *replaces* the sole anonymous
/// runtime), [`ToWorker::JobRound`] runs a round against one job, and
/// [`ToWorker::Detach`] drops a finished job's state. The same codec also
/// gains the client-facing [`ClientRequest`]/[`ClientResponse`] frames
/// the daemon and `mrsub submit` speak over TCP — riding the versioned
/// header means client/daemon version skew fails the first frame with a
/// structured [`WireError::BadVersion`] instead of a decode mystery.
///
/// v6: true elasticity. [`ToWorker::Rebalance`] moves machines between
/// *live* workers at round boundaries: the receiver drops the listed
/// machine ids it hosts, adopts the listed ones (shards arena-elided
/// exactly like v4 adoptions) by replaying the store-mutating history,
/// and replies [`FromWorker::Ready`]. Combined with coordinator-side
/// worker respawn and late `--connect` joins, pool membership can now
/// change mid-experiment without touching selection semantics — RNG
/// streams and store replay key on *global* machine ids, never on which
/// worker hosts them.
///
/// v7: constraints and the non-monotone/matroid algorithm family.
/// [`crate::core::Constraint`] becomes wire-encodable, and two
/// constraint-carrying tasks join the vocabulary:
/// [`RoundTask::PartitionGreedy`] (one randomized-partition round of the
/// Barbosa–Ene–Nguyen–Ward framework — the machine derives its *logical*
/// part of the ground set from `(seed, round)` and runs a constrained
/// greedy over it) and [`RoundTask::ConstrainedFilter`] (DASH's adaptive
/// threshold filter, replying [`TaskReply::Valued`] — surviving ids plus
/// their marginals, so the central sequencing step never re-queries).
pub const WIRE_VERSION: u16 = 7;

/// Frame magic: "MRSB" (MapReduce-Submodular Backend).
pub const FRAME_MAGIC: [u8; 4] = *b"MRSB";

/// Default hard cap on a single frame's payload (64 MiB); configurable via
/// `ClusterConfig::max_frame_bytes` / `[cluster] max_frame_mb`.
pub const DEFAULT_MAX_FRAME: usize = 64 << 20;

/// Frame header bytes: magic + version + payload length.
const HEADER_LEN: usize = 4 + 2 + 4;

/// Typed wire-level failure. Every decode path returns one of these;
/// none panics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Underlying pipe I/O failed (worker died, pipe closed, …).
    Io(String),
    /// The stream ended inside a frame.
    Truncated {
        /// Bytes the decoder needed.
        needed: usize,
        /// Bytes actually available.
        got: usize,
    },
    /// First four bytes were not [`FRAME_MAGIC`].
    BadMagic([u8; 4]),
    /// Frame carried a different protocol version.
    BadVersion {
        /// Version found in the frame.
        got: u16,
        /// Version this binary speaks.
        want: u16,
    },
    /// Payload checksum mismatch (corruption in transit).
    BadChecksum {
        /// Checksum found in the frame.
        got: u32,
        /// Checksum recomputed over the payload.
        want: u32,
    },
    /// Frame length exceeded the configured cap.
    FrameTooLarge {
        /// Declared (or attempted) payload length.
        len: usize,
        /// Configured cap.
        max: usize,
    },
    /// Structurally invalid payload (bad tag, bad length, trailing bytes).
    Malformed(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(m) => write!(f, "wire i/o error: {m}"),
            WireError::Truncated { needed, got } => {
                write!(f, "truncated frame: needed {needed} bytes, got {got}")
            }
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:?}"),
            WireError::BadVersion { got, want } => {
                write!(f, "wire version mismatch: frame v{got}, binary speaks v{want}")
            }
            WireError::BadChecksum { got, want } => {
                write!(f, "frame checksum mismatch: {got:#010x} != {want:#010x}")
            }
            WireError::FrameTooLarge { len, max } => {
                write!(f, "frame payload of {len} bytes exceeds max-frame cap {max}")
            }
            WireError::Malformed(m) => write!(f, "malformed payload: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Total on-the-wire size of a frame carrying a `payload_len`-byte
/// payload (header + payload + checksum) — byte accounting without I/O.
pub fn frame_size(payload_len: usize) -> usize {
    HEADER_LEN + payload_len + 4
}

/// FNV-1a (32-bit) over the payload — cheap, dependency-free, and plenty
/// for catching pipe truncation/corruption (not cryptographic).
pub fn checksum(data: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Write one frame; returns the total bytes written (header + payload +
/// checksum) for IPC accounting.
pub fn write_frame(w: &mut dyn Write, payload: &[u8], max_frame: usize) -> Result<usize, WireError> {
    if payload.len() > max_frame {
        return Err(WireError::FrameTooLarge { len: payload.len(), max: max_frame });
    }
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&FRAME_MAGIC);
    header[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    header[6..10].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    let sum = checksum(payload).to_le_bytes();
    let io = |e: std::io::Error| WireError::Io(e.to_string());
    w.write_all(&header).map_err(io)?;
    w.write_all(payload).map_err(io)?;
    w.write_all(&sum).map_err(io)?;
    w.flush().map_err(io)?;
    Ok(frame_size(payload.len()))
}

fn read_exact_or(r: &mut dyn Read, buf: &mut [u8]) -> Result<(), WireError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Err(WireError::Truncated { needed: buf.len(), got: filled }),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Read one frame; returns `(payload, total_bytes_read)`.
///
/// A clean EOF *before any header byte* is reported as `Truncated { got: 0
/// }` — callers treat it as "peer closed the stream".
pub fn read_frame(r: &mut dyn Read, max_frame: usize) -> Result<(Vec<u8>, usize), WireError> {
    let mut header = [0u8; HEADER_LEN];
    read_exact_or(r, &mut header)?;
    let mut magic = [0u8; 4];
    magic.copy_from_slice(&header[..4]);
    if magic != FRAME_MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion { got: version, want: WIRE_VERSION });
    }
    let len = u32::from_le_bytes([header[6], header[7], header[8], header[9]]) as usize;
    if len > max_frame {
        return Err(WireError::FrameTooLarge { len, max: max_frame });
    }
    let mut payload = vec![0u8; len];
    read_exact_or(r, &mut payload)?;
    let mut sum = [0u8; 4];
    read_exact_or(r, &mut sum)?;
    let got = u32::from_le_bytes(sum);
    let want = checksum(&payload);
    if got != want {
        return Err(WireError::BadChecksum { got, want });
    }
    Ok((payload, HEADER_LEN + len + 4))
}

// --- byte codec -------------------------------------------------------------

/// Append-only encoder (little-endian throughout).
#[derive(Debug, Default)]
pub struct Enc {
    /// The bytes written so far.
    pub buf: Vec<u8>,
}

impl Enc {
    /// Fresh empty encoder.
    pub fn new() -> Self {
        Enc { buf: Vec::new() }
    }

    /// Append a `u8`.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u16`.
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32`.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `usize` (as `u64`).
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Append an `f64` as its IEEE bit pattern (exact round-trip).
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Append a bool (one byte).
    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a length-prefixed element-id slice.
    pub fn ids(&mut self, ids: &[ElementId]) {
        self.u32(ids.len() as u32);
        for &e in ids {
            self.u32(e);
        }
    }

    /// Append a length-prefixed `f64` slice (bit patterns).
    pub fn f64s(&mut self, xs: &[f64]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.f64(x);
        }
    }
}

/// Cursor-style decoder over a payload; every getter checks remaining
/// bytes and returns [`WireError::Truncated`] instead of slicing past the
/// end.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// Decode from `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { needed: n, got: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    /// Read a `u16`.
    pub fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Read a `u32`.
    pub fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a `u64`.
    pub fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read a `usize` (encoded as `u64`; checked narrowing).
    pub fn usize(&mut self) -> Result<usize, WireError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| WireError::Malformed(format!("usize overflow: {v}")))
    }

    /// Read an `f64` bit pattern.
    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a bool.
    pub fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(WireError::Malformed(format!("bad bool byte {b}"))),
        }
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("invalid utf-8 string".into()))
    }

    /// Read a length-prefixed element-id vector (length sanity-checked
    /// against the remaining bytes before allocation).
    pub fn ids(&mut self) -> Result<Vec<ElementId>, WireError> {
        let len = self.u32()? as usize;
        if self.remaining() < len * 4 {
            return Err(WireError::Truncated { needed: len * 4, got: self.remaining() });
        }
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    /// Read a length-prefixed `f64` vector.
    pub fn f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let len = self.u32()? as usize;
        if self.remaining() < len * 8 {
            return Err(WireError::Truncated { needed: len * 8, got: self.remaining() });
        }
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    /// Assert the payload is fully consumed (catches layout drift).
    pub fn finish(&self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after message",
                self.remaining()
            )));
        }
        Ok(())
    }
}

// --- constraint codec -------------------------------------------------------

impl Constraint {
    /// Encode into `enc` (tag 1 = cardinality, 2 = partition matroid).
    /// Lives here rather than in `core` so the whole wire surface — and
    /// the drift lint's fingerprint anchors — stay in one place.
    pub fn encode(&self, enc: &mut Enc) {
        match self {
            Constraint::Cardinality { k } => {
                enc.u8(1);
                enc.usize(*k);
            }
            Constraint::PartitionMatroid { parts, capacities } => {
                enc.u8(2);
                enc.ids(parts);
                enc.u32(capacities.len() as u32);
                for &c in capacities {
                    enc.usize(c);
                }
            }
        }
    }

    /// Decode one constraint.
    pub fn decode(dec: &mut Dec<'_>) -> Result<Constraint, WireError> {
        Ok(match dec.u8()? {
            1 => Constraint::Cardinality { k: dec.usize()? },
            2 => {
                let parts = dec.ids()?;
                let len = dec.u32()? as usize;
                if dec.remaining() < len * 8 {
                    return Err(WireError::Truncated { needed: len * 8, got: dec.remaining() });
                }
                let mut capacities = Vec::with_capacity(len);
                for _ in 0..len {
                    capacities.push(dec.usize()?);
                }
                Constraint::PartitionMatroid { parts, capacities }
            }
            t => return Err(WireError::Malformed(format!("unknown Constraint tag {t}"))),
        })
    }
}

// --- round tasks ------------------------------------------------------------

/// One OPT-guess filter instruction inside [`RoundTask::MultiFilter`].
#[derive(Debug, Clone, PartialEq)]
pub struct GuessFilter {
    /// Stable guess identifier (coordinator-chosen).
    pub id: u32,
    /// The broadcast partial solution `G` to filter against, in insertion
    /// order (the worker rehydrates an oracle state by replaying it).
    pub base: Vec<ElementId>,
    /// Threshold τ for this guess.
    pub tau: f64,
}

/// A per-machine shard program — the unit of work the coordinator ships to
/// every simulated machine in one synchronous round. The same
/// [`crate::mapreduce::shard`] interpreter executes these for the
/// in-process backends and inside `mrsub worker`, so all backends are
/// bit-identical by construction.
#[derive(Debug, Clone, PartialEq)]
pub enum RoundTask {
    /// `ThresholdFilter(shard, base, τ)` (Algorithm 2): ship the shard
    /// elements whose marginal w.r.t. the rehydrated `base` is ≥ τ.
    Filter {
        /// Broadcast partial solution, insertion order.
        base: Vec<ElementId>,
        /// Threshold.
        tau: f64,
    },
    /// Per-guess threshold filtering (Algorithms 5/6): one filter per OPT
    /// guess. With `persist`, each guess filters its machine-resident
    /// shard copy from the previous round and retains the survivors
    /// (Algorithm 5's persistently shrinking shards); without, every guess
    /// filters the machine's original shard (Algorithm 6's one-shot round).
    MultiFilter {
        /// Retain per-guess filtered shards across rounds.
        persist: bool,
        /// Active guesses.
        guesses: Vec<GuessFilter>,
        /// Guess ids whose persistent shards can be dropped (guess done).
        drop: Vec<u32>,
    },
    /// Lazy greedy over the shard up to `k` elements (RandGreeDi / MZ
    /// core-set round 1).
    LocalGreedy {
        /// Cardinality bound.
        k: usize,
    },
    /// Max singleton value over the shard (OPT-guess seeding).
    MaxSingleton,
    /// The `c·k` largest-singleton shard elements, ascending ids
    /// (Algorithm 7's worker).
    TopSingletons {
        /// Cardinality bound.
        k: usize,
        /// Ship factor (elements shipped = `c·k`).
        c: usize,
    },
    /// Several programs in one synchronous round (Theorem 8 runs the dense
    /// and sparse workers in the same physical round).
    Batch(Vec<RoundTask>),
    /// One Sample&Prune round (Kumar et al.): permanently prune the
    /// machine's current shard to the elements with marginal ≥ `floor`
    /// w.r.t. the rehydrated `base`, then ship the elements ≥ `tau` —
    /// whole if they fit `per_share`, else a uniform sample of that size
    /// drawn from the per-machine RNG stream
    /// `machine_seed(seed, round, machine)`. The pruned shard persists
    /// machine-side ([`crate::mapreduce::shard::GuessStore`]); only the
    /// shipped elements cross the wire.
    PruneSample {
        /// Broadcast partial solution `G`, insertion order.
        base: Vec<ElementId>,
        /// Permanent pruning threshold (safe for every future τ).
        floor: f64,
        /// Current shipping threshold.
        tau: f64,
        /// Central-budget share per machine (sample size when oversized).
        per_share: usize,
        /// Round-derived RNG seed (coordinator-chosen).
        seed: u64,
        /// Round index, part of the per-machine RNG stream id.
        round: u32,
    },
    /// Elastic-pool recovery (process backend only): a surviving worker
    /// adopts a dead worker's simulated machines. The worker appends the
    /// machines with their *original* (spawn-time) shards, replays the
    /// store-mutating task history in order — rebuilding the
    /// machine-resident state (persistent `MultiFilter` shards, seeded
    /// `PruneSample` pruned bases) deterministically, because every
    /// randomized task carries its RNG seed and streams derive from
    /// *global* machine ids — and then runs the in-flight `pending` task
    /// for just the adopted machines, replying one `pending`-shaped
    /// [`TaskReply`] per adopted machine. Never reaches the in-process
    /// interpreter: in-process machines cannot die.
    AdoptMachines {
        /// Global ids of the machines being adopted, in adoption order.
        machines: Vec<u32>,
        /// One spawn-time shard per adopted machine (same order). Empty
        /// when `arena` is set: the adopter reads spawn shards from its
        /// memfd mapping by global machine id, and no shard bytes cross
        /// the wire.
        shards: Vec<Vec<ElementId>>,
        /// Shards live in the fd-passed arena mapping (wire v4,
        /// `@uds+arena`); `shards` above is elided from the frame.
        arena: bool,
        /// Store-mutating tasks of all completed rounds, in round order
        /// (see [`RoundTask::mutates_store`]); replayed effects-only.
        replay: Vec<RoundTask>,
        /// The in-flight round task, re-run for the adopted machines.
        pending: Box<RoundTask>,
    },
    /// One randomized-partition round of the Barbosa–Ene–Nguyen–Ward
    /// framework (wire v7): the machine *ignores its physical shard* and
    /// instead derives its logical part of the full ground set — element
    /// `e` belongs to part [`crate::mapreduce::shard::partition_of`]`(seed,
    /// round, e, parts)`, and machine `m` owns part `m` — then runs a
    /// constrained lazy greedy over that part up to `k` elements. Because
    /// the part derivation keys on the *global* machine id and the worker
    /// rebuilds the full oracle from its spec, no shuffle crosses the wire
    /// and every backend computes the identical re-partition.
    PartitionGreedy {
        /// Cardinality bound for the local greedy.
        k: usize,
        /// Number of logical parts (= machine count).
        parts: u32,
        /// The independence system the local greedy selects under.
        constraint: Constraint,
        /// Round-derived partition seed (coordinator-chosen).
        seed: u64,
        /// Round index — a fresh `(seed, round)` pair re-randomizes the
        /// partition every round.
        round: u32,
    },
    /// DASH's adaptive threshold filter (wire v7): ship the shard elements
    /// whose marginal w.r.t. the rehydrated `base` is ≥ `tau` *and* that
    /// the constraint still admits on top of `base`, replying
    /// [`TaskReply::Valued`] with the marginals attached so the central
    /// sequencing step orders candidates without re-querying the oracle.
    ConstrainedFilter {
        /// Broadcast partial solution, insertion order.
        base: Vec<ElementId>,
        /// Threshold.
        tau: f64,
        /// The independence system feasibility is checked against.
        constraint: Constraint,
    },
}

impl RoundTask {
    /// Encode into `enc`.
    pub fn encode(&self, enc: &mut Enc) {
        match self {
            RoundTask::Filter { base, tau } => {
                enc.u8(1);
                enc.ids(base);
                enc.f64(*tau);
            }
            RoundTask::MultiFilter { persist, guesses, drop } => {
                enc.u8(2);
                enc.bool(*persist);
                enc.u32(guesses.len() as u32);
                for g in guesses {
                    enc.u32(g.id);
                    enc.ids(&g.base);
                    enc.f64(g.tau);
                }
                enc.ids(drop);
            }
            RoundTask::LocalGreedy { k } => {
                enc.u8(3);
                enc.usize(*k);
            }
            RoundTask::MaxSingleton => enc.u8(4),
            RoundTask::TopSingletons { k, c } => {
                enc.u8(5);
                enc.usize(*k);
                enc.usize(*c);
            }
            RoundTask::Batch(tasks) => {
                enc.u8(6);
                enc.u32(tasks.len() as u32);
                for t in tasks {
                    t.encode(enc);
                }
            }
            RoundTask::PruneSample { base, floor, tau, per_share, seed, round } => {
                enc.u8(7);
                enc.ids(base);
                enc.f64(*floor);
                enc.f64(*tau);
                enc.usize(*per_share);
                enc.u64(*seed);
                enc.u32(*round);
            }
            RoundTask::AdoptMachines { machines, shards, arena, replay, pending } => {
                enc.u8(8);
                enc.ids(machines);
                enc.bool(*arena);
                if !*arena {
                    enc.u32(shards.len() as u32);
                    for s in shards {
                        enc.ids(s);
                    }
                } else {
                    debug_assert!(shards.is_empty(), "arena adoptions elide shard payloads");
                }
                enc.u32(replay.len() as u32);
                for t in replay {
                    t.encode(enc);
                }
                pending.encode(enc);
            }
            RoundTask::PartitionGreedy { k, parts, constraint, seed, round } => {
                enc.u8(9);
                enc.usize(*k);
                enc.u32(*parts);
                constraint.encode(enc);
                enc.u64(*seed);
                enc.u32(*round);
            }
            RoundTask::ConstrainedFilter { base, tau, constraint } => {
                enc.u8(10);
                enc.ids(base);
                enc.f64(*tau);
                constraint.encode(enc);
            }
        }
    }

    /// Decode one task.
    pub fn decode(dec: &mut Dec<'_>) -> Result<RoundTask, WireError> {
        Ok(match dec.u8()? {
            1 => RoundTask::Filter { base: dec.ids()?, tau: dec.f64()? },
            2 => {
                let persist = dec.bool()?;
                let n = dec.u32()? as usize;
                let mut guesses = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    guesses.push(GuessFilter { id: dec.u32()?, base: dec.ids()?, tau: dec.f64()? });
                }
                RoundTask::MultiFilter { persist, guesses, drop: dec.ids()? }
            }
            3 => RoundTask::LocalGreedy { k: dec.usize()? },
            4 => RoundTask::MaxSingleton,
            5 => RoundTask::TopSingletons { k: dec.usize()?, c: dec.usize()? },
            6 => {
                let n = dec.u32()? as usize;
                let mut tasks = Vec::with_capacity(n.min(64));
                for _ in 0..n {
                    tasks.push(RoundTask::decode(dec)?);
                }
                RoundTask::Batch(tasks)
            }
            7 => RoundTask::PruneSample {
                base: dec.ids()?,
                floor: dec.f64()?,
                tau: dec.f64()?,
                per_share: dec.usize()?,
                seed: dec.u64()?,
                round: dec.u32()?,
            },
            8 => {
                let machines = dec.ids()?;
                let arena = dec.bool()?;
                let shards = if arena {
                    Vec::new()
                } else {
                    let n = dec.u32()? as usize;
                    if n != machines.len() {
                        return Err(WireError::Malformed(format!(
                            "adopt: {n} shards for {} machines",
                            machines.len()
                        )));
                    }
                    let mut shards = Vec::with_capacity(n.min(4096));
                    for _ in 0..n {
                        shards.push(dec.ids()?);
                    }
                    shards
                };
                let r = dec.u32()? as usize;
                let mut replay = Vec::with_capacity(r.min(1024));
                for _ in 0..r {
                    replay.push(RoundTask::decode(dec)?);
                }
                RoundTask::AdoptMachines {
                    machines,
                    shards,
                    arena,
                    replay,
                    pending: Box::new(RoundTask::decode(dec)?),
                }
            }
            9 => RoundTask::PartitionGreedy {
                k: dec.usize()?,
                parts: dec.u32()?,
                constraint: Constraint::decode(dec)?,
                seed: dec.u64()?,
                round: dec.u32()?,
            },
            10 => RoundTask::ConstrainedFilter {
                base: dec.ids()?,
                tau: dec.f64()?,
                constraint: Constraint::decode(dec)?,
            },
            t => return Err(WireError::Malformed(format!("unknown RoundTask tag {t}"))),
        })
    }

    /// Display label for errors/metrics.
    pub fn label(&self) -> &'static str {
        match self {
            RoundTask::Filter { .. } => "filter",
            RoundTask::MultiFilter { .. } => "multi-filter",
            RoundTask::LocalGreedy { .. } => "local-greedy",
            RoundTask::MaxSingleton => "max-singleton",
            RoundTask::TopSingletons { .. } => "top-singletons",
            RoundTask::Batch(_) => "batch",
            RoundTask::PruneSample { .. } => "prune-sample",
            RoundTask::AdoptMachines { .. } => "adopt-machines",
            RoundTask::PartitionGreedy { .. } => "partition-greedy",
            RoundTask::ConstrainedFilter { .. } => "constrained-filter",
        }
    }

    /// True iff executing this task leaves machine-resident state behind
    /// ([`crate::mapreduce::shard::GuessStore`]): persistent or dropping
    /// `MultiFilter`s and the permanently-pruning `PruneSample`. The
    /// elastic pool records exactly these into its replay history —
    /// adopted machines rebuild their stores by re-running them in order.
    pub fn mutates_store(&self) -> bool {
        match self {
            RoundTask::MultiFilter { persist, drop, .. } => *persist || !drop.is_empty(),
            RoundTask::PruneSample { .. } => true,
            RoundTask::Batch(tasks) => tasks.iter().any(RoundTask::mutates_store),
            _ => false,
        }
    }

    /// True iff this task performs a Sample&Prune pruning round (directly,
    /// inside a `Batch`, or as the `pending` of an adoption) — the hook
    /// the `die-on-prune` fault injection keys on.
    pub fn contains_prune(&self) -> bool {
        match self {
            RoundTask::PruneSample { .. } => true,
            RoundTask::Batch(tasks) => tasks.iter().any(RoundTask::contains_prune),
            RoundTask::AdoptMachines { pending, .. } => pending.contains_prune(),
            _ => false,
        }
    }
}

/// True iff `reply` has the shape `task` produces — the coordinator
/// validates every worker reply against this at the trust boundary, so a
/// wrong-variant reply (dispatch bug, mismatched worker binary) surfaces
/// as a structured error instead of a silent empty default.
pub fn reply_matches(task: &RoundTask, reply: &TaskReply) -> bool {
    match (task, reply) {
        (RoundTask::Filter { .. }, TaskReply::Ids(_)) => true,
        (RoundTask::MultiFilter { .. }, TaskReply::Multi(_)) => true,
        (RoundTask::LocalGreedy { .. }, TaskReply::Ids(_)) => true,
        (RoundTask::MaxSingleton, TaskReply::Scalar(_)) => true,
        (RoundTask::TopSingletons { .. }, TaskReply::Ids(_)) => true,
        (RoundTask::Batch(tasks), TaskReply::Batch(replies)) => {
            tasks.len() == replies.len()
                && tasks.iter().zip(replies).all(|(t, r)| reply_matches(t, r))
        }
        (RoundTask::PruneSample { .. }, TaskReply::Pruned { .. }) => true,
        (RoundTask::PartitionGreedy { .. }, TaskReply::Ids(_)) => true,
        (RoundTask::ConstrainedFilter { .. }, TaskReply::Valued { .. }) => true,
        // an adoption reply carries the re-run in-flight task's results,
        // one per adopted machine — each shaped like `pending`.
        (RoundTask::AdoptMachines { pending, .. }, reply) => reply_matches(pending, reply),
        _ => false,
    }
}

/// Per-machine result of a [`RoundTask`] — shape mirrors the task variant.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskReply {
    /// Selected/surviving element ids.
    Ids(Vec<ElementId>),
    /// A scalar (max singleton value).
    Scalar(f64),
    /// Per-guess survivor lists.
    Multi(Vec<(u32, Vec<ElementId>)>),
    /// One reply per sub-task of a `Batch`.
    Batch(Vec<TaskReply>),
    /// A [`RoundTask::PruneSample`] result: the shipped elements plus
    /// whether every eligible element fit the per-machine budget share
    /// (the pruned shard itself stays machine-resident).
    Pruned {
        /// Elements shipped to the central machine, ascending ids.
        shipped: Vec<ElementId>,
        /// True iff nothing was sampled away (`eligible ≤ per_share`).
        fit: bool,
        /// Size of the machine-resident pruned shard after this round
        /// (memory accounting only — the shard itself never ships).
        resident: u64,
    },
    /// A [`RoundTask::ConstrainedFilter`] result: the surviving elements
    /// with their marginals attached, so the central sequencing step can
    /// order candidates without re-querying the oracle. `ids` and `values`
    /// are parallel arrays of equal length.
    Valued {
        /// Surviving element ids, ascending.
        ids: Vec<ElementId>,
        /// `values[i]` = marginal of `ids[i]` w.r.t. the broadcast base.
        values: Vec<f64>,
    },
}

impl TaskReply {
    /// Encode into `enc`.
    pub fn encode(&self, enc: &mut Enc) {
        match self {
            TaskReply::Ids(ids) => {
                enc.u8(1);
                enc.ids(ids);
            }
            TaskReply::Scalar(v) => {
                enc.u8(2);
                enc.f64(*v);
            }
            TaskReply::Multi(parts) => {
                enc.u8(3);
                enc.u32(parts.len() as u32);
                for (id, ids) in parts {
                    enc.u32(*id);
                    enc.ids(ids);
                }
            }
            TaskReply::Batch(replies) => {
                enc.u8(4);
                enc.u32(replies.len() as u32);
                for r in replies {
                    r.encode(enc);
                }
            }
            TaskReply::Pruned { shipped, fit, resident } => {
                enc.u8(5);
                enc.ids(shipped);
                enc.bool(*fit);
                enc.u64(*resident);
            }
            TaskReply::Valued { ids, values } => {
                debug_assert_eq!(ids.len(), values.len(), "Valued arrays must be parallel");
                enc.u8(6);
                enc.ids(ids);
                enc.f64s(values);
            }
        }
    }

    /// Decode one reply.
    pub fn decode(dec: &mut Dec<'_>) -> Result<TaskReply, WireError> {
        Ok(match dec.u8()? {
            1 => TaskReply::Ids(dec.ids()?),
            2 => TaskReply::Scalar(dec.f64()?),
            3 => {
                let n = dec.u32()? as usize;
                let mut parts = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    parts.push((dec.u32()?, dec.ids()?));
                }
                TaskReply::Multi(parts)
            }
            4 => {
                let n = dec.u32()? as usize;
                let mut replies = Vec::with_capacity(n.min(64));
                for _ in 0..n {
                    replies.push(TaskReply::decode(dec)?);
                }
                TaskReply::Batch(replies)
            }
            5 => TaskReply::Pruned {
                shipped: dec.ids()?,
                fit: dec.bool()?,
                resident: dec.u64()?,
            },
            6 => {
                let ids = dec.ids()?;
                let values = dec.f64s()?;
                if ids.len() != values.len() {
                    return Err(WireError::Malformed(format!(
                        "Valued reply has {} ids but {} values",
                        ids.len(),
                        values.len()
                    )));
                }
                TaskReply::Valued { ids, values }
            }
            t => return Err(WireError::Malformed(format!("unknown TaskReply tag {t}"))),
        })
    }

    /// Extract `Ids`, defaulting to empty on shape mismatch (shape is
    /// enforced by the task/reply pairing; mismatch is a logic bug caught
    /// by debug assertions and the conformance suite).
    pub fn into_ids(self) -> Vec<ElementId> {
        match self {
            TaskReply::Ids(ids) => ids,
            other => {
                debug_assert!(false, "expected Ids reply, got {other:?}");
                Vec::new()
            }
        }
    }

    /// Extract `Scalar`, defaulting to 0.0 on shape mismatch.
    pub fn as_scalar(&self) -> f64 {
        match self {
            TaskReply::Scalar(v) => *v,
            other => {
                debug_assert!(false, "expected Scalar reply, got {other:?}");
                0.0
            }
        }
    }

    /// Borrowing view of `Multi`, defaulting to empty on shape mismatch
    /// (for streamed-reply consumers that only need to inspect parts as
    /// they arrive).
    pub fn as_multi(&self) -> &[(u32, Vec<ElementId>)] {
        match self {
            TaskReply::Multi(parts) => parts,
            other => {
                debug_assert!(false, "expected Multi reply, got {other:?}");
                &[]
            }
        }
    }

    /// Extract `Multi`, defaulting to empty on shape mismatch.
    pub fn into_multi(self) -> Vec<(u32, Vec<ElementId>)> {
        match self {
            TaskReply::Multi(parts) => parts,
            other => {
                debug_assert!(false, "expected Multi reply, got {other:?}");
                Vec::new()
            }
        }
    }

    /// Extract `Batch`, defaulting to empty on shape mismatch.
    pub fn into_batch(self) -> Vec<TaskReply> {
        match self {
            TaskReply::Batch(replies) => replies,
            other => {
                debug_assert!(false, "expected Batch reply, got {other:?}");
                Vec::new()
            }
        }
    }

    /// Extract `Pruned`, defaulting to empty/fit on shape mismatch.
    pub fn into_pruned(self) -> (Vec<ElementId>, bool, u64) {
        match self {
            TaskReply::Pruned { shipped, fit, resident } => (shipped, fit, resident),
            other => {
                debug_assert!(false, "expected Pruned reply, got {other:?}");
                (Vec::new(), true, 0)
            }
        }
    }

    /// Extract `Valued`, defaulting to empty on shape mismatch.
    pub fn into_valued(self) -> (Vec<ElementId>, Vec<f64>) {
        match self {
            TaskReply::Valued { ids, values } => (ids, values),
            other => {
                debug_assert!(false, "expected Valued reply, got {other:?}");
                (Vec::new(), Vec::new())
            }
        }
    }
}

impl CommSize for TaskReply {
    fn comm_size(&self) -> usize {
        match self {
            TaskReply::Ids(ids) => ids.len(),
            TaskReply::Scalar(_) => 1,
            TaskReply::Multi(parts) => parts.iter().map(|(_, ids)| ids.len()).sum(),
            TaskReply::Batch(replies) => replies.iter().map(|r| r.comm_size()).sum(),
            TaskReply::Pruned { shipped, .. } => shipped.len(),
            TaskReply::Valued { ids, .. } => ids.len(),
        }
    }
}

// --- coordinator <-> worker messages ---------------------------------------

/// First message to a worker: everything it needs to become a
/// shared-nothing replica of its simulated machines.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerInit {
    /// Oracle construction recipe (rebuilt deterministically worker-side).
    pub spec: OracleSpec,
    /// Simulated machine ids this worker hosts.
    pub machines: Vec<u32>,
    /// One shard per hosted machine (same order as `machines`). Empty
    /// when `arena` is set: the worker reads shards from its fd-passed
    /// memfd mapping by global machine id (wire v4, `@uds+arena`).
    pub shards: Vec<Vec<ElementId>>,
    /// The broadcast sample `S`. Empty when `arena` is set (read from
    /// the mapping).
    pub sample: Vec<ElementId>,
    /// Shard + sample payloads live in the fd-passed arena mapping; the
    /// fields above are elided from the frame.
    pub arena: bool,
}

impl WorkerInit {
    /// Encode into `enc` (shared by [`ToWorker::Init`] and
    /// [`ToWorker::Attach`], which must stay byte-compatible).
    pub fn encode(&self, enc: &mut Enc) {
        self.spec.encode(enc);
        enc.ids(&self.machines);
        enc.bool(self.arena);
        if !self.arena {
            enc.u32(self.shards.len() as u32);
            for s in &self.shards {
                enc.ids(s);
            }
            enc.ids(&self.sample);
        } else {
            debug_assert!(
                self.shards.is_empty() && self.sample.is_empty(),
                "arena inits elide shard/sample payloads"
            );
        }
    }

    /// Decode one init payload.
    pub fn decode(dec: &mut Dec<'_>) -> Result<WorkerInit, WireError> {
        let spec = OracleSpec::decode(dec)?;
        let machines = dec.ids()?;
        let arena = dec.bool()?;
        let (shards, sample) = if arena {
            (Vec::new(), Vec::new())
        } else {
            let n = dec.u32()? as usize;
            if n != machines.len() {
                return Err(WireError::Malformed(format!(
                    "init: {n} shards for {} machines",
                    machines.len()
                )));
            }
            let mut shards = Vec::with_capacity(n);
            for _ in 0..n {
                shards.push(dec.ids()?);
            }
            (shards, dec.ids()?)
        };
        Ok(WorkerInit { spec, machines, shards, sample, arena })
    }
}

/// Coordinator → worker messages.
#[derive(Debug, Clone, PartialEq)]
pub enum ToWorker {
    /// Shard + spec handoff; worker replies [`FromWorker::Ready`].
    Init(WorkerInit),
    /// Execute one round task over every hosted shard.
    Round(RoundTask),
    /// Clean shutdown (worker exits 0).
    Shutdown,
    /// Install a *job-keyed* runtime next to any the worker already
    /// holds (the serving daemon's warm pool attaches one per submitted
    /// job; one-shot runs keep using [`ToWorker::Init`], which is the
    /// anonymous job slot). Worker replies [`FromWorker::Ready`].
    Attach {
        /// Daemon-assigned job id (nonzero; 0 is the anonymous slot).
        job: u64,
        /// The per-job shard + spec handoff.
        init: WorkerInit,
    },
    /// Execute one round task against job `job`'s runtime.
    JobRound {
        /// Job whose machines run the task.
        job: u64,
        /// The round program.
        task: RoundTask,
    },
    /// Drop job `job`'s runtime (shards, stores, caches). No reply; a
    /// detach of an unknown job is a no-op, so the daemon can fire these
    /// without tracking per-worker attach acknowledgements.
    Detach {
        /// Job to forget.
        job: u64,
    },
    /// Between-round machine move (wire v6): the receiving *live* worker
    /// first forgets the machines in `drop` (they moved to another
    /// worker), then adopts the machines in `machines` — appending them
    /// with their spawn-time shards and replaying the store-mutating
    /// history, exactly like [`RoundTask::AdoptMachines`] but with no
    /// in-flight `pending` task (rebalancing happens only at round
    /// boundaries) — and replies [`FromWorker::Ready`]. `job` selects the
    /// runtime (0 is the anonymous one-shot slot).
    Rebalance {
        /// Runtime to rebalance (0 = the anonymous [`ToWorker::Init`] slot).
        job: u64,
        /// Global ids of hosted machines this worker must forget.
        drop: Vec<u32>,
        /// Global ids of the machines to adopt, in adoption order.
        machines: Vec<u32>,
        /// One spawn-time shard per adopted machine (same order). Empty
        /// when `arena` is set: shards are read from the fd-passed memfd
        /// mapping by global machine id.
        shards: Vec<Vec<ElementId>>,
        /// Shards live in the arena mapping; `shards` is elided.
        arena: bool,
        /// Store-mutating task history to replay for the adopted
        /// machines, in round order.
        replay: Vec<RoundTask>,
    },
}

impl ToWorker {
    /// Encode to a payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut enc = Enc::new();
        match self {
            ToWorker::Init(init) => {
                enc.u8(1);
                init.encode(&mut enc);
            }
            ToWorker::Round(task) => {
                enc.u8(2);
                task.encode(&mut enc);
            }
            ToWorker::Shutdown => enc.u8(3),
            ToWorker::Attach { job, init } => {
                enc.u8(4);
                enc.u64(*job);
                init.encode(&mut enc);
            }
            ToWorker::JobRound { job, task } => {
                enc.u8(5);
                enc.u64(*job);
                task.encode(&mut enc);
            }
            ToWorker::Detach { job } => {
                enc.u8(6);
                enc.u64(*job);
            }
            ToWorker::Rebalance { job, drop, machines, shards, arena, replay } => {
                enc.u8(7);
                enc.u64(*job);
                enc.ids(drop);
                enc.ids(machines);
                enc.bool(*arena);
                if !*arena {
                    enc.u32(shards.len() as u32);
                    for s in shards {
                        enc.ids(s);
                    }
                } else {
                    debug_assert!(shards.is_empty(), "arena rebalances elide shard payloads");
                }
                enc.u32(replay.len() as u32);
                for t in replay {
                    t.encode(&mut enc);
                }
            }
        }
        enc.buf
    }

    /// Decode from a payload.
    pub fn decode(payload: &[u8]) -> Result<ToWorker, WireError> {
        let mut dec = Dec::new(payload);
        let msg = match dec.u8()? {
            1 => ToWorker::Init(WorkerInit::decode(&mut dec)?),
            2 => ToWorker::Round(RoundTask::decode(&mut dec)?),
            3 => ToWorker::Shutdown,
            4 => {
                let job = dec.u64()?;
                ToWorker::Attach { job, init: WorkerInit::decode(&mut dec)? }
            }
            5 => ToWorker::JobRound { job: dec.u64()?, task: RoundTask::decode(&mut dec)? },
            6 => ToWorker::Detach { job: dec.u64()? },
            7 => {
                let job = dec.u64()?;
                let drop = dec.ids()?;
                let machines = dec.ids()?;
                let arena = dec.bool()?;
                let shards = if arena {
                    Vec::new()
                } else {
                    let n = dec.u32()? as usize;
                    if n != machines.len() {
                        return Err(WireError::Malformed(format!(
                            "rebalance: {n} shards for {} machines",
                            machines.len()
                        )));
                    }
                    let mut shards = Vec::with_capacity(n.min(4096));
                    for _ in 0..n {
                        shards.push(dec.ids()?);
                    }
                    shards
                };
                let r = dec.u32()? as usize;
                let mut replay = Vec::with_capacity(r.min(1024));
                for _ in 0..r {
                    replay.push(RoundTask::decode(&mut dec)?);
                }
                ToWorker::Rebalance { job, drop, machines, shards, arena, replay }
            }
            t => return Err(WireError::Malformed(format!("unknown ToWorker tag {t}"))),
        };
        dec.finish()?;
        Ok(msg)
    }
}

/// Worker → coordinator messages.
#[derive(Debug, Clone, PartialEq)]
pub enum FromWorker {
    /// Connect-time handshake, the very first frame on every transport:
    /// identifies which worker slot this byte stream belongs to (socket
    /// transports accept connections in arbitrary order) and the wire
    /// version the worker speaks. Version mismatches fail here, before
    /// any shard data moves.
    Hello {
        /// The worker binary's [`WIRE_VERSION`].
        version: u16,
        /// Worker slot id (`--id` / `MRSUB_WORKER_ID`; spawn order).
        worker: u32,
    },
    /// Init handshake: the worker rebuilt its oracle and is ready for
    /// rounds, speaking `version`.
    Ready {
        /// The worker binary's [`WIRE_VERSION`].
        version: u16,
    },
    /// One round's results: a reply per hosted machine (machine order of
    /// the init), plus the worker-side oracle-call delta
    /// `(total, batched, batches)` for the round.
    RoundDone {
        /// Per-machine replies.
        replies: Vec<TaskReply>,
        /// Oracle calls issued worker-side during the round.
        calls: (u64, u64, u64),
    },
    /// Structured worker-side failure (bad spec, bad task, …).
    Fail {
        /// Human-readable reason.
        message: String,
    },
}

impl FromWorker {
    /// Encode to a payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut enc = Enc::new();
        match self {
            FromWorker::Ready { version } => {
                enc.u8(1);
                enc.u16(*version);
            }
            FromWorker::RoundDone { replies, calls } => {
                enc.u8(2);
                enc.u32(replies.len() as u32);
                for r in replies {
                    r.encode(&mut enc);
                }
                enc.u64(calls.0);
                enc.u64(calls.1);
                enc.u64(calls.2);
            }
            FromWorker::Fail { message } => {
                enc.u8(3);
                enc.str(message);
            }
            FromWorker::Hello { version, worker } => {
                enc.u8(4);
                enc.u16(*version);
                enc.u32(*worker);
            }
        }
        enc.buf
    }

    /// Decode from a payload.
    pub fn decode(payload: &[u8]) -> Result<FromWorker, WireError> {
        let mut dec = Dec::new(payload);
        let msg = match dec.u8()? {
            1 => FromWorker::Ready { version: dec.u16()? },
            2 => {
                let n = dec.u32()? as usize;
                let mut replies = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    replies.push(TaskReply::decode(&mut dec)?);
                }
                FromWorker::RoundDone {
                    replies,
                    calls: (dec.u64()?, dec.u64()?, dec.u64()?),
                }
            }
            3 => FromWorker::Fail { message: dec.str()? },
            4 => FromWorker::Hello { version: dec.u16()?, worker: dec.u32()? },
            t => return Err(WireError::Malformed(format!("unknown FromWorker tag {t}"))),
        };
        dec.finish()?;
        Ok(msg)
    }
}

// --- client <-> daemon messages (mrsub submit <-> mrsub serve) --------------

/// Client → daemon requests, spoken by `mrsub submit` over TCP to a
/// long-running `mrsub serve` daemon. Rides the same versioned,
/// checksummed frame as the worker protocol, so a version-skewed client
/// fails its very first frame with [`WireError::BadVersion`].
#[derive(Debug, Clone, PartialEq)]
pub enum ClientRequest {
    /// Submit one optimization job; the daemon replies
    /// [`ClientResponse::JobResult`] on this connection when it finishes
    /// (or [`ClientResponse::Error`] if it can't run).
    SubmitJob {
        /// Algorithm name, the `mrsub run --algorithm` syntax
        /// (e.g. `"two-round"`, `"combined:0.1"`).
        algorithm: String,
        /// Cardinality constraint.
        k: usize,
        /// Experiment seed (shard partition + algorithm randomness).
        seed: u64,
        /// Simulated machine count for the MapReduce layout.
        machines: usize,
        /// Oracle construction recipe; also the warm pool's dataset
        /// cache key.
        spec: OracleSpec,
    },
    /// Ask for one job's lifecycle state.
    JobStatus {
        /// Daemon-assigned job id (from [`ClientResponse::JobResult`] or
        /// [`ClientResponse::Jobs`]).
        id: u64,
    },
    /// List all jobs the daemon has seen, with their states.
    ListJobs,
    /// Ask the daemon to finish in-flight jobs, shut the warm pool down,
    /// and exit (the serve-smoke harness's clean-exit path).
    Shutdown,
}

impl ClientRequest {
    /// Encode to a payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut enc = Enc::new();
        match self {
            ClientRequest::SubmitJob { algorithm, k, seed, machines, spec } => {
                enc.u8(1);
                enc.str(algorithm);
                enc.usize(*k);
                enc.u64(*seed);
                enc.usize(*machines);
                spec.encode(&mut enc);
            }
            ClientRequest::JobStatus { id } => {
                enc.u8(2);
                enc.u64(*id);
            }
            ClientRequest::ListJobs => enc.u8(3),
            ClientRequest::Shutdown => enc.u8(4),
        }
        enc.buf
    }

    /// Decode from a payload.
    pub fn decode(payload: &[u8]) -> Result<ClientRequest, WireError> {
        let mut dec = Dec::new(payload);
        let msg = match dec.u8()? {
            1 => ClientRequest::SubmitJob {
                algorithm: dec.str()?,
                k: dec.usize()?,
                seed: dec.u64()?,
                machines: dec.usize()?,
                spec: OracleSpec::decode(&mut dec)?,
            },
            2 => ClientRequest::JobStatus { id: dec.u64()? },
            3 => ClientRequest::ListJobs,
            4 => ClientRequest::Shutdown,
            t => return Err(WireError::Malformed(format!("unknown ClientRequest tag {t}"))),
        };
        dec.finish()?;
        Ok(msg)
    }
}

/// Daemon → client responses.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientResponse {
    /// A finished job: the selection, its value, and the full
    /// [`crate::coordinator::ExperimentRecord`] as a JSON document (the
    /// client parses it back with the crate's own JSON parser).
    JobResult {
        /// Daemon-assigned job id.
        id: u64,
        /// Selected element ids, insertion order — bit-identical to the
        /// same (algorithm, spec, k, seed, machines) run standalone.
        selection: Vec<ElementId>,
        /// Objective value of the selection.
        value: f64,
        /// Per-job experiment record, serialized JSON.
        record_json: String,
    },
    /// One job's lifecycle state: `"queued"`, `"running"`, `"done"`, or
    /// `"failed: <reason>"`.
    Status {
        /// Job id.
        id: u64,
        /// State label.
        state: String,
    },
    /// All jobs the daemon has seen, `(id, state)` in id order.
    Jobs {
        /// `(job id, state label)` pairs.
        jobs: Vec<(u64, String)>,
    },
    /// Structured failure (unknown algorithm, bad spec, pool death, …).
    Error {
        /// Human-readable reason.
        message: String,
    },
    /// Acknowledges [`ClientRequest::Shutdown`]; the daemon exits after
    /// draining in-flight jobs.
    ShuttingDown,
}

impl ClientResponse {
    /// Encode to a payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut enc = Enc::new();
        match self {
            ClientResponse::JobResult { id, selection, value, record_json } => {
                enc.u8(1);
                enc.u64(*id);
                enc.ids(selection);
                enc.f64(*value);
                enc.str(record_json);
            }
            ClientResponse::Status { id, state } => {
                enc.u8(2);
                enc.u64(*id);
                enc.str(state);
            }
            ClientResponse::Jobs { jobs } => {
                enc.u8(3);
                enc.u32(jobs.len() as u32);
                for (id, state) in jobs {
                    enc.u64(*id);
                    enc.str(state);
                }
            }
            ClientResponse::Error { message } => {
                enc.u8(4);
                enc.str(message);
            }
            ClientResponse::ShuttingDown => enc.u8(5),
        }
        enc.buf
    }

    /// Decode from a payload.
    pub fn decode(payload: &[u8]) -> Result<ClientResponse, WireError> {
        let mut dec = Dec::new(payload);
        let msg = match dec.u8()? {
            1 => ClientResponse::JobResult {
                id: dec.u64()?,
                selection: dec.ids()?,
                value: dec.f64()?,
                record_json: dec.str()?,
            },
            2 => ClientResponse::Status { id: dec.u64()?, state: dec.str()? },
            3 => {
                let n = dec.u32()? as usize;
                let mut jobs = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    jobs.push((dec.u64()?, dec.str()?));
                }
                ClientResponse::Jobs { jobs }
            }
            4 => ClientResponse::Error { message: dec.str()? },
            5 => ClientResponse::ShuttingDown,
            t => return Err(WireError::Malformed(format!("unknown ClientResponse tag {t}"))),
        };
        dec.finish()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{forall, Gen};

    /// Property-test case budget: full depth natively, a handful under
    /// Miri (each interpreted case is ~1000x slower; the coverage there
    /// is the borrow/UB checking, not the case count).
    fn cases(native: usize) -> usize {
        if cfg!(miri) {
            4
        } else {
            native
        }
    }

    fn arb_ids(g: &mut Gen, max_len: usize) -> Vec<ElementId> {
        let len = g.usize_in(0, max_len + 1);
        (0..len).map(|_| g.usize_in(0, 1 << 20) as ElementId).collect()
    }

    fn arb_constraint(g: &mut Gen) -> Constraint {
        if g.bool_with(0.5) {
            Constraint::cardinality(g.usize_in(1, 50))
        } else {
            let parts_n = g.usize_in(1, 6) as u32;
            let n = g.usize_in(1, 30);
            Constraint::partition_matroid(
                (0..n).map(|e| e as u32 % parts_n).collect(),
                (0..parts_n).map(|_| g.usize_in(1, 4)).collect(),
            )
        }
    }

    fn arb_task(g: &mut Gen, depth: usize) -> RoundTask {
        // the two recursive variants (Batch, AdoptMachines) only at depth 0
        // so generation terminates.
        let hi = if depth == 0 { 11 } else { 9 };
        match g.usize_in(1, hi) {
            1 => RoundTask::Filter { base: arb_ids(g, 20), tau: g.f64_in(-3.0, 3.0) },
            2 => {
                let n = g.usize_in(0, 4);
                RoundTask::MultiFilter {
                    persist: g.bool_with(0.5),
                    guesses: (0..n)
                        .map(|i| GuessFilter {
                            id: i as u32,
                            base: arb_ids(g, 10),
                            tau: g.f64_in(0.0, 5.0),
                        })
                        .collect(),
                    drop: arb_ids(g, 4),
                }
            }
            3 => RoundTask::LocalGreedy { k: g.usize_in(0, 100) },
            4 => RoundTask::MaxSingleton,
            5 => RoundTask::TopSingletons { k: g.usize_in(1, 50), c: g.usize_in(1, 8) },
            6 => RoundTask::PruneSample {
                base: arb_ids(g, 15),
                floor: g.f64_in(0.0, 2.0),
                tau: g.f64_in(0.0, 5.0),
                per_share: g.usize_in(1, 200),
                seed: g.u64_in(1 << 40),
                round: g.usize_in(0, 64) as u32,
            },
            7 => RoundTask::PartitionGreedy {
                k: g.usize_in(1, 60),
                parts: g.usize_in(1, 16) as u32,
                constraint: arb_constraint(g),
                seed: g.u64_in(1 << 40),
                round: g.usize_in(0, 32) as u32,
            },
            8 => RoundTask::ConstrainedFilter {
                base: arb_ids(g, 15),
                tau: g.f64_in(0.0, 5.0),
                constraint: arb_constraint(g),
            },
            9 => {
                let n = g.usize_in(0, 4);
                RoundTask::Batch((0..n).map(|_| arb_task(g, depth + 1)).collect())
            }
            _ => {
                let n = g.usize_in(1, 4);
                let machines: Vec<u32> = (0..n).map(|i| i as u32 * 3).collect();
                // arena adoptions carry no shard payloads at all.
                let arena = g.bool_with(0.5);
                let shards =
                    if arena { Vec::new() } else { (0..n).map(|_| arb_ids(g, 12)).collect() };
                let r = g.usize_in(0, 3);
                RoundTask::AdoptMachines {
                    machines,
                    shards,
                    arena,
                    replay: (0..r).map(|_| arb_task(g, depth + 1)).collect(),
                    pending: Box::new(arb_task(g, depth + 1)),
                }
            }
        }
    }

    fn arb_reply(g: &mut Gen, depth: usize) -> TaskReply {
        let hi = if depth == 0 { 7 } else { 6 };
        match g.usize_in(1, hi) {
            1 => TaskReply::Ids(arb_ids(g, 30)),
            2 => TaskReply::Scalar(g.f64_in(-1e9, 1e9)),
            3 => {
                let n = g.usize_in(0, 5);
                TaskReply::Multi((0..n).map(|i| (i as u32, arb_ids(g, 10))).collect())
            }
            4 => TaskReply::Pruned {
                shipped: arb_ids(g, 20),
                fit: g.bool_with(0.5),
                resident: g.u64_in(1 << 20),
            },
            5 => {
                let ids = arb_ids(g, 20);
                let values = ids.iter().map(|_| g.f64_in(-2.0, 10.0)).collect();
                TaskReply::Valued { ids, values }
            }
            _ => {
                let n = g.usize_in(0, 4);
                TaskReply::Batch((0..n).map(|_| arb_reply(g, depth + 1)).collect())
            }
        }
    }

    fn frame_roundtrip(payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        let written = write_frame(&mut buf, payload, DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(written, buf.len());
        let mut cursor = std::io::Cursor::new(buf);
        let (got, read) = read_frame(&mut cursor, DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(read, written);
        got
    }

    #[test]
    fn frame_roundtrips_and_counts_bytes() {
        assert_eq!(frame_roundtrip(b"hello"), b"hello");
        assert_eq!(frame_roundtrip(b""), b"");
    }

    /// Fixed-value codec exercise (no RNG, no depth): one coordinator→
    /// worker Init + Round and one worker→coordinator RoundDone through
    /// real checksummed frames. This is the wire path's Miri anchor —
    /// `./verify.sh miri` interprets it even when the property tests
    /// above run at their reduced case budget.
    #[test]
    fn codec_smoke_roundtrip_runs_under_miri() {
        use crate::oracle::spec::OracleSpec;
        let init = ToWorker::Init(WorkerInit {
            spec: OracleSpec::Modular { weights: vec![0.25, 1.0, 2.5] },
            machines: vec![0, 2],
            shards: vec![vec![1, 4, 9], vec![2, 8]],
            sample: vec![4, 9],
            arena: false,
        });
        let framed = frame_roundtrip(&init.encode());
        assert_eq!(ToWorker::decode(&framed).unwrap(), init);

        let round = ToWorker::Round(RoundTask::Batch(vec![
            RoundTask::Filter { base: vec![1, 4], tau: 0.5 },
            RoundTask::LocalGreedy { k: 2 },
        ]));
        let framed = frame_roundtrip(&round.encode());
        assert_eq!(ToWorker::decode(&framed).unwrap(), round);

        let done = FromWorker::RoundDone {
            replies: vec![TaskReply::Batch(vec![
                TaskReply::Ids(vec![9]),
                TaskReply::Ids(vec![1, 4]),
            ])],
            calls: (12, 3, 2),
        };
        let framed = frame_roundtrip(&done.encode());
        assert_eq!(FromWorker::decode(&framed).unwrap(), done);
    }

    #[test]
    fn prop_task_roundtrip() {
        forall(0xA11, cases(60), |g| {
            let task = arb_task(g, 0);
            let mut enc = Enc::new();
            task.encode(&mut enc);
            let mut dec = Dec::new(&enc.buf);
            let back = RoundTask::decode(&mut dec).expect("decode");
            dec.finish().expect("fully consumed");
            assert_eq!(task, back);
        });
    }

    #[test]
    fn prop_reply_roundtrip() {
        forall(0xA12, cases(60), |g| {
            let reply = arb_reply(g, 0);
            let mut enc = Enc::new();
            reply.encode(&mut enc);
            let mut dec = Dec::new(&enc.buf);
            let back = TaskReply::decode(&mut dec).expect("decode");
            dec.finish().expect("fully consumed");
            assert_eq!(reply, back);
        });
    }

    #[test]
    fn prop_messages_roundtrip_through_frames() {
        forall(0xA13, cases(40), |g| {
            let msg = ToWorker::Round(arb_task(g, 0));
            let payload = msg.encode();
            let framed = frame_roundtrip(&payload);
            assert_eq!(ToWorker::decode(&framed).unwrap(), msg);

            let reply = FromWorker::RoundDone {
                replies: (0..g.usize_in(0, 4)).map(|_| arb_reply(g, 0)).collect(),
                calls: (g.u64_in(1000), g.u64_in(1000), g.u64_in(100)),
            };
            let framed = frame_roundtrip(&reply.encode());
            assert_eq!(FromWorker::decode(&framed).unwrap(), reply);
        });
    }

    #[test]
    fn prop_corrupted_frames_error_never_panic() {
        forall(0xA14, cases(80), |g| {
            let task = arb_task(g, 0);
            let mut buf = Vec::new();
            write_frame(&mut buf, &ToWorker::Round(task).encode(), DEFAULT_MAX_FRAME).unwrap();

            // flip one byte anywhere in the frame.
            let idx = g.usize_in(0, buf.len());
            let bit = 1u8 << g.usize_in(0, 8);
            let mut corrupt = buf.clone();
            corrupt[idx] ^= bit;
            let mut cursor = std::io::Cursor::new(corrupt);
            match read_frame(&mut cursor, DEFAULT_MAX_FRAME) {
                Ok(_) => {
                    // A payload byte flip is always caught (FNV-1a folds
                    // every byte through an invertible multiply), and
                    // header flips fail the magic/version/length checks —
                    // reaching Ok on a corrupted frame is the one
                    // unacceptable outcome.
                    panic!("1-bit corruption went undetected at byte {idx}");
                }
                Err(_) => {} // structured error: the contract.
            }

            // truncation at every prefix length errors cleanly.
            let cut = g.usize_in(0, buf.len());
            let mut cursor = std::io::Cursor::new(buf[..cut].to_vec());
            assert!(read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_err());
        });
    }

    #[test]
    fn oversized_frames_rejected_both_sides() {
        let payload = vec![0u8; 256];
        let mut buf = Vec::new();
        match write_frame(&mut buf, &payload, 64) {
            Err(WireError::FrameTooLarge { len: 256, max: 64 }) => {}
            other => panic!("expected FrameTooLarge, got {other:?}"),
        }
        // receiver side: a legal frame read under a smaller cap.
        write_frame(&mut buf, &payload, DEFAULT_MAX_FRAME).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        match read_frame(&mut cursor, 64) {
            Err(WireError::FrameTooLarge { len: 256, max: 64 }) => {}
            other => panic!("expected FrameTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn adopt_machines_roundtrips_and_classifies() {
        let prune = RoundTask::PruneSample {
            base: vec![1, 2],
            floor: 0.5,
            tau: 1.0,
            per_share: 4,
            seed: 9,
            round: 2,
        };
        let adopt = RoundTask::AdoptMachines {
            machines: vec![3, 7],
            shards: vec![vec![1, 2, 3], vec![4, 5]],
            arena: false,
            replay: vec![prune.clone()],
            pending: Box::new(RoundTask::LocalGreedy { k: 5 }),
        };
        let mut enc = Enc::new();
        adopt.encode(&mut enc);
        let mut dec = Dec::new(&enc.buf);
        assert_eq!(RoundTask::decode(&mut dec).unwrap(), adopt);
        dec.finish().unwrap();

        // store-mutation classification drives the replay history.
        assert!(prune.mutates_store());
        assert!(!RoundTask::LocalGreedy { k: 3 }.mutates_store());
        assert!(!RoundTask::MaxSingleton.mutates_store());
        assert!(RoundTask::Batch(vec![RoundTask::MaxSingleton, prune.clone()]).mutates_store());
        assert!(!adopt.mutates_store(), "adoption itself is not replayed");
        let mf = |persist: bool, drop: Vec<u32>| RoundTask::MultiFilter {
            persist,
            guesses: vec![],
            drop,
        };
        assert!(mf(true, vec![]).mutates_store());
        assert!(mf(false, vec![1]).mutates_store());
        assert!(!mf(false, vec![]).mutates_store());

        // prune detection descends into Batch and pending.
        assert!(prune.contains_prune());
        assert!(RoundTask::Batch(vec![RoundTask::MaxSingleton, prune.clone()]).contains_prune());
        assert!(!adopt.contains_prune(), "pending is local-greedy here");
        let adopt_prune = RoundTask::AdoptMachines {
            machines: vec![0],
            shards: vec![vec![]],
            arena: false,
            replay: vec![],
            pending: Box::new(prune),
        };
        assert!(adopt_prune.contains_prune());

        // an adoption reply is validated against its pending task's shape.
        assert!(reply_matches(&adopt, &TaskReply::Ids(vec![1])));
        assert!(!reply_matches(&adopt, &TaskReply::Scalar(1.0)));
        assert!(reply_matches(
            &adopt_prune,
            &TaskReply::Pruned { shipped: vec![], fit: true, resident: 0 }
        ));
    }

    #[test]
    fn arena_frames_elide_shard_payloads() {
        use crate::oracle::spec::OracleSpec;
        let spec = OracleSpec::Coverage {
            n: 4096,
            universe: 2048,
            avg_degree: 4,
            weighted: false,
            seed: 7,
        };
        let big_shards: Vec<Vec<ElementId>> = (0..8).map(|m| vec![m as u32; 4096]).collect();
        let big_sample: Vec<ElementId> = (0..2048).collect();
        let machines: Vec<u32> = (0..8).collect();

        let wire_init = ToWorker::Init(WorkerInit {
            spec: spec.clone(),
            machines: machines.clone(),
            shards: big_shards.clone(),
            sample: big_sample,
            arena: false,
        })
        .encode();
        let arena_init = ToWorker::Init(WorkerInit {
            spec,
            machines,
            shards: Vec::new(),
            sample: Vec::new(),
            arena: true,
        })
        .encode();
        // the arena form is O(1): spec + machine ids + the flag, not the
        // tens of KiB of shard/sample payload.
        assert!(
            arena_init.len() < 256 && wire_init.len() > 100_000,
            "arena init {} bytes vs wire init {} bytes",
            arena_init.len(),
            wire_init.len()
        );
        // both forms round-trip exactly.
        for payload in [&wire_init, &arena_init] {
            let back = ToWorker::decode(payload).unwrap();
            assert_eq!(back.encode(), **payload);
        }

        let wire_adopt = RoundTask::AdoptMachines {
            machines: vec![1, 3],
            shards: big_shards[..2].to_vec(),
            arena: false,
            replay: vec![],
            pending: Box::new(RoundTask::MaxSingleton),
        };
        let arena_adopt = RoundTask::AdoptMachines {
            machines: vec![1, 3],
            shards: Vec::new(),
            arena: true,
            replay: vec![],
            pending: Box::new(RoundTask::MaxSingleton),
        };
        let size = |t: &RoundTask| {
            let mut enc = Enc::new();
            t.encode(&mut enc);
            enc.buf.len()
        };
        assert!(size(&arena_adopt) < 64 && size(&wire_adopt) > 16_000);
        let mut enc = Enc::new();
        arena_adopt.encode(&mut enc);
        let mut dec = Dec::new(&enc.buf);
        assert_eq!(RoundTask::decode(&mut dec).unwrap(), arena_adopt);
        dec.finish().unwrap();
    }

    #[test]
    fn job_keyed_worker_messages_roundtrip() {
        use crate::oracle::spec::OracleSpec;
        let init = WorkerInit {
            spec: OracleSpec::Modular { weights: vec![1.0, 0.5] },
            machines: vec![1, 5],
            shards: vec![vec![3, 7], vec![2]],
            sample: vec![7],
            arena: false,
        };
        for msg in [
            ToWorker::Attach { job: 9, init: init.clone() },
            ToWorker::JobRound { job: 9, task: RoundTask::LocalGreedy { k: 3 } },
            ToWorker::Detach { job: 9 },
        ] {
            let framed = frame_roundtrip(&msg.encode());
            assert_eq!(ToWorker::decode(&framed).unwrap(), msg);
        }
        // Attach is byte-compatible with Init after the (tag, job) prefix:
        // both encode through WorkerInit::encode.
        let attach = ToWorker::Attach { job: 42, init: init.clone() }.encode();
        let plain = ToWorker::Init(init).encode();
        assert_eq!(&attach[1 + 8..], &plain[1..]);
        // arena attaches elide shard payloads, exactly like arena inits.
        let arena_attach = ToWorker::Attach {
            job: 1,
            init: WorkerInit {
                spec: OracleSpec::Modular { weights: vec![1.0] },
                machines: (0..64).collect(),
                shards: Vec::new(),
                sample: Vec::new(),
                arena: true,
            },
        };
        let payload = arena_attach.encode();
        assert!(payload.len() < 512, "arena attach is O(1) framing: {} bytes", payload.len());
        assert_eq!(ToWorker::decode(&payload).unwrap(), arena_attach);
    }

    #[test]
    fn rebalance_frames_roundtrip_and_elide_arena_shards() {
        let replay = vec![RoundTask::PruneSample {
            base: vec![1, 2],
            floor: 0.5,
            tau: 1.0,
            per_share: 4,
            seed: 9,
            round: 2,
        }];
        // wire form carries the adopted shards; drop-only moves are legal.
        let msgs = [
            ToWorker::Rebalance {
                job: 0,
                drop: vec![5],
                machines: vec![3, 7],
                shards: vec![vec![1, 2, 3], vec![4, 5]],
                arena: false,
                replay: replay.clone(),
            },
            ToWorker::Rebalance {
                job: 42,
                drop: vec![0, 1],
                machines: vec![],
                shards: vec![],
                arena: false,
                replay: vec![],
            },
        ];
        for msg in msgs {
            let framed = frame_roundtrip(&msg.encode());
            assert_eq!(ToWorker::decode(&framed).unwrap(), msg);
        }
        // arena form is O(1): shard payloads never cross the wire.
        let big: Vec<Vec<ElementId>> = (0..8).map(|m| vec![m as u32; 4096]).collect();
        let wire = ToWorker::Rebalance {
            job: 1,
            drop: vec![],
            machines: (0..8).collect(),
            shards: big,
            arena: false,
            replay: vec![],
        }
        .encode();
        let arena = ToWorker::Rebalance {
            job: 1,
            drop: vec![],
            machines: (0..8).collect(),
            shards: Vec::new(),
            arena: true,
            replay: vec![],
        };
        let payload = arena.encode();
        assert!(
            payload.len() < 128 && wire.len() > 100_000,
            "arena rebalance {} bytes vs wire {} bytes",
            payload.len(),
            wire.len()
        );
        assert_eq!(ToWorker::decode(&payload).unwrap(), arena);
        // a shard-count/machine-count mismatch is malformed, not a panic.
        let bad = {
            let mut enc = Enc::new();
            enc.u8(7);
            enc.u64(0);
            enc.ids(&[]);
            enc.ids(&[1, 2]); // two machines...
            enc.bool(false);
            enc.u32(1); // ...but one shard
            enc.ids(&[9]);
            enc.u32(0);
            enc.buf
        };
        assert!(matches!(ToWorker::decode(&bad), Err(WireError::Malformed(_))));
    }

    #[test]
    fn client_frames_roundtrip() {
        use crate::oracle::spec::OracleSpec;
        let reqs = [
            ClientRequest::SubmitJob {
                algorithm: "combined:0.1".into(),
                k: 16,
                seed: 7,
                machines: 8,
                spec: OracleSpec::Coverage {
                    n: 512,
                    universe: 256,
                    avg_degree: 4,
                    weighted: true,
                    seed: 3,
                },
            },
            ClientRequest::JobStatus { id: 12 },
            ClientRequest::ListJobs,
            ClientRequest::Shutdown,
        ];
        for req in reqs {
            let framed = frame_roundtrip(&req.encode());
            assert_eq!(ClientRequest::decode(&framed).unwrap(), req);
        }
        let resps = [
            ClientResponse::JobResult {
                id: 12,
                selection: vec![4, 9, 1],
                value: 37.5,
                record_json: "{\"value\":37.5}".into(),
            },
            ClientResponse::Status { id: 12, state: "running".into() },
            ClientResponse::Jobs {
                jobs: vec![(1, "done".into()), (2, "failed: bad spec".into())],
            },
            ClientResponse::Error { message: "unknown algorithm".into() },
            ClientResponse::ShuttingDown,
        ];
        for resp in resps {
            let framed = frame_roundtrip(&resp.encode());
            assert_eq!(ClientResponse::decode(&framed).unwrap(), resp);
        }
        // truncation errors structurally, never panics.
        let full = ClientResponse::JobResult {
            id: 1,
            selection: vec![2, 3],
            value: 1.0,
            record_json: "{}".into(),
        }
        .encode();
        for cut in 0..full.len() {
            assert!(ClientResponse::decode(&full[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn bad_magic_and_version_detected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"xyz", DEFAULT_MAX_FRAME).unwrap();
        let mut bad_magic = buf.clone();
        bad_magic[0] = b'X';
        assert!(matches!(
            read_frame(&mut std::io::Cursor::new(bad_magic), DEFAULT_MAX_FRAME),
            Err(WireError::BadMagic(_))
        ));
        let mut bad_version = buf.clone();
        bad_version[4] = WIRE_VERSION as u8 + 1;
        assert!(matches!(
            read_frame(&mut std::io::Cursor::new(bad_version), DEFAULT_MAX_FRAME),
            Err(WireError::BadVersion { .. })
        ));
    }
}
