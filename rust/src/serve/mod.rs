//! Multi-tenant serving daemon (`mrsub serve`) and its client.
//!
//! The daemon turns the one-shot experiment pipeline into a long-running
//! service: it listens on TCP for [`ClientRequest`] frames (the same
//! versioned, checksummed codec the worker protocol uses — see
//! [`crate::mapreduce::wire`]), and runs each submitted optimization job
//! through the existing [`crate::coordinator::run_experiment`] path, so
//! every serving result is **bit-identical by construction** to the same
//! `(algorithm, spec, k, seed, machines)` run standalone.
//!
//! ## Warm pool
//!
//! On a process backend (`--backend process:N[@transport]`) the daemon
//! spawns **one** [`ProcessPool`] lazily, on the first job, from that
//! job's deterministic partition — computed exactly as
//! [`crate::mapreduce::MrCluster::new`] computes it — and then shares it
//! across all jobs via [`PoolLease`]s: each job *attaches* its dataset
//! (job-keyed worker runtimes; see `ProcessPool::attach_job`) instead of
//! paying a worker spawn, and detaches when it finishes. Jobs never pay
//! a per-job worker spawn; a job whose dataset is byte-identical to the
//! pool's spawn dataset attaches with every shard payload elided through
//! the zero-copy arena (the *arena-cache hit*, surfaced in
//! [`ServeStats`]). Because one mutex guards the pool, concurrent jobs
//! interleave at round granularity — worker streams never carry two
//! jobs' frames at once, so replies cannot be misattributed.
//!
//! ## Elasticity
//!
//! Under `--recovery requeue[:R]` the shared pool is *self-healing*: a
//! worker that dies mid-job is absorbed by the requeue path and replaced
//! with a freshly spawned process at the next round boundary, so the pool
//! returns to its `process:N` size instead of shrinking for the daemon's
//! remaining lifetime ([`ServeStats::workers_respawned`] counts these).
//! With `--elastic` the pool additionally *grows* past `N` (up to `2N`)
//! while more jobs than workers are in flight, and the deterministic
//! rebalance planner sheds machines onto the new workers between rounds.
//! Neither mechanism touches selections: placement is invisible to
//! results, so served jobs stay bit-identical to standalone runs even
//! across deaths, respawns, and rebalances.
//!
//! On the in-process backends there is no pool: jobs run standalone.
//! That path keeps the daemon fully testable without spawning worker
//! processes.
//!
//! ## Protocol
//!
//! One request frame, one response frame, repeated until the client hangs
//! up. [`ClientRequest::SubmitJob`] blocks its connection until the job
//! finishes and answers [`ClientResponse::JobResult`] (selection, value,
//! and the full [`ExperimentRecord`] as JSON); concurrency comes from
//! concurrent connections, each served by its own thread.
//! [`ClientRequest::Shutdown`] drains and stops the daemon.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::algorithms::combined::CombinedTwoRound;
use crate::algorithms::dash::Dash;
use crate::algorithms::randgreedi::RandGreeDi;
use crate::algorithms::MrAlgorithm;
use crate::config::GreedyAlg;
use crate::coordinator::{run_experiment, ExperimentRecord};
use crate::core::{derive_seed, Error, Result};
use crate::mapreduce::backend::BackendKind;
use crate::mapreduce::partition::{
    default_machines, partition_and_sample, sample_probability, Partitioned,
};
use crate::mapreduce::process::{PoolLease, PoolOptions, ProcessPool};
use crate::mapreduce::wire::{self, ClientRequest, ClientResponse, Enc, WireError};
use crate::mapreduce::ClusterConfig;
use crate::oracle::spec::OracleSpec;
use crate::oracle::Oracle;
use crate::workload::Instance;

/// Oracles kept warm across jobs, keyed by encoded [`OracleSpec`]
/// (most-recently-used first). Bounds daemon memory: an 9th distinct
/// spec evicts the coldest entry.
const ORACLE_CACHE_CAP: usize = 8;

/// Daemon construction parameters.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// `HOST:PORT` to listen on; port `0` picks a free port (tests).
    pub bind: String,
    /// Base cluster configuration every job inherits (backend, timeouts,
    /// recovery policy, worker executable/env, frame cap). Per-job
    /// `seed`/`machines`/`oracle_spec` are overwritten from the request.
    pub cfg: ClusterConfig,
}

/// A point-in-time snapshot of the daemon's counters (tests and the
/// serve-smoke harness assert on these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeStats {
    /// Jobs that ran to completion successfully.
    pub jobs_completed: u64,
    /// Warm-pool attaches served entirely from the zero-copy arena.
    pub arena_hits: u64,
    /// Warm-pool attaches that shipped shards over the wire.
    pub arena_misses: u64,
    /// Worker processes spawned over the daemon's lifetime (the warm
    /// pool spawns exactly once — this never grows after the first job).
    pub workers_spawned: u64,
    /// Workers still alive in the warm pool (0 before the first
    /// process-backend job).
    pub workers_alive: u64,
    /// Replacement workers activated after the initial spawn: in-round
    /// respawns after a death, late-join back-fills, and `--elastic`
    /// growth (`ProcessPool::respawns`).
    pub workers_respawned: u64,
}

struct DaemonState {
    next_job: u64,
    jobs: BTreeMap<u64, String>,
    pool: Option<Arc<Mutex<ProcessPool>>>,
    oracle_cache: Vec<(Vec<u8>, Arc<dyn Oracle>)>,
    jobs_completed: u64,
    workers_spawned: u64,
}

struct Shared {
    cfg: ClusterConfig,
    max_frame: usize,
    addr: SocketAddr,
    state: Mutex<DaemonState>,
    /// Serializes warm-pool spawning so two racing first jobs cannot
    /// each spawn a worker set.
    spawn_lock: Mutex<()>,
    shutdown: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

/// A running serving daemon. Dropping (or [`Daemon::wait`]) tears the
/// warm pool down, which shuts every worker process down in turn.
pub struct Daemon {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl Daemon {
    /// Bind and start serving in background threads; returns as soon as
    /// the listener is live (use [`Daemon::addr`] to reach it).
    pub fn start(opts: ServeOptions) -> Result<Daemon> {
        let listener = TcpListener::bind(&opts.bind)
            .map_err(|e| Error::Config(format!("cannot bind {}: {e}", opts.bind)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Runtime(format!("cannot resolve bound address: {e}")))?;
        let max_frame = opts.cfg.max_frame_bytes;
        let shared = Arc::new(Shared {
            cfg: opts.cfg,
            max_frame,
            addr,
            state: Mutex::new(DaemonState {
                next_job: 1,
                jobs: BTreeMap::new(),
                pool: None,
                oracle_cache: Vec::new(),
                jobs_completed: 0,
                workers_spawned: 0,
            }),
            spawn_lock: Mutex::new(()),
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        Ok(Daemon { addr, shared, accept: Some(accept) })
    }

    /// The daemon's bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot the serving counters.
    pub fn stats(&self) -> ServeStats {
        let st = lock_state(&self.shared);
        let (arena_hits, arena_misses, workers_alive, workers_respawned) = match &st.pool {
            Some(pool) => match pool.lock() {
                Ok(p) => {
                    let (h, m) = p.arena_attach_stats();
                    (h, m, p.alive_workers() as u64, p.respawns())
                }
                Err(_) => (0, 0, 0, 0),
            },
            None => (0, 0, 0, 0),
        };
        ServeStats {
            jobs_completed: st.jobs_completed,
            arena_hits,
            arena_misses,
            workers_spawned: st.workers_spawned,
            workers_alive,
            workers_respawned,
        }
    }

    /// Block until the daemon shuts down (a [`ClientRequest::Shutdown`]
    /// frame arrives), then drain in-flight connections and tear the
    /// warm pool down. Consumes the daemon.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let conns =
            std::mem::take(&mut *self.shared.conns.lock().unwrap_or_else(|e| e.into_inner()));
        for h in conns {
            let _ = h.join();
        }
        // dropping the pool Arc's last strong ref shuts the workers down.
        lock_state(&self.shared).pool = None;
    }
}

/// Lock the daemon state, recovering from a poisoned mutex (a panicking
/// connection thread must not wedge the whole daemon).
fn lock_state(shared: &Shared) -> std::sync::MutexGuard<'_, DaemonState> {
    shared.state.lock().unwrap_or_else(|e| e.into_inner())
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        let handle = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || handle_connection(stream, &shared))
        };
        shared.conns.lock().unwrap_or_else(|e| e.into_inner()).push(handle);
    }
}

fn handle_connection(mut stream: TcpStream, shared: &Shared) {
    loop {
        let payload = match wire::read_frame(&mut stream, shared.max_frame) {
            Ok((payload, _)) => payload,
            // client hung up (or sent garbage): this connection is done.
            Err(_) => return,
        };
        let req = match ClientRequest::decode(&payload) {
            Ok(req) => req,
            Err(e) => {
                let resp = ClientResponse::Error { message: format!("undecodable request: {e}") };
                let _ = respond(&mut stream, &resp, shared.max_frame);
                return;
            }
        };
        let resp = match req {
            ClientRequest::SubmitJob { algorithm, k, seed, machines, spec } => {
                submit(shared, &algorithm, k, seed, machines, &spec)
            }
            ClientRequest::JobStatus { id } => {
                let st = lock_state(shared);
                match st.jobs.get(&id) {
                    Some(state) => ClientResponse::Status { id, state: state.clone() },
                    None => ClientResponse::Error { message: format!("unknown job {id}") },
                }
            }
            ClientRequest::ListJobs => {
                let st = lock_state(shared);
                ClientResponse::Jobs {
                    jobs: st.jobs.iter().map(|(&id, s)| (id, s.clone())).collect(),
                }
            }
            ClientRequest::Shutdown => {
                shared.shutdown.store(true, Ordering::SeqCst);
                let _ = respond(&mut stream, &ClientResponse::ShuttingDown, shared.max_frame);
                // wake the accept loop so it observes the flag.
                let _ = TcpStream::connect(shared.addr);
                return;
            }
        };
        if !respond(&mut stream, &resp, shared.max_frame) {
            return;
        }
    }
}

fn respond(stream: &mut TcpStream, resp: &ClientResponse, max_frame: usize) -> bool {
    wire::write_frame(stream, &resp.encode(), max_frame).is_ok()
}

/// Run one submitted job start to finish, maintaining the registry state
/// around it. Never panics the connection thread: every failure becomes a
/// [`ClientResponse::Error`] and a `failed:` registry state.
fn submit(
    shared: &Shared,
    algorithm: &str,
    k: usize,
    seed: u64,
    machines: usize,
    spec: &OracleSpec,
) -> ClientResponse {
    let id = {
        let mut st = lock_state(shared);
        let id = st.next_job;
        st.next_job += 1;
        st.jobs.insert(id, "running".into());
        id
    };
    match run_job(shared, id, algorithm, k, seed, machines, spec) {
        Ok(record) => {
            {
                let mut st = lock_state(shared);
                st.jobs.insert(id, "done".into());
                st.jobs_completed += 1;
            }
            eprintln!(
                "serve: job {id} done alg={algorithm} k={k} seed={seed} value={:.4}",
                record.value
            );
            ClientResponse::JobResult {
                id,
                selection: record.selection.clone(),
                value: record.value,
                record_json: record.to_json().to_string_compact(),
            }
        }
        Err(e) => {
            lock_state(shared).jobs.insert(id, format!("failed: {e}"));
            eprintln!("serve: job {id} failed alg={algorithm} k={k} seed={seed}: {e}");
            ClientResponse::Error { message: format!("job {id} failed: {e}") }
        }
    }
}

fn run_job(
    shared: &Shared,
    id: u64,
    algorithm: &str,
    k: usize,
    seed: u64,
    machines: usize,
    spec: &OracleSpec,
) -> Result<ExperimentRecord> {
    let alg = build_algorithm(algorithm)?;
    let oracle = cached_oracle(shared, spec)?;
    let inst = Instance::new(format!("serve-job-{id}"), oracle).with_spec(spec.clone());
    let mut cfg = shared.cfg.clone();
    cfg.seed = seed;
    cfg.machines = if machines == 0 { None } else { Some(machines) };
    cfg.oracle_spec = Some(spec.clone());
    if let BackendKind::Process { workers, .. } = cfg.backend_kind() {
        let pool = ensure_pool(shared, &inst, k, &cfg)?;
        if cfg.elastic {
            // pool size tracks job load: with more in-flight jobs than
            // workers, grow (bounded at 2N — round-granularity interleaving
            // caps the useful parallelism) and let the next rebalance shed
            // machines onto the new workers.
            let running =
                lock_state(shared).jobs.values().filter(|s| s.as_str() == "running").count();
            if running > workers {
                if let Ok(mut p) = pool.lock() {
                    p.grow_to(running.min(workers.saturating_mul(2)));
                }
            }
        }
        cfg.shared_pool = Some(PoolLease { pool: Arc::clone(&pool), job: id });
        let out = run_experiment(&inst, alg.as_ref(), k, &cfg);
        if let Ok(mut p) = pool.lock() {
            p.detach_job(id);
        }
        out
    } else {
        // in-process backends: no pool to share — run standalone. This is
        // also the fully in-process test path.
        run_experiment(&inst, alg.as_ref(), k, &cfg)
    }
}

/// Spawn the warm pool if this is the first process-backend job,
/// otherwise hand back the existing one. The pool's spawn dataset (and
/// therefore its arena layout) is the first job's deterministic
/// partition, computed exactly as [`crate::mapreduce::MrCluster::new`]
/// computes it — later jobs with the same `(spec, k, seed, machines)`
/// re-derive the identical dataset and attach arena-elided.
fn ensure_pool(
    shared: &Shared,
    inst: &Instance,
    k: usize,
    cfg: &ClusterConfig,
) -> Result<Arc<Mutex<ProcessPool>>> {
    let _spawning = shared.spawn_lock.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(pool) = &lock_state(shared).pool {
        return Ok(Arc::clone(pool));
    }
    let BackendKind::Process { workers, transport } = cfg.backend_kind() else {
        return Err(Error::Config("warm pool requires a process backend".into()));
    };
    let n = inst.n;
    if k == 0 || k > n {
        return Err(Error::InvalidK { k, n });
    }
    let spec = cfg
        .oracle_spec
        .clone()
        .ok_or_else(|| Error::Config("warm pool requires an oracle spec".into()))?;
    let m = cfg.machines.unwrap_or_else(|| default_machines(n, k));
    let p = sample_probability(n, k, cfg.sample_factor);
    let Partitioned { shards, sample } =
        partition_and_sample(n, m, p, derive_seed(cfg.seed, 0xA16_0003));
    let opts = PoolOptions {
        workers,
        transport,
        timeout: Duration::from_millis(cfg.worker_timeout_ms.max(1)),
        connect_timeout: Duration::from_millis(cfg.effective_connect_timeout_ms().max(1)),
        max_frame: cfg.max_frame_bytes,
        exe: cfg.worker_exe.clone(),
        env: cfg.worker_env.clone(),
        recovery: cfg.recovery,
        elastic: cfg.elastic,
    };
    let pool = Arc::new(Mutex::new(ProcessPool::spawn(&spec, &shards, &sample, &opts)?));
    let mut st = lock_state(shared);
    st.workers_spawned += workers as u64;
    st.pool = Some(Arc::clone(&pool));
    Ok(pool)
}

/// Build (or fetch from the bounded MRU cache) the oracle for a spec.
/// Cached by encoded spec bytes, so two jobs over the same dataset pay
/// oracle construction once.
fn cached_oracle(shared: &Shared, spec: &OracleSpec) -> Result<Arc<dyn Oracle>> {
    let key = {
        let mut enc = Enc::new();
        spec.encode(&mut enc);
        enc.buf
    };
    {
        let mut st = lock_state(shared);
        if let Some(pos) = st.oracle_cache.iter().position(|(k, _)| *k == key) {
            let entry = st.oracle_cache.remove(pos);
            let oracle = Arc::clone(&entry.1);
            st.oracle_cache.insert(0, entry);
            return Ok(oracle);
        }
    }
    // build outside the state lock: generators can be expensive.
    let oracle = spec.build()?;
    let mut st = lock_state(shared);
    st.oracle_cache.insert(0, (key, Arc::clone(&oracle)));
    st.oracle_cache.truncate(ORACLE_CACHE_CAP);
    Ok(oracle)
}

/// The serving algorithm registry: `combined[:eps]` (default ε = 0.1,
/// the paper's headline Theorem 8 algorithm), `randgreedi`, `greedy`,
/// `dash[:eps]` (default ε = 0.1, the low-adaptivity threshold sweep).
fn build_algorithm(name: &str) -> Result<Box<dyn MrAlgorithm>> {
    let (kind, param) = match name.split_once(':') {
        Some((k, p)) => (k, Some(p)),
        None => (name, None),
    };
    let eps = |default: f64| -> Result<f64> {
        let Some(p) = param else { return Ok(default) };
        match p.parse::<f64>() {
            Ok(e) if e > 0.0 && e < 1.0 => Ok(e),
            _ => Err(Error::Config(format!(
                "bad algorithm parameter {p:?} in {name:?} (need 0 < eps < 1)"
            ))),
        }
    };
    Ok(match kind {
        "combined" => Box::new(CombinedTwoRound::new(eps(0.1)?)),
        "randgreedi" => Box::new(RandGreeDi::default()),
        "greedy" => Box::new(GreedyAlg),
        "dash" => Box::new(Dash::new(eps(0.1)?)),
        other => {
            return Err(Error::Config(format!(
                "unknown serve algorithm {other:?} \
                 (expected combined[:eps], randgreedi, greedy, or dash[:eps])"
            )))
        }
    })
}

/// Client side: send one request frame to `addr` and read the single
/// response frame (`mrsub submit` and the tests drive the daemon through
/// this).
pub fn request(addr: &str, req: &ClientRequest, max_frame: usize) -> Result<ClientResponse> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Error::Config(format!("cannot connect to {addr}: {e}")))?;
    wire::write_frame(&mut stream, &req.encode(), max_frame).map_err(wire_err)?;
    let (payload, _) = wire::read_frame(&mut stream, max_frame).map_err(wire_err)?;
    ClientResponse::decode(&payload).map_err(wire_err)
}

fn wire_err(e: WireError) -> Error {
    Error::Runtime(format!("serve wire error: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn spec() -> OracleSpec {
        OracleSpec::Coverage { n: 120, universe: 60, avg_degree: 6, weighted: false, seed: 7 }
    }

    fn serial_cfg() -> ClusterConfig {
        ClusterConfig { parallel: false, ..ClusterConfig::default() }
    }

    fn start_serial() -> Daemon {
        Daemon::start(ServeOptions { bind: "127.0.0.1:0".into(), cfg: serial_cfg() }).unwrap()
    }

    fn submit_req(algorithm: &str, k: usize, seed: u64) -> ClientRequest {
        ClientRequest::SubmitJob {
            algorithm: algorithm.into(),
            k,
            seed,
            machines: 0,
            spec: spec(),
        }
    }

    #[test]
    fn served_job_is_bit_identical_to_standalone() {
        let daemon = start_serial();
        let addr = daemon.addr().to_string();
        let resp =
            request(&addr, &submit_req("combined", 8, 42), wire::DEFAULT_MAX_FRAME).unwrap();
        let ClientResponse::JobResult { id, selection, value, record_json } = resp else {
            panic!("expected JobResult, got {resp:?}");
        };
        assert_eq!(id, 1);

        let oracle = spec().build().unwrap();
        let inst = Instance::new("standalone".into(), oracle).with_spec(spec());
        let mut cfg = serial_cfg();
        cfg.seed = 42;
        let rec = run_experiment(&inst, &CombinedTwoRound::new(0.1), 8, &cfg).unwrap();
        assert_eq!(selection, rec.selection, "served selection must match standalone");
        assert_eq!(value, rec.value);

        // the record round-trips through the crate's own JSON layer and
        // carries the selection.
        let parsed = Json::parse(&record_json).unwrap();
        assert!(parsed.get("selection").is_some(), "record JSON must carry the selection");
        assert_eq!(daemon.stats().jobs_completed, 1);
    }

    #[test]
    fn status_and_listing_track_jobs() {
        let daemon = start_serial();
        let addr = daemon.addr().to_string();
        let resp =
            request(&addr, &submit_req("greedy", 5, 9), wire::DEFAULT_MAX_FRAME).unwrap();
        assert!(matches!(resp, ClientResponse::JobResult { id: 1, .. }));
        let status = request(
            &addr,
            &ClientRequest::JobStatus { id: 1 },
            wire::DEFAULT_MAX_FRAME,
        )
        .unwrap();
        assert!(
            matches!(&status, ClientResponse::Status { id: 1, state } if state == "done"),
            "unexpected status: {status:?}"
        );
        let jobs = request(&addr, &ClientRequest::ListJobs, wire::DEFAULT_MAX_FRAME).unwrap();
        let ClientResponse::Jobs { jobs } = jobs else { panic!("expected Jobs") };
        assert_eq!(jobs, vec![(1, "done".to_string())]);
    }

    #[test]
    fn unknown_algorithm_is_a_structured_error() {
        let daemon = start_serial();
        let addr = daemon.addr().to_string();
        let resp =
            request(&addr, &submit_req("simulated-annealing", 5, 9), wire::DEFAULT_MAX_FRAME)
                .unwrap();
        let ClientResponse::Error { message } = resp else {
            panic!("expected Error, got {resp:?}");
        };
        assert!(message.contains("unknown serve algorithm"), "got: {message}");
        // the failure is recorded, not dropped.
        let status = request(
            &addr,
            &ClientRequest::JobStatus { id: 1 },
            wire::DEFAULT_MAX_FRAME,
        )
        .unwrap();
        assert!(
            matches!(&status, ClientResponse::Status { state, .. } if state.starts_with("failed:")),
            "unexpected status: {status:?}"
        );
        assert_eq!(daemon.stats().jobs_completed, 0);
    }

    #[test]
    fn shutdown_acks_and_daemon_drains() {
        let daemon = start_serial();
        let addr = daemon.addr().to_string();
        let resp = request(&addr, &ClientRequest::Shutdown, wire::DEFAULT_MAX_FRAME).unwrap();
        assert!(matches!(resp, ClientResponse::ShuttingDown));
        daemon.wait(); // must return, not hang.
    }

    #[test]
    fn oracle_cache_is_bounded_and_reuses_entries() {
        let daemon = start_serial();
        let addr = daemon.addr().to_string();
        for seed in 0..3 {
            let req = ClientRequest::SubmitJob {
                algorithm: "greedy".into(),
                k: 4,
                seed: 1,
                machines: 0,
                spec: OracleSpec::Coverage {
                    n: 80,
                    universe: 40,
                    avg_degree: 5,
                    weighted: false,
                    seed,
                },
            };
            let resp = request(&addr, &req, wire::DEFAULT_MAX_FRAME).unwrap();
            assert!(matches!(resp, ClientResponse::JobResult { .. }));
        }
        // same spec as the last job: served from the MRU cache (observable
        // only as a completed job here; the cache bound is the invariant).
        let resp = request(
            &addr,
            &ClientRequest::SubmitJob {
                algorithm: "greedy".into(),
                k: 4,
                seed: 1,
                machines: 0,
                spec: OracleSpec::Coverage {
                    n: 80,
                    universe: 40,
                    avg_degree: 5,
                    weighted: false,
                    seed: 2,
                },
            },
            wire::DEFAULT_MAX_FRAME,
        )
        .unwrap();
        assert!(matches!(resp, ClientResponse::JobResult { .. }));
        assert_eq!(daemon.stats().jobs_completed, 4);
    }
}
