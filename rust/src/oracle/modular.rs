//! Modular (additive) oracle: `f(S) = Σ_{e ∈ S} w_e`, `w_e >= 0`.
//!
//! The degenerate boundary of the submodular family — marginals never
//! shrink. Greedy and the paper's thresholding algorithms are both *exact*
//! here (they pick the top-k weights), which makes this family a sharp
//! correctness probe: any measured ratio < 1 − ε on a modular instance is a
//! bug, not an approximation artifact.

use std::sync::Arc;

use super::{Oracle, OracleState, Selection};
use crate::core::ElementId;

/// Additive instance defined by non-negative element weights.
#[derive(Debug)]
pub struct ModularOracle {
    weights: Arc<Vec<f64>>,
}

impl ModularOracle {
    /// Build from element weights (must be non-negative for monotonicity).
    pub fn new(weights: Vec<f64>) -> Self {
        debug_assert!(weights.iter().all(|&w| w >= 0.0));
        ModularOracle { weights: Arc::new(weights) }
    }

    /// Exact optimum for cardinality k: sum of the k largest weights.
    pub fn exact_opt(&self, k: usize) -> f64 {
        let mut w: Vec<f64> = self.weights.as_ref().clone();
        w.sort_by(|a, b| b.partial_cmp(a).unwrap());
        w.iter().take(k).sum()
    }
}

impl Oracle for ModularOracle {
    fn ground_size(&self) -> usize {
        self.weights.len()
    }

    fn state(&self) -> Box<dyn OracleState> {
        Box::new(ModularState {
            weights: Arc::clone(&self.weights),
            sel: Selection::new(self.weights.len()),
            value: 0.0,
        })
    }
}

#[derive(Debug, Clone)]
struct ModularState {
    weights: Arc<Vec<f64>>,
    sel: Selection,
    value: f64,
}

impl OracleState for ModularState {
    fn value(&self) -> f64 {
        self.value
    }

    fn marginal(&self, e: ElementId) -> f64 {
        if self.sel.contains(e) {
            0.0
        } else {
            self.weights[e as usize]
        }
    }

    /// Block path: a straight gather from the weight vector.
    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(es.len(), out.len());
        for (o, &e) in out.iter_mut().zip(es) {
            *o = if self.sel.contains(e) { 0.0 } else { self.weights[e as usize] };
        }
    }

    fn reset(&mut self) {
        self.sel.clear();
        self.value = 0.0;
    }

    fn insert(&mut self, e: ElementId) {
        if self.sel.insert(e) {
            self.value += self.weights[e as usize];
        }
    }

    fn selected(&self) -> &[ElementId] {
        self.sel.order()
    }

    fn clone_state(&self) -> Box<dyn OracleState> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::axioms::check_axioms;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    #[test]
    fn values_and_opt() {
        let o = ModularOracle::new(vec![3.0, 1.0, 2.0, 5.0]);
        assert_eq!(o.value(&[0, 2]), 5.0);
        assert_eq!(o.exact_opt(2), 8.0);
        assert_eq!(o.exact_opt(10), 11.0);
        let mut st = o.state();
        st.insert(3);
        st.insert(3); // duplicate no-op
        assert_eq!(st.value(), 5.0);
    }

    #[test]
    fn prop_modular_axioms() {
        forall(0x30D, 25, |g| {
            let seed = g.u64_in(300);
            let n = g.usize_in(4, 40);
            let mut rng = Rng::seed_from_u64(seed);
            let w: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(0.0, 10.0)).collect();
            let o = ModularOracle::new(w);
            check_axioms(&o, seed ^ 0x77, 6);
        });
    }
}
