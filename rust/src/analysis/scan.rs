//! Line/token-level Rust source scanner for the lint engine.
//!
//! This is deliberately not a parser. The lints need exactly three views
//! of a source file: (a) per-line **code** with comments removed and
//! string/char-literal *contents* blanked, so token searches can never
//! false-positive inside either; (b) per-line **comment** text, so allow
//! pragmas (`// LINT-ALLOW: …`, `// SAFETY: …`) can be read back out; and
//! (c) the whole file **stripped** of comments but with literals intact,
//! which is what the wire-fingerprint span extraction hashes. One
//! hand-rolled state machine produces all three in a single pass.
//!
//! The lexical subset it understands — line comments, nested block
//! comments, escape-aware string/char literals, raw strings, byte
//! strings, and the lifetime-tick vs char-literal distinction — is
//! exactly the subset the scanned sources use.
//! `python/tools/wire_fingerprint.py` mirrors the same rules so the
//! blessed fingerprint can be bootstrapped without a Rust toolchain;
//! keep the two in lock-step.

/// One scanned source line (1-indexed by position in [`Scanned::lines`]).
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Code with comments removed and literal contents blanked (the
    /// delimiters remain, so token boundaries survive).
    pub code: String,
    /// Concatenated comment text on the line (`//`, `///`, `/* … */`).
    pub comment: String,
}

/// Scanner output: per-line views plus the whole-file stripped text.
#[derive(Debug, Default)]
pub struct Scanned {
    /// Per-line code/comment split.
    pub lines: Vec<Line>,
    /// The whole file with comments removed but literal contents kept —
    /// the input to fingerprint span extraction.
    pub stripped: String,
    /// Per-line flag: inside a `#[cfg(test)] mod` span (same index space
    /// as [`Scanned::lines`]).
    pub in_test: Vec<bool>,
}

/// Scan `src` in one pass (see the module docs for the three views).
pub fn scan(src: &str) -> Scanned {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut i = 0usize;
    let mut out = Scanned::default();
    let mut cur = Line::default();
    while i < n {
        let c = chars[i];
        if c == '\n' {
            out.stripped.push('\n');
            out.lines.push(std::mem::take(&mut cur));
            i += 1;
        } else if c == '/' && chars.get(i + 1) == Some(&'/') {
            i += 2;
            while i < n && chars[i] != '\n' {
                cur.comment.push(chars[i]);
                i += 1;
            }
        } else if c == '/' && chars.get(i + 1) == Some(&'*') {
            i = consume_block_comment(&chars, i + 2, &mut out, &mut cur);
        } else if c == '"' {
            i = consume_string(&chars, i, &mut out, &mut cur);
        } else if c == 'r' && !prev_is_ident(&chars, i) && raw_string_hashes(&chars, i).is_some() {
            i = consume_raw_string(&chars, i, &mut out, &mut cur);
        } else if c == '\'' {
            if tick_is_lifetime(&chars, i) {
                out.stripped.push(c);
                cur.code.push(c);
                i += 1;
            } else {
                i = consume_char_literal(&chars, i, &mut out, &mut cur);
            }
        } else {
            out.stripped.push(c);
            cur.code.push(c);
            i += 1;
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        out.lines.push(cur);
    }
    out.in_test = mark_test_lines(&out.lines);
    out
}

/// `'` starts a lifetime (not a char literal) when followed by an
/// identifier char that is *not* itself closed by a `'` one char later
/// (so `'a>` is a lifetime but `'a'` — and `'_'` — are char literals).
fn tick_is_lifetime(chars: &[char], i: usize) -> bool {
    match chars.get(i + 1) {
        Some(&c) if c.is_alphabetic() || c == '_' => chars.get(i + 2) != Some(&'\''),
        _ => false,
    }
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// Number of `#`s in a raw-string opener `r#*"` at `i`, or `None` if the
/// `r` does not open a raw string.
fn raw_string_hashes(chars: &[char], i: usize) -> Option<usize> {
    let mut j = i + 1;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then(|| j - (i + 1))
}

/// From just past `/*`, consume a (nested) block comment; returns the
/// index past the closing `*/`. Comment text lands in the per-line view.
fn consume_block_comment(chars: &[char], mut i: usize, out: &mut Scanned, cur: &mut Line) -> usize {
    let mut depth = 1usize;
    while i < chars.len() && depth > 0 {
        if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
            depth += 1;
            i += 2;
        } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
            depth -= 1;
            i += 2;
        } else {
            if chars[i] == '\n' {
                out.stripped.push('\n');
                out.lines.push(std::mem::take(cur));
            } else {
                cur.comment.push(chars[i]);
            }
            i += 1;
        }
    }
    i
}

/// From an opening `"`, consume an escape-aware string literal; contents
/// go to `stripped` only (the code view keeps just the delimiters).
fn consume_string(chars: &[char], mut i: usize, out: &mut Scanned, cur: &mut Line) -> usize {
    out.stripped.push('"');
    cur.code.push('"');
    i += 1;
    while i < chars.len() {
        let c = chars[i];
        if c == '\\' && i + 1 < chars.len() {
            out.stripped.push(c);
            out.stripped.push(chars[i + 1]);
            i += 2;
        } else if c == '"' {
            out.stripped.push('"');
            cur.code.push('"');
            return i + 1;
        } else if c == '\n' {
            out.stripped.push('\n');
            out.lines.push(std::mem::take(cur));
            i += 1;
        } else {
            out.stripped.push(c);
            i += 1;
        }
    }
    i
}

/// From the `r` of `r#*"…"#*`, consume a raw string literal (delimiters to
/// both views, contents to `stripped` only).
fn consume_raw_string(chars: &[char], i: usize, out: &mut Scanned, cur: &mut Line) -> usize {
    let hashes = raw_string_hashes(chars, i).unwrap_or(0);
    let opener: String = chars[i..=i + hashes + 1].iter().collect();
    out.stripped.push_str(&opener);
    cur.code.push_str(&opener);
    let mut j = i + hashes + 2;
    while j < chars.len() {
        if chars[j] == '"' && chars[j + 1..].iter().take(hashes).all(|&h| h == '#') {
            let closer: String = chars[j..=j + hashes].iter().collect();
            out.stripped.push_str(&closer);
            cur.code.push_str(&closer);
            return j + hashes + 1;
        }
        if chars[j] == '\n' {
            out.stripped.push('\n');
            out.lines.push(std::mem::take(cur));
        } else {
            out.stripped.push(chars[j]);
        }
        j += 1;
    }
    j
}

/// From an opening `'`, consume a char literal (delimiters to both views,
/// contents to `stripped` only).
fn consume_char_literal(chars: &[char], mut i: usize, out: &mut Scanned, cur: &mut Line) -> usize {
    out.stripped.push('\'');
    cur.code.push('\'');
    i += 1;
    while i < chars.len() {
        let c = chars[i];
        if c == '\\' && i + 1 < chars.len() {
            out.stripped.push(c);
            out.stripped.push(chars[i + 1]);
            i += 2;
        } else if c == '\'' {
            out.stripped.push('\'');
            cur.code.push('\'');
            return i + 1;
        } else if c == '\n' {
            // unterminated literal: bail rather than eat the file.
            return i;
        } else {
            out.stripped.push(c);
            i += 1;
        }
    }
    i
}

/// Mark the line spans of `#[cfg(test)] mod …` (and `#[cfg(all(test, …))]`
/// variants) via brace depth, so test-only code can be exempted from
/// production-scoped lints.
fn mark_test_lines(lines: &[Line]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut depth: i32 = 0;
    let mut pending_cfg = false;
    let mut span_depth: Option<i32> = None;
    for (idx, line) in lines.iter().enumerate() {
        let code = line.code.as_str();
        if span_depth.is_none() && code.contains("#[cfg(") && code.contains("test") {
            pending_cfg = true;
        }
        if pending_cfg && has_token(code, "mod") {
            span_depth = Some(depth);
            pending_cfg = false;
        } else if pending_cfg
            && (has_token(code, "fn") || has_token(code, "struct") || has_token(code, "impl"))
        {
            // the cfg attribute applied to a non-mod item; stop waiting.
            pending_cfg = false;
        }
        if span_depth.is_some() {
            in_test[idx] = true;
        }
        for ch in code.chars() {
            match ch {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if span_depth.is_some_and(|d| depth <= d) {
                        span_depth = None;
                    }
                }
                _ => {}
            }
        }
    }
    in_test
}

/// Identifier-boundary token search over a code view (`tok` must be
/// ASCII). `HashMap` matches `HashMap::new` but not `MyHashMapLike`.
pub fn has_token(code: &str, tok: &str) -> bool {
    count_token(code, tok) > 0
}

/// Count identifier-boundary occurrences of `tok` in a code view.
pub fn count_token(code: &str, tok: &str) -> usize {
    let mut count = 0usize;
    let mut at = 0usize;
    while let Some(pos) = code[at..].find(tok) {
        let i = at + pos;
        let end = i + tok.len();
        let before_ok = !code[..i].ends_with(|c: char| c.is_alphanumeric() || c == '_');
        let after_ok = !code[end..].starts_with(|c: char| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            count += 1;
        }
        at = end;
    }
    count
}

/// Find `anchor` in stripped text at an identifier boundary and return
/// the item span it starts: through the matching close brace of the first
/// top-level `{`, or through the first top-level `;` for brace-less
/// items. Literals are skipped, so braces inside them never miscount.
/// Mirrored by `python/tools/wire_fingerprint.py`.
pub fn extract_item<'a>(stripped: &'a str, anchor: &str) -> Option<&'a str> {
    let start = find_anchor(stripped, anchor)?;
    let rest = &stripped[start..];
    let bytes = rest.as_bytes();
    let mut depth: i32 = 0;
    // `[u8; 4]` and `(a; b)`-style positions must not terminate the item:
    // `;` only ends a brace-less item outside every bracket/paren too.
    let mut nest: i32 = 0;
    let mut i = 0usize;
    while i < bytes.len() {
        match bytes[i] {
            b'"' => {
                i = skip_string_bytes(bytes, i);
                continue;
            }
            b'r' if !byte_prev_is_ident(bytes, i) => {
                if let Some(h) = byte_raw_hashes(bytes, i) {
                    i = skip_raw_string_bytes(bytes, i, h);
                    continue;
                }
            }
            b'\'' => {
                if !byte_tick_is_lifetime(bytes, i) {
                    i = skip_char_bytes(bytes, i);
                    continue;
                }
            }
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&rest[..=i]);
                }
            }
            b'[' | b'(' => nest += 1,
            b']' | b')' => nest -= 1,
            b';' if depth == 0 && nest == 0 => return Some(&rest[..=i]),
            _ => {}
        }
        i += 1;
    }
    None
}

fn find_anchor(stripped: &str, anchor: &str) -> Option<usize> {
    for (pos, _) in stripped.match_indices(anchor) {
        let end = pos + anchor.len();
        let before_ok = !stripped[..pos].ends_with(|c: char| c.is_alphanumeric() || c == '_');
        let after_ok = !stripped[end..].starts_with(|c: char| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return Some(pos);
        }
    }
    None
}

fn byte_prev_is_ident(bytes: &[u8], i: usize) -> bool {
    i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_')
}

fn byte_raw_hashes(bytes: &[u8], i: usize) -> Option<usize> {
    let mut j = i + 1;
    while bytes.get(j) == Some(&b'#') {
        j += 1;
    }
    (bytes.get(j) == Some(&b'"')).then(|| j - (i + 1))
}

fn byte_tick_is_lifetime(bytes: &[u8], i: usize) -> bool {
    match bytes.get(i + 1) {
        Some(&c) if c.is_ascii_alphabetic() || c == b'_' => bytes.get(i + 2) != Some(&b'\''),
        _ => false,
    }
}

fn skip_string_bytes(bytes: &[u8], mut i: usize) -> usize {
    i += 1;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

fn skip_raw_string_bytes(bytes: &[u8], i: usize, hashes: usize) -> usize {
    let mut j = i + hashes + 2;
    while j < bytes.len() {
        if bytes[j] == b'"' && bytes[j + 1..].iter().take(hashes).all(|&h| h == b'#') {
            return j + hashes + 1;
        }
        j += 1;
    }
    j
}

fn skip_char_bytes(bytes: &[u8], mut i: usize) -> usize {
    i += 1;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => i += 2,
            b'\'' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_split_from_code() {
        let s = scan("let x = 1; // trailing note\n/* block */ let y = 2;\n");
        assert_eq!(s.lines[0].code, "let x = 1; ");
        assert_eq!(s.lines[0].comment, " trailing note");
        assert_eq!(s.lines[1].code, " let y = 2;");
        assert_eq!(s.lines[1].comment, " block ");
        assert!(!s.stripped.contains("note"));
        assert!(s.stripped.contains("let y = 2;"));
    }

    #[test]
    fn nested_block_comments_and_doc_comments() {
        let s = scan("/* outer /* inner */ still out */ code();\n/// SAFETY: doc\n");
        assert_eq!(s.lines[0].code, " code();");
        assert!(s.lines[0].comment.contains("inner"));
        assert!(s.lines[1].comment.contains("SAFETY: doc"));
        assert_eq!(s.lines[1].code, "");
    }

    #[test]
    fn string_contents_blanked_in_code_kept_in_stripped() {
        let s = scan("let u = \"// not a comment { HashMap }\";\n");
        assert_eq!(s.lines[0].code, "let u = \"\";");
        assert!(!has_token(&s.lines[0].code, "HashMap"));
        assert!(s.stripped.contains("not a comment"));
        assert!(s.lines[0].comment.is_empty());
    }

    #[test]
    fn escaped_quotes_and_multiline_strings() {
        let s = scan("let a = \"he said \\\"hi\\\"\";\nlet b = \"line1\nline2\"; done();\n");
        assert_eq!(s.lines[0].code, "let a = \"\";");
        assert_eq!(s.lines[1].code, "let b = \"");
        assert_eq!(s.lines[2].code, "\"; done();");
    }

    #[test]
    fn raw_and_byte_strings() {
        let s = scan("let r = r#\"raw \"quoted\" {brace}\"#; let b = b\"bytes\";\n");
        assert_eq!(s.lines[0].code, "let r = r#\"\"#; let b = b\"\";");
        assert!(s.stripped.contains("raw \"quoted\" {brace}"));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let s = scan("fn f<'a>(x: &'a str) { let c = 'x'; let u = '_'; let e = '\\''; }\n");
        let code = &s.lines[0].code;
        assert!(code.contains("<'a>"), "{code}");
        assert!(code.contains("&'a str"), "{code}");
        assert!(code.contains("let c = '';"), "{code}");
        assert!(code.contains("let u = '';"), "{code}");
        assert!(code.contains("let e = '';"), "{code}");
    }

    #[test]
    fn cfg_test_mod_spans_marked() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let s = scan(src);
        assert_eq!(s.in_test, vec![false, false, true, true, true, false]);
        let gated = "#[cfg(all(test, target_os = \"linux\"))]\nmod t {\n    x();\n}\n";
        let s = scan(gated);
        assert_eq!(s.in_test, vec![false, true, true, true]);
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("use std::collections::HashMap;", "HashMap"));
        assert!(has_token("HashMap::new()", "HashMap"));
        assert!(!has_token("MyHashMapLike", "HashMap"));
        assert!(!has_token("random_instance()", "random"));
        assert!(!has_token("unsafe_op_in_unsafe_fn", "unsafe"));
        assert_eq!(count_token("unsafe { unsafe_fn() }; unsafe {}", "unsafe"), 2);
    }

    #[test]
    fn extract_item_spans() {
        let text = "pub const N: usize = 4 + 2;\npub enum E {\n  A { s: String },\n  B,\n}\nfn x() {}";
        assert_eq!(extract_item(text, "pub const N"), Some("pub const N: usize = 4 + 2;"));
        // `;` inside brackets must not terminate the item early.
        let magic = "pub const M: [u8; 4] = *b\"MRSB\";\nnext();";
        assert_eq!(extract_item(magic, "pub const M"), Some("pub const M: [u8; 4] = *b\"MRSB\";"));
        let e = extract_item(text, "pub enum E").unwrap();
        assert!(e.starts_with("pub enum E {") && e.ends_with('}'));
        assert!(e.contains("B,"));
        assert!(!e.contains("fn x"));
        assert_eq!(extract_item(text, "pub enum EX"), None);
    }

    #[test]
    fn extract_item_skips_literal_braces() {
        let text = "pub fn f() { let s = \"}{\"; let c = '}'; done() }";
        let span = extract_item(text, "pub fn f").unwrap();
        assert!(span.ends_with("done() }"), "{span}");
    }
}
