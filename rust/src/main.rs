//! `mrsub` — launcher for the MapReduce-submodular reproduction.
//!
//! ```text
//! mrsub run --config cfg.toml      one configured experiment (+ JSON report)
//! mrsub demo [--k K] [--n N] [--seed S]
//!                                  all paper algorithms + baselines, one table
//! mrsub sweep-t [--t-max T] [--k K] [--seed S]
//!                                  ratio vs #thresholds (E2 series)
//! mrsub adversarial [--t-max T] [--k K]
//!                                  Theorem-4 tightness (E3 series)
//! mrsub engine-check [--artifacts DIR]
//!                                  PJRT artifacts + HLO-oracle cross-check
//! ```
//!
//! (Arg parsing is hand-rolled — this workspace builds offline without clap;
//! see the note in Cargo.toml.)

use anyhow::{bail, Context, Result};

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::multi_round::MultiRound;
use mrsub::algorithms::mz_coreset::MzCoreset;
use mrsub::algorithms::randgreedi::RandGreeDi;
use mrsub::algorithms::sample_prune::SamplePrune;
use mrsub::algorithms::stochastic::StochasticGreedy;
use mrsub::algorithms::two_round::TwoRoundKnownOpt;
use mrsub::algorithms::MrAlgorithm;
use mrsub::config::{GreedyAlg, RunConfig};
use mrsub::coordinator::{render_table, run_experiment, write_json};
use mrsub::core::threshold_bound;
use mrsub::mapreduce::ClusterConfig;
use mrsub::workload::adversarial::AdversarialGen;
use mrsub::workload::planted::PlantedCoverageGen;
use mrsub::workload::WorkloadGen;

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(rest: &[String]) -> Result<Args> {
        let mut flags = std::collections::HashMap::new();
        let mut it = rest.iter();
        while let Some(flag) = it.next() {
            let key = flag
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {flag:?}"))?;
            let value = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key.replace('-', "_"), value.clone());
        }
        Ok(Args { flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("invalid value {v:?} for --{key}")),
        }
    }

    fn get_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }
}

const USAGE: &str = "usage: mrsub <run|demo|sweep-t|adversarial|engine-check> [--flag value]...
  run           --config <file.toml>
  demo          [--k 20] [--n 20000] [--seed 7]
  sweep-t       [--t-max 6] [--k 20] [--seed 7]
  adversarial   [--t-max 5] [--k 60]
  engine-check  [--artifacts <dir>]";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprintln!("{USAGE}");
        bail!("missing subcommand");
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(args.get_str("config").context("run needs --config")?),
        "demo" => cmd_demo(args.get("k", 20)?, args.get("n", 20_000)?, args.get("seed", 7)?),
        "sweep-t" => cmd_sweep_t(args.get("t_max", 6)?, args.get("k", 20)?, args.get("seed", 7)?),
        "adversarial" => cmd_adversarial(args.get("t_max", 5)?, args.get("k", 60)?),
        "engine-check" => cmd_engine_check(args.get_str("artifacts")),
        other => {
            eprintln!("{USAGE}");
            bail!("unknown subcommand {other:?}")
        }
    }
}

fn cmd_run(path: &str) -> Result<()> {
    let cfg = RunConfig::load(path)?;
    let inst = cfg.instance.build(cfg.seed)?;
    let alg = cfg.algorithm.build(&inst, cfg.k);
    let mut cluster_cfg = cfg.cluster.clone();
    cluster_cfg.seed = cfg.seed;
    let rec = run_experiment(&inst, alg.as_ref(), cfg.k, &cluster_cfg)?;
    println!("{}", render_table("run", std::slice::from_ref(&rec)));
    if let Some(out) = cfg.output {
        write_json(&out, &[rec])?;
        println!("report written to {out}");
    }
    Ok(())
}

fn cmd_demo(k: usize, n: usize, seed: u64) -> Result<()> {
    let inst = PlantedCoverageGen::dense(k, n / 2, n).generate(seed);
    let opt = inst.known_opt.unwrap();
    let cfg = ClusterConfig { seed, ..ClusterConfig::default() };
    let algs: Vec<Box<dyn MrAlgorithm>> = vec![
        Box::new(GreedyAlg),
        Box::new(TwoRoundKnownOpt::new(opt)),
        Box::new(CombinedTwoRound::new(0.1)),
        Box::new(MultiRound::known(3, opt)),
        Box::new(MultiRound::guessing(3, 0.2)),
        Box::new(RandGreeDi),
        Box::new(MzCoreset),
        Box::new(SamplePrune::new(0.2)),
        Box::new(StochasticGreedy::new(0.1)),
    ];
    let mut records = Vec::new();
    for alg in &algs {
        records.push(run_experiment(&inst, alg.as_ref(), k, &cfg)?);
    }
    println!("{}", render_table(&format!("demo: {} (OPT = {opt})", inst.name), &records));
    Ok(())
}

fn cmd_sweep_t(t_max: usize, k: usize, seed: u64) -> Result<()> {
    let inst = PlantedCoverageGen::dense(k, 4000, 8000).generate(seed);
    let opt = inst.known_opt.unwrap();
    let cfg = ClusterConfig { seed, ..ClusterConfig::default() };
    println!("\n== E2: ratio vs t (bound 1-(1-1/(t+1))^t -> 1-1/e) ==");
    println!("{:>3} {:>8} {:>10} {:>10} {:>8}", "t", "rounds", "ratio", "bound", "ok");
    for t in 1..=t_max {
        let rec = run_experiment(&inst, &MultiRound::known(t, opt), k, &cfg)?;
        let bound = threshold_bound(t);
        println!(
            "{:>3} {:>8} {:>10.4} {:>10.4} {:>8}",
            t,
            rec.rounds,
            rec.ratio,
            bound,
            if rec.ratio >= bound - 1e-9 { "yes" } else { "NO" }
        );
    }
    Ok(())
}

fn cmd_adversarial(t_max: usize, k: usize) -> Result<()> {
    println!("\n== E3: Theorem 4 tightness (measured ratio vs cap) ==");
    println!("{:>3} {:>10} {:>10} {:>10}", "t", "ratio", "cap", "slack");
    for t in 1..=t_max {
        let inst = AdversarialGen::new(t, k).generate(0);
        let opt = inst.known_opt.unwrap();
        let cfg = ClusterConfig { seed: 1, ..ClusterConfig::default() };
        let rec = run_experiment(&inst, &MultiRound::known(t, opt), k, &cfg)?;
        let cap = threshold_bound(t);
        println!("{:>3} {:>10.4} {:>10.4} {:>10.4}", t, rec.ratio, cap, cap - rec.ratio);
    }
    Ok(())
}

fn cmd_engine_check(artifacts: Option<&str>) -> Result<()> {
    use mrsub::oracle::hlo::HloFacilityOracle;
    use mrsub::oracle::Oracle;
    use mrsub::runtime::{default_artifact_dir, MarginalsEngine};
    use mrsub::workload::facility::FacilityGen;
    use std::sync::Arc;

    let dir = artifacts
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    println!("loading artifacts from {}", dir.display());
    let engine = Arc::new(MarginalsEngine::load(&dir)?);
    println!("engine tiles: B={} D={}", engine.tile_b(), engine.tile_d());

    let (n, d, sim) = FacilityGen::new(1000, 512).build_matrix(3);
    let hlo = HloFacilityOracle::new(n, d, sim, Arc::clone(&engine));
    let mut st_h = hlo.state();
    let mut st_n = hlo.native().state();
    for e in [3u32, 700, 512] {
        st_h.insert(e);
        st_n.insert(e);
    }
    let es: Vec<u32> = (0..n as u32).step_by(7).collect();
    let mut out_h = vec![0.0; es.len()];
    let mut out_n = vec![0.0; es.len()];
    st_h.marginals(&es, &mut out_h);
    st_n.marginals(&es, &mut out_n);
    let max_err =
        out_h.iter().zip(&out_n).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
    println!("batch of {}: max |hlo - native| = {max_err:.3e}", es.len());
    println!("PJRT executions: {}", engine.executions());
    anyhow::ensure!(max_err < 1e-3, "HLO oracle disagrees with native oracle");
    println!("engine-check OK");
    Ok(())
}
