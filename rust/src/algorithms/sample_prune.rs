//! Sample&Prune — adapted from Kumar, Moseley, Vassilvitskii & Vattani
//! (TOPC 2015), the MapReduce greedy the paper cites as its inspiration.
//!
//! Descending-threshold schedule with τ falling by (1−ε) per step, O(log(k/ε)/ε)
//! rounds in the worst case (vs the paper's *constant* 2): in each round
//! every machine prunes its shard to the elements still above τ w.r.t. the
//! broadcast partial solution; if the surviving mass fits the central
//! machine's √(nk) budget it is shipped whole, otherwise a uniform sample
//! of that budget is shipped; the central machine extends the solution by
//! threshold greedy and broadcasts it back. This reproduces the
//! sample-then-prune structure and round complexity that E6 compares
//! against.

use super::threshold::{merge_sorted, threshold_filter, threshold_greedy};
use super::{finish, AlgResult, MrAlgorithm};
use crate::core::{derive_seed, ElementId, Result, Solution};
use crate::mapreduce::wire::{RoundTask, TaskReply};
use crate::mapreduce::{machine_seed, ClusterConfig, MrCluster};
use crate::oracle::Oracle;
use crate::util::rng::Rng;

/// Kumar et al.-style Sample&Prune threshold greedy.
#[derive(Debug, Clone, Copy)]
pub struct SamplePrune {
    /// Threshold decay per round (τ ← τ·(1−eps)).
    pub eps: f64,
    /// Hard cap on rounds (safety; the schedule terminates well before).
    pub max_rounds: usize,
}

impl SamplePrune {
    /// Default configuration (ε = 0.2).
    pub fn new(eps: f64) -> Self {
        assert!(eps > 0.0 && eps < 1.0);
        SamplePrune { eps, max_rounds: 200 }
    }
}

impl MrAlgorithm for SamplePrune {
    fn name(&self) -> String {
        format!("sample-prune(eps={})", self.eps)
    }

    fn run(&self, oracle: &dyn Oracle, k: usize, cfg: &ClusterConfig) -> Result<AlgResult> {
        let n = oracle.ground_size();
        let mut cluster = MrCluster::new(n, k, cfg)?;
        let budget = ((n as f64 * k as f64).sqrt().ceil() as usize).max(k);

        // Round 1: global max singleton Δ (typed shard round; worker-side
        // on the process backend). The later prune+sample rounds carry
        // per-machine RNG state and stay coordinator-side for now (see
        // ROADMAP).
        let maxes = cluster.shard_round("r1:max-singleton", 0, oracle, &RoundTask::MaxSingleton)?;
        let delta = maxes.iter().map(TaskReply::as_scalar).fold(0.0f64, f64::max);
        if delta <= 0.0 {
            return Ok(AlgResult { solution: Solution::empty(), metrics: cluster.into_metrics() });
        }

        let mut g = oracle.state();
        let mut shards: Vec<Vec<ElementId>> = cluster.shards().to_vec();
        let mut tau = delta;
        let floor = self.eps * delta / k as f64;
        let mut round = 0usize;
        while tau > floor && g.len() < k && round < self.max_rounds {
            round += 1;
            // Worker: permanently prune the shard at the *floor* (safe for
            // every future threshold — marginals only shrink), and ship the
            // elements above the current τ, sampled down to the central
            // budget share if oversized.
            let g_ref = &g;
            let per_share = (budget / shards.len().max(1)).max(1);
            let seed = derive_seed(cluster.seed(), round as u64);
            let shards_in = std::mem::take(&mut shards);
            let outputs: Vec<(Vec<ElementId>, Vec<ElementId>, bool)> = {
                let run = |(i, shard): (usize, &Vec<ElementId>)| {
                    let kept = threshold_filter(g_ref.as_ref(), shard, floor);
                    let eligible = threshold_filter(g_ref.as_ref(), &kept, tau);
                    let fit = eligible.len() <= per_share;
                    let shipped = if fit {
                        eligible
                    } else {
                        let mut rng = Rng::seed_from_u64(machine_seed(seed, round, i));
                        let mut s = eligible;
                        rng.shuffle(&mut s);
                        s.truncate(per_share);
                        s.sort_unstable();
                        s
                    };
                    (kept, shipped, fit)
                };
                shards_in.iter().enumerate().map(run).collect()
            };
            let max_resident =
                shards_in.iter().map(Vec::len).max().unwrap_or(0) + g.len();
            let mut kept_shards = Vec::with_capacity(outputs.len());
            let mut shipped = Vec::with_capacity(outputs.len());
            let mut all_fit = true;
            for (kept, ship, fit) in outputs {
                kept_shards.push(kept);
                shipped.push(ship);
                all_fit &= fit;
            }
            shards = kept_shards;
            let sent: usize = shipped.iter().map(Vec::len).sum();
            cluster.raw_round(&format!("r{}a:prune+sample", round + 1), max_resident, sent, sent, || {})?;

            // Central: extend by threshold greedy at τ; broadcast G.
            let pool = merge_sorted(&shipped);
            let mut progressed = false;
            cluster.raw_round(&format!("r{}b:extend", round + 1), 0, g.len() * shards.len(), pool.len(), || {
                let added = threshold_greedy(g.as_mut(), &pool, tau, k);
                progressed = !added.is_empty();
            })?;
            // decay once the shipped pool covered every eligible element
            // (nothing left at this level) or no progress was possible.
            if all_fit || !progressed {
                tau *= 1.0 - self.eps;
            }
        }

        let solution = finish(oracle, g.selected().to_vec());
        Ok(AlgResult { solution, metrics: cluster.into_metrics() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::lazy_greedy;
    use crate::workload::coverage::CoverageGen;

    fn cfg(seed: u64) -> ClusterConfig {
        ClusterConfig { seed, parallel: false, ..ClusterConfig::default() }
    }

    #[test]
    fn near_greedy_quality_many_rounds() {
        let o = CoverageGen::new(600, 300, 5).build(1);
        let g = lazy_greedy(&o, 12);
        let res = SamplePrune::new(0.2).run(&o, 12, &cfg(2)).unwrap();
        assert!(
            res.solution.value >= (1.0 - 0.25) * g.value * 0.5_f64.max(0.5),
            "sample-prune {} too far below greedy {}",
            res.solution.value,
            g.value
        );
        // The point of E6: it takes (many) more than 2 compute rounds.
        assert!(res.metrics.num_rounds() > 3, "expected a multi-round schedule");
    }

    #[test]
    fn zero_function_terminates() {
        let o = crate::oracle::modular::ModularOracle::new(vec![0.0; 50]);
        let res = SamplePrune::new(0.3).run(&o, 5, &cfg(3)).unwrap();
        assert!(res.solution.is_empty());
    }

    #[test]
    fn respects_k() {
        let o = CoverageGen::new(200, 100, 4).build(4);
        let res = SamplePrune::new(0.25).run(&o, 6, &cfg(5)).unwrap();
        assert!(res.solution.len() <= 6);
    }
}
