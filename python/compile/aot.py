"""AOT lowering: jax (L2, with the Pallas L1 kernel inside) -> HLO text.

HLO *text* (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version behind the published ``xla`` 0.1.6
crate) rejects (``proto.id() <= INT_MAX``). The text parser reassigns ids,
so text round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (all f32, shapes fixed in model.py):
  artifacts/marginals.hlo.txt        batch_marginals  : (B,D), (D,)      -> ((B,),)
  artifacts/update.hlo.txt           select_update    : (D,), (D,)       -> ((D,),)
  artifacts/filter.hlo.txt           filter_threshold : (B,D), (D,), ()  -> ((B,), (B,))

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile target
``make artifacts`` is a no-op when the inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifacts(b: int, d: int) -> dict[str, str]:
    """Lower the three entry points at shapes (b, d); return name -> HLO text."""
    sim = jax.ShapeDtypeStruct((b, d), jnp.float32)
    vec = jax.ShapeDtypeStruct((d,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return {
        "marginals": to_hlo_text(jax.jit(model.batch_marginals).lower(sim, vec)),
        "update": to_hlo_text(jax.jit(model.select_update).lower(vec, vec)),
        "filter": to_hlo_text(jax.jit(model.filter_threshold).lower(sim, vec, scalar)),
    }


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    p.add_argument("--b", type=int, default=model.AOT_B, help="candidate block size")
    p.add_argument("--d", type=int, default=model.AOT_D, help="universe tile size")
    args = p.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    texts = lower_artifacts(args.b, args.d)
    for name, text in texts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars -> {path}")

    # Shape manifest so the Rust runtime can assert it loaded what it expects.
    manifest = {
        "b": args.b,
        "d": args.d,
        "dtype": "f32",
        "artifacts": {name: f"{name}.hlo.txt" for name in texts},
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest -> {mpath}")


if __name__ == "__main__":
    main()
