//! The invariant lint registry: what `mrsub check-invariants` enforces.
//!
//! Each lint is a cheap pass over the scanner's per-line code/comment
//! views ([`crate::analysis::scan`]) — no parsing, no type information —
//! chosen so every rule is enforceable on the seed tree without
//! grandfathering. A finding can be silenced only with a *reasoned*
//! pragma on the offending line or the line directly above:
//!
//! ```text
//! // LINT-ALLOW: <lint-name> <reason>
//! ```
//!
//! (The pre-existing `// ALLOW-IGNORE: <reason>` and `// ALLOW-DEAD:
//! <reason>` pragmas from verify.sh keep working for their two lints.)
//! A pragma without a reason does not count — the reason is the review
//! artifact.

use std::path::Path;

use crate::analysis::scan::{count_token, has_token, Scanned};
use crate::analysis::{fingerprint, Finding};

/// Registry metadata for one lint (rendered in docs and JSON reports).
#[derive(Debug, Clone, Copy)]
pub struct LintInfo {
    /// Stable lint name — the `LINT-ALLOW:` pragma key.
    pub name: &'static str,
    /// What the lint scans, repo-relative.
    pub scope: &'static str,
    /// Why the invariant matters.
    pub rationale: &'static str,
    /// How to silence one finding, when silencing is legitimate.
    pub pragma: &'static str,
}

/// Every lint `mrsub check-invariants` runs, in report order.
pub const LINTS: &[LintInfo] = &[
    LintInfo {
        name: "wire-drift",
        scope: "rust/src/mapreduce/wire.rs + rust/src/oracle/spec.rs",
        rationale: "frame/message/OracleSpec layout changes must move WIRE_VERSION and \
                    re-bless the committed fingerprint together",
        pragma: "none — run `mrsub check-invariants --bless` after bumping WIRE_VERSION",
    },
    LintInfo {
        name: "determinism",
        scope: "rust/src/algorithms/, rust/src/oracle/, rust/src/mapreduce/shard.rs \
                (non-test code)",
        rationale: "selection-critical code must not iterate hash-seeded containers or \
                    consume clocks/OS entropy — bit-identity across backends depends on it",
        pragma: "// LINT-ALLOW: determinism <reason>",
    },
    LintInfo {
        name: "unsafe-safety",
        scope: "rust/src/mapreduce/, rust/src/runtime/, rust/src/util/pool.rs; \
                plus rust/src/lib.rs must deny unsafe_op_in_unsafe_fn",
        rationale: "every unsafe block documents its proof obligation where it stands",
        pragma: "none — write the `// SAFETY:` comment (≤ 3 lines above the block)",
    },
    LintInfo {
        name: "unsafe-budget",
        scope: "rust/src/mapreduce/, rust/src/runtime/, rust/src/util/pool.rs",
        rationale: "unsafe stays confined to the audited files listed in \
                    rust/src/analysis/lints.rs at their audited block counts",
        pragma: "none — grow the per-file budget in UNSAFE_BUDGET consciously",
    },
    LintInfo {
        name: "ignored-test",
        scope: "rust/ + examples/",
        rationale: "an #[ignore]d test is a disabled assertion; disabling one must be a \
                    visible, justified act",
        pragma: "// ALLOW-IGNORE: <reason>  (or // LINT-ALLOW: ignored-test <reason>)",
    },
    LintInfo {
        name: "dead-code",
        scope: "rust/src/",
        rationale: "#[allow(dead_code)] is how stranded code hides through refactors",
        pragma: "// ALLOW-DEAD: <reason>  (or // LINT-ALLOW: dead-code <reason>)",
    },
];

/// Per-file unsafe-block budgets (token occurrences of `unsafe` in code).
/// Files in the unsafe scope but not listed here have a budget of zero.
/// Growing a budget is a reviewed act: the numbers are the audit trail.
const UNSAFE_BUDGET: &[(&str, usize)] = &[
    ("rust/src/mapreduce/arena.rs", 7),
    ("rust/src/util/pool.rs", 8),
    ("rust/src/runtime/mod.rs", 1),
];

/// Hash-order / entropy / clock tokens the determinism lint rejects.
const DETERMINISM_TOKENS: &[(&str, &str)] = &[
    ("HashMap", "hash-seeded iteration order"),
    ("HashSet", "hash-seeded iteration order"),
    ("thread_rng", "OS-entropy RNG"),
    ("random", "un-seeded randomness"),
    ("SystemTime", "wall clock"),
    ("Instant", "monotonic clock"),
];

fn in_determinism_scope(path: &str) -> bool {
    path.starts_with("rust/src/algorithms/")
        || path.starts_with("rust/src/oracle/")
        || path == "rust/src/mapreduce/shard.rs"
}

fn in_unsafe_scope(path: &str) -> bool {
    path.starts_with("rust/src/mapreduce/")
        || path.starts_with("rust/src/runtime/")
        || path == "rust/src/util/pool.rs"
}

/// A `// LINT-ALLOW: <lint> <reason>` pragma (with a nonempty reason) on
/// line `idx` or the line directly above.
fn lint_allowed(scanned: &Scanned, idx: usize, lint: &str) -> bool {
    let lines = &scanned.lines;
    let check = |i: usize| -> bool {
        if let Some(at) = lines[i].comment.find("LINT-ALLOW:") {
            let rest = lines[i].comment[at + "LINT-ALLOW:".len()..].trim_start();
            if let Some(reason) = rest.strip_prefix(lint) {
                // the lint name must end at a word boundary, and the
                // reason must be nonempty: the reason is the artifact.
                return reason.starts_with(char::is_whitespace) && !reason.trim().is_empty();
            }
        }
        false
    };
    check(idx) || (idx > 0 && check(idx - 1))
}

/// The legacy same-line pragmas (`ALLOW-IGNORE:` / `ALLOW-DEAD:`) that
/// verify.sh has always honored; a reason is still required.
fn legacy_allowed(scanned: &Scanned, idx: usize, key: &str) -> bool {
    if let Some(at) = scanned.lines[idx].comment.find(key) {
        return !scanned.lines[idx].comment[at + key.len()..].trim().is_empty();
    }
    false
}

/// A `SAFETY:` comment on line `idx` or within the 3 lines above it.
fn has_safety_comment(scanned: &Scanned, idx: usize) -> bool {
    let lo = idx.saturating_sub(3);
    scanned.lines[lo..=idx].iter().any(|l| l.comment.contains("SAFETY:"))
}

/// Run every per-file lint on one scanned file.
pub(crate) fn lint_file(path: &str, scanned: &Scanned, findings: &mut Vec<Finding>) {
    if in_determinism_scope(path) {
        lint_determinism(path, scanned, findings);
    }
    if in_unsafe_scope(path) {
        lint_unsafe(path, scanned, findings);
    }
    if path == "rust/src/lib.rs" {
        lint_deny_attr(path, scanned, findings);
    }
    lint_pragma_attrs(path, scanned, findings);
}

fn lint_determinism(path: &str, scanned: &Scanned, findings: &mut Vec<Finding>) {
    for (idx, line) in scanned.lines.iter().enumerate() {
        if scanned.in_test[idx] {
            continue;
        }
        for &(tok, why) in DETERMINISM_TOKENS {
            if has_token(&line.code, tok) && !lint_allowed(scanned, idx, "determinism") {
                findings.push(Finding::new(
                    "determinism",
                    path,
                    idx + 1,
                    format!(
                        "`{tok}` ({why}) in selection-critical code; make it \
                         deterministic or justify with `// LINT-ALLOW: determinism <reason>`"
                    ),
                ));
            }
        }
    }
}

fn lint_unsafe(path: &str, scanned: &Scanned, findings: &mut Vec<Finding>) {
    let mut blocks = 0usize;
    for (idx, line) in scanned.lines.iter().enumerate() {
        let here = count_token(&line.code, "unsafe");
        blocks += here;
        if here > 0 && !has_safety_comment(scanned, idx) {
            findings.push(Finding::new(
                "unsafe-safety",
                path,
                idx + 1,
                "`unsafe` without a `// SAFETY:` comment on the same line or the 3 lines \
                 above it"
                    .to_string(),
            ));
        }
    }
    let budget =
        UNSAFE_BUDGET.iter().find(|(p, _)| *p == path).map_or(0, |&(_, n)| n);
    if blocks > budget {
        findings.push(Finding::new(
            "unsafe-budget",
            path,
            1,
            format!(
                "{blocks} `unsafe` occurrence(s) exceed this file's budget of {budget}; \
                 confine unsafe to audited files (grow UNSAFE_BUDGET in \
                 rust/src/analysis/lints.rs only with review)"
            ),
        ));
    }
}

fn lint_deny_attr(path: &str, scanned: &Scanned, findings: &mut Vec<Finding>) {
    let denied = scanned.lines.iter().any(|l| {
        l.code.contains("deny") && l.code.contains("unsafe_op_in_unsafe_fn")
    });
    if !denied {
        findings.push(Finding::new(
            "unsafe-safety",
            path,
            1,
            "crate root must carry `#![deny(unsafe_op_in_unsafe_fn)]` so unsafe fn \
             bodies spell out their unsafe blocks"
                .to_string(),
        ));
    }
}

fn lint_pragma_attrs(path: &str, scanned: &Scanned, findings: &mut Vec<Finding>) {
    for (idx, line) in scanned.lines.iter().enumerate() {
        if line.code.contains("#[ignore")
            && !legacy_allowed(scanned, idx, "ALLOW-IGNORE:")
            && !lint_allowed(scanned, idx, "ignored-test")
        {
            findings.push(Finding::new(
                "ignored-test",
                path,
                idx + 1,
                "#[ignore] without an `// ALLOW-IGNORE: <reason>` justification".to_string(),
            ));
        }
        if path.starts_with("rust/src/")
            && line.code.contains("#[allow(dead_code")
            && !legacy_allowed(scanned, idx, "ALLOW-DEAD:")
            && !lint_allowed(scanned, idx, "dead-code")
        {
            findings.push(Finding::new(
                "dead-code",
                path,
                idx + 1,
                "#[allow(dead_code)] without an `// ALLOW-DEAD: <reason>` justification"
                    .to_string(),
            ));
        }
    }
}

/// The wire-drift lint: fingerprint the tree and compare against the
/// committed bless. Runs at tree level (it needs two files + the blessed
/// file), so it lives outside [`lint_file`].
pub(crate) fn lint_wire_drift(root: &Path, findings: &mut Vec<Finding>) {
    let wire_rs = "rust/src/mapreduce/wire.rs";
    let mut fail = |msg: String| {
        findings.push(Finding::new("wire-drift", wire_rs, 1, msg));
    };
    let fp = match fingerprint::tree_fingerprint(root) {
        Ok(fp) => fp,
        Err(e) => return fail(e.to_string()),
    };
    let version = match fingerprint::tree_wire_version(root) {
        Ok(v) => v,
        Err(e) => return fail(e.to_string()),
    };
    let blessed = match fingerprint::read_blessed(root) {
        Ok(b) => b,
        Err(e) => return fail(e.to_string()),
    };
    match (fp == blessed.fingerprint, version == blessed.version) {
        (true, true) => {}
        (false, true) => fail(format!(
            "wire definitions drifted (fingerprint {fp:#018x} != blessed \
             {:#018x}) without a WIRE_VERSION bump; bump it in {wire_rs}, then \
             `mrsub check-invariants --bless`",
            blessed.fingerprint
        )),
        (false, false) => fail(format!(
            "wire definitions drifted and WIRE_VERSION moved ({} -> {version}); \
             re-record with `mrsub check-invariants --bless`",
            blessed.version
        )),
        (true, false) => fail(format!(
            "WIRE_VERSION moved ({} -> {version}) but the wire definitions did not; \
             re-bless (or revert the bump)",
            blessed.version
        )),
    }
}
