//! Schema guard for the `mrsub bench` JSON report.
//!
//! The report used to have no version field, so consumers (plot scripts,
//! dashboards) could break silently when a key was renamed. Now:
//!
//! 1. every report carries `"schema_version"` =
//!    [`mrsub::coordinator::BENCH_SCHEMA_VERSION`];
//! 2. the committed fixture `tests/fixtures/bench_report_v4.json` is a
//!    frozen example of the current schema, and this test deserializes it
//!    and checks every required key — so a schema change forces a
//!    deliberate fixture + version bump in the same commit;
//! 3. `./verify.sh ci` generates a *fresh* smoke report and re-runs the
//!    same validation on it via the `MRSUB_BENCH_REPORT` env var — so the
//!    live report writer cannot drift from the committed schema either.

use mrsub::coordinator::BENCH_SCHEMA_VERSION;
use mrsub::util::json::Json;

const FIXTURE: &str = include_str!("fixtures/bench_report_v4.json");

fn require<'a>(obj: &'a Json, key: &str) -> &'a Json {
    obj.get(key).unwrap_or_else(|| panic!("report missing required key {key:?}"))
}

/// The one schema definition, applied to the committed fixture and to any
/// freshly generated report (`MRSUB_BENCH_REPORT`).
fn validate_report(report: &Json) {
    let version = require(report, "schema_version")
        .as_usize()
        .expect("schema_version must be an integer");
    assert_eq!(
        version as u32, BENCH_SCHEMA_VERSION,
        "report schema_version diverged from BENCH_SCHEMA_VERSION — \
         bump both (and the fixture contents) together"
    );
    for key in ["schema_version", "n", "k", "seed"] {
        assert!(require(report, key).as_f64().is_some(), "{key} must be numeric");
    }

    let Json::Arr(hotpath) = require(report, "hotpath") else {
        panic!("hotpath must be an array");
    };
    assert!(!hotpath.is_empty());
    for row in hotpath {
        for key in ["scalar_elems_per_s", "batched_elems_per_s", "speedup", "n"] {
            assert!(require(row, key).as_f64().is_some(), "hotpath.{key}");
        }
        for key in ["family", "instance"] {
            assert!(require(row, key).as_str().is_some(), "hotpath.{key}");
        }
    }

    let Json::Arr(cluster) = require(report, "cluster") else {
        panic!("cluster must be an array");
    };
    assert!(!cluster.is_empty());
    let mut saw_process_row = false;
    let mut saw_dash = false;
    let mut saw_matroid = false;
    for row in cluster {
        assert!(require(row, "family").as_str().is_some(), "cluster.family");
        let algorithm = require(row, "algorithm").as_str().expect("cluster.algorithm");
        assert!(!algorithm.is_empty(), "cluster.algorithm must be nonempty");
        saw_dash |= algorithm.starts_with("dash");
        saw_matroid |= algorithm.ends_with("-matroid");
        for key in [
            "n",
            "k",
            "wall_ms",
            "value",
            "oracle_calls",
            "batched_oracle_calls",
            "oracle_batches",
            "ipc_bytes_out",
            "ipc_bytes_in",
            "mapped_bytes",
            "rounds",
        ] {
            assert!(require(row, key).as_f64().is_some(), "cluster.{key}");
        }
        let backend = require(row, "backend").as_str().expect("cluster.backend");
        // backend labels in reports must round-trip into configs.
        assert!(
            mrsub::mapreduce::backend::BackendKind::parse(backend, 1).is_ok(),
            "backend label {backend:?} must be parseable"
        );
        if backend.starts_with("process:") {
            saw_process_row = true;
            let out = require(row, "ipc_bytes_out").as_f64().unwrap();
            let inb = require(row, "ipc_bytes_in").as_f64().unwrap();
            assert!(
                out > 0.0 && inb > 0.0,
                "process rows must carry nonzero IPC byte counts"
            );
        }
    }
    assert!(
        saw_process_row,
        "report must exemplify a process-backend row (IPC overhead vs rayon)"
    );
    assert!(
        saw_dash,
        "report must exemplify a dash row (bench smoke covers the low-adaptivity axis)"
    );
    assert!(
        saw_matroid,
        "report must exemplify a matroid-constrained row (bench smoke covers the \
         constraint axis)"
    );
}

#[test]
fn committed_fixture_matches_current_schema() {
    // version pin + required fields in one pass (validate_report leads
    // with the schema_version assertion).
    validate_report(&Json::parse(FIXTURE).expect("fixture must be valid JSON"));
}

/// CI hook: `./verify.sh ci` runs a small `mrsub bench` smoke and points
/// `MRSUB_BENCH_REPORT` at the fresh report; the live writer must satisfy
/// the exact schema the committed fixture freezes. A no-op (trivially
/// green) when the env var is absent, so plain `cargo test` runs don't
/// need a pre-built report.
#[test]
fn env_supplied_report_matches_committed_schema() {
    let Some(path) = std::env::var_os("MRSUB_BENCH_REPORT") else {
        return;
    };
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {path:?}: {e}"));
    let report = Json::parse(&text).expect("generated bench report must be valid JSON");
    validate_report(&report);
}
