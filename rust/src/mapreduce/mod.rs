//! MRC cluster simulator.
//!
//! Simulates the MapReduce model of Karloff–Suri–Vassilvitskii as the paper
//! instantiates it (§1.1): `m = √(n/k)` worker machines of memory
//! `O(√(nk))` elements, one central machine with memory relaxed by a
//! `Õ(·)` factor, and computation proceeding in synchronous rounds. The
//! simulator is the *measurement instrument* for the reproduction: it
//! executes each round (optionally in parallel across simulated machines
//! via rayon), accounts resident memory and communication in elements — the
//! unit of the paper's analysis — and can hard-enforce the budgets.

pub mod partition;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::core::{derive_seed, ElementId, Error, Result};
use crate::metrics::{MrMetrics, RoundStat};
use crate::util::pool::parallel_map;
use partition::{default_machines, partition_and_sample, sample_probability, Partitioned};

/// Cluster construction parameters.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of worker machines; `None` = the paper's `⌈√(n/k)⌉`.
    pub machines: Option<usize>,
    /// Sampling constant `c` in `p = c·√(k/n)` (paper: 4).
    pub sample_factor: f64,
    /// Master seed; every random choice in the run derives from it.
    pub seed: u64,
    /// If true, exceeding an MRC memory budget aborts with
    /// [`Error::MemoryBudget`] instead of just being recorded.
    pub enforce_memory: bool,
    /// Execute worker machines in parallel with rayon.
    pub parallel: bool,
    /// Shared oracle-call counter (from [`crate::oracle::CountingOracle`]);
    /// wired by the coordinator so every algorithm's cluster reports
    /// per-round oracle calls. Not part of any serialized config.
    pub call_counter: Option<Arc<AtomicU64>>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            machines: None,
            sample_factor: 4.0,
            seed: 0xC0FFEE,
            enforce_memory: false,
            parallel: true,
            call_counter: None,
        }
    }
}

/// Per-machine view handed to a worker-round closure.
#[derive(Debug, Clone, Copy)]
pub struct MachineCtx<'a> {
    /// Machine index `0..m`.
    pub id: usize,
    /// This machine's shard `V_i` (current, i.e. after any persistent filtering).
    pub shard: &'a [ElementId],
    /// The broadcast sample `S`.
    pub sample: &'a [ElementId],
}

/// Message-size accounting: how many *elements* (the MRC memory unit) a
/// round output occupies on the wire.
pub trait CommSize {
    /// Size in elements.
    fn comm_size(&self) -> usize;
}

impl CommSize for ElementId {
    fn comm_size(&self) -> usize {
        1
    }
}

impl CommSize for f64 {
    fn comm_size(&self) -> usize {
        1
    }
}

impl CommSize for () {
    fn comm_size(&self) -> usize {
        0
    }
}

impl<T: CommSize> CommSize for Vec<T> {
    fn comm_size(&self) -> usize {
        self.iter().map(CommSize::comm_size).sum()
    }
}

impl<T: CommSize> CommSize for Option<T> {
    fn comm_size(&self) -> usize {
        self.as_ref().map_or(0, CommSize::comm_size)
    }
}

impl<A: CommSize, B: CommSize> CommSize for (A, B) {
    fn comm_size(&self) -> usize {
        self.0.comm_size() + self.1.comm_size()
    }
}

impl<A: CommSize, B: CommSize, C: CommSize> CommSize for (A, B, C) {
    fn comm_size(&self) -> usize {
        self.0.comm_size() + self.1.comm_size() + self.2.comm_size()
    }
}

/// The simulated cluster: shards, broadcast sample, and metering state.
pub struct MrCluster {
    cfg: ClusterConfig,
    shards: Vec<Vec<ElementId>>,
    sample: Vec<ElementId>,
    metrics: MrMetrics,
    /// Optional shared oracle-call counter (from [`crate::oracle::CountingOracle`]);
    /// snapshotted around each round so `RoundStat::oracle_calls` is per-round.
    call_counter: Option<Arc<AtomicU64>>,
}

impl MrCluster {
    /// Build a cluster over ground set `0..n` with cardinality parameter `k`
    /// and run Algorithm 3 (PartitionAndSample). The initial distribution
    /// (shards + broadcast sample) is recorded as round `"r0:partition"`.
    pub fn new(n: usize, k: usize, cfg: &ClusterConfig) -> Result<Self> {
        if k == 0 || k > n {
            return Err(Error::InvalidK { k, n });
        }
        let m = cfg.machines.unwrap_or_else(|| default_machines(n, k));
        let p = sample_probability(n, k, cfg.sample_factor);
        let Partitioned { shards, sample } =
            partition_and_sample(n, m, p, derive_seed(cfg.seed, 0xA16_0003));

        let sample_size = sample.len();
        let max_shard = shards.iter().map(Vec::len).max().unwrap_or(0);
        let mut cluster = MrCluster {
            cfg: cfg.clone(),
            shards,
            sample,
            metrics: MrMetrics { rounds: Vec::new(), n, k, machines: m, sample_size },
            call_counter: cfg.call_counter.clone(),
        };
        // Round 0: the input distribution itself. Every machine receives its
        // shard plus the broadcast sample; the central machine receives S.
        cluster.record_round(
            "r0:partition+sample",
            m,
            max_shard + sample_size,
            n + (m + 1) * sample_size,
            sample_size,
            0,
            std::time::Duration::ZERO,
        )?;
        Ok(cluster)
    }

    /// Attach a shared oracle-call counter for per-round accounting.
    pub fn with_call_counter(mut self, counter: Arc<AtomicU64>) -> Self {
        self.call_counter = Some(counter);
        self
    }

    /// Number of worker machines.
    pub fn machines(&self) -> usize {
        self.shards.len()
    }

    /// The broadcast sample `S` (ascending ids).
    pub fn sample(&self) -> &[ElementId] {
        &self.sample
    }

    /// Current shard of machine `i`.
    pub fn shard(&self, i: usize) -> &[ElementId] {
        &self.shards[i]
    }

    /// All current shards.
    pub fn shards(&self) -> &[Vec<ElementId>] {
        &self.shards
    }

    /// Replace the shards (persistent filtering between rounds, Alg 5).
    pub fn set_shards(&mut self, shards: Vec<Vec<ElementId>>) {
        assert_eq!(shards.len(), self.shards.len(), "machine count is fixed");
        self.shards = shards;
    }

    /// Metrics collected so far.
    pub fn metrics(&self) -> &MrMetrics {
        &self.metrics
    }

    /// Consume the cluster, returning its metrics.
    pub fn into_metrics(self) -> MrMetrics {
        self.metrics
    }

    /// Cluster seed (for algorithms needing extra derived randomness).
    pub fn seed(&self) -> u64 {
        self.cfg.seed
    }

    fn calls_snapshot(&self) -> u64 {
        self.call_counter.as_ref().map_or(0, |c| c.load(Ordering::Relaxed))
    }

    /// Execute one synchronous worker round: `f` runs on every machine
    /// (rayon-parallel if configured); outputs are shipped to the central
    /// machine. `extra_resident` accounts broadcast state beyond shard+sample
    /// (e.g. a partial solution `G`, ≤ k elements).
    pub fn worker_round<T, F>(&mut self, name: &str, extra_resident: usize, f: F) -> Result<Vec<T>>
    where
        T: CommSize + Send,
        F: Fn(MachineCtx<'_>) -> T + Sync,
    {
        let start = Instant::now();
        let calls0 = self.calls_snapshot();
        let sample = &self.sample;
        let outputs: Vec<T> = parallel_map(&self.shards, self.cfg.parallel, |id, shard| {
            f(MachineCtx { id, shard, sample })
        });
        let max_resident = self
            .shards
            .iter()
            .map(|s| s.len() + self.sample.len() + extra_resident)
            .max()
            .unwrap_or(0);
        let total_sent: usize = outputs.iter().map(CommSize::comm_size).sum();
        let calls = self.calls_snapshot() - calls0;
        self.record_round(
            name,
            self.shards.len(),
            max_resident,
            total_sent,
            total_sent,
            calls,
            start.elapsed(),
        )?;
        Ok(outputs)
    }

    /// Execute a central-machine round. `received` is the number of elements
    /// the central machine holds this round (it is checked against the
    /// relaxed central budget); `f` runs once.
    pub fn central_round<T, F>(&mut self, name: &str, received: usize, f: F) -> Result<T>
    where
        F: FnOnce() -> T,
    {
        let start = Instant::now();
        let calls0 = self.calls_snapshot();
        let out = f();
        let calls = self.calls_snapshot() - calls0;
        self.record_round(name, 0, 0, 0, received, calls, start.elapsed())?;
        Ok(out)
    }

    /// Low-level round for algorithms whose per-machine residency is not
    /// simply `shard + sample` (e.g. multi-guess variants that keep one
    /// filtered shard copy per OPT guess). The closure does the whole
    /// round's work (it may parallelize internally with rayon); the caller
    /// supplies the accounting numbers.
    pub fn raw_round<T, F>(
        &mut self,
        name: &str,
        max_resident: usize,
        total_sent: usize,
        central_recv: usize,
        f: F,
    ) -> Result<T>
    where
        F: FnOnce() -> T,
    {
        let start = Instant::now();
        let calls0 = self.calls_snapshot();
        let out = f();
        let calls = self.calls_snapshot() - calls0;
        let machines = self.shards.len();
        self.record_round(name, machines, max_resident, total_sent, central_recv, calls, start.elapsed())?;
        Ok(out)
    }

    /// Whether worker rounds execute machine closures in parallel.
    pub fn parallel(&self) -> bool {
        self.cfg.parallel
    }

    #[allow(clippy::too_many_arguments)]
    fn record_round(
        &mut self,
        name: &str,
        machines: usize,
        max_resident: usize,
        total_sent: usize,
        central_recv: usize,
        oracle_calls: u64,
        wall: std::time::Duration,
    ) -> Result<()> {
        self.metrics.rounds.push(RoundStat {
            name: name.to_string(),
            machines,
            max_resident,
            total_sent,
            central_recv,
            oracle_calls,
            wall,
        });
        if self.cfg.enforce_memory && name != "r0:partition+sample" {
            let mb = self.metrics.machine_budget();
            if max_resident > mb {
                return Err(Error::MemoryBudget { round: name.into(), used: max_resident, budget: mb });
            }
            let cb = self.metrics.central_budget();
            if central_recv > cb {
                return Err(Error::MemoryBudget { round: name.into(), used: central_recv, budget: cb });
            }
        }
        Ok(())
    }
}

/// Derive a per-machine RNG seed for randomized per-machine logic.
pub fn machine_seed(cluster_seed: u64, round: usize, machine: usize) -> u64 {
    derive_seed(cluster_seed, ((round as u64) << 32) | machine as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(seed: u64) -> ClusterConfig {
        ClusterConfig { seed, parallel: false, ..ClusterConfig::default() }
    }

    #[test]
    fn new_cluster_partitions_and_records_round0() {
        let c = MrCluster::new(1000, 10, &cfg(1)).unwrap();
        assert_eq!(c.machines(), 10);
        assert_eq!(c.metrics().rounds.len(), 1);
        let total: usize = c.shards().iter().map(Vec::len).sum();
        assert_eq!(total, 1000);
        assert_eq!(c.metrics().sample_size, c.sample().len());
    }

    #[test]
    fn invalid_k_rejected() {
        assert!(MrCluster::new(10, 0, &cfg(1)).is_err());
        assert!(MrCluster::new(10, 11, &cfg(1)).is_err());
    }

    #[test]
    fn worker_round_accounts_communication() {
        let mut c = MrCluster::new(100, 4, &cfg(2)).unwrap();
        let outs = c
            .worker_round("r1:test", 0, |ctx| {
                ctx.shard.iter().take(3).copied().collect::<Vec<_>>()
            })
            .unwrap();
        assert_eq!(outs.len(), c.machines());
        let sent: usize = outs.iter().map(Vec::len).sum();
        let r = &c.metrics().rounds[1];
        assert_eq!(r.total_sent, sent);
        assert_eq!(r.central_recv, sent);
        assert!(r.max_resident >= c.sample().len());
    }

    #[test]
    fn central_round_records_received() {
        let mut c = MrCluster::new(100, 4, &cfg(3)).unwrap();
        let v = c.central_round("r2:central", 37, || 41).unwrap();
        assert_eq!(v, 41);
        assert_eq!(c.metrics().rounds[1].central_recv, 37);
    }

    #[test]
    fn parallel_and_serial_rounds_agree() {
        let mut serial = MrCluster::new(500, 8, &cfg(4)).unwrap();
        let par_cfg = ClusterConfig { parallel: true, ..cfg(4) };
        let mut par = MrCluster::new(500, 8, &par_cfg).unwrap();
        let f = |ctx: MachineCtx<'_>| -> Vec<ElementId> {
            ctx.shard.iter().filter(|&&e| e % 3 == 0).copied().collect()
        };
        let a = serial.worker_round("r", 0, f).unwrap();
        let b = par.worker_round("r", 0, f).unwrap();
        assert_eq!(a, b, "parallel execution must preserve per-machine outputs");
    }

    #[test]
    fn enforce_memory_trips_on_oversend() {
        let mut c = MrCluster::new(100, 2, &ClusterConfig {
            enforce_memory: true,
            parallel: false,
            ..ClusterConfig::default()
        })
        .unwrap();
        // central budget for n=100,k=2 is ~ 8·√200·log2(3) ≈ 179; send way more.
        let err = c.worker_round("r1:blowup", 0, |ctx| {
            let mut v = ctx.shard.to_vec();
            for _ in 0..6 {
                v.extend_from_slice(ctx.shard);
            }
            v
        });
        assert!(err.is_err() || c.metrics().peak_central_recv() < c.metrics().central_budget());
    }

    #[test]
    fn comm_size_impls() {
        assert_eq!(3u32.comm_size(), 1);
        assert_eq!(2.5f64.comm_size(), 1);
        assert_eq!(().comm_size(), 0);
        assert_eq!(vec![1u32, 2, 3].comm_size(), 3);
        assert_eq!((vec![1u32, 2], 1.0f64).comm_size(), 3);
        assert_eq!(Some(vec![1u32]).comm_size(), 1);
        assert_eq!(None::<Vec<ElementId>>.comm_size(), 0);
        assert_eq!(vec![vec![1u32], vec![2, 3]].comm_size(), 3);
    }
}
