//! PJRT-accelerated facility-location oracle (the L3↔L1 bridge).
//!
//! Same objective as [`super::facility::FacilityOracle`], but batched
//! marginal queries are served by the AOT-compiled JAX/Pallas artifact
//! (`artifacts/marginals.hlo.txt`) through [`crate::runtime::MarginalsEngine`].
//! Scalar queries fall back to the native row scan so the oracle is a
//! drop-in [`Oracle`] anywhere.
//!
//! This oracle is *not* a special case in the algorithms: since batched
//! evaluation ([`OracleState::marginals`]) is the primary query interface
//! of every hot loop, the PJRT engine is simply one more backend of that
//! block path — algorithms see identical semantics over the native
//! column-tiled kernel and the device kernel. Gated behind the `xla`
//! feature (the default build is offline-clean).

use std::sync::Arc;

use super::facility::FacilityOracle;
use super::{Oracle, OracleState, Selection};
use crate::core::ElementId;
use crate::runtime::MarginalsEngine;

/// Facility-location oracle whose batch marginals run on the PJRT engine.
pub struct HloFacilityOracle {
    native: FacilityOracle,
    engine: Arc<MarginalsEngine>,
    n: usize,
    d: usize,
    /// Row-major padded similarity matrix (d padded up to the engine tile).
    sim_padded: Arc<Vec<f32>>,
    d_padded: usize,
}

impl HloFacilityOracle {
    /// Wrap a dense facility instance with a PJRT engine. The similarity
    /// matrix is re-padded once so every universe tile is engine-aligned.
    pub fn new(n: usize, d: usize, sim: Vec<f32>, engine: Arc<MarginalsEngine>) -> Self {
        let tile_d = engine.tile_d();
        let d_padded = d.div_ceil(tile_d) * tile_d;
        let mut sim_padded = vec![0.0f32; n * d_padded];
        for i in 0..n {
            sim_padded[i * d_padded..i * d_padded + d].copy_from_slice(&sim[i * d..(i + 1) * d]);
        }
        let native = FacilityOracle::new(n, d, sim);
        HloFacilityOracle { native, engine, n, d, sim_padded: Arc::new(sim_padded), d_padded }
    }

    /// The native (pure-Rust) twin — used by tests to cross-check numerics.
    pub fn native(&self) -> &FacilityOracle {
        &self.native
    }
}

impl Oracle for HloFacilityOracle {
    fn ground_size(&self) -> usize {
        self.n
    }

    fn state(&self) -> Box<dyn OracleState> {
        Box::new(HloFacilityState {
            native: self.native.state(),
            engine: Arc::clone(&self.engine),
            sim_padded: Arc::clone(&self.sim_padded),
            cur_padded: vec![0.0f32; self.d_padded],
            sel: Selection::new(self.n),
            d: self.d,
            d_padded: self.d_padded,
        })
    }
}

struct HloFacilityState {
    /// Native state drives scalar marginals, value, and insertion.
    native: Box<dyn OracleState>,
    engine: Arc<MarginalsEngine>,
    sim_padded: Arc<Vec<f32>>,
    /// Padded coverage vector mirrored from the native state's `cur`.
    cur_padded: Vec<f32>,
    sel: Selection,
    d: usize,
    d_padded: usize,
}

impl OracleState for HloFacilityState {
    fn value(&self) -> f64 {
        self.native.value()
    }

    fn marginal(&self, e: ElementId) -> f64 {
        self.native.marginal(e)
    }

    fn insert(&mut self, e: ElementId) {
        if !self.sel.insert(e) {
            return;
        }
        self.native.insert(e);
        // mirror the coverage update into the padded vector.
        let row = &self.sim_padded[e as usize * self.d_padded..e as usize * self.d_padded + self.d];
        for (c, s) in self.cur_padded[..self.d].iter_mut().zip(row) {
            if *s > *c {
                *c = *s;
            }
        }
    }

    fn selected(&self) -> &[ElementId] {
        self.sel.order()
    }

    fn reset(&mut self) {
        self.native.reset();
        self.cur_padded.fill(0.0);
        self.sel.clear();
    }

    fn clone_state(&self) -> Box<dyn OracleState> {
        Box::new(HloFacilityState {
            native: self.native.clone_state(),
            engine: Arc::clone(&self.engine),
            sim_padded: Arc::clone(&self.sim_padded),
            cur_padded: self.cur_padded.clone(),
            sel: self.sel.clone(),
            d: self.d,
            d_padded: self.d_padded,
        })
    }

    /// The accelerated hot path: one PJRT call per (block × universe tile).
    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(es.len(), out.len());
        if es.is_empty() {
            return;
        }
        let rows = |e: ElementId| {
            &self.sim_padded[e as usize * self.d_padded..(e as usize + 1) * self.d_padded]
        };
        self.engine
            .batch_marginals(es, rows, &self.cur_padded, out)
            .expect("PJRT batch marginal execution failed");
        // members must report 0 regardless of padding artifacts.
        for (o, &e) in out.iter_mut().zip(es) {
            if self.sel.contains(e) {
                *o = 0.0;
            }
        }
    }
}
