//! E7b ("Table 4", hot path) — the marginal-evaluation hot path that
//! dominates every algorithm's wall time, across the three backends:
//!
//! * native Rust row-scan (facility oracle),
//! * the AOT JAX/Pallas kernel through PJRT (HLO engine), and
//! * scalar one-at-a-time marginals (the naive baseline),
//!
//! measured as µs per 256×2048 block and elements/s through
//! ThresholdFilter. Skips the PJRT rows when artifacts are absent.

use std::sync::Arc;

use mrsub::algorithms::threshold::threshold_filter;
use mrsub::oracle::hlo::HloFacilityOracle;
use mrsub::oracle::Oracle;
use mrsub::runtime::{default_artifact_dir, MarginalsEngine};
use mrsub::util::bench::{fmt_dur, time};
use mrsub::workload::facility::FacilityGen;

fn main() {
    let n = 4096;
    let d = 2048;
    println!("== E7b: marginal hot path, facility {n}x{d} (one engine tile) ==\n");
    let (n_, d_, sim) = FacilityGen::clustered(n, d, 16).build_matrix(11);
    let native = FacilityGen::clustered(n, d, 16).build(11);

    let mut st = native.state();
    for e in [0u32, 100, 2000, 4000] {
        st.insert(e);
    }
    let es: Vec<u32> = (0..n as u32).collect();
    let block = &es[..256];

    // scalar loop (naive)
    let t_scalar = time(2, 10, || {
        let mut acc = 0.0;
        for &e in block {
            acc += st.marginal(e);
        }
        acc
    });
    println!("native scalar   256-block: {}", t_scalar.display());

    // native batched
    let mut out = vec![0.0f64; 256];
    let t_batch = time(2, 10, || st.marginals(block, &mut out));
    println!("native batch    256-block: {}", t_batch.display());

    // full filter pass over all n
    let t_filter = time(1, 5, || threshold_filter(st.as_ref(), &es, 1.0));
    println!(
        "native filter   {n} elems: {}   ({:.2e} elems/s)",
        t_filter.display(),
        n as f64 / t_filter.median.as_secs_f64()
    );

    // PJRT engine
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("\n(PJRT rows skipped: no artifacts at {} — run `make artifacts`)", dir.display());
        return;
    }
    let engine = Arc::new(MarginalsEngine::load(&dir).expect("engine"));
    let hlo = HloFacilityOracle::new(n_, d_, sim, Arc::clone(&engine));
    let mut st_h = hlo.state();
    for e in [0u32, 100, 2000, 4000] {
        st_h.insert(e);
    }
    let mut out_h = vec![0.0f64; 256];
    let t_hlo = time(2, 10, || st_h.marginals(block, &mut out_h));
    println!("\npjrt batch      256-block: {}", t_hlo.display());
    let t_hlo_filter = time(1, 5, || threshold_filter(st_h.as_ref(), &es, 1.0));
    println!(
        "pjrt filter     {n} elems: {}   ({:.2e} elems/s)",
        t_hlo_filter.display(),
        n as f64 / t_hlo_filter.median.as_secs_f64()
    );
    println!("pjrt executions: {}", engine.executions());

    // correctness spot check while we're here
    let mut a = vec![0.0; 256];
    let mut b = vec![0.0; 256];
    st.marginals(block, &mut a);
    st_h.marginals(block, &mut b);
    let err = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max);
    println!("max |native - pjrt| on block: {err:.2e}");

    println!("\nblock work: 256×2048 f32 = 2 MiB touched / {} µs (native batch)", t_batch.median.as_micros());
    println!("roofline note: the op is bandwidth-bound (1 FLOP/4B); native ≈ memory");
    println!("speed, PJRT adds per-call literal/launch overhead ({} vs {} per block) that", fmt_dur(t_hlo.median), fmt_dur(t_batch.median));
    println!("amortizes only on multi-block batches — see EXPERIMENTS.md §Perf.");
}
