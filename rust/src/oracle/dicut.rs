//! Directed-cut oracle: `f(S) = Σ_{(u,v) ∈ A : u ∈ S, v ∉ S} w_uv`.
//!
//! The canonical *non-monotone* submodular function (non-negative, and
//! `f(V) = 0` on any loop-free digraph): adding an arc's head to `S`
//! un-cuts the arc, so marginals can be negative. This is the family the
//! Barbosa–Ene–Nguyen–Ward randomized framework (arXiv 1502.02606) and
//! DASH are exercised on. Its axioms are checked by
//! [`crate::oracle::axioms::check_axioms_nonmono`] — the monotone checker
//! would (correctly) reject it.

use std::sync::Arc;

use super::{Oracle, OracleState, Selection};
use crate::core::ElementId;

/// Weighted directed-cut instance over a digraph on vertices `0..n`.
#[derive(Debug)]
pub struct DicutOracle {
    data: Arc<DicutData>,
}

#[derive(Debug)]
struct DicutData {
    n: usize,
    /// CSR offsets per vertex into `out` (arcs leaving the vertex).
    out_offsets: Vec<u32>,
    /// (head, arc id) out-adjacency.
    out: Vec<(u32, u32)>,
    /// CSR offsets per vertex into `inc` (arcs entering the vertex).
    in_offsets: Vec<u32>,
    /// (tail, arc id) in-adjacency.
    inc: Vec<(u32, u32)>,
    /// Arc weights indexed by arc id.
    weights: Vec<f64>,
}

impl DicutOracle {
    /// Build from an arc list `(u, v, w)` over vertices `0..n`. Parallel
    /// arcs each count; self-loops are legal but can never be cut.
    pub fn new(n: usize, arcs: &[(u32, u32, f64)]) -> Self {
        let mut out_deg = vec![0u32; n];
        let mut in_deg = vec![0u32; n];
        for &(u, v, _) in arcs {
            assert!((u as usize) < n && (v as usize) < n, "arc endpoint out of range");
            out_deg[u as usize] += 1;
            in_deg[v as usize] += 1;
        }
        let mut out_offsets = vec![0u32; n + 1];
        let mut in_offsets = vec![0u32; n + 1];
        for i in 0..n {
            out_offsets[i + 1] = out_offsets[i] + out_deg[i];
            in_offsets[i + 1] = in_offsets[i] + in_deg[i];
        }
        let mut out = vec![(0u32, 0u32); arcs.len()];
        let mut inc = vec![(0u32, 0u32); arcs.len()];
        let mut out_cur: Vec<u32> = out_offsets[..n].to_vec();
        let mut in_cur: Vec<u32> = in_offsets[..n].to_vec();
        let mut weights = Vec::with_capacity(arcs.len());
        for (aid, &(u, v, w)) in arcs.iter().enumerate() {
            let aid32 = aid as u32;
            weights.push(w);
            out[out_cur[u as usize] as usize] = (v, aid32);
            out_cur[u as usize] += 1;
            inc[in_cur[v as usize] as usize] = (u, aid32);
            in_cur[v as usize] += 1;
        }
        DicutOracle {
            data: Arc::new(DicutData { n, out_offsets, out, in_offsets, inc, weights }),
        }
    }

    /// Number of arcs.
    pub fn num_arcs(&self) -> usize {
        self.data.weights.len()
    }

    /// Total arc weight (upper bound on OPT).
    pub fn total_weight(&self) -> f64 {
        self.data.weights.iter().sum()
    }
}

impl Oracle for DicutOracle {
    fn ground_size(&self) -> usize {
        self.data.n
    }

    fn state(&self) -> Box<dyn OracleState> {
        Box::new(DicutState {
            data: Arc::clone(&self.data),
            sel: Selection::new(self.data.n),
            value: 0.0,
        })
    }
}

#[derive(Debug, Clone)]
struct DicutState {
    data: Arc<DicutData>,
    sel: Selection,
    value: f64,
}

impl DicutState {
    /// Per-vertex gain kernel shared by the scalar, block, and insert
    /// paths, so all three see bit-identical deltas: newly cut out-arcs
    /// (head outside `S ∪ {e}`) minus un-cut in-arcs (tail inside `S`).
    /// Can be negative — the function is non-monotone.
    #[inline]
    fn gain_of(&self, e: ElementId) -> f64 {
        let d = &*self.data;
        let i = e as usize;
        let mut gain = 0.0;
        let (lo, hi) = (d.out_offsets[i] as usize, d.out_offsets[i + 1] as usize);
        for &(v, aid) in &d.out[lo..hi] {
            if v != e && !self.sel.contains(v) {
                gain += d.weights[aid as usize];
            }
        }
        let (lo, hi) = (d.in_offsets[i] as usize, d.in_offsets[i + 1] as usize);
        for &(u, aid) in &d.inc[lo..hi] {
            if self.sel.contains(u) {
                gain -= d.weights[aid as usize];
            }
        }
        gain
    }
}

impl OracleState for DicutState {
    fn value(&self) -> f64 {
        self.value
    }

    fn marginal(&self, e: ElementId) -> f64 {
        if self.sel.contains(e) {
            return 0.0;
        }
        self.gain_of(e)
    }

    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(es.len(), out.len());
        for (o, &e) in out.iter_mut().zip(es) {
            *o = if self.sel.contains(e) { 0.0 } else { self.gain_of(e) };
        }
    }

    fn reset(&mut self) {
        self.sel.clear();
        self.value = 0.0;
    }

    fn insert(&mut self, e: ElementId) {
        if self.sel.contains(e) {
            return;
        }
        // exact telescoping: the incremental value is the marginal itself.
        let gain = self.gain_of(e);
        self.sel.insert(e);
        self.value += gain;
    }

    fn selected(&self) -> &[ElementId] {
        self.sel.order()
    }

    fn clone_state(&self) -> Box<dyn OracleState> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::axioms::check_axioms_nonmono;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn path() -> DicutOracle {
        // 0 → 1 → 2 with weights 2, 3.
        DicutOracle::new(3, &[(0, 1, 2.0), (1, 2, 3.0)])
    }

    #[test]
    fn values_and_negative_marginals() {
        let o = path();
        assert_eq!(o.value(&[0]), 2.0);
        assert_eq!(o.value(&[1]), 3.0);
        assert_eq!(o.value(&[0, 1]), 3.0, "0→1 un-cut once 1 joins");
        assert_eq!(o.value(&[0, 1, 2]), 0.0, "full set cuts nothing");
        let mut st = o.state();
        st.insert(0);
        assert_eq!(st.marginal(1), 1.0, "+3 (1→2) − 2 (0→1)");
        st.insert(1);
        assert_eq!(st.marginal(2), -3.0, "non-monotone: joining 2 only un-cuts");
        assert_eq!(o.total_weight(), 5.0);
        assert_eq!(o.num_arcs(), 2);
    }

    #[test]
    fn self_loop_never_cut() {
        let o = DicutOracle::new(2, &[(0, 0, 5.0), (0, 1, 1.0)]);
        assert_eq!(o.value(&[0]), 1.0);
        assert_eq!(o.value(&[0, 1]), 0.0);
    }

    #[test]
    fn nonmono_axioms_hold_random_digraph() {
        let mut rng = Rng::seed_from_u64(0xD1C);
        let n = 30u32;
        let arcs: Vec<(u32, u32, f64)> = (0..120)
            .map(|_| {
                (rng.gen_range(0..n as usize) as u32, rng.gen_range(0..n as usize) as u32, {
                    1.0 + rng.gen_range(0..8) as f64 * 0.5
                })
            })
            .collect();
        let o = DicutOracle::new(n as usize, &arcs);
        check_axioms_nonmono(&o, 23, 30);
    }

    #[test]
    fn prop_dicut_axioms() {
        forall(0xD1C2, 20, |g| {
            let seed = g.u64_in(300);
            let n = g.usize_in(6, 30);
            let m = g.usize_in(5, 4 * n);
            let mut rng = Rng::seed_from_u64(seed);
            let arcs: Vec<(u32, u32, f64)> = (0..m)
                .map(|_| {
                    (rng.gen_range(0..n) as u32, rng.gen_range(0..n) as u32, {
                        0.5 + rng.gen_range(0..10) as f64 * 0.25
                    })
                })
                .collect();
            let o = DicutOracle::new(n, &arcs);
            check_axioms_nonmono(&o, seed ^ 0xcafe, 6);
        });
    }
}
