//! Transport demo: run a small instance on the shared-nothing process
//! backend over a Unix-domain socket (`process:2@uds`) and print the
//! per-round IPC byte accounting (referenced from docs/ARCHITECTURE.md).
//!
//! ```text
//! cargo run --release --example remote_workers
//! ```
//!
//! The example binary doubles as its own worker: the process pool
//! re-executes `current_exe()` with a `worker` argv, which this `main`
//! forwards to [`mrsub::mapreduce::process::worker_main`] — exactly what
//! the `mrsub` binary does. For the multi-host flavor of the same flow,
//! run a coordinator with `--backend process:N@tcp:HOST:PORT` and start
//! `mrsub worker --connect HOST:PORT --id I` on the other machines (see
//! README § transports).

use mrsub::algorithms::randgreedi::RandGreeDi;
use mrsub::algorithms::MrAlgorithm;
use mrsub::mapreduce::backend::BackendKind;
use mrsub::mapreduce::transport::Transport;
use mrsub::mapreduce::ClusterConfig;
use mrsub::workload::coverage::CoverageGen;
use mrsub::workload::WorkloadGen;

fn main() {
    // worker re-exec hook: the pool spawns `current_exe() worker …`.
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) == Some("worker") {
        std::process::exit(mrsub::mapreduce::process::worker_main(&args[1..]));
    }

    let inst = CoverageGen::new(4_000, 2_000, 8).generate(7);
    let k = 25;
    let cfg = ClusterConfig {
        seed: 7,
        backend: Some(BackendKind::Process { workers: 2, transport: Transport::Uds }),
        // shared-nothing workers rebuild the oracle from its spec.
        oracle_spec: inst.spec.clone(),
        ..ClusterConfig::default()
    };
    let res = RandGreeDi.run(inst.oracle.as_ref(), k, &cfg).expect("process:2@uds run");

    println!("instance: {} (n = {}, k = {k})", inst.name, inst.n);
    println!("f(S) = {:.3} with |S| = {}", res.solution.value, res.solution.len());
    println!();
    println!("{:<26} {:>13} {:>13}", "round", "ipc-out bytes", "ipc-in bytes");
    for r in &res.metrics.rounds {
        println!("{:<26} {:>13} {:>13}", r.name, r.ipc_bytes_out, r.ipc_bytes_in);
    }
    let (out, inn) = res.metrics.total_ipc_bytes();
    println!("{:<26} {:>13} {:>13}", "total", out, inn);
    assert!(out > 0 && inn > 0, "typed rounds must cross the socket");
}
