//! Edge-coverage ("vertex cover value") oracle on a graph:
//! `f(S) = Σ_{uv ∈ E : u ∈ S or v ∈ S} w_uv`.
//!
//! This is the *monotone* relative of max-cut — the weight of edges touched
//! by the selected vertex set — and is submodular because it is a coverage
//! function over the edge set. It exercises the algorithms on graph-shaped
//! instances (heavy-tailed degrees under Barabási–Albert workloads) where
//! marginals shrink quickly as hubs get picked.

use std::sync::Arc;

use super::{Oracle, OracleState, Selection};
use crate::core::ElementId;

/// Weighted edge-coverage instance over an undirected graph.
#[derive(Debug)]
pub struct CutCoverageOracle {
    data: Arc<CutData>,
}

#[derive(Debug)]
struct CutData {
    n: usize,
    /// CSR offsets per vertex into `adj`.
    offsets: Vec<u32>,
    /// (edge id, weight index is edge id) adjacency: neighbor + edge id.
    adj: Vec<(u32, u32)>,
    /// Edge weights indexed by edge id.
    weights: Vec<f64>,
}

impl CutCoverageOracle {
    /// Build from an edge list `(u, v, w)` over vertices `0..n`.
    /// Self-loops are allowed and count once; parallel edges each count.
    pub fn new(n: usize, edges: &[(u32, u32, f64)]) -> Self {
        let mut deg = vec![0u32; n];
        for &(u, v, _) in edges {
            assert!((u as usize) < n && (v as usize) < n, "edge endpoint out of range");
            deg[u as usize] += 1;
            if u != v {
                deg[v as usize] += 1;
            }
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut adj = vec![(0u32, 0u32); offsets[n] as usize];
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        let mut weights = Vec::with_capacity(edges.len());
        for (eid, &(u, v, w)) in edges.iter().enumerate() {
            let eid32 = eid as u32;
            weights.push(w);
            adj[cursor[u as usize] as usize] = (v, eid32);
            cursor[u as usize] += 1;
            if u != v {
                adj[cursor[v as usize] as usize] = (u, eid32);
                cursor[v as usize] += 1;
            }
        }
        CutCoverageOracle { data: Arc::new(CutData { n, offsets, adj, weights }) }
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.data.weights.len()
    }

    /// Total edge weight (upper bound on OPT).
    pub fn total_weight(&self) -> f64 {
        self.data.weights.iter().sum()
    }
}

impl Oracle for CutCoverageOracle {
    fn ground_size(&self) -> usize {
        self.data.n
    }

    fn state(&self) -> Box<dyn OracleState> {
        Box::new(CutState {
            data: Arc::clone(&self.data),
            covered: vec![false; self.data.weights.len()],
            sel: Selection::new(self.data.n),
            value: 0.0,
        })
    }
}

#[derive(Debug, Clone)]
struct CutState {
    data: Arc<CutData>,
    covered: Vec<bool>,
    sel: Selection,
    value: f64,
}

impl CutState {
    /// Per-vertex gain kernel shared by the scalar and block paths, so
    /// both return bit-identical values.
    #[inline]
    fn gain_of(&self, v: ElementId) -> f64 {
        let d = &*self.data;
        let (lo, hi) = (d.offsets[v as usize] as usize, d.offsets[v as usize + 1] as usize);
        let mut gain = 0.0;
        for &(_, eid) in &d.adj[lo..hi] {
            if !self.covered[eid as usize] {
                gain += d.weights[eid as usize];
            }
        }
        gain
    }
}

impl OracleState for CutState {
    fn value(&self) -> f64 {
        self.value
    }

    fn marginal(&self, e: ElementId) -> f64 {
        if self.sel.contains(e) {
            return 0.0;
        }
        self.gain_of(e)
    }

    /// Block path: one adjacency sweep per block with member tests and
    /// data pointers hoisted out of the virtual call.
    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(es.len(), out.len());
        for (o, &e) in out.iter_mut().zip(es) {
            *o = if self.sel.contains(e) { 0.0 } else { self.gain_of(e) };
        }
    }

    fn reset(&mut self) {
        let data = Arc::clone(&self.data);
        for &v in self.sel.order() {
            let (lo, hi) =
                (data.offsets[v as usize] as usize, data.offsets[v as usize + 1] as usize);
            for &(_, eid) in &data.adj[lo..hi] {
                self.covered[eid as usize] = false;
            }
        }
        self.sel.clear();
        self.value = 0.0;
    }

    fn insert(&mut self, e: ElementId) {
        if !self.sel.insert(e) {
            return;
        }
        let data = Arc::clone(&self.data);
        let (lo, hi) = (data.offsets[e as usize] as usize, data.offsets[e as usize + 1] as usize);
        for &(_, eid) in &data.adj[lo..hi] {
            let eid = eid as usize;
            if !self.covered[eid] {
                self.covered[eid] = true;
                self.value += data.weights[eid];
            }
        }
    }

    fn selected(&self) -> &[ElementId] {
        self.sel.order()
    }

    fn clone_state(&self) -> Box<dyn OracleState> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::axioms::check_axioms;
    use crate::util::check::forall;

    fn triangle() -> CutCoverageOracle {
        CutCoverageOracle::new(3, &[(0, 1, 1.0), (1, 2, 2.0), (0, 2, 4.0)])
    }

    #[test]
    fn values() {
        let o = triangle();
        assert_eq!(o.value(&[0]), 5.0); // edges 0-1 and 0-2
        assert_eq!(o.value(&[1]), 3.0);
        assert_eq!(o.value(&[0, 1]), 7.0);
        assert_eq!(o.value(&[0, 1, 2]), 7.0);
        assert_eq!(o.total_weight(), 7.0);
        let mut st = o.state();
        st.insert(0);
        assert_eq!(st.marginal(1), 2.0); // only edge 1-2 uncovered
        assert_eq!(st.marginal(2), 2.0);
    }

    #[test]
    fn self_loop_counts_once() {
        let o = CutCoverageOracle::new(2, &[(0, 0, 3.0), (0, 1, 1.0)]);
        assert_eq!(o.value(&[0]), 4.0);
        assert_eq!(o.value(&[1]), 1.0);
    }

    #[test]
    fn axioms_hold_random_graph() {
        let o = crate::workload::graph::GraphGen::erdos_renyi(40, 0.15).build(9);
        check_axioms(&o, 23, 30);
    }

    #[test]
    fn prop_cut_axioms() {
        forall(0xCC1, 20, |g| {
            let seed = g.u64_in(300);
            let n = g.usize_in(6, 30);
            let p = g.f64_in(0.05, 0.5);
            let o = crate::workload::graph::GraphGen::erdos_renyi(n, p).build(seed);
            check_axioms(&o, seed ^ 0xcafe, 6);
        });
    }
}
