//! Shared-nothing process backend: one OS worker process per group of
//! simulated machines, speaking the [`crate::mapreduce::wire`] protocol
//! over a pluggable byte-stream transport
//! ([`crate::mapreduce::transport`]): stdin/stdout pipes (default), a
//! Unix-domain socket, or TCP.
//!
//! ## Topology
//!
//! [`ProcessPool::spawn`] re-executes the current binary (or an explicit
//! `worker_exe`) with the hidden `mrsub worker` subcommand, one process
//! per worker, and assigns the `m` simulated machines round-robin across
//! the `N` workers of `--backend process:N[@transport]`. On the socket
//! transports the coordinator binds a listener first and workers dial
//! back (`MRSUB_CONNECT`); with an explicit TCP bind address
//! (`process:N@tcp:HOST:PORT`) **no** local workers are spawned — the
//! pool waits for `N` external `mrsub worker --connect HOST:PORT --id I`
//! processes, which is how workers span hosts. Each worker receives —
//! once, at init — the oracle *spec* (rebuilt deterministically on its
//! side; no shared memory), its machines' shards, and the broadcast
//! sample. Worker processes then persist across rounds: Algorithm 5's
//! `t` thresholds pay one spawn, not `t`.
//!
//! ## Handshakes
//!
//! The first frame on every new byte stream — any transport — is
//! [`FromWorker::Hello`], carrying the worker's slot id (socket
//! connections arrive in arbitrary order) and its [`WIRE_VERSION`]; a
//! version mismatch or an unknown slot fails here, before any shard data
//! moves. [`ToWorker::Init`] → [`FromWorker::Ready`] then completes setup
//! exactly as on pipes. Connection establishment is bounded by its own
//! `connect_timeout_ms` (round replies have a separate, compute-sized
//! `worker_timeout_ms`): a worker that never connects (crashed,
//! connection refused, wrong endpoint) degrades into a structured
//! [`Error::Worker`] when the accept deadline expires.
//!
//! ## Zero-copy shard arena (`@uds+arena`)
//!
//! On the `uds+arena` transport the coordinator packs every machine's
//! shard plus the broadcast sample into one read-only memfd region
//! ([`crate::mapreduce::arena`]) *before* spawning workers, and passes
//! the file descriptor over the Unix socket (`SCM_RIGHTS`) the moment
//! each worker connects — before any frame moves. Workers `mmap` the
//! region and resolve shards by global machine id, so `Init` and
//! [`RoundTask::AdoptMachines`] ship O(1) framing instead of re-encoding
//! shard payloads: the elided bytes are metered separately as
//! [`RoundIpcStats::mapped_bytes`]. If the arena cannot be built (no
//! memfd — e.g. a non-Linux host), the pool transparently falls back to
//! the wire path and behaves exactly like plain `@uds`; pipe and TCP
//! transports never use the arena.
//!
//! ## Round protocol
//!
//! A round writes one `Round(task)` frame to every worker (all workers
//! compute concurrently), then joins the replies **in arrival order**
//! (pipelined): [`ProcessPool::round_with`] streams each machine's
//! [`TaskReply`] to the caller the moment it lands, so the coordinator
//! overlaps round `t+1`'s partition/threshold accounting with the slower
//! workers still computing round `t`. Replies also carry the worker-side
//! oracle-call delta, which the coordinator merges into its
//! [`OracleCounters`] so `MrMetrics` sees one coherent count. All frame
//! traffic is metered identically on every transport — the per-round IPC
//! byte counts land in `RoundStat::ipc_bytes_*`.
//!
//! ## Failure surface and elasticity
//!
//! Every failure mode — worker killed mid-round, truncated or corrupted
//! reply frame, oversized frame, handshake version mismatch, refused or
//! dropped connection, worker-side error — is detected structurally
//! (never a panic, never a poisoned coordinator): the pool marks the
//! worker dead, force-closes its stream, and reaps the child (when it
//! spawned one). What happens next is the [`RecoveryPolicy`]:
//!
//! * [`RecoveryPolicy::Fail`] (default): the round surfaces a structured
//!   [`Error::Worker`] and the algorithm's `run` returns `Err`.
//! * [`RecoveryPolicy::Requeue`]: the dead worker's simulated machines
//!   are **re-queued onto surviving workers** — the pool ships each
//!   adopter a [`RoundTask::AdoptMachines`] carrying the orphaned
//!   machines' spawn-time shards, the store-mutating task history to
//!   replay (rebuilding pruned bases and persistent guess shards
//!   deterministically), and the in-flight round task to re-run for just
//!   those machines. The round then completes as if nothing happened,
//!   with selections bit-identical to `Serial` (asserted per transport by
//!   the conformance suite). A bounded budget of worker deaths is
//!   tolerated per pool lifetime; exhausting it — or losing the last
//!   worker — still fails with a structured [`Error::Worker`].
//!
//! Each worker gets a dedicated reader thread *and* writer thread, so the
//! coordinator itself never blocks on a stream — a worker that stops
//! replying *or* stops reading is bounded by `worker_timeout_ms`, never a
//! coordinator hang; connection establishment is bounded separately by
//! `connect_timeout_ms`. Reply shapes are validated against the task
//! ([`wire::reply_matches`]) before use.
//!
//! The `MRSUB_FAULT` environment variable (set by the conformance suite
//! via `worker_env`) injects worker-side faults with the syntax
//! `kind[:nth][@worker]` (see [`FaultSpec`]): `die-mid-round`,
//! `hang-round`, `truncate-frame`, `corrupt-checksum`, `bad-version`,
//! `no-connect`, `die-on-prune`.
//!
//! ## Warm pool, job-keyed state (`mrsub serve`)
//!
//! The serving daemon keeps **one** pool alive across many optimization
//! jobs. Instead of re-spawning workers per job, each job *attaches*:
//! [`ProcessPool::attach_job`] round-robins the job's machines over the
//! surviving workers and ships a job-keyed [`ToWorker::Attach`] (the same
//! [`WorkerInit`] payload `Init` carries, prefixed with the job id);
//! workers hold one independent runtime per job in a map, so concurrent
//! jobs never share stores or caches. [`ProcessPool::round_job`] then runs
//! rounds exactly like [`ProcessPool::round_with`] — same broadcast, same
//! arrival-order join, same adoption-based recovery — against that job's
//! machine assignment, and [`ProcessPool::detach_job`] frees the worker
//! runtimes when the job completes. When an attaching job's dataset is
//! byte-identical to the spawn dataset the arena already holds, the
//! attach elides every shard/sample payload (the warm-pool *arena-cache
//! hit*, metered via [`ProcessPool::arena_attach_stats`]).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::core::{ElementId, Error, Result};
use crate::mapreduce::arena::{self, Arena, ArenaMap};
use crate::mapreduce::shard::{self, GuessStore, ShardData, StateCache};
use crate::mapreduce::transport::{self, LinkControl, Listener, Transport};
use crate::mapreduce::wire::{
    self, FromWorker, RoundTask, TaskReply, ToWorker, WireError, WorkerInit, DEFAULT_MAX_FRAME,
    WIRE_VERSION,
};
use crate::oracle::spec::OracleSpec;
use crate::oracle::{CountingOracle, Oracle, OracleCounters};

/// What the pool does when a worker dies mid-run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// Any worker failure aborts the run with a structured
    /// [`Error::Worker`] — the default, and the pre-elastic behavior.
    #[default]
    Fail,
    /// Re-queue a dead worker's machines onto surviving workers (via
    /// [`RoundTask::AdoptMachines`]), tolerating up to `budget` worker
    /// deaths over the pool's lifetime. Exhausting the budget, or losing
    /// the last worker, still yields a structured [`Error::Worker`].
    Requeue {
        /// Worker deaths tolerated per pool lifetime (≥ 1).
        budget: usize,
    },
}

impl RecoveryPolicy {
    /// Parse a config/CLI value: `"fail"`, `"requeue"` (budget 1), or
    /// `"requeue:R"` with `R ≥ 1`. Unknown strings (including
    /// `"requeue:0"` — a zero budget is spelled `"fail"`) are `None`.
    pub fn parse(s: &str) -> Option<RecoveryPolicy> {
        match s {
            "fail" => Some(RecoveryPolicy::Fail),
            "requeue" => Some(RecoveryPolicy::Requeue { budget: 1 }),
            _ => s
                .strip_prefix("requeue:")
                .and_then(|r| r.trim().parse::<usize>().ok())
                .filter(|&b| b >= 1)
                .map(|budget| RecoveryPolicy::Requeue { budget }),
        }
    }

    /// Display label; round-trips through [`RecoveryPolicy::parse`].
    pub fn label(&self) -> String {
        match self {
            RecoveryPolicy::Fail => "fail".into(),
            RecoveryPolicy::Requeue { budget } => format!("requeue:{budget}"),
        }
    }
}

/// Pool construction knobs (derived from `ClusterConfig` by the cluster).
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Worker processes to spawn (capped at the machine count).
    pub workers: usize,
    /// Coordinator ↔ worker byte-stream transport.
    pub transport: Transport,
    /// Per-reply wait bound: a worker silent for longer mid-round is
    /// declared dead.
    pub timeout: Duration,
    /// Connection-establishment bound (socket accept loop + `Hello`),
    /// split from `timeout` so slow rounds don't force sloppy connect
    /// deadlines.
    pub connect_timeout: Duration,
    /// Hard cap on a single frame's payload.
    pub max_frame: usize,
    /// Worker executable; `None` = `std::env::current_exe()` (the normal
    /// case — coordinator and worker are the same binary). Tests point
    /// this at the built `mrsub` binary.
    pub exe: Option<PathBuf>,
    /// Extra environment for workers (fault injection uses `MRSUB_FAULT`).
    pub env: Vec<(String, String)>,
    /// Worker-death handling: fail fast, or re-queue machines onto
    /// surviving workers within a bounded retry budget.
    pub recovery: RecoveryPolicy,
}

impl Default for PoolOptions {
    fn default() -> Self {
        PoolOptions {
            workers: 1,
            transport: Transport::Pipe,
            timeout: Duration::from_millis(30_000),
            connect_timeout: Duration::from_millis(30_000),
            max_frame: DEFAULT_MAX_FRAME,
            exe: None,
            env: Vec::new(),
            recovery: RecoveryPolicy::Fail,
        }
    }
}

/// Per-round IPC accounting returned by [`ProcessPool::round`].
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundIpcStats {
    /// Frame bytes coordinator → workers this round.
    pub bytes_out: u64,
    /// Frame bytes workers → coordinator this round.
    pub bytes_in: u64,
    /// Worker-side oracle calls `(total, batched, batches)` this round.
    pub calls: (u64, u64, u64),
    /// Worker deaths recovered from this round ([`RecoveryPolicy::Requeue`]).
    pub recoveries: u64,
    /// Frame bytes of [`RoundTask::AdoptMachines`] reshipments this round
    /// (a subset of `bytes_out`).
    pub reshipped_bytes: u64,
    /// Shard/sample payload bytes resolved from the mmap'd arena instead
    /// of shipped as frames this round (4 bytes per elided element id);
    /// always `0` on the wire path. *Not* a subset of `bytes_out` — these
    /// bytes never crossed the stream.
    pub mapped_bytes: u64,
}

/// Frames from a reader thread: `(payload, frame_bytes)` or a wire error.
type FrameResult = std::result::Result<(Vec<u8>, usize), WireError>;

struct WorkerHandle {
    /// The spawned OS process; `None` for external workers that joined
    /// over `mrsub worker --connect` (nothing to reap — dropping the
    /// stream is the only lever).
    child: Option<Child>,
    /// Payloads to the dedicated writer thread (which owns the stream and
    /// does the blocking `write`); `None` once closed (shutdown/failure).
    /// Queueing instead of writing inline keeps the coordinator off the
    /// stream: a worker that stops *reading* cannot wedge the coordinator
    /// — the reply timeout still fires and the worker is declared dead.
    tx: Option<mpsc::Sender<Vec<u8>>>,
    /// Frames from the dedicated reader thread.
    rx: mpsc::Receiver<FrameResult>,
    /// Force-close handle for the underlying stream (no-op for pipes).
    control: LinkControl,
    /// Fires when the writer thread has drained its queue and exited —
    /// a bounded flush handshake (the `Shutdown` frame in particular)
    /// consulted at shutdown before the stream is cut.
    writer_done: mpsc::Receiver<()>,
    /// Simulated machine ids this worker hosts.
    machines: Vec<usize>,
    alive: bool,
}

/// A running pool of shared-nothing worker processes.
pub struct ProcessPool {
    workers: Vec<WorkerHandle>,
    n_machines: usize,
    timeout: Duration,
    max_frame: usize,
    bytes_out: u64,
    bytes_in: u64,
    /// Spawn-time shards, kept coordinator-side as the reship source for
    /// [`RoundTask::AdoptMachines`] (machine-resident *derived* state is
    /// rebuilt by replaying `history`, never reshipped). Empty under
    /// [`RecoveryPolicy::Fail`] — the default policy pays no memory for a
    /// recovery path it never takes.
    shards: Vec<Vec<ElementId>>,
    /// Store-mutating tasks of completed rounds, in round order — the
    /// deterministic replay an adopted machine rebuilds its
    /// [`GuessStore`] from (see [`RoundTask::mutates_store`]).
    history: Vec<RoundTask>,
    recovery: RecoveryPolicy,
    /// Worker deaths already recovered from (checked against the budget).
    deaths_spent: usize,
    /// Lifetime recovery-event count (per-round deltas land in stats).
    recoveries: u64,
    /// Lifetime `AdoptMachines` frame bytes.
    reshipped_bytes: u64,
    /// The shared shard arena, when `@uds+arena` built one. Held for the
    /// pool lifetime so the memfd outlives every worker's mapping path;
    /// `None` means the wire path (other transports, or arena fallback).
    arena: Option<Arena>,
    /// Lifetime arena-resolved payload bytes (the `Init`/adoption shard
    /// and sample bytes that never crossed a stream).
    mapped_bytes: u64,
    /// Per-job state of the warm-pool serving path (`mrsub serve`):
    /// machine assignments, reship shards, and replay history, keyed by
    /// job id. Empty on one-shot pools, which use the legacy
    /// pool-level assignment above.
    jobs: BTreeMap<u64, JobState>,
    /// The exact dataset the arena was laid out from at spawn. An
    /// attaching job may elide its shard/sample payloads only when its
    /// dataset is byte-identical to this one — the memfd cannot be
    /// re-passed mid-stream, so "close enough" would read wrong shards.
    arena_dataset: Option<(Vec<Vec<ElementId>>, Vec<ElementId>)>,
    /// Warm-pool attaches whose payloads were elided via the arena.
    arena_hits: u64,
    /// Warm-pool attaches that had to ship shards over the wire.
    arena_misses: u64,
}

/// One attached job's coordinator-side state on a warm pool — the
/// job-keyed mirror of the pool-level `machines`/`shards`/`history`
/// fields the one-shot path uses.
struct JobState {
    /// Machines of this job hosted by each worker slot (parallel to
    /// `ProcessPool::workers`); machine ids are job-local `0..n_machines`.
    assign: Vec<Vec<usize>>,
    /// Attach-time shards, the reship source for this job's adoptions.
    /// Empty under [`RecoveryPolicy::Fail`].
    shards: Vec<Vec<ElementId>>,
    /// Store-mutating tasks of this job's completed rounds, in order.
    history: Vec<RoundTask>,
    /// Machine count of this job.
    n_machines: usize,
    /// Whether this job's shards resolve from the arena mapping.
    arena: bool,
}

/// A lease on a daemon-owned warm pool: the shared pool handle plus the
/// job id this cluster's typed rounds run under. Carried (never
/// serialized) in [`crate::mapreduce::ClusterConfig::shared_pool`].
/// Rounds of concurrent jobs serialize on the pool mutex one round at a
/// time, which keeps per-round accounting exact and replies bit-identical
/// to a dedicated pool's — the interleaving happens *between* rounds.
#[derive(Clone)]
pub struct PoolLease {
    /// The daemon's warm pool (one per `mrsub serve` process).
    pub pool: std::sync::Arc<std::sync::Mutex<ProcessPool>>,
    /// Job id in the pool's job-keyed state (and in every worker's
    /// runtime map). Never 0 — job 0 is the workers' anonymous
    /// legacy-`Init` slot.
    pub job: u64,
}

impl std::fmt::Debug for PoolLease {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PoolLease {{ job: {} }}", self.job)
    }
}

/// Mutable join state threaded through the pipelined reply loop.
struct RoundProgress {
    /// Per-machine replies, filled in arrival order.
    out: Vec<Option<TaskReply>>,
    /// Merged worker-side oracle-call deltas `(total, batched, batches)`.
    calls: (u64, u64, u64),
    /// Machines orphaned by worker deaths, awaiting re-placement.
    orphans: Vec<usize>,
}

fn worker_error(worker: usize, message: impl Into<String>) -> Error {
    Error::Worker { worker, message: message.into() }
}

/// Accumulate a worker's `(total, batched, batches)` oracle-call delta.
fn merge_calls(acc: &mut (u64, u64, u64), c: (u64, u64, u64)) {
    acc.0 += c.0;
    acc.1 += c.1;
    acc.2 += c.2;
}

/// The one version-mismatch wording, shared by every handshake site
/// (socket Hello, pipe Hello, Ready) so the transports never drift.
fn version_mismatch(version: u16) -> String {
    format!("wire version mismatch: worker speaks v{version}, coordinator v{WIRE_VERSION}")
}

/// Diversifies UDS socket paths across pools within one process.
static POOL_TAG: AtomicU64 = AtomicU64::new(1);

/// Upper bound on the wait for a `Hello` after a stream connects. A real
/// worker sends it as its very first act, so this only fires for silent
/// strays (port scanners, health checks) — and bounds how long any single
/// stray can stall the (serial) accept loop; several strays in a row
/// still burn the pool deadline, which is why an explicit TCP bind
/// belongs on a trusted network segment (see README).
const HELLO_BUDGET: Duration = Duration::from_secs(2);

/// Start the dedicated reader + writer threads over a worker byte stream;
/// returns the send queue, the receive channel, and a drain signal the
/// writer fires just before exiting (a *bounded* flush handshake for
/// shutdown — never a join that could hang the coordinator).
fn start_io_threads(
    mut reader: Box<dyn Read + Send>,
    mut writer: Box<dyn Write + Send>,
    max_frame: usize,
) -> (mpsc::Sender<Vec<u8>>, mpsc::Receiver<FrameResult>, mpsc::Receiver<()>) {
    let (reply_tx, rx) = mpsc::channel();
    let (tx, payload_rx) = mpsc::channel::<Vec<u8>>();
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || loop {
        let res = wire::read_frame(&mut reader, max_frame);
        let stop = res.is_err();
        if reply_tx.send(res).is_err() || stop {
            break;
        }
    });
    std::thread::spawn(move || {
        // exits when the sender is dropped (shutdown/mark_dead) or the
        // stream breaks; dropping a pipe writer EOFs the worker.
        while let Ok(payload) = payload_rx.recv() {
            if wire::write_frame(&mut writer, &payload, max_frame).is_err() {
                break;
            }
        }
        let _ = done_tx.send(());
    });
    (tx, rx, done_rx)
}

/// A connected-but-not-yet-initialized worker stream (handshake state).
struct Pending {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<FrameResult>,
    control: LinkControl,
    writer_done: mpsc::Receiver<()>,
}

/// Read and decode the connect-time `Hello` from a pending stream;
/// returns `(version, worker id, frame bytes)` for the IPC meter.
fn expect_hello(
    pending: &Pending,
    deadline: Instant,
) -> std::result::Result<(u16, u32, u64), String> {
    let remaining = deadline.saturating_duration_since(Instant::now()).min(HELLO_BUDGET);
    let waited_ms = remaining.as_millis();
    match pending.rx.recv_timeout(remaining) {
        Ok(Ok((payload, nbytes))) => match FromWorker::decode(&payload) {
            Ok(FromWorker::Hello { version, worker }) => Ok((version, worker, nbytes as u64)),
            Ok(other) => Err(format!("expected Hello handshake, got {other:?}")),
            Err(e) => Err(format!("undecodable handshake frame: {e}")),
        },
        Ok(Err(WireError::Truncated { got: 0, .. })) => {
            Err("stream closed before the Hello handshake (worker crashed?)".into())
        }
        Ok(Err(e)) => Err(format!("bad handshake frame: {e}")),
        Err(mpsc::RecvTimeoutError::Timeout) => {
            Err(format!(
                "no Hello within {waited_ms} ms of connecting \
                 (worker connected but went silent)"
            ))
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            Err("stream closed before the Hello handshake".into())
        }
    }
}

impl ProcessPool {
    /// Spawn (or await) workers, complete the `Hello` handshake, ship
    /// each worker its shards + spec + sample, and complete the `Ready`
    /// handshake.
    pub fn spawn(
        spec: &OracleSpec,
        shards: &[Vec<ElementId>],
        sample: &[ElementId],
        opts: &PoolOptions,
    ) -> Result<ProcessPool> {
        let m = shards.len();
        if m == 0 {
            return Err(Error::Config("process pool needs at least one machine".into()));
        }
        let w = opts.workers.clamp(1, m);
        let external = opts.transport.external_workers();
        // Build the shared shard arena before any worker exists, so the
        // fd can be passed at connect time. A build failure (no memfd —
        // non-Linux host) is a transparent fallback, not an error: the
        // env flag stays unset, Init ships shards as frames, and the
        // pool behaves exactly like plain `@uds` (mapped_bytes stays 0).
        let shared = if opts.transport.wants_arena() {
            Arena::build(shards, sample).ok()
        } else {
            None
        };
        let listener = Listener::bind(&opts.transport, POOL_TAG.fetch_add(1, Ordering::Relaxed))
            .map_err(|e| {
                Error::Config(format!("bind {} listener: {e}", opts.transport))
            })?;
        let mut machines_of: Vec<Vec<usize>> = vec![Vec::new(); w];
        for i in 0..m {
            machines_of[i % w].push(i);
        }

        // --- process phase: spawn local workers (unless external) --------
        let mut children: Vec<Child> = Vec::new(); // index == worker slot
        let abort = |mut children: Vec<Child>, slots: Vec<Option<Pending>>| {
            for slot in slots.into_iter().flatten() {
                slot.control.force_close();
            }
            for child in &mut children {
                let _ = child.kill();
                let _ = child.wait();
            }
        };
        if !external {
            let exe = match &opts.exe {
                Some(p) => p.clone(),
                None => std::env::current_exe().map_err(|e| {
                    Error::Config(format!("cannot locate worker executable: {e}"))
                })?,
            };
            for wi in 0..w {
                let mut cmd = Command::new(&exe);
                cmd.arg("worker")
                    .stderr(Stdio::inherit())
                    .env("MRSUB_MAX_FRAME", opts.max_frame.to_string())
                    .env("MRSUB_WORKER_ID", wi.to_string());
                if shared.is_some() {
                    // the worker blocks on the fd-pass before its Hello.
                    cmd.env("MRSUB_ARENA", "1");
                } else {
                    // a stale flag inherited from the environment would
                    // wedge a wire-path worker waiting for an fd that
                    // never comes; clear it.
                    cmd.env_remove("MRSUB_ARENA");
                }
                match &listener {
                    None => {
                        // a stale MRSUB_CONNECT inherited from the
                        // coordinator's environment would flip a pipe
                        // worker into socket-dial mode; clear it.
                        cmd.stdin(Stdio::piped())
                            .stdout(Stdio::piped())
                            .env_remove("MRSUB_CONNECT");
                    }
                    Some(l) => {
                        // socket workers keep stdio free; they dial back.
                        cmd.stdin(Stdio::null())
                            .stdout(Stdio::inherit())
                            .env("MRSUB_CONNECT", l.endpoint());
                    }
                }
                for (key, val) in &opts.env {
                    cmd.env(key, val);
                }
                match cmd.spawn() {
                    Ok(child) => children.push(child),
                    Err(e) => {
                        // reap the workers already spawned — no zombies on a
                        // partial spawn (process-limit pressure, vanished exe).
                        abort(children, Vec::new());
                        return Err(worker_error(wi, format!("spawn {}: {e}", exe.display())));
                    }
                }
            }
        }

        // --- connection + Hello phase ------------------------------------
        // bounded by the dedicated connect timeout, not the (possibly much
        // larger, compute-sized) per-round reply timeout.
        let deadline = Instant::now() + opts.connect_timeout;
        let timeout_ms = opts.connect_timeout.as_millis();
        let mut slots: Vec<Option<Pending>> = (0..w).map(|_| None).collect();
        // socket Hello frames are consumed here, before the pool exists;
        // meter them so all transports account handshake bytes alike
        // (pipe Hellos flow through `recv`, which meters inline).
        let mut hello_bytes_in: u64 = 0;
        match &listener {
            None => {
                // pipes are wired at spawn: stream `wi` IS worker `wi`.
                for (wi, child) in children.iter_mut().enumerate() {
                    let stdin = child.stdin.take().expect("stdin piped");
                    let stdout = child.stdout.take().expect("stdout piped");
                    let (tx, rx, writer_done) =
                        start_io_threads(Box::new(stdout), Box::new(stdin), opts.max_frame);
                    slots[wi] =
                        Some(Pending { tx, rx, control: LinkControl::Pipe, writer_done });
                }
            }
            Some(l) => {
                let mut filled = 0usize;
                // external mode drops bad joins per-connection; the reason
                // for the last rejection is folded into the eventual
                // timeout error so the operator sees *why* a slot stayed
                // empty (e.g. a stale old-version worker retrying).
                let mut last_reject: Option<String> = None;
                while filled < w {
                    let link = match l.accept_until(deadline) {
                        Ok(Some(link)) => link,
                        Ok(None) => {
                            let missing =
                                slots.iter().position(Option::is_none).unwrap_or(0);
                            abort(children, slots);
                            let mut msg = format!(
                                "no worker connection within {timeout_ms} ms \
                                 (connection refused, worker crashed before \
                                 connecting, or wrong --connect endpoint?)"
                            );
                            if let Some(r) = last_reject {
                                msg.push_str(&format!("; last rejected join: {r}"));
                            }
                            return Err(worker_error(missing, msg));
                        }
                        Err(e) => {
                            abort(children, slots);
                            return Err(worker_error(0, format!("accept failed: {e}")));
                        }
                    };
                    let control = link.control.clone();
                    let (tx, rx, writer_done) =
                        start_io_threads(link.reader, link.writer, opts.max_frame);
                    let pending = Pending { tx, rx, control, writer_done };
                    if let Some(a) = &shared {
                        // pass the arena fd as the stream's very first
                        // byte (the worker maps it before sending its
                        // Hello); no frames are queued yet, so the
                        // carrier cannot interleave with the writer
                        // thread.
                        let sent = match &pending.control {
                            LinkControl::Uds(s) => a.send_fd(s),
                            _ => Err(std::io::Error::new(
                                std::io::ErrorKind::Unsupported,
                                "arena needs a UDS stream",
                            )),
                        };
                        if let Err(e) = sent {
                            pending.control.force_close();
                            abort(children, slots);
                            return Err(worker_error(0, format!("arena fd-pass failed: {e}")));
                        }
                    }
                    match expect_hello(&pending, deadline) {
                        Ok((version, worker, _)) if version != WIRE_VERSION => {
                            pending.control.force_close();
                            if external {
                                // a stray old-binary join must not tear
                                // down already-joined workers.
                                last_reject = Some(version_mismatch(version));
                                continue;
                            }
                            abort(children, slots);
                            return Err(worker_error(
                                worker as usize,
                                version_mismatch(version),
                            ));
                        }
                        Ok((_, worker, nbytes)) => {
                            let wi = worker as usize;
                            if wi >= w || slots[wi].is_some() {
                                pending.control.force_close();
                                let msg = format!(
                                    "unexpected worker id {wi} in Hello \
                                     (pool has {w} slots; duplicate --id?)"
                                );
                                if external {
                                    last_reject = Some(msg);
                                    continue;
                                }
                                abort(children, slots);
                                return Err(worker_error(wi, msg));
                            }
                            hello_bytes_in += nbytes;
                            slots[wi] = Some(pending);
                            filled += 1;
                        }
                        Err(msg) if external => {
                            // an open listener on a real network attracts
                            // strays (port scanners, health checks): a
                            // stream that dies or garbles before its Hello
                            // is dropped, not a pool-fatal event — a truly
                            // missing worker still trips the accept
                            // deadline above.
                            pending.control.force_close();
                            last_reject = Some(msg);
                        }
                        Err(msg) => {
                            // spawned-worker mode: every stream is one of
                            // ours, so a pre-Hello death is a real worker
                            // failure — fail fast with the cause.
                            pending.control.force_close();
                            let missing =
                                slots.iter().position(Option::is_none).unwrap_or(0);
                            abort(children, slots);
                            return Err(worker_error(missing, msg));
                        }
                    }
                }
            }
        }
        drop(listener); // all workers joined; unlink the UDS path now.

        // --- assemble + pipe-mode Hello + Init/Ready ----------------------
        let mut children = children.into_iter().map(Some).collect::<Vec<_>>();
        children.resize_with(w, || None);
        let workers: Vec<WorkerHandle> = slots
            .into_iter()
            .zip(machines_of)
            .enumerate()
            .map(|(wi, (pending, machines))| {
                let p = pending.expect("every slot filled above");
                WorkerHandle {
                    child: children[wi].take(),
                    tx: Some(p.tx),
                    rx: p.rx,
                    control: p.control,
                    writer_done: p.writer_done,
                    machines,
                    alive: true,
                }
            })
            .collect();
        let mut pool = ProcessPool {
            workers,
            n_machines: m,
            timeout: opts.timeout,
            max_frame: opts.max_frame,
            bytes_out: 0,
            bytes_in: hello_bytes_in,
            shards: match opts.recovery {
                RecoveryPolicy::Requeue { .. } => shards.to_vec(),
                RecoveryPolicy::Fail => Vec::new(),
            },
            history: Vec::new(),
            recovery: opts.recovery,
            deaths_spent: 0,
            recoveries: 0,
            reshipped_bytes: 0,
            arena_dataset: shared
                .as_ref()
                .map(|_| (shards.to_vec(), sample.to_vec())),
            arena: shared,
            mapped_bytes: 0,
            jobs: BTreeMap::new(),
            arena_hits: 0,
            arena_misses: 0,
        };
        if matches!(opts.transport, Transport::Pipe) {
            // socket hellos were consumed during accept; pipe hellos are
            // still queued — same handshake, same validation.
            for wi in 0..pool.workers.len() {
                match pool.recv(wi)? {
                    FromWorker::Hello { version, worker }
                        if version == WIRE_VERSION && worker as usize == wi => {}
                    FromWorker::Hello { version, .. } if version != WIRE_VERSION => {
                        return Err(pool.mark_dead(wi, version_mismatch(version)))
                    }
                    other => {
                        return Err(
                            pool.mark_dead(wi, format!("bad Hello handshake: {other:?}"))
                        )
                    }
                }
            }
        }
        let use_arena = pool.arena.is_some();
        for wi in 0..pool.workers.len() {
            let machines: Vec<u32> =
                pool.workers[wi].machines.iter().map(|&i| i as u32).collect();
            let init = if use_arena {
                // the worker resolves shards from its mapping; meter the
                // elided payload so the wire-vs-mapped split is visible.
                let words: usize = pool.workers[wi]
                    .machines
                    .iter()
                    .map(|&i| shards[i].len())
                    .sum::<usize>()
                    + sample.len();
                pool.mapped_bytes += 4 * words as u64;
                ToWorker::Init(WorkerInit {
                    spec: spec.clone(),
                    machines,
                    shards: Vec::new(),
                    sample: Vec::new(),
                    arena: true,
                })
            } else {
                ToWorker::Init(WorkerInit {
                    spec: spec.clone(),
                    machines,
                    shards: pool.workers[wi]
                        .machines
                        .iter()
                        .map(|&i| shards[i].clone())
                        .collect(),
                    sample: sample.to_vec(),
                    arena: false,
                })
            };
            pool.send(wi, &init)?;
        }
        for wi in 0..pool.workers.len() {
            match pool.recv(wi)? {
                FromWorker::Ready { version } if version == WIRE_VERSION => {}
                FromWorker::Ready { version } => {
                    return Err(pool.mark_dead(wi, version_mismatch(version)))
                }
                FromWorker::Fail { message } => {
                    return Err(pool.mark_dead(wi, format!("init failed: {message}")))
                }
                other => {
                    return Err(pool.mark_dead(wi, format!("unexpected init reply: {other:?}")))
                }
            }
        }
        Ok(pool)
    }

    /// Number of worker processes.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Number of simulated machines served.
    pub fn machines(&self) -> usize {
        self.n_machines
    }

    /// Total frame bytes sent/received since spawn.
    pub fn total_ipc_bytes(&self) -> (u64, u64) {
        (self.bytes_out, self.bytes_in)
    }

    /// Total shard/sample payload bytes resolved from the arena mapping
    /// since spawn (includes the `Init` elisions, which predate round 1).
    pub fn total_mapped_bytes(&self) -> u64 {
        self.mapped_bytes
    }

    /// Whether the zero-copy arena is active (built *and* fd-passed); on
    /// the fallback or non-arena transports this is `false` and every
    /// payload crosses the wire.
    pub fn arena_active(&self) -> bool {
        self.arena.is_some()
    }

    /// Worker processes still alive. The pool never replaces a dead
    /// worker with a new process, so this never grows — the serve smoke's
    /// "zero re-spawned workers" check compares it against
    /// [`ProcessPool::workers`].
    pub fn alive_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    /// Whether `job` is currently attached to this pool.
    pub fn has_job(&self, job: u64) -> bool {
        self.jobs.contains_key(&job)
    }

    /// Lifetime warm-pool attach meters `(arena hits, misses)`: attaches
    /// whose dataset matched the spawn arena exactly (every shard/sample
    /// payload elided) vs attaches that shipped shards over the wire.
    pub fn arena_attach_stats(&self) -> (u64, u64) {
        (self.arena_hits, self.arena_misses)
    }

    /// Execute one round on every worker; returns per-machine replies (in
    /// machine order) plus the round's IPC stats.
    ///
    /// Under [`RecoveryPolicy::Requeue`], a worker death mid-round does
    /// not abort: the dead worker's machines are adopted by survivors
    /// (shards + store-replay reshipped, the in-flight task re-run for
    /// just those machines) and the round completes with the same
    /// per-machine replies a fault-free run produces.
    pub fn round(&mut self, task: &RoundTask) -> Result<(Vec<TaskReply>, RoundIpcStats)> {
        self.round_with(task, &mut |_, _| {})
    }

    /// [`ProcessPool::round`] with a streaming hook: `on_reply(machine,
    /// reply)` fires the moment a machine's reply arrives (arrival order,
    /// not machine order), letting the caller overlap the next round's
    /// coordinator-side accounting with workers still computing this one.
    /// The returned vector is identical to [`ProcessPool::round`]'s — the
    /// hook only changes *when* the caller sees each reply, never the
    /// replies themselves, so bit-identity is unaffected. Each machine's
    /// reply is surfaced exactly once (a recovered machine's adopted
    /// re-run does not re-fire the hook when the original reply landed
    /// before the death).
    pub fn round_with(
        &mut self,
        task: &RoundTask,
        on_reply: &mut dyn FnMut(usize, &TaskReply),
    ) -> Result<(Vec<TaskReply>, RoundIpcStats)> {
        // A pool that failed structurally in an earlier round stays
        // failed: machines stranded on dead workers (fail policy,
        // exhausted budget, lost last worker) can never answer, so keep
        // surfacing the structured error instead of panicking on the
        // missing replies.
        let assigned: usize =
            self.workers.iter().filter(|w| w.alive).map(|w| w.machines.len()).sum();
        if assigned != self.n_machines {
            let wi = self.workers.iter().position(|w| !w.alive).unwrap_or(0);
            return Err(worker_error(wi, "worker is dead (earlier failure)"));
        }
        let (out0, in0) = (self.bytes_out, self.bytes_in);
        let (rec0, reship0) = (self.recoveries, self.reshipped_bytes);
        let map0 = self.mapped_bytes;
        // one encode; every worker receives byte-identical frames.
        let payload = ToWorker::Round(task.clone()).encode();
        let mut progress = RoundProgress {
            out: (0..self.n_machines).map(|_| None).collect(),
            calls: (0, 0, 0),
            // machines whose round result was lost to a worker death and
            // must be re-placed (stays empty under the fail policy, which
            // returns instead).
            orphans: Vec::new(),
        };

        // --- broadcast ---------------------------------------------------
        let mut awaiting: Vec<(usize, Vec<usize>)> = Vec::new();
        for wi in 0..self.workers.len() {
            if !self.workers[wi].alive {
                continue; // died in an earlier round; hosts no machines.
            }
            match self.send_payload(wi, &payload) {
                Ok(()) => awaiting.push((wi, self.workers[wi].machines.clone())),
                Err(e) => self.on_worker_death(wi, e, &mut progress.orphans, None)?,
            }
        }

        // --- join replies (arrival order: the pipelined scheduler) -------
        self.join_replies(awaiting, task, self.timeout, false, &mut progress, on_reply, None)?;

        // --- recovery: detect → re-queue → adopt → replay → re-run -------
        // The adopter must replay the whole store-mutating history before
        // answering, so its reply deadline scales with the replay length
        // instead of misdiagnosing a long (legitimate) replay as a death.
        let adoption_timeout = self.timeout.saturating_mul(self.history.len() as u32 + 2);
        while !progress.orphans.is_empty() {
            let batch = std::mem::take(&mut progress.orphans);
            let assignment = self.assign_orphans(&batch, None)?;
            let mut adopting: Vec<(usize, Vec<usize>)> = Vec::new();
            for (wi, machines) in assignment {
                let use_arena = self.arena.is_some();
                let adopt = RoundTask::AdoptMachines {
                    machines: machines.iter().map(|&m| m as u32).collect(),
                    // arena adopters resolve shards from their mapping:
                    // the reship carries replay + pending only.
                    shards: if use_arena {
                        Vec::new()
                    } else {
                        machines.iter().map(|&m| self.shards[m].clone()).collect()
                    },
                    arena: use_arena,
                    replay: self.history.clone(),
                    pending: Box::new(task.clone()),
                };
                let adopt_payload = ToWorker::Round(adopt).encode();
                if adopt_payload.len() > self.max_frame {
                    // a coordinator-side sizing problem, not a worker
                    // death: killing the healthy adopter here would
                    // cascade the same oversized frame through every
                    // survivor and burn the whole budget.
                    return Err(worker_error(
                        wi,
                        format!(
                            "adoption reship of {} machine(s) exceeds the max-frame \
                             cap ({} > {} bytes) — raise max_frame_mb",
                            machines.len(),
                            adopt_payload.len(),
                            self.max_frame
                        ),
                    ));
                }
                let frame = wire::frame_size(adopt_payload.len()) as u64;
                match self.send_payload(wi, &adopt_payload) {
                    Ok(()) => {
                        self.reshipped_bytes += frame;
                        if use_arena {
                            let words: usize =
                                machines.iter().map(|&m| self.shards[m].len()).sum();
                            self.mapped_bytes += 4 * words as u64;
                        }
                        adopting.push((wi, machines));
                    }
                    Err(e) => {
                        // the adopter itself just died: the machines it was
                        // about to adopt rejoin the orphans next to its own.
                        progress.orphans.extend(machines);
                        self.on_worker_death(wi, e, &mut progress.orphans, None)?;
                    }
                }
            }
            self.join_replies(adopting, task, adoption_timeout, true, &mut progress, on_reply, None)?;
        }

        if matches!(self.recovery, RecoveryPolicy::Requeue { .. }) && task.mutates_store() {
            // completed rounds with machine-resident effects feed the
            // replay history future adoptions rebuild state from (not
            // tracked under the fail policy, which never adopts).
            self.history.push(task.clone());
        }
        let replies: Vec<TaskReply> = progress
            .out
            .into_iter()
            .map(|r| r.expect("every machine is assigned a worker"))
            .collect();
        let stats = RoundIpcStats {
            bytes_out: self.bytes_out - out0,
            bytes_in: self.bytes_in - in0,
            calls: progress.calls,
            recoveries: self.recoveries - rec0,
            reshipped_bytes: self.reshipped_bytes - reship0,
            mapped_bytes: self.mapped_bytes - map0,
        };
        Ok((replies, stats))
    }

    /// Attach a job's dataset to the warm pool (`mrsub serve`): round-robin
    /// its machines over the surviving workers and ship each one a
    /// job-keyed [`ToWorker::Attach`], awaiting its `Ready`. When the
    /// pool's arena already holds this exact dataset (byte-identical
    /// shards and sample — the warm-pool **arena-cache hit**), every
    /// shard/sample payload is elided from the attach frames and the
    /// elided bytes land in the mapped meter instead. Returns whether the
    /// attach was arena-elided. Attach failures are not recovered — the
    /// caller surfaces them as a job failure.
    pub fn attach_job(
        &mut self,
        job: u64,
        spec: &OracleSpec,
        shards: &[Vec<ElementId>],
        sample: &[ElementId],
    ) -> Result<bool> {
        if self.jobs.contains_key(&job) {
            return Err(Error::Config(format!("job {job} is already attached")));
        }
        let m = shards.len();
        if m == 0 {
            return Err(Error::Config("job needs at least one machine".into()));
        }
        let alive: Vec<usize> =
            (0..self.workers.len()).filter(|&wi| self.workers[wi].alive).collect();
        if alive.is_empty() {
            return Err(worker_error(0, "no surviving workers to attach the job to"));
        }
        let mut assign: Vec<Vec<usize>> = vec![Vec::new(); self.workers.len()];
        for i in 0..m {
            assign[alive[i % alive.len()]].push(i);
        }
        let arena = self.arena.is_some()
            && self
                .arena_dataset
                .as_ref()
                .is_some_and(|(ds, dsample)| ds == shards && dsample == sample);
        if arena {
            self.arena_hits += 1;
        } else {
            self.arena_misses += 1;
        }
        for &wi in &alive {
            let machines: Vec<u32> = assign[wi].iter().map(|&i| i as u32).collect();
            let init = if arena {
                let words: usize =
                    assign[wi].iter().map(|&i| shards[i].len()).sum::<usize>() + sample.len();
                self.mapped_bytes += 4 * words as u64;
                WorkerInit {
                    spec: spec.clone(),
                    machines,
                    shards: Vec::new(),
                    sample: Vec::new(),
                    arena: true,
                }
            } else {
                WorkerInit {
                    spec: spec.clone(),
                    machines,
                    shards: assign[wi].iter().map(|&i| shards[i].clone()).collect(),
                    sample: sample.to_vec(),
                    arena: false,
                }
            };
            self.send(wi, &ToWorker::Attach { job, init })?;
        }
        for &wi in &alive {
            match self.recv(wi)? {
                FromWorker::Ready { version } if version == WIRE_VERSION => {}
                FromWorker::Ready { version } => {
                    return Err(self.mark_dead(wi, version_mismatch(version)))
                }
                FromWorker::Fail { message } => {
                    return Err(self.mark_dead(wi, format!("attach failed: {message}")))
                }
                other => {
                    return Err(
                        self.mark_dead(wi, format!("unexpected attach reply: {other:?}"))
                    )
                }
            }
        }
        self.jobs.insert(job, JobState {
            assign,
            shards: match self.recovery {
                RecoveryPolicy::Requeue { .. } => shards.to_vec(),
                RecoveryPolicy::Fail => Vec::new(),
            },
            history: Vec::new(),
            n_machines: m,
            arena,
        });
        Ok(arena)
    }

    /// One round of an attached job — [`ProcessPool::round_with`] against
    /// the job's own machine assignment, shards, and replay history. Same
    /// broadcast, same arrival-order join, same adoption-based recovery;
    /// additionally, machines stranded on workers that died while *other*
    /// jobs' rounds were in flight are re-queued here at round start
    /// (their loss was charged to the death budget when the death was
    /// detected, so the re-queue itself is free).
    pub fn round_job(
        &mut self,
        job: u64,
        task: &RoundTask,
        on_reply: &mut dyn FnMut(usize, &TaskReply),
    ) -> Result<(Vec<TaskReply>, RoundIpcStats)> {
        if !self.jobs.contains_key(&job) {
            return Err(Error::Config(format!("round for unattached job {job}")));
        }
        let (out0, in0) = (self.bytes_out, self.bytes_in);
        let (rec0, reship0) = (self.recoveries, self.reshipped_bytes);
        let map0 = self.mapped_bytes;
        let n_machines = self.jobs[&job].n_machines;
        let mut progress = RoundProgress {
            out: (0..n_machines).map(|_| None).collect(),
            calls: (0, 0, 0),
            orphans: Vec::new(),
        };

        // --- round-start re-queue of machines on already-dead workers ----
        let alive_flags: Vec<bool> = self.workers.iter().map(|h| h.alive).collect();
        {
            let js = self.jobs.get_mut(&job).expect("checked above");
            for (wi, alive) in alive_flags.iter().enumerate() {
                if !alive && !js.assign[wi].is_empty() {
                    progress.orphans.extend(std::mem::take(&mut js.assign[wi]));
                }
            }
        }
        if !progress.orphans.is_empty() && matches!(self.recovery, RecoveryPolicy::Fail) {
            let wi = self.workers.iter().position(|h| !h.alive).unwrap_or(0);
            return Err(worker_error(wi, "worker is dead (earlier failure)"));
        }

        // --- broadcast to the workers hosting this job's machines --------
        let payload = ToWorker::JobRound { job, task: task.clone() }.encode();
        let mut awaiting: Vec<(usize, Vec<usize>)> = Vec::new();
        for wi in 0..self.workers.len() {
            let machines = self.jobs[&job].assign[wi].clone();
            if machines.is_empty() || !self.workers[wi].alive {
                continue;
            }
            match self.send_payload(wi, &payload) {
                Ok(()) => awaiting.push((wi, machines)),
                Err(e) => self.on_worker_death(wi, e, &mut progress.orphans, Some(job))?,
            }
        }
        self.join_replies(
            awaiting,
            task,
            self.timeout,
            false,
            &mut progress,
            on_reply,
            Some(job),
        )?;

        // --- recovery: re-queue → adopt → replay → re-run ----------------
        let adoption_timeout =
            self.timeout.saturating_mul(self.jobs[&job].history.len() as u32 + 2);
        while !progress.orphans.is_empty() {
            let batch = std::mem::take(&mut progress.orphans);
            let assignment = self.assign_orphans(&batch, Some(job))?;
            let mut adopting: Vec<(usize, Vec<usize>)> = Vec::new();
            for (wi, machines) in assignment {
                let (adopt_payload, arena_words) = {
                    let js = &self.jobs[&job];
                    let adopt = RoundTask::AdoptMachines {
                        machines: machines.iter().map(|&m| m as u32).collect(),
                        shards: if js.arena {
                            Vec::new()
                        } else {
                            machines.iter().map(|&m| js.shards[m].clone()).collect()
                        },
                        arena: js.arena,
                        replay: js.history.clone(),
                        pending: Box::new(task.clone()),
                    };
                    let words: usize = if js.arena {
                        machines.iter().map(|&m| js.shards[m].len()).sum()
                    } else {
                        0
                    };
                    (
                        ToWorker::JobRound { job, task: adopt }.encode(),
                        js.arena.then_some(words),
                    )
                };
                if adopt_payload.len() > self.max_frame {
                    return Err(worker_error(
                        wi,
                        format!(
                            "adoption reship of {} machine(s) exceeds the max-frame \
                             cap ({} > {} bytes) — raise max_frame_mb",
                            machines.len(),
                            adopt_payload.len(),
                            self.max_frame
                        ),
                    ));
                }
                let frame = wire::frame_size(adopt_payload.len()) as u64;
                match self.send_payload(wi, &adopt_payload) {
                    Ok(()) => {
                        self.reshipped_bytes += frame;
                        if let Some(words) = arena_words {
                            self.mapped_bytes += 4 * words as u64;
                        }
                        adopting.push((wi, machines));
                    }
                    Err(e) => {
                        progress.orphans.extend(machines);
                        self.on_worker_death(wi, e, &mut progress.orphans, Some(job))?;
                    }
                }
            }
            self.join_replies(
                adopting,
                task,
                adoption_timeout,
                true,
                &mut progress,
                on_reply,
                Some(job),
            )?;
        }

        if matches!(self.recovery, RecoveryPolicy::Requeue { .. }) && task.mutates_store() {
            self.jobs.get_mut(&job).expect("attached").history.push(task.clone());
        }
        let replies: Vec<TaskReply> = progress
            .out
            .into_iter()
            .map(|r| r.expect("every machine is assigned a worker"))
            .collect();
        let stats = RoundIpcStats {
            bytes_out: self.bytes_out - out0,
            bytes_in: self.bytes_in - in0,
            calls: progress.calls,
            recoveries: self.recoveries - rec0,
            reshipped_bytes: self.reshipped_bytes - reship0,
            mapped_bytes: self.mapped_bytes - map0,
        };
        Ok((replies, stats))
    }

    /// Detach a completed (or failed) job: drop its coordinator-side
    /// state and tell surviving workers to free its runtime. A no-op for
    /// unknown jobs; send failures are ignored — a dead worker has no
    /// runtime left to free.
    pub fn detach_job(&mut self, job: u64) {
        if self.jobs.remove(&job).is_none() {
            return;
        }
        let payload = ToWorker::Detach { job }.encode();
        for wi in 0..self.workers.len() {
            if self.workers[wi].alive {
                let _ = self.send_payload(wi, &payload);
            }
        }
    }

    /// Pipelined reply join: poll every listed worker and consume each
    /// `RoundDone` the moment it arrives (arrival order, not worker
    /// order), streaming per-machine replies into `progress.out` and the
    /// caller's hook. Arrival order cannot affect the result — replies
    /// land in per-machine slots and call deltas are commutative sums. A
    /// worker silent past `timeout` (rolling: any arrival resets the
    /// clock) is declared dead exactly as the serial join did; `adopting`
    /// marks the adoption pass, whose workers own their listed machines
    /// only once their reply lands.
    fn join_replies(
        &mut self,
        mut pending: Vec<(usize, Vec<usize>)>,
        shape: &RoundTask,
        timeout: Duration,
        adopting: bool,
        progress: &mut RoundProgress,
        on_reply: &mut dyn FnMut(usize, &TaskReply),
        job: Option<u64>,
    ) -> Result<()> {
        let ms = timeout.as_millis();
        let mut last_arrival = Instant::now();
        while !pending.is_empty() {
            let mut progressed = false;
            let mut i = 0;
            while i < pending.len() {
                let polled = match self.poll_frame(pending[i].0) {
                    None => {
                        i += 1;
                        continue;
                    }
                    Some(p) => p,
                };
                progressed = true;
                let (wi, machines) = pending.swap_remove(i);
                let done =
                    polled.and_then(|msg| self.check_round_done(wi, msg, shape, machines.len()));
                match done {
                    Ok((replies, c)) => {
                        for (slot, reply) in replies.into_iter().enumerate() {
                            // a machine whose pre-death reply already
                            // landed keeps it — determinism makes the
                            // adopted re-run byte-identical anyway.
                            let m = machines[slot];
                            if progress.out[m].is_none() {
                                on_reply(m, &reply);
                                progress.out[m] = Some(reply);
                            }
                        }
                        merge_calls(&mut progress.calls, c);
                        if adopting {
                            match job {
                                None => self.workers[wi].machines.extend(machines),
                                Some(j) => self
                                    .jobs
                                    .get_mut(&j)
                                    .expect("attached")
                                    .assign[wi]
                                    .extend(machines),
                            }
                        }
                    }
                    Err(e) => {
                        if adopting {
                            progress.orphans.extend(machines);
                        }
                        self.on_worker_death(wi, e, &mut progress.orphans, job)?;
                    }
                }
            }
            if progressed {
                last_arrival = Instant::now();
            } else if last_arrival.elapsed() >= timeout {
                // every still-pending worker blew the reply deadline.
                for (wi, machines) in std::mem::take(&mut pending) {
                    let e =
                        self.mark_dead(wi, format!("no reply within {ms} ms (worker hung?)"));
                    if adopting {
                        progress.orphans.extend(machines);
                    }
                    self.on_worker_death(wi, e, &mut progress.orphans, job)?;
                }
            } else {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        Ok(())
    }

    /// Non-blocking receive of one frame from worker `wi` (the pipelined
    /// join's poll step): `None` when nothing has arrived yet, `Some(Err)`
    /// when the stream broke (the worker is marked dead on the way out).
    fn poll_frame(&mut self, wi: usize) -> Option<Result<FromWorker>> {
        match self.workers[wi].rx.try_recv() {
            Ok(Ok((payload, nbytes))) => {
                self.bytes_in += nbytes as u64;
                match FromWorker::decode(&payload) {
                    Ok(msg) => Some(Ok(msg)),
                    Err(e) => Some(Err(self.mark_dead(wi, format!("undecodable reply: {e}")))),
                }
            }
            Ok(Err(WireError::Truncated { got: 0, .. })) => Some(Err(
                self.mark_dead(wi, "worker closed its stream (exited or was killed)"),
            )),
            Ok(Err(e)) => Some(Err(self.mark_dead(wi, format!("bad reply frame: {e}")))),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(
                self.mark_dead(wi, "worker reader disconnected (process gone)"),
            )),
        }
    }

    /// Validate one worker's in-round message as the `RoundDone` answering
    /// `shape` (for adoptions, the in-flight `pending` task —
    /// [`wire::reply_matches`] on `AdoptMachines` delegates to it),
    /// checking the reply count and each reply's shape.
    fn check_round_done(
        &mut self,
        wi: usize,
        msg: FromWorker,
        shape: &RoundTask,
        expected: usize,
    ) -> Result<(Vec<TaskReply>, (u64, u64, u64))> {
        match msg {
            FromWorker::RoundDone { replies, calls } => {
                if replies.len() != expected {
                    return Err(self.mark_dead(
                        wi,
                        format!("returned {} replies for {expected} machines", replies.len()),
                    ));
                }
                if let Some(bad) = replies.iter().find(|r| !wire::reply_matches(shape, r)) {
                    let msg = format!("reply shape mismatch for {} task: {bad:?}", shape.label());
                    return Err(self.mark_dead(wi, msg));
                }
                Ok((replies, calls))
            }
            FromWorker::Fail { message } => Err(self.mark_dead(wi, message)),
            other => {
                Err(self.mark_dead(wi, format!("unexpected mid-round message: {other:?}")))
            }
        }
    }

    /// A worker failed mid-round (already marked dead by the send/recv
    /// path). Under [`RecoveryPolicy::Fail`], propagate the structured
    /// error; under [`RecoveryPolicy::Requeue`] with budget left, consume
    /// one death and move the worker's machines onto the orphan list.
    /// `job` picks whose machines are orphaned: the legacy per-pool
    /// assignment (`None`) or a warm-pool job's (`Some`). Either way the
    /// death is charged to the shared budget exactly once, here.
    fn on_worker_death(
        &mut self,
        wi: usize,
        err: Error,
        orphans: &mut Vec<usize>,
        job: Option<u64>,
    ) -> Result<()> {
        match self.recovery {
            RecoveryPolicy::Fail => Err(err),
            RecoveryPolicy::Requeue { budget } => {
                if self.deaths_spent >= budget {
                    return Err(worker_error(
                        wi,
                        format!(
                            "recovery budget exhausted \
                             ({budget} worker death(s) already re-queued): {err}"
                        ),
                    ));
                }
                self.deaths_spent += 1;
                self.recoveries += 1;
                let machines = match job {
                    None => std::mem::take(&mut self.workers[wi].machines),
                    Some(j) => {
                        std::mem::take(&mut self.jobs.get_mut(&j).expect("attached").assign[wi])
                    }
                };
                orphans.extend(machines);
                Ok(())
            }
        }
    }

    /// Deterministically place orphaned machines on surviving workers:
    /// each orphan goes to the currently least-loaded survivor (ties to
    /// the lowest worker index). Errs structurally when no survivor is
    /// left.
    fn assign_orphans(
        &self,
        orphans: &[usize],
        job: Option<u64>,
    ) -> Result<Vec<(usize, Vec<usize>)>> {
        let job_assign = job.map(|j| &self.jobs[&j].assign);
        let mut load: Vec<(usize, usize)> = self
            .workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.alive)
            .map(|(wi, w)| {
                (wi, job_assign.map_or(w.machines.len(), |assign| assign[wi].len()))
            })
            .collect();
        if load.is_empty() {
            return Err(worker_error(
                0,
                format!(
                    "no surviving workers to adopt {} re-queued machine(s) \
                     (last worker died)",
                    orphans.len()
                ),
            ));
        }
        let mut groups: Vec<(usize, Vec<usize>)> =
            load.iter().map(|&(wi, _)| (wi, Vec::new())).collect();
        for &m in orphans {
            let pos = (0..load.len())
                .min_by_key(|&i| (load[i].1, load[i].0))
                .expect("nonempty survivor set");
            load[pos].1 += 1;
            groups[pos].1.push(m);
        }
        groups.retain(|(_, ms)| !ms.is_empty());
        Ok(groups)
    }

    /// Fault injection (tests): kill worker `wi`'s OS process *without*
    /// telling the pool — the next round must surface a structured error,
    /// exactly as if the process died on its own. External workers (no
    /// child handle) get their stream force-closed instead.
    pub fn kill_worker(&mut self, wi: usize) {
        if let Some(w) = self.workers.get_mut(wi) {
            match &mut w.child {
                Some(child) => {
                    let _ = child.kill();
                    let _ = child.wait();
                }
                None => w.control.force_close(),
            }
        }
    }

    fn send(&mut self, wi: usize, msg: &ToWorker) -> Result<()> {
        self.send_payload(wi, &msg.encode())
    }

    /// Queue one frame for the worker's writer thread. Never blocks on the
    /// stream; oversized payloads fail here (structured), write failures
    /// surface at the next `recv` (dead stream / timeout).
    fn send_payload(&mut self, wi: usize, payload: &[u8]) -> Result<()> {
        if !self.workers[wi].alive {
            return Err(worker_error(wi, "worker is dead (earlier failure)"));
        }
        if payload.len() > self.max_frame {
            let e = WireError::FrameTooLarge { len: payload.len(), max: self.max_frame };
            return Err(self.mark_dead(wi, format!("send failed: {e}")));
        }
        let queued = match &self.workers[wi].tx {
            Some(tx) => tx.send(payload.to_vec()).is_ok(),
            None => false,
        };
        if !queued {
            return Err(self.mark_dead(wi, "send failed: writer thread gone (stream broken)"));
        }
        self.bytes_out += wire::frame_size(payload.len()) as u64;
        Ok(())
    }

    fn recv(&mut self, wi: usize) -> Result<FromWorker> {
        self.recv_within(wi, self.timeout)
    }

    /// [`ProcessPool::recv`] with an explicit wait bound (adoption replies
    /// get a replay-scaled deadline).
    fn recv_within(&mut self, wi: usize, timeout: Duration) -> Result<FromWorker> {
        if !self.workers[wi].alive {
            return Err(worker_error(wi, "worker is dead (earlier failure)"));
        }
        match self.workers[wi].rx.recv_timeout(timeout) {
            Ok(Ok((payload, nbytes))) => {
                self.bytes_in += nbytes as u64;
                match FromWorker::decode(&payload) {
                    Ok(msg) => Ok(msg),
                    Err(e) => Err(self.mark_dead(wi, format!("undecodable reply: {e}"))),
                }
            }
            Ok(Err(WireError::Truncated { got: 0, .. })) => {
                Err(self.mark_dead(wi, "worker closed its stream (exited or was killed)"))
            }
            Ok(Err(e)) => Err(self.mark_dead(wi, format!("bad reply frame: {e}"))),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let ms = timeout.as_millis();
                Err(self.mark_dead(wi, format!("no reply within {ms} ms (worker hung?)")))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(self.mark_dead(wi, "worker reader disconnected (process gone)"))
            }
        }
    }

    /// Mark `wi` dead, tear its stream down, reap the child (if any), and
    /// build the structured error.
    fn mark_dead(&mut self, wi: usize, message: impl Into<String>) -> Error {
        let w = &mut self.workers[wi];
        w.alive = false;
        w.tx = None; // writer thread exits; on pipes this drops stdin.
        w.control.force_close();
        if let Some(child) = &mut w.child {
            let _ = child.kill();
            let _ = child.wait();
        }
        worker_error(wi, message)
    }

    fn shutdown_all(&mut self) {
        for w in &mut self.workers {
            if let Some(tx) = w.tx.take() {
                let _ = tx.send(ToWorker::Shutdown.encode());
            } // dropping tx ends the writer; on pipes that also EOFs the
              // worker, which is a shutdown too.
        }
        for w in &mut self.workers {
            let Some(child) = &mut w.child else {
                // external worker, nothing to reap: wait (bounded) for the
                // writer to signal it drained the Shutdown frame, so the
                // close below cannot sever it mid-write — then close our
                // end so a worker that missed it observes EOF and exits.
                // A dead worker's writer has already exited and signaled.
                let _ = w.writer_done.recv_timeout(Duration::from_millis(250));
                w.control.force_close();
                continue;
            };
            let deadline = Instant::now() + Duration::from_millis(250);
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
            // unblock any reader thread still parked on the socket.
            w.control.force_close();
        }
    }
}

impl Drop for ProcessPool {
    fn drop(&mut self) {
        self.shutdown_all();
    }
}

// --- worker side ------------------------------------------------------------

struct WorkerRuntime {
    oracle: CountingOracle<std::sync::Arc<dyn Oracle>>,
    counters: std::sync::Arc<OracleCounters>,
    machines: Vec<usize>,
    /// Owned (wire path) or arena-mapped (zero-copy path) per machine.
    shards: Vec<ShardData>,
    stores: Vec<GuessStore>,
    /// Cross-round broadcast-state cache: Algorithm 5's per-guess `G`
    /// states persist here between rounds instead of being replayed from
    /// scratch (see [`StateCache`]).
    cache: StateCache,
}

/// Resolve a machine list against the arena mapping; a machine the arena
/// does not cover is a structural error (coordinator/worker disagree on
/// the region layout), never a silent empty shard.
fn arena_shards(
    map: &ArenaMap,
    machines: &[u32],
) -> std::result::Result<Vec<ShardData>, String> {
    machines
        .iter()
        .map(|&m| {
            map.shard(m).map(ShardData::Mapped).ok_or_else(|| {
                format!(
                    "arena has no shard for machine {m} (mapping covers {} machines)",
                    map.machines()
                )
            })
        })
        .collect()
}

fn send_reply(w: &mut dyn Write, msg: &FromWorker, max_frame: usize) -> bool {
    wire::write_frame(w, &msg.encode(), max_frame).is_ok()
}

/// Parsed `MRSUB_FAULT` spec: `kind[:nth][@worker]` — e.g.
/// `die-mid-round`, `die-mid-round:2`, `die-on-prune:2@1`. `nth`
/// (default 1, 1-based) selects which occurrence of the triggering event
/// fires the fault — `Round` frames for the round faults, pruning rounds
/// for `die-on-prune`. `@worker` scopes the fault to one worker slot, so
/// the recovery tests can kill a single worker out of a live pool while
/// its siblings survive to adopt the orphaned machines.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultSpec {
    /// Fault kind: `die-mid-round`, `hang-round`, `truncate-frame`,
    /// `corrupt-checksum`, `bad-version`, `no-connect`, `die-on-prune`.
    pub kind: String,
    /// 1-based occurrence of the triggering event that fires the fault.
    pub nth: u32,
    /// Worker slot the fault applies to; `None` = every worker.
    pub worker: Option<u32>,
}

impl FaultSpec {
    /// Parse the `MRSUB_FAULT` syntax. Never fails: unknown kinds simply
    /// never fire, and a malformed `@worker`/`:nth` part degrades to the
    /// untargeted/first-occurrence default.
    pub fn parse(s: &str) -> FaultSpec {
        let (body, worker) = match s.rsplit_once('@') {
            Some((b, w)) => (b, w.trim().parse().ok()),
            None => (s, None),
        };
        let (kind, nth) = match body.rsplit_once(':') {
            Some((k, n)) => match n.trim().parse::<u32>() {
                Ok(n) => (k, n.max(1)),
                Err(_) => (body, 1),
            },
            None => (body, 1),
        };
        FaultSpec { kind: kind.to_string(), nth, worker }
    }

    /// Whether this fault fires for worker slot `worker_id`.
    pub fn applies_to(&self, worker_id: u32) -> bool {
        self.worker.map_or(true, |w| w == worker_id)
    }
}

/// Execute a round-scoped injected fault if it fires this round; returns
/// the worker exit code to die with, `None` to proceed normally.
fn fire_round_fault(
    f: &FaultSpec,
    task: &RoundTask,
    rounds_seen: u32,
    prunes_seen: u32,
    w: &mut dyn Write,
    max_frame: usize,
) -> Option<i32> {
    let fires = match f.kind.as_str() {
        "die-mid-round" | "hang-round" | "truncate-frame" | "corrupt-checksum" => {
            rounds_seen == f.nth
        }
        "die-on-prune" => task.contains_prune() && prunes_seen == f.nth,
        _ => false,
    };
    if !fires {
        return None;
    }
    match f.kind.as_str() {
        // go silent: the coordinator's worker_timeout_ms must bound the
        // wait and declare the worker dead.
        "hang-round" => std::thread::sleep(Duration::from_secs(20)),
        "truncate-frame" => {
            let reply = FromWorker::RoundDone { replies: Vec::new(), calls: (0, 0, 0) };
            let mut framed = Vec::new();
            let _ = wire::write_frame(&mut framed, &reply.encode(), max_frame);
            let half = framed.len() / 2;
            let _ = w.write_all(&framed[..half]);
            let _ = w.flush();
        }
        "corrupt-checksum" => {
            let reply = FromWorker::RoundDone { replies: Vec::new(), calls: (0, 0, 0) };
            let mut framed = Vec::new();
            let _ = wire::write_frame(&mut framed, &reply.encode(), max_frame);
            if let Some(last) = framed.last_mut() {
                *last ^= 0xFF;
            }
            let _ = w.write_all(&framed);
            let _ = w.flush();
        }
        // die-mid-round / die-on-prune: vanish without a reply — the
        // coordinator sees a closed stream, like an OOM-killed worker.
        _ => {}
    }
    Some(3)
}

/// Worker-side adoption ([`RoundTask::AdoptMachines`]): append the
/// orphaned machines, rebuild their machine-resident state by replaying
/// the store-mutating history — deterministic, because RNG streams key on
/// *global* machine ids and every randomized task carries its seed — then
/// run the in-flight `pending` task for just the adopted machines,
/// returning one reply per adopted machine.
fn adopt_machines(
    rt: &mut WorkerRuntime,
    machines: Vec<u32>,
    shards: Vec<ShardData>,
    replay: Vec<RoundTask>,
    pending: &RoundTask,
) -> Vec<TaskReply> {
    let n0 = rt.machines.len();
    let adopted = machines.len();
    rt.machines.extend(machines.iter().map(|&i| i as usize));
    rt.shards.extend(shards);
    rt.stores.extend(std::iter::repeat_with(GuessStore::default).take(adopted));
    // the replay's bases differ from the cached (current-round) states;
    // checkout resets and replays as needed, then the pending re-run
    // advances the cache right back — bit-identity is unaffected.
    for t in &replay {
        let _ = shard::run_task_all_cached(
            &rt.oracle,
            &rt.shards[n0..],
            &mut rt.stores[n0..],
            &rt.machines[n0..],
            t,
            &crate::mapreduce::backend::Serial,
            &mut rt.cache,
        );
    }
    shard::run_task_all_cached(
        &rt.oracle,
        &rt.shards[n0..],
        &mut rt.stores[n0..],
        &rt.machines[n0..],
        pending,
        &crate::mapreduce::backend::Serial,
        &mut rt.cache,
    )
}

/// The job id the legacy single-tenant `Init` path lives under: `Init`
/// installs its runtime in this anonymous slot and `Round` frames look it
/// up there, so one worker loop serves both the one-shot pools and the
/// warm serving pool ([`ToWorker::Attach`] jobs, ids allocated from 1).
const LEGACY_JOB: u64 = 0;

/// Build a per-job worker runtime from a [`WorkerInit`]: construct the
/// oracle from its spec, then resolve shards from the wire payload or —
/// when the init is arena-flagged — from the zero-copy arena mapping.
/// `what` names the carrying frame (`Init`/`Attach`) in error messages.
fn build_runtime(
    init: WorkerInit,
    arena_map: Option<&ArenaMap>,
    what: &str,
) -> std::result::Result<WorkerRuntime, String> {
    let oracle =
        init.spec.build().map_err(|e| format!("cannot build oracle: {e}"))?;
    let shards = if init.arena {
        match arena_map {
            Some(map) => arena_shards(map, &init.machines)?,
            None => {
                return Err(format!(
                    "arena-flagged {what} but no arena mapping \
                     (transport without fd-passing?)"
                ))
            }
        }
    } else {
        init.shards.into_iter().map(ShardData::Owned).collect()
    };
    let counting = CountingOracle::new(oracle);
    let counters = counting.counter();
    let n = shards.len();
    Ok(WorkerRuntime {
        oracle: counting,
        counters,
        machines: init.machines.iter().map(|&i| i as usize).collect(),
        shards,
        stores: vec![GuessStore::default(); n],
        cache: StateCache::default(),
    })
}

/// Run one round task against a job's runtime, resolving adoption shards
/// from the arena when flagged. Returns the per-machine replies plus the
/// oracle-call deltas the round incurred on this runtime's counters.
fn run_round_task(
    rt: &mut WorkerRuntime,
    task: RoundTask,
    arena_map: Option<&ArenaMap>,
) -> std::result::Result<(Vec<TaskReply>, (u64, u64, u64)), String> {
    let before = rt.counters.snapshot();
    let replies = match task {
        RoundTask::AdoptMachines { machines, shards, arena, replay, pending } => {
            let data = if arena {
                match arena_map {
                    Some(map) => arena_shards(map, &machines)?,
                    None => {
                        return Err("arena-flagged adoption but no arena mapping".into())
                    }
                }
            } else {
                shards.into_iter().map(ShardData::Owned).collect()
            };
            adopt_machines(rt, machines, data, replay, &pending)
        }
        task => shard::run_task_all_cached(
            &rt.oracle,
            &rt.shards,
            &mut rt.stores,
            &rt.machines,
            &task,
            &crate::mapreduce::backend::Serial,
            &mut rt.cache,
        ),
    };
    let after = rt.counters.snapshot();
    let calls = (
        after.0.saturating_sub(before.0),
        after.1.saturating_sub(before.1),
        after.2.saturating_sub(before.2),
    );
    Ok((replies, calls))
}

/// The worker main loop over arbitrary streams (in-memory in unit tests,
/// pipes or sockets in production). Sends the connect-time `Hello` (as
/// worker slot `worker_id`), then serves frames — including
/// [`RoundTask::AdoptMachines`] adoptions from the elastic pool and the
/// warm pool's job-keyed `Attach`/`JobRound`/`Detach` — until shutdown.
/// Returns the process exit code. Wire-path form of
/// [`run_worker_mapped`] (no arena).
pub fn run_worker(
    r: &mut dyn Read,
    w: &mut dyn Write,
    max_frame: usize,
    worker_id: u32,
    fault: Option<&str>,
) -> i32 {
    run_worker_mapped(r, w, max_frame, worker_id, fault, None)
}

/// [`run_worker`] with an optional pre-received arena mapping: on the
/// `@uds+arena` transport, [`worker_main`] receives the arena fd before
/// the first frame, maps it, and hands the mapping in here; arena-flagged
/// `Init`/`AdoptMachines` frames then resolve shards from the mapping
/// (zero-copy) instead of decoding them. An arena-flagged frame without a
/// mapping is a structural `Fail`, never a silent empty shard.
pub fn run_worker_mapped(
    r: &mut dyn Read,
    w: &mut dyn Write,
    max_frame: usize,
    worker_id: u32,
    fault: Option<&str>,
    arena_map: Option<ArenaMap>,
) -> i32 {
    let fault = fault.map(FaultSpec::parse).filter(|f| f.applies_to(worker_id));
    let faulted = |kind: &str| fault.as_ref().is_some_and(|f| f.kind == kind);
    let hello_version = if faulted("bad-version") {
        WIRE_VERSION.wrapping_add(1)
    } else {
        WIRE_VERSION
    };
    if !send_reply(
        w,
        &FromWorker::Hello { version: hello_version, worker: worker_id },
        max_frame,
    ) {
        return 3;
    }
    // one independent runtime per job: the legacy `Init` path lives in the
    // anonymous slot [`LEGACY_JOB`], serving-daemon jobs under their ids.
    let mut jobs: BTreeMap<u64, WorkerRuntime> = BTreeMap::new();
    let mut rounds_seen = 0u32;
    let mut prunes_seen = 0u32;
    loop {
        let payload = match wire::read_frame(r, max_frame) {
            Ok((payload, _)) => payload,
            // clean EOF before a header byte: coordinator closed the stream.
            Err(WireError::Truncated { got: 0, .. }) => return 0,
            Err(e) => {
                send_reply(w, &FromWorker::Fail { message: e.to_string() }, max_frame);
                return 3;
            }
        };
        let msg = match ToWorker::decode(&payload) {
            Ok(msg) => msg,
            Err(e) => {
                send_reply(
                    w,
                    &FromWorker::Fail { message: format!("undecodable message: {e}") },
                    max_frame,
                );
                return 3;
            }
        };
        match msg {
            ToWorker::Init(init) => {
                match build_runtime(init, arena_map.as_ref(), "Init") {
                    Ok(rt) => {
                        jobs.insert(LEGACY_JOB, rt);
                        let version = if faulted("bad-version") {
                            WIRE_VERSION.wrapping_add(1)
                        } else {
                            WIRE_VERSION
                        };
                        if !send_reply(w, &FromWorker::Ready { version }, max_frame) {
                            return 3;
                        }
                    }
                    Err(message) => {
                        send_reply(w, &FromWorker::Fail { message }, max_frame);
                        return 3;
                    }
                }
            }
            ToWorker::Attach { job, init } => {
                match build_runtime(init, arena_map.as_ref(), "Attach") {
                    Ok(rt) => {
                        jobs.insert(job, rt);
                        let version = if faulted("bad-version") {
                            WIRE_VERSION.wrapping_add(1)
                        } else {
                            WIRE_VERSION
                        };
                        if !send_reply(w, &FromWorker::Ready { version }, max_frame) {
                            return 3;
                        }
                    }
                    // a failed attach poisons one job, not the worker: the
                    // other tenants' runtimes keep serving.
                    Err(message) => {
                        if !send_reply(w, &FromWorker::Fail { message }, max_frame) {
                            return 3;
                        }
                    }
                }
            }
            ToWorker::Round(task) => {
                rounds_seen += 1;
                if task.contains_prune() {
                    prunes_seen += 1;
                }
                if let Some(f) = &fault {
                    let fired = fire_round_fault(f, &task, rounds_seen, prunes_seen, w, max_frame);
                    if let Some(code) = fired {
                        return code;
                    }
                }
                let Some(rt) = jobs.get_mut(&LEGACY_JOB) else {
                    send_reply(
                        w,
                        &FromWorker::Fail { message: "round before init".into() },
                        max_frame,
                    );
                    return 3;
                };
                match run_round_task(rt, task, arena_map.as_ref()) {
                    Ok((replies, calls)) => {
                        if !send_reply(w, &FromWorker::RoundDone { replies, calls }, max_frame) {
                            return 3;
                        }
                    }
                    Err(message) => {
                        send_reply(w, &FromWorker::Fail { message }, max_frame);
                        return 3;
                    }
                }
            }
            ToWorker::JobRound { job, task } => {
                rounds_seen += 1;
                if task.contains_prune() {
                    prunes_seen += 1;
                }
                if let Some(f) = &fault {
                    let fired = fire_round_fault(f, &task, rounds_seen, prunes_seen, w, max_frame);
                    if let Some(code) = fired {
                        return code;
                    }
                }
                let Some(rt) = jobs.get_mut(&job) else {
                    // a coordinator bug, but scoped to this job: Fail its
                    // round and keep serving the other tenants.
                    let message = format!("job round before attach (job {job})");
                    if !send_reply(w, &FromWorker::Fail { message }, max_frame) {
                        return 3;
                    }
                    continue;
                };
                match run_round_task(rt, task, arena_map.as_ref()) {
                    Ok((replies, calls)) => {
                        if !send_reply(w, &FromWorker::RoundDone { replies, calls }, max_frame) {
                            return 3;
                        }
                    }
                    Err(message) => {
                        send_reply(w, &FromWorker::Fail { message }, max_frame);
                        return 3;
                    }
                }
            }
            ToWorker::Detach { job } => {
                // fire-and-forget: the coordinator does not await an ack.
                jobs.remove(&job);
            }
            ToWorker::Shutdown => return 0,
        }
    }
}

/// Entry point for the hidden `mrsub worker` subcommand: serve the wire
/// protocol on stdin/stdout (default) or on a dialed-back socket
/// (`--connect HOST:PORT` / `--connect-uds PATH` / `MRSUB_CONNECT`),
/// identifying as worker slot `--id N` / `MRSUB_WORKER_ID`. Returns the
/// process exit code.
pub fn worker_main(args: &[String]) -> i32 {
    let max_frame = std::env::var("MRSUB_MAX_FRAME")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(DEFAULT_MAX_FRAME);
    let fault = std::env::var("MRSUB_FAULT").ok();
    let mut endpoint = std::env::var("MRSUB_CONNECT").ok();
    let mut worker_id: u32 = std::env::var("MRSUB_WORKER_ID")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let mut it = args.iter();
    while let Some(flag) = it.next() {
        let mut value = |name: &str| -> Option<String> {
            let v = it.next();
            if v.is_none() {
                eprintln!("mrsub worker: {name} needs a value");
            }
            v.cloned()
        };
        match flag.as_str() {
            "--connect" => match value("--connect") {
                // bare HOST:PORT means TCP; explicit uds:/tcp: pass through.
                Some(v) if v.starts_with("uds:") || v.starts_with("tcp:") => {
                    endpoint = Some(v);
                }
                Some(v) => endpoint = Some(format!("tcp:{v}")),
                None => return 2,
            },
            "--connect-uds" => match value("--connect-uds") {
                Some(v) => endpoint = Some(format!("uds:{v}")),
                None => return 2,
            },
            "--id" => match value("--id").and_then(|v| v.parse().ok()) {
                Some(v) => worker_id = v,
                None => {
                    eprintln!("mrsub worker: --id needs a non-negative integer");
                    return 2;
                }
            },
            other => {
                eprintln!("mrsub worker: unknown flag {other:?}");
                return 2;
            }
        }
    }
    // fault: die before ever connecting — the coordinator's accept
    // deadline must degrade this into a structured connection error.
    let no_connect = fault
        .as_deref()
        .map(FaultSpec::parse)
        .is_some_and(|f| f.kind == "no-connect" && f.applies_to(worker_id));
    if no_connect {
        return 3;
    }
    match endpoint {
        None => {
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            let mut r = stdin.lock();
            let mut w = stdout.lock();
            run_worker(&mut r, &mut w, max_frame, worker_id, fault.as_deref())
        }
        Some(ep) => {
            // a hand-launched remote worker may beat the coordinator's
            // bind; retry briefly before giving up with a structured
            // connection-refused error on stderr.
            let mut link = None;
            for attempt in 0..10 {
                match transport::connect(&ep) {
                    Ok(l) => {
                        link = Some(l);
                        break;
                    }
                    Err(e) if attempt == 9 => {
                        eprintln!("mrsub worker: connect {ep}: {e} (connection refused?)");
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(150)),
                }
            }
            match link {
                Some(mut link) => {
                    // arena handshake: the coordinator passes the memfd
                    // as the stream's first byte, before any frame; map
                    // it now so arena-flagged Inits can resolve shards.
                    let want_arena =
                        std::env::var("MRSUB_ARENA").is_ok_and(|v| v == "1");
                    let arena_map = match (&link.control, want_arena) {
                        (LinkControl::Uds(s), true) => {
                            match arena::recv_fd(s, Duration::from_secs(30))
                                .and_then(ArenaMap::from_fd)
                            {
                                Ok(map) => Some(map),
                                Err(e) => {
                                    eprintln!("mrsub worker: arena mapping failed: {e}");
                                    return 3;
                                }
                            }
                        }
                        _ => None,
                    };
                    run_worker_mapped(
                        &mut *link.reader,
                        &mut *link.writer,
                        max_frame,
                        worker_id,
                        fault.as_deref(),
                        arena_map,
                    )
                }
                None => 3,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! In-memory worker-loop tests (no process spawning — the spawning
    //! path is exercised by `tests/backend_conformance.rs`, which can see
    //! the built `mrsub` binary).

    use super::*;
    use crate::mapreduce::wire::{Dec, Enc};

    fn spec() -> OracleSpec {
        OracleSpec::Coverage { n: 60, universe: 40, avg_degree: 3, weighted: false, seed: 5 }
    }

    fn framed(msgs: &[ToWorker]) -> Vec<u8> {
        let mut buf = Vec::new();
        for m in msgs {
            wire::write_frame(&mut buf, &m.encode(), DEFAULT_MAX_FRAME).unwrap();
        }
        buf
    }

    fn read_replies(buf: &[u8]) -> Vec<FromWorker> {
        let mut cursor = std::io::Cursor::new(buf.to_vec());
        let mut out = Vec::new();
        while let Ok((payload, _)) = wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME) {
            out.push(FromWorker::decode(&payload).unwrap());
        }
        out
    }

    #[test]
    fn worker_loop_serves_hello_init_round_shutdown() {
        let init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0, 1],
            shards: vec![(0..30).collect(), (30..60).collect()],
            sample: vec![1, 2, 3],
            arena: false,
        });
        let round = ToWorker::Round(RoundTask::LocalGreedy { k: 3 });
        let input = framed(&[init, round, ToWorker::Shutdown]);
        let mut r = std::io::Cursor::new(input);
        let mut out = Vec::new();
        let code = run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 7, None);
        assert_eq!(code, 0);
        let replies = read_replies(&out);
        assert_eq!(replies.len(), 3);
        assert!(
            matches!(replies[0], FromWorker::Hello { version: WIRE_VERSION, worker: 7 }),
            "first frame must be the connect-time Hello, got {:?}",
            replies[0]
        );
        assert!(matches!(replies[1], FromWorker::Ready { version: WIRE_VERSION }));
        match &replies[2] {
            FromWorker::RoundDone { replies, calls } => {
                assert_eq!(replies.len(), 2, "one reply per hosted machine");
                assert!(calls.0 > 0, "worker-side oracle calls reported");
                assert!(calls.1 > 0, "greedy heap fill runs the block path");
            }
            other => panic!("expected RoundDone, got {other:?}"),
        }
    }

    #[test]
    fn worker_eof_is_clean_exit_after_hello() {
        let mut r = std::io::Cursor::new(Vec::new());
        let mut out = Vec::new();
        assert_eq!(run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 0, None), 0);
        let replies = read_replies(&out);
        assert_eq!(replies.len(), 1, "only the Hello goes out before EOF");
        assert!(matches!(replies[0], FromWorker::Hello { .. }));
    }

    #[test]
    fn worker_round_before_init_fails_structurally() {
        let input = framed(&[ToWorker::Round(RoundTask::MaxSingleton)]);
        let mut r = std::io::Cursor::new(input);
        let mut out = Vec::new();
        assert_ne!(run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 0, None), 0);
        match &read_replies(&out)[1] {
            FromWorker::Fail { message } => assert!(message.contains("before init")),
            other => panic!("expected Fail, got {other:?}"),
        }
    }

    #[test]
    fn worker_rejects_corrupted_input_frame() {
        let mut input = framed(&[ToWorker::Round(RoundTask::MaxSingleton)]);
        let len = input.len();
        input[len - 1] ^= 0x55; // corrupt the checksum
        let mut r = std::io::Cursor::new(input);
        let mut out = Vec::new();
        assert_ne!(run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 0, None), 0);
        match &read_replies(&out)[1] {
            FromWorker::Fail { message } => assert!(message.contains("checksum")),
            other => panic!("expected Fail, got {other:?}"),
        }
    }

    #[test]
    fn bad_version_fault_poisons_the_hello() {
        let mut r = std::io::Cursor::new(Vec::new());
        let mut out = Vec::new();
        run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 2, Some("bad-version"));
        match &read_replies(&out)[0] {
            FromWorker::Hello { version, worker: 2 } => {
                assert_ne!(*version, WIRE_VERSION, "faulted Hello must carry a wrong version")
            }
            other => panic!("expected Hello, got {other:?}"),
        }
    }

    #[test]
    fn fault_injection_shapes_are_detectable() {
        // truncate-frame: the emitted bytes must NOT parse as a frame.
        let init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0],
            shards: vec![(0..60).collect()],
            sample: vec![],
            arena: false,
        });
        let round = ToWorker::Round(RoundTask::MaxSingleton);
        let input = framed(&[init.clone(), round.clone()]);
        let mut out = Vec::new();
        let code = run_worker(
            &mut std::io::Cursor::new(input.clone()),
            &mut out,
            DEFAULT_MAX_FRAME,
            0,
            Some("truncate-frame"),
        );
        assert_ne!(code, 0);
        // first two frames (Hello, Ready) parse, third is truncated.
        let mut cursor = std::io::Cursor::new(out);
        assert!(wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_ok());
        assert!(wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_ok());
        assert!(matches!(
            wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME),
            Err(WireError::Truncated { .. })
        ));

        // corrupt-checksum: third frame fails the checksum.
        let mut out = Vec::new();
        run_worker(
            &mut std::io::Cursor::new(input),
            &mut out,
            DEFAULT_MAX_FRAME,
            0,
            Some("corrupt-checksum"),
        );
        let mut cursor = std::io::Cursor::new(out);
        assert!(wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_ok());
        assert!(wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_ok());
        assert!(matches!(
            wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME),
            Err(WireError::BadChecksum { .. })
        ));
    }

    #[test]
    fn fault_spec_parses_kind_occurrence_and_target() {
        let f = FaultSpec::parse("die-mid-round");
        assert_eq!(f, FaultSpec { kind: "die-mid-round".into(), nth: 1, worker: None });
        assert!(f.applies_to(0) && f.applies_to(7));

        let f = FaultSpec::parse("die-mid-round:3");
        assert_eq!(f.nth, 3);
        let f = FaultSpec::parse("die-on-prune:2@1");
        assert_eq!(f, FaultSpec { kind: "die-on-prune".into(), nth: 2, worker: Some(1) });
        assert!(f.applies_to(1));
        assert!(!f.applies_to(0));

        // degenerate forms degrade instead of failing.
        assert_eq!(FaultSpec::parse("hang-round:x").kind, "hang-round:x");
        assert_eq!(FaultSpec::parse("no-connect@zzz").worker, None);
        assert_eq!(FaultSpec::parse("truncate-frame:0").nth, 1);
    }

    #[test]
    fn targeted_fault_spares_other_workers() {
        let init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0],
            shards: vec![(0..60).collect()],
            sample: vec![],
            arena: false,
        });
        let round = ToWorker::Round(RoundTask::MaxSingleton);
        let input = framed(&[init, round, ToWorker::Shutdown]);

        // fault targets worker 1: worker 0 serves the round normally…
        let mut out = Vec::new();
        let code = run_worker(
            &mut std::io::Cursor::new(input.clone()),
            &mut out,
            DEFAULT_MAX_FRAME,
            0,
            Some("die-mid-round@1"),
        );
        assert_eq!(code, 0, "untargeted worker must be unaffected");
        assert_eq!(read_replies(&out).len(), 3, "Hello + Ready + RoundDone");

        // …while worker 1 dies on the round frame without replying.
        let mut out = Vec::new();
        let code = run_worker(
            &mut std::io::Cursor::new(input),
            &mut out,
            DEFAULT_MAX_FRAME,
            1,
            Some("die-mid-round@1"),
        );
        assert_ne!(code, 0);
        assert_eq!(read_replies(&out).len(), 2, "Hello + Ready only");
    }

    #[test]
    fn occurrence_counter_delays_the_fault() {
        let init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0],
            shards: vec![(0..60).collect()],
            sample: vec![],
            arena: false,
        });
        let round = ToWorker::Round(RoundTask::MaxSingleton);
        let input = framed(&[init, round.clone(), round, ToWorker::Shutdown]);
        let mut out = Vec::new();
        let code = run_worker(
            &mut std::io::Cursor::new(input),
            &mut out,
            DEFAULT_MAX_FRAME,
            0,
            Some("die-mid-round:2"),
        );
        assert_ne!(code, 0);
        // Hello + Ready + first RoundDone, then death on round 2.
        assert_eq!(read_replies(&out).len(), 3);
    }

    #[test]
    fn adoption_replay_matches_native_hosting() {
        // A machine adopted mid-run (original shard + replayed history +
        // re-run pending task) must be indistinguishable from a machine
        // hosted since spawn — the bit-identity-under-recovery contract at
        // the worker level.
        let shard0: Vec<ElementId> = (0..30).collect();
        let shard1: Vec<ElementId> = (30..60).collect();
        let prune1 = RoundTask::PruneSample {
            base: vec![],
            floor: 0.1,
            tau: 0.5,
            per_share: 6,
            seed: 17,
            round: 1,
        };
        // the pending task reads the machine-resident pruned base, so it
        // only matches if the replay rebuilt the store correctly.
        let prune2 = RoundTask::PruneSample {
            base: vec![2, 40],
            floor: 0.3,
            tau: 0.9,
            per_share: 4,
            seed: 23,
            round: 2,
        };

        // reference: one worker hosts both machines from the start.
        let input = framed(&[
            ToWorker::Init(WorkerInit {
                spec: spec(),
                machines: vec![0, 1],
                shards: vec![shard0.clone(), shard1.clone()],
                sample: vec![],
                arena: false,
            }),
            ToWorker::Round(prune1.clone()),
            ToWorker::Round(prune2.clone()),
            ToWorker::Shutdown,
        ]);
        let mut out = Vec::new();
        assert_eq!(
            run_worker(&mut std::io::Cursor::new(input), &mut out, DEFAULT_MAX_FRAME, 0, None),
            0
        );
        let reference = read_replies(&out);
        let FromWorker::RoundDone { replies: ref_round2, .. } = &reference[3] else {
            panic!("expected the prune2 RoundDone, got {:?}", reference[3]);
        };
        let want_machine1 = ref_round2[1].clone();

        // elastic: the worker hosts machine 0 only; machine 1 arrives by
        // adoption, with prune1 in the replay and prune2 as pending.
        let adopt = RoundTask::AdoptMachines {
            machines: vec![1],
            shards: vec![shard1],
            arena: false,
            replay: vec![prune1.clone()],
            pending: Box::new(prune2),
        };
        let input = framed(&[
            ToWorker::Init(WorkerInit {
                spec: spec(),
                machines: vec![0],
                shards: vec![shard0],
                sample: vec![],
                arena: false,
            }),
            ToWorker::Round(prune1),
            ToWorker::Round(adopt),
            ToWorker::Shutdown,
        ]);
        let mut out = Vec::new();
        assert_eq!(
            run_worker(&mut std::io::Cursor::new(input), &mut out, DEFAULT_MAX_FRAME, 0, None),
            0
        );
        let elastic = read_replies(&out);
        let FromWorker::RoundDone { replies: adopt_replies, .. } = &elastic[3] else {
            panic!("expected the adoption RoundDone, got {:?}", elastic[3]);
        };
        assert_eq!(adopt_replies.len(), 1, "one reply per adopted machine");
        assert_eq!(
            adopt_replies[0], want_machine1,
            "adopted machine must reproduce the natively-hosted reply bit for bit"
        );
    }

    #[test]
    fn recovery_policy_parse_label_roundtrip() {
        assert_eq!(RecoveryPolicy::parse("fail"), Some(RecoveryPolicy::Fail));
        assert_eq!(RecoveryPolicy::parse("requeue"), Some(RecoveryPolicy::Requeue { budget: 1 }));
        assert_eq!(RecoveryPolicy::parse("requeue:3"), Some(RecoveryPolicy::Requeue { budget: 3 }));
        assert_eq!(RecoveryPolicy::parse("requeue:0"), None, "zero budget is spelled fail");
        assert_eq!(RecoveryPolicy::parse("retry"), None);
        assert_eq!(RecoveryPolicy::parse("requeue:-1"), None);
        for p in [RecoveryPolicy::Fail, RecoveryPolicy::Requeue { budget: 7 }] {
            assert_eq!(RecoveryPolicy::parse(&p.label()), Some(p));
        }
        assert_eq!(RecoveryPolicy::default(), RecoveryPolicy::Fail);
    }

    #[test]
    fn spec_is_wire_codable_inside_init() {
        // Init round-trips through encode/decode with the spec intact.
        let init = WorkerInit {
            spec: spec(),
            machines: vec![3, 7],
            shards: vec![vec![1, 2], vec![3]],
            sample: vec![9],
            arena: false,
        };
        let msg = ToWorker::Init(init.clone());
        match ToWorker::decode(&msg.encode()).unwrap() {
            ToWorker::Init(back) => assert_eq!(back, init),
            other => panic!("expected Init, got {other:?}"),
        }
        // Enc/Dec are also usable standalone for specs.
        let mut enc = Enc::new();
        init.spec.encode(&mut enc);
        let mut dec = Dec::new(&enc.buf);
        assert_eq!(OracleSpec::decode(&mut dec).unwrap(), init.spec);
    }

    #[test]
    fn arena_init_without_mapping_fails_structurally() {
        // an arena-flagged Init reaching a worker that never received the
        // fd (pipe/TCP, or a lost fd-pass) must Fail, not serve garbage.
        let init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0],
            shards: Vec::new(),
            sample: Vec::new(),
            arena: true,
        });
        let input = framed(&[init]);
        let mut r = std::io::Cursor::new(input);
        let mut out = Vec::new();
        assert_ne!(run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 0, None), 0);
        match &read_replies(&out)[1] {
            FromWorker::Fail { message } => {
                assert!(message.contains("no arena mapping"), "got: {message}")
            }
            other => panic!("expected Fail, got {other:?}"),
        }
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn arena_worker_round_matches_wire_worker_round() {
        // the zero-copy contract at the worker level: an arena-resolved
        // worker must produce byte-identical RoundDone frames to a worker
        // that decoded the same shards off the wire.
        use std::os::unix::net::UnixStream;
        let shards: Vec<Vec<ElementId>> = vec![(0..30).collect(), (30..60).collect()];
        let sample: Vec<ElementId> = vec![1, 2, 3];
        let round = ToWorker::Round(RoundTask::Batch(vec![
            RoundTask::LocalGreedy { k: 3 },
            RoundTask::PruneSample {
                base: vec![],
                floor: 0.1,
                tau: 0.5,
                per_share: 6,
                seed: 17,
                round: 1,
            },
        ]));

        // wire reference.
        let wire_init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0, 1],
            shards: shards.clone(),
            sample: sample.clone(),
            arena: false,
        });
        let input = framed(&[wire_init, round.clone(), ToWorker::Shutdown]);
        let mut out = Vec::new();
        assert_eq!(
            run_worker(&mut std::io::Cursor::new(input), &mut out, DEFAULT_MAX_FRAME, 0, None),
            0
        );
        let wire_replies = read_replies(&out);

        // arena path: build, fd-pass over a socketpair, map, serve.
        let a = Arena::build(&shards, &sample).expect("memfd arena");
        let (tx, rx) = UnixStream::pair().unwrap();
        a.send_fd(&tx).unwrap();
        let map = ArenaMap::from_fd(
            arena::recv_fd(&rx, Duration::from_secs(5)).unwrap(),
        )
        .unwrap();
        let arena_init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0, 1],
            shards: Vec::new(),
            sample: Vec::new(),
            arena: true,
        });
        let input = framed(&[arena_init, round, ToWorker::Shutdown]);
        let mut out = Vec::new();
        assert_eq!(
            run_worker_mapped(
                &mut std::io::Cursor::new(input),
                &mut out,
                DEFAULT_MAX_FRAME,
                0,
                None,
                Some(map),
            ),
            0
        );
        assert_eq!(read_replies(&out), wire_replies, "arena and wire workers must agree");
    }
}
