//! DASH — the low-adaptivity distributed threshold algorithm (Dey et al.,
//! arXiv 2206.09563): a descending-threshold sweep where each threshold
//! costs *one* MapReduce round, so the total round count is
//! `O(log(k/ε) / ε)` — independent of `k` — instead of the `k` adaptive
//! rounds of sequential greedy.
//!
//! Per threshold `τ`, every machine ships its shard elements whose
//! marginal w.r.t. the broadcast partial solution clears `τ` *and* that
//! the constraint still admits ([`RoundTask::ConstrainedFilter`], replies
//! carrying the marginals). The coordinator sequences the candidates by
//! shipped value (descending, id ascending on ties — fully deterministic)
//! and keeps those whose *recomputed* marginal still clears `(1 − ε)·τ`,
//! the standard guard against stale filter-time marginals. With the
//! default cardinality constraint this matches the classic descending-
//! threshold guarantee; with a partition matroid the output is feasible
//! by construction and the greedy exchange argument gives the usual
//! constant factor.

use std::cmp::Ordering;

use super::{finish, AlgResult, MrAlgorithm};
use crate::core::{Constraint, ElementId, Result, Solution};
use crate::mapreduce::wire::{RoundTask, TaskReply};
use crate::mapreduce::{ClusterConfig, MrCluster};
use crate::oracle::Oracle;

/// DASH with threshold decay `1 − eps` (see module docs).
#[derive(Debug, Clone)]
pub struct Dash {
    /// Threshold decay / slack parameter.
    pub eps: f64,
    /// Independence system; `None` = the uniform matroid of rank `k`.
    pub constraint: Option<Constraint>,
}

impl Dash {
    /// Cardinality-constrained DASH.
    pub fn new(eps: f64) -> Self {
        Dash { eps, constraint: None }
    }

    /// DASH under an explicit independence system.
    pub fn constrained(eps: f64, constraint: Constraint) -> Self {
        Dash { eps, constraint: Some(constraint) }
    }
}

/// Upper bound on DASH's MapReduce round count: one max-singleton round
/// plus one round per threshold in the geometric sweep from `d` down to
/// `ε·d/k` with ratio `1 − ε` — `⌈ln(k/ε) / −ln(1−ε)⌉`, independent of
/// the ground-set size and sublinear in `k`.
pub fn dash_round_bound(k: usize, eps: f64) -> usize {
    ((k as f64 / eps).ln() / -(1.0 - eps).ln()).ceil() as usize + 2
}

impl MrAlgorithm for Dash {
    fn name(&self) -> String {
        match &self.constraint {
            None => format!("dash(eps={})", self.eps),
            Some(c) => format!("dash(eps={},{})", self.eps, c.label()),
        }
    }

    fn run(&self, oracle: &dyn Oracle, k: usize, cfg: &ClusterConfig) -> Result<AlgResult> {
        let n = oracle.ground_size();
        let constraint =
            self.constraint.clone().unwrap_or_else(|| Constraint::cardinality(k));
        constraint.validate(n)?;
        let mut cluster = MrCluster::new(n, k, cfg)?;

        // Round 1: the global max singleton anchors the threshold sweep.
        let d = cluster
            .shard_round("r1:max-singleton", 0, oracle, &RoundTask::MaxSingleton)?
            .iter()
            .map(TaskReply::as_scalar)
            .fold(0.0_f64, f64::max);
        if d <= 0.0 {
            return Ok(AlgResult {
                solution: Solution::empty(),
                metrics: cluster.into_metrics(),
            });
        }

        let floor = self.eps * d / k as f64;
        let mut tau = d;
        let mut state = oracle.state();
        let mut cursor = constraint.cursor();
        let mut round = 1usize;
        while tau >= floor && state.len() < k && !cursor.saturated() {
            round += 1;
            let task = RoundTask::ConstrainedFilter {
                base: state.selected().to_vec(),
                tau,
                constraint: constraint.clone(),
            };
            let replies = cluster.shard_round(
                &format!("r{round}:constrained-filter"),
                state.len(),
                oracle,
                &task,
            )?;
            // shards partition the ground set, so candidate ids are unique
            // across replies; order by shipped value desc, id asc.
            let mut cands: Vec<(f64, ElementId)> = Vec::new();
            for reply in replies {
                let (ids, values) = reply.into_valued();
                cands.extend(values.into_iter().zip(ids));
            }
            cands.sort_unstable_by(|a, b| {
                b.0.partial_cmp(&a.0).unwrap_or(Ordering::Equal).then_with(|| a.1.cmp(&b.1))
            });
            for (_, e) in cands {
                if state.len() >= k || cursor.saturated() {
                    break;
                }
                if !cursor.admits(e) {
                    continue;
                }
                // re-check against the *current* selection: filter-time
                // marginals go stale as this pass inserts.
                if state.marginal(e) >= (1.0 - self.eps) * tau {
                    state.insert(e);
                    cursor.admit(e);
                }
            }
            tau *= 1.0 - self.eps;
        }

        Ok(AlgResult {
            solution: finish(oracle, state.selected().to_vec()),
            metrics: cluster.into_metrics(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::dicut::PlantedDicutGen;
    use crate::workload::planted::{PlantedCoverageGen, PlantedMatroidGen};
    use crate::workload::WorkloadGen;

    fn cfg(seed: u64) -> ClusterConfig {
        ClusterConfig { seed, parallel: false, ..ClusterConfig::default() }
    }

    #[test]
    fn recovers_most_of_the_planted_cover() {
        let inst = PlantedCoverageGen::dense(10, 1000, 500).generate(1);
        let opt = inst.known_opt.unwrap();
        let res = Dash::new(0.1).run(inst.oracle.as_ref(), 10, &cfg(2)).unwrap();
        assert!(res.solution.value / opt >= 0.5, "ratio {}", res.solution.value / opt);
    }

    #[test]
    fn round_count_is_low_adaptivity() {
        let inst = PlantedCoverageGen::dense(32, 2000, 800).generate(3);
        let eps = 0.3;
        let res = Dash::new(eps).run(inst.oracle.as_ref(), 32, &cfg(4)).unwrap();
        let rounds = res.metrics.num_rounds();
        assert!(
            rounds <= dash_round_bound(32, eps),
            "{rounds} rounds exceeds the bound {}",
            dash_round_bound(32, eps)
        );
        assert!(rounds < 32, "DASH must beat greedy's k-round adaptivity");
    }

    #[test]
    fn matroid_constrained_output_is_feasible() {
        let g = PlantedMatroidGen::new(8, 400, 100, 1);
        let inst = g.generate(5);
        let c = g.constraint(inst.n);
        let res = Dash::constrained(0.1, c.clone())
            .run(inst.oracle.as_ref(), 8, &cfg(6))
            .unwrap();
        assert!(c.is_feasible(&res.solution.elements), "selection violates the matroid");
        assert!(res.solution.value > 0.0);
    }

    #[test]
    fn nonmonotone_dicut_only_selects_positive_gains() {
        let g = PlantedDicutGen::new(8, 60, 4);
        let inst = g.generate(7);
        let res = Dash::new(0.2).run(inst.oracle.as_ref(), 8, &cfg(8)).unwrap();
        assert!(res.solution.value > 0.0, "dicut selection must cut something");
        assert!(res.solution.len() <= 8);
    }

    #[test]
    fn zero_objective_returns_empty() {
        let o = crate::oracle::modular::ModularOracle::new(vec![0.0; 40]);
        let res = Dash::new(0.1).run(&o, 5, &cfg(9)).unwrap();
        assert!(res.solution.elements.is_empty());
    }
}
