//! The paper's algorithms and the baselines they are compared against.
//!
//! | module | contents | rounds | guarantee |
//! |---|---|---|---|
//! | [`threshold`] | Algorithms 1–2 (ThresholdGreedy / ThresholdFilter) | — | building blocks |
//! | [`two_round`] | Algorithm 4, OPT known | 2 | 1/2 |
//! | [`multi_round`] | Algorithm 5, OPT known or guessed | 2t (+2) | 1 − (1 − 1/(t+1))^t |
//! | [`dense`] | Algorithm 6 (dense inputs) | 2 | 1/2 − ε |
//! | [`sparse`] | Algorithm 7 (sparse inputs) | 2 | 1/2 − ε |
//! | [`combined`] | Theorem 8 (dense ∥ sparse) | 2 | 1/2 − ε |
//! | [`greedy`] | sequential greedy / lazy / threshold greedy | — | 1 − 1/e |
//! | [`stochastic`] | stochastic greedy | — | 1 − 1/e − ε (expectation) |
//! | [`randgreedi`] | Barbosa et al. distributed greedy (cardinality default; randomized-partition matroid/non-monotone form via `constrained`) | 2 (or rounds+1) | 1/2 (w/ duplication caveats) |
//! | [`mz_coreset`] | Mirrokni–Zadimoghaddam core-sets | 2 | 0.27 |
//! | [`sample_prune`] | Kumar et al. Sample&Prune | O(log(k)/ε) | 1/2 − ε |
//! | [`dash`] | DASH low-adaptivity threshold sweep (cardinality or matroid) | O(log(k/ε)/ε) | 1/2 − ε |

pub mod combined;
pub mod dash;
pub mod dense;
pub mod greedy;
pub mod multi_round;
pub mod mz_coreset;
pub mod randgreedi;
pub mod sample_prune;
pub mod sparse;
pub mod stochastic;
pub mod threshold;
pub mod two_round;

use crate::core::{Result, Solution};
use crate::mapreduce::ClusterConfig;
use crate::metrics::MrMetrics;
use crate::oracle::Oracle;

/// Result of a (distributed) algorithm execution.
#[derive(Debug, Clone)]
pub struct AlgResult {
    /// The solution found.
    pub solution: Solution,
    /// MRC cost metrics (empty `rounds` for sequential baselines).
    pub metrics: MrMetrics,
}

impl AlgResult {
    /// Wrap a sequential result (no MapReduce rounds).
    pub fn sequential(solution: Solution, n: usize, k: usize) -> Self {
        AlgResult {
            solution,
            metrics: MrMetrics { n, k, machines: 1, sample_size: 0, rounds: Vec::new() },
        }
    }
}

/// A cardinality-constrained submodular maximization algorithm running in
/// the simulated MRC cluster (or sequentially, reporting zero rounds).
pub trait MrAlgorithm {
    /// Display name, e.g. `"combined(eps=0.1)"`.
    fn name(&self) -> String;

    /// Run on `oracle` with cardinality bound `k`.
    fn run(&self, oracle: &dyn Oracle, k: usize, cfg: &ClusterConfig) -> Result<AlgResult>;
}

/// Evaluate and package a set of selected elements as a [`Solution`].
pub(crate) fn finish(oracle: &dyn Oracle, elements: Vec<crate::core::ElementId>) -> Solution {
    let value = oracle.value(&elements);
    Solution { elements, value }
}
