//! (Weighted) set-coverage oracle: `f(S) = Σ_{j ∈ ∪_{e∈S} C_e} w_j`.
//!
//! The canonical monotone submodular family and the one the paper's
//! antecedents (max-coverage in MapReduce/streaming: McGregor–Vu,
//! Assadi–Khanna) study directly. Elements are sets over a universe
//! `0..universe`; the state keeps a covered bitmap so a marginal costs
//! O(|C_e|).

use std::sync::Arc;

use super::{Oracle, OracleState, Selection};
use crate::core::ElementId;

/// Immutable coverage instance (CSR adjacency: element -> covered items).
#[derive(Debug)]
pub struct CoverageOracle {
    data: Arc<CoverageData>,
}

#[derive(Debug)]
struct CoverageData {
    /// CSR offsets, length n+1.
    offsets: Vec<u32>,
    /// Concatenated covered-item lists.
    items: Vec<u32>,
    /// Universe item weights (all 1.0 for unweighted coverage).
    weights: Vec<f64>,
}

impl CoverageOracle {
    /// Build from per-element item lists and a weight per universe item.
    ///
    /// Panics if any item id is out of range of `weights`.
    pub fn new(sets: Vec<Vec<u32>>, weights: Vec<f64>) -> Self {
        let mut offsets = Vec::with_capacity(sets.len() + 1);
        let mut items = Vec::new();
        offsets.push(0u32);
        for s in &sets {
            for &j in s {
                assert!((j as usize) < weights.len(), "item {j} out of universe");
                items.push(j);
            }
            offsets.push(items.len() as u32);
        }
        CoverageOracle { data: Arc::new(CoverageData { offsets, items, weights }) }
    }

    /// Unweighted coverage (all item weights 1).
    pub fn unweighted(sets: Vec<Vec<u32>>, universe: usize) -> Self {
        Self::new(sets, vec![1.0; universe])
    }

    /// Universe size.
    pub fn universe(&self) -> usize {
        self.data.weights.len()
    }

    /// Items covered by element `e`.
    pub fn items_of(&self, e: ElementId) -> &[u32] {
        let d = &self.data;
        &d.items[d.offsets[e as usize] as usize..d.offsets[e as usize + 1] as usize]
    }

    /// Total universe weight — an upper bound on OPT for any k.
    pub fn total_weight(&self) -> f64 {
        self.data.weights.iter().sum()
    }
}

impl Oracle for CoverageOracle {
    fn ground_size(&self) -> usize {
        self.data.offsets.len() - 1
    }

    fn state(&self) -> Box<dyn OracleState> {
        Box::new(CoverageState {
            data: Arc::clone(&self.data),
            covered: vec![false; self.data.weights.len()],
            sel: Selection::new(self.data.offsets.len() - 1),
            value: 0.0,
        })
    }
}

#[derive(Debug, Clone)]
struct CoverageState {
    data: Arc<CoverageData>,
    covered: Vec<bool>,
    sel: Selection,
    value: f64,
}

impl CoverageState {
    /// Per-element gain kernel shared by the scalar and block paths, so
    /// both return bit-identical values.
    #[inline]
    fn gain_of(&self, e: ElementId) -> f64 {
        let d = &*self.data;
        let (lo, hi) = (d.offsets[e as usize] as usize, d.offsets[e as usize + 1] as usize);
        let mut gain = 0.0;
        for &j in &d.items[lo..hi] {
            if !self.covered[j as usize] {
                gain += d.weights[j as usize];
            }
        }
        gain
    }
}

impl OracleState for CoverageState {
    fn value(&self) -> f64 {
        self.value
    }

    fn marginal(&self, e: ElementId) -> f64 {
        if self.sel.contains(e) {
            return 0.0;
        }
        self.gain_of(e)
    }

    /// Block path: one CSR sweep per block with the member test and data
    /// pointers hoisted out of the virtual call — the coverage hot path of
    /// ThresholdFilter.
    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(es.len(), out.len());
        for (o, &e) in out.iter_mut().zip(es) {
            *o = if self.sel.contains(e) { 0.0 } else { self.gain_of(e) };
        }
    }

    fn reset(&mut self) {
        let data = Arc::clone(&self.data);
        for &e in self.sel.order() {
            let (lo, hi) =
                (data.offsets[e as usize] as usize, data.offsets[e as usize + 1] as usize);
            for &j in &data.items[lo..hi] {
                self.covered[j as usize] = false;
            }
        }
        self.sel.clear();
        self.value = 0.0;
    }

    fn insert(&mut self, e: ElementId) {
        if !self.sel.insert(e) {
            return;
        }
        let d = Arc::clone(&self.data);
        let (lo, hi) = (d.offsets[e as usize] as usize, d.offsets[e as usize + 1] as usize);
        for &j in &d.items[lo..hi] {
            let j = j as usize;
            if !self.covered[j] {
                self.covered[j] = true;
                self.value += d.weights[j];
            }
        }
    }

    fn selected(&self) -> &[ElementId] {
        self.sel.order()
    }

    fn clone_state(&self) -> Box<dyn OracleState> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::axioms::check_axioms;
    use crate::util::check::forall;

    fn tiny() -> CoverageOracle {
        // e0 = {0,1}, e1 = {1,2}, e2 = {3}, e3 = {} (empty set)
        CoverageOracle::unweighted(vec![vec![0, 1], vec![1, 2], vec![3], vec![]], 4)
    }

    #[test]
    fn values_and_marginals() {
        let o = tiny();
        assert_eq!(o.ground_size(), 4);
        assert_eq!(o.universe(), 4);
        assert_eq!(o.value(&[0]), 2.0);
        assert_eq!(o.value(&[0, 1]), 3.0);
        assert_eq!(o.value(&[0, 1, 2]), 4.0);
        assert_eq!(o.value(&[3]), 0.0);
        let mut st = o.state();
        st.insert(0);
        assert_eq!(st.marginal(1), 1.0); // only item 2 is new
        assert_eq!(st.marginal(0), 0.0); // member
        st.insert(1);
        assert_eq!(st.value(), 3.0);
        assert_eq!(st.selected(), &[0, 1]);
    }

    #[test]
    fn weighted_coverage_counts_weights() {
        let o = CoverageOracle::new(vec![vec![0], vec![1], vec![0, 1]], vec![5.0, 0.5]);
        assert_eq!(o.value(&[2]), 5.5);
        assert_eq!(o.total_weight(), 5.5);
        let mut st = o.state();
        st.insert(0);
        assert_eq!(st.marginal(2), 0.5);
    }

    #[test]
    fn axioms_hold_random_instance() {
        let o = crate::workload::coverage::CoverageGen::new(60, 40, 5).build(3);
        check_axioms(&o, 11, 40);
    }

    #[test]
    fn prop_coverage_axioms() {
        forall(0xC01, 25, |g| {
            let seed = g.u64_in(1000);
            let n = g.usize_in(8, 40);
            let u = g.usize_in(4, 30);
            let deg = g.usize_in(1, 6);
            let o = crate::workload::coverage::CoverageGen::new(n, u, deg).build(seed);
            check_axioms(&o, seed ^ 0xabc, 8);
        });
    }

    #[test]
    fn prop_value_never_exceeds_universe() {
        forall(0xC02, 30, |g| {
            let seed = g.u64_in(200);
            let o = crate::workload::coverage::CoverageGen::new(30, 20, 4).build(seed);
            let all: Vec<ElementId> = (0..30).collect();
            assert!(o.value(&all) <= o.total_weight() + 1e-9);
        });
    }
}
