//! Instance generators for the experiment suite.
//!
//! Each generator is a small config struct with a deterministic
//! `build(seed)` (concrete oracle, used by unit tests) and a
//! [`WorkloadGen::generate`] that wraps it into an [`Instance`] with
//! provenance metadata and — where the construction permits — the *exact*
//! optimum, which lets benches report true approximation ratios rather
//! than ratios against greedy.

pub mod adversarial;
pub mod corpus;
pub mod coverage;
pub mod dicut;
pub mod facility;
pub mod graph;
pub mod planted;

use std::sync::Arc;

use crate::oracle::spec::OracleSpec;
use crate::oracle::Oracle;

/// A generated problem instance: oracle + provenance.
#[derive(Clone)]
pub struct Instance {
    /// Human-readable description, e.g. `"coverage(n=10000,u=4000,deg=12)"`.
    pub name: String,
    /// The submodular objective.
    pub oracle: Arc<dyn Oracle>,
    /// Ground-set size.
    pub n: usize,
    /// Exact `OPT_k` when the construction plants it (planted / adversarial
    /// / modular); `None` otherwise.
    pub known_opt: Option<f64>,
    /// The `k` the planted optimum refers to (when `known_opt` is set).
    pub planted_k: Option<usize>,
    /// Serializable construction recipe — what the shared-nothing process
    /// backend ships to its workers so they can rebuild a bit-identical
    /// oracle. All in-repo generators attach one.
    pub spec: Option<OracleSpec>,
}

impl Instance {
    /// Build an instance with no planted optimum.
    pub fn new(name: impl Into<String>, oracle: Arc<dyn Oracle>) -> Self {
        let n = oracle.ground_size();
        Instance { name: name.into(), oracle, n, known_opt: None, planted_k: None, spec: None }
    }

    /// Attach a known optimum for cardinality `k`.
    pub fn with_opt(mut self, opt: f64, k: usize) -> Self {
        self.known_opt = Some(opt);
        self.planted_k = Some(k);
        self
    }

    /// Attach the serializable construction recipe.
    pub fn with_spec(mut self, spec: OracleSpec) -> Self {
        self.spec = Some(spec);
        self
    }
}

/// A reproducible instance generator.
pub trait WorkloadGen {
    /// Generate the instance deterministically from `seed`.
    fn generate(&self, seed: u64) -> Instance;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::modular::ModularOracle;

    #[test]
    fn instance_metadata() {
        let inst = Instance::new("m", Arc::new(ModularOracle::new(vec![1.0, 2.0])))
            .with_opt(2.0, 1);
        assert_eq!(inst.n, 2);
        assert_eq!(inst.known_opt, Some(2.0));
        assert_eq!(inst.planted_k, Some(1));
    }
}
