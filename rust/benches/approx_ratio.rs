//! E1 ("Table 1") — approximation ratios of the paper's 2-round
//! algorithms across workload families, plus the E5 ("Table 2")
//! dense/sparse regime split.
//!
//! Paper claims reproduced: Theorem 8 (combined ≥ 1/2 − ε in 2 rounds, no
//! duplication, OPT unknown); Lemma 1 (Algorithm 4 ≥ 1/2 with OPT);
//! Lemmas 5/7 (dense/sparse sub-algorithms on their regimes).
//! Ratios are vs the planted OPT where known (marked *), else vs lazy
//! greedy (conservative: greedy ≤ OPT).

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::dense::DenseTwoRound;
use mrsub::algorithms::sparse::SparseTwoRound;
use mrsub::algorithms::two_round::TwoRoundKnownOpt;
use mrsub::algorithms::MrAlgorithm;
use mrsub::coordinator::run_experiment;
use mrsub::mapreduce::ClusterConfig;
use mrsub::workload::corpus::ZipfCorpusGen;
use mrsub::workload::coverage::CoverageGen;
use mrsub::workload::facility::FacilityGen;
use mrsub::workload::graph::GraphGen;
use mrsub::workload::planted::PlantedCoverageGen;
use mrsub::workload::{Instance, WorkloadGen};

fn main() {
    let k = 40;
    let eps = 0.1;
    let seeds = [1u64, 2, 3];
    let workloads: Vec<(&str, Box<dyn Fn(u64) -> Instance>)> = vec![
        ("coverage(20k)", Box::new(|s| CoverageGen::new(20_000, 8_000, 10).generate(s))),
        ("wcoverage(20k)", Box::new(|s| CoverageGen::weighted(20_000, 8_000, 10).generate(s))),
        ("zipf(15k docs)", Box::new(|s| ZipfCorpusGen::new(15_000, 10_000, 30).generate(s))),
        ("facility(4k x 1k)", Box::new(|s| FacilityGen::clustered(4_000, 1_000, 12).generate(s))),
        ("ba-graph(10k)", Box::new(|s| GraphGen::barabasi_albert(10_000, 3).generate(s))),
        ("planted-dense*", Box::new(|s| PlantedCoverageGen::dense(40, 8_000, 20_000).generate(s))),
        ("planted-sparse*", Box::new(|s| PlantedCoverageGen::sparse(40, 8_000, 20_000).generate(s))),
    ];

    println!("== E1/E5: 2-round approximation ratios (k={k}, eps={eps}, {} seeds) ==", seeds.len());
    println!("(ratio vs planted OPT where marked *, else vs lazy greedy)");
    println!(
        "{:<18} {:>10} {:>10} {:>10} {:>10} {:>8} {:>10}",
        "workload", "combined", "dense", "sparse", "alg4-opt", "rounds", "central"
    );
    for (name, gen) in &workloads {
        let mut ratios = [0.0f64; 4];
        let mut rounds = 0;
        let mut central = 0usize;
        for &seed in &seeds {
            let inst = gen(seed);
            let cfg = ClusterConfig { seed, ..ClusterConfig::default() };
            let algs: Vec<Box<dyn MrAlgorithm>> = vec![
                Box::new(CombinedTwoRound::new(eps)),
                Box::new(DenseTwoRound::new(eps)),
                Box::new(SparseTwoRound::new(eps)),
                Box::new(TwoRoundKnownOpt::new(inst.known_opt.unwrap_or_else(|| {
                    mrsub::algorithms::greedy::lazy_greedy(&inst.oracle, k).value
                }))),
            ];
            for (i, alg) in algs.iter().enumerate() {
                let rec = run_experiment(&inst, alg.as_ref(), k, &cfg).expect("run");
                ratios[i] += rec.ratio / seeds.len() as f64;
                if i == 0 {
                    rounds = rec.rounds;
                    central = central.max(rec.peak_central_recv);
                }
            }
        }
        println!(
            "{:<18} {:>10.4} {:>10.4} {:>10.4} {:>10.4} {:>8} {:>10}",
            name, ratios[0], ratios[1], ratios[2], ratios[3], rounds, central
        );
    }
    println!("\npaper bound: combined ≥ 1/2 − ε = {:.2} in exactly 2 rounds (Theorem 8);", 0.5 - eps);
    println!("expected shape: combined ≥ bound everywhere; dense weak on planted-sparse,");
    println!("sparse weak on dense families — their max is not (that is Theorem 8's point).");
}
