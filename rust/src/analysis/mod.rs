//! Dependency-free static analysis: the `mrsub check-invariants` engine.
//!
//! The repo's bit-identity contract rests on invariants no compiler pass
//! checks: wire-layout changes must move
//! [`crate::mapreduce::wire::WIRE_VERSION`] and the committed fingerprint
//! together, selection-critical code must stay deterministic, and the
//! hand-declared FFI in [`crate::mapreduce::arena`] must keep its `unsafe`
//! audited. This module grows the [`crate::util::check`] idiom — tiny,
//! offline, hand-rolled verification substrates — into a lint engine:
//!
//! * [`scan`] — a line/token-level Rust scanner (comment/literal-aware)
//!   shared by every lint;
//! * [`lints`] — the registry ([`LINTS`]) and the per-lint passes;
//! * [`fingerprint`] — the committed wire-layout fingerprint behind the
//!   `wire-drift` lint (re-recorded via `mrsub check-invariants --bless`);
//! * [`check_tree`] / [`Report`] — the driver plus human and JSON reports.
//!
//! The engine is exercised three ways: `cargo test` runs fixture trees
//! with planted violations (`rust/tests/invariant_lints.rs`),
//! `./verify.sh lint` (and its CI job) runs the full registry over the
//! repo tree, and `mrsub check-invariants --json` feeds tooling.

pub mod fingerprint;
pub mod lints;
pub mod scan;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use lints::{LintInfo, LINTS};

use crate::util::json::Json;

/// One lint violation, anchored to a file and 1-indexed line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Name of the lint that fired (a [`LINTS`] entry).
    pub lint: &'static str,
    /// Repo-relative path (forward slashes).
    pub file: String,
    /// 1-indexed line the finding anchors to.
    pub line: usize,
    /// What is wrong and how to fix (or legitimately silence) it.
    pub message: String,
}

impl Finding {
    pub(crate) fn new(
        lint: &'static str,
        file: &str,
        line: usize,
        message: String,
    ) -> Finding {
        Finding { lint, file: file.to_string(), line, message }
    }
}

/// Outcome of a [`check_tree`] run.
#[derive(Debug)]
pub struct Report {
    /// Every finding, sorted by (file, line, lint).
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl Report {
    /// True when the tree is clean.
    pub fn ok(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report (multi-line, trailing newline).
    pub fn render(&self) -> String {
        if self.ok() {
            return format!(
                "check-invariants: OK ({} files scanned, {} lints)\n",
                self.files_scanned,
                LINTS.len()
            );
        }
        let mut out = format!(
            "check-invariants: {} finding(s) in {} files scanned\n",
            self.findings.len(),
            self.files_scanned
        );
        for f in &self.findings {
            out.push_str(&format!("  [{}] {}:{}\n      {}\n", f.lint, f.file, f.line, f.message));
        }
        out
    }

    /// JSON form (schema 1) for `mrsub check-invariants --json`.
    pub fn to_json(&self) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Json::obj([
                    ("lint", Json::Str(f.lint.to_string())),
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("message", Json::Str(f.message.clone())),
                ])
            })
            .collect();
        Json::obj([
            ("schema", Json::Num(1.0)),
            ("ok", Json::Bool(self.ok())),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            ("findings", Json::Arr(findings)),
        ])
    }
}

/// Run the full lint registry over the tree at `root` (a checkout with a
/// `rust/src/` underneath). Missing subtrees (`examples/` in a test
/// fixture) are skipped, not errors; unreadable files are.
pub fn check_tree(root: &Path) -> io::Result<Report> {
    let files = collect_rs_files(root)?;
    let mut findings = Vec::new();
    for rel in &files {
        let src = fs::read_to_string(root.join(rel))?;
        let scanned = scan::scan(&src);
        lints::lint_file(rel, &scanned, &mut findings);
    }
    lints::lint_wire_drift(root, &mut findings);
    findings.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    Ok(Report { findings, files_scanned: files.len() })
}

/// Re-record the blessed wire fingerprint for the tree at `root` (see
/// [`fingerprint::bless`] for the refusal rule). Returns a status line.
pub fn bless(root: &Path) -> io::Result<String> {
    fingerprint::bless(root)
}

/// Every `.rs` file under `root/rust/` and `root/examples/`, sorted, as
/// repo-relative forward-slash paths.
fn collect_rs_files(root: &Path) -> io::Result<Vec<String>> {
    let mut abs = Vec::new();
    for top in ["rust", "examples"] {
        walk(&root.join(top), &mut abs)?;
    }
    let mut rel: Vec<String> = abs
        .iter()
        .map(|p| {
            p.strip_prefix(root)
                .expect("walked under root")
                .to_string_lossy()
                .replace('\\', "/")
        })
        .collect();
    rel.sort();
    Ok(rel)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()), // missing subtree: nothing to scan.
    };
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == "target") {
                continue;
            }
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
