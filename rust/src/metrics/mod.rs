//! Metrics in the paper's own cost model: synchronous rounds, per-machine
//! resident memory (in *elements*, the unit the MRC analysis uses),
//! communication volume, central-machine load, and oracle-call counts.

use std::time::Duration;

use crate::util::json::Json;

/// Statistics for one synchronous MapReduce round.
#[derive(Debug, Clone)]
pub struct RoundStat {
    /// Human-readable round label, e.g. `"r1:filter"`.
    pub name: String,
    /// Number of worker machines that executed this round.
    pub machines: usize,
    /// Max elements resident on any worker (shard + sample + received).
    pub max_resident: usize,
    /// Total elements sent by workers this round.
    pub total_sent: usize,
    /// Elements received by the central machine this round.
    pub central_recv: usize,
    /// Oracle calls issued during the round (workers + central; batched
    /// calls count as their block length).
    pub oracle_calls: u64,
    /// Of `oracle_calls`, the queries served through the block-marginal
    /// path ([`crate::oracle::OracleState::marginals`]).
    pub batched_calls: u64,
    /// Number of block-marginal calls issued during the round.
    pub oracle_batches: u64,
    /// Wire-frame bytes coordinator → workers this round (0 unless the
    /// round ran on the shared-nothing process backend).
    pub ipc_bytes_out: u64,
    /// Wire-frame bytes workers → coordinator this round.
    pub ipc_bytes_in: u64,
    /// Worker deaths recovered from this round (elastic process backend
    /// under `--recovery requeue:R`; 0 everywhere else).
    pub recoveries: u64,
    /// Frame bytes reshipped to surviving workers for machine adoption
    /// this round (a subset of `ipc_bytes_out`).
    pub reshipped_bytes: u64,
    /// Replacement workers spawned into dead slots (or back-filled by
    /// late joins) at this round's boundary — the elastic process
    /// backend's closed recovery loop; 0 everywhere else.
    pub respawns: u64,
    /// Machines moved between workers by the deterministic rebalance
    /// planner at this round's boundary (elastic process backend; 0
    /// everywhere else).
    pub rebalanced_machines: u64,
    /// Shard/sample payload bytes workers resolved from the mmap'd shard
    /// arena instead of receiving as frames this round (`@uds+arena`
    /// only; *not* a subset of `ipc_bytes_out` — these bytes never
    /// crossed the wire).
    pub mapped_bytes: u64,
    /// Wall-clock time of the simulated round.
    pub wall: Duration,
}

impl RoundStat {
    /// JSON form for reports.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::Str(self.name.clone())),
            ("machines", Json::Num(self.machines as f64)),
            ("max_resident", Json::Num(self.max_resident as f64)),
            ("total_sent", Json::Num(self.total_sent as f64)),
            ("central_recv", Json::Num(self.central_recv as f64)),
            ("oracle_calls", Json::Num(self.oracle_calls as f64)),
            ("batched_calls", Json::Num(self.batched_calls as f64)),
            ("oracle_batches", Json::Num(self.oracle_batches as f64)),
            ("ipc_bytes_out", Json::Num(self.ipc_bytes_out as f64)),
            ("ipc_bytes_in", Json::Num(self.ipc_bytes_in as f64)),
            ("recoveries", Json::Num(self.recoveries as f64)),
            ("reshipped_bytes", Json::Num(self.reshipped_bytes as f64)),
            ("respawns", Json::Num(self.respawns as f64)),
            ("rebalanced_machines", Json::Num(self.rebalanced_machines as f64)),
            ("mapped_bytes", Json::Num(self.mapped_bytes as f64)),
            ("wall_us", Json::Num(self.wall.as_micros() as f64)),
        ])
    }
}

/// Aggregate metrics for one algorithm execution.
#[derive(Debug, Clone, Default)]
pub struct MrMetrics {
    /// Per-round statistics, in execution order.
    pub rounds: Vec<RoundStat>,
    /// Ground-set size of the instance.
    pub n: usize,
    /// Cardinality constraint.
    pub k: usize,
    /// Number of worker machines m = ceil(sqrt(n/k)).
    pub machines: usize,
    /// Size of the broadcast sample S.
    pub sample_size: usize,
}

impl MrMetrics {
    /// Number of synchronous MapReduce rounds executed.
    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Peak elements resident on any worker machine across rounds.
    pub fn peak_machine_memory(&self) -> usize {
        self.rounds.iter().map(|r| r.max_resident).max().unwrap_or(0)
    }

    /// Peak elements received by the central machine in a single round.
    pub fn peak_central_recv(&self) -> usize {
        self.rounds.iter().map(|r| r.central_recv).max().unwrap_or(0)
    }

    /// Total communication volume (elements shipped) across rounds,
    /// including the initial partition+sample distribution.
    pub fn total_communication(&self) -> usize {
        self.rounds.iter().map(|r| r.total_sent).sum()
    }

    /// Total oracle calls across rounds.
    pub fn total_oracle_calls(&self) -> u64 {
        self.rounds.iter().map(|r| r.oracle_calls).sum()
    }

    /// Total queries served through the block-marginal path.
    pub fn total_batched_calls(&self) -> u64 {
        self.rounds.iter().map(|r| r.batched_calls).sum()
    }

    /// Total block-marginal calls across rounds.
    pub fn total_oracle_batches(&self) -> u64 {
        self.rounds.iter().map(|r| r.oracle_batches).sum()
    }

    /// Total IPC frame bytes `(coordinator→workers, workers→coordinator)`
    /// across rounds — nonzero only for process-backend runs.
    pub fn total_ipc_bytes(&self) -> (u64, u64) {
        (
            self.rounds.iter().map(|r| r.ipc_bytes_out).sum(),
            self.rounds.iter().map(|r| r.ipc_bytes_in).sum(),
        )
    }

    /// Total worker deaths recovered from across rounds (elastic process
    /// backend under `requeue`; 0 for fault-free or in-process runs).
    pub fn total_recoveries(&self) -> u64 {
        self.rounds.iter().map(|r| r.recoveries).sum()
    }

    /// Total frame bytes reshipped for machine adoption across rounds.
    pub fn total_reshipped_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.reshipped_bytes).sum()
    }

    /// Total replacement workers spawned (or back-filled) across rounds —
    /// together with `total_recoveries`, the closed elastic loop: every
    /// recovery should eventually be matched by a respawn returning the
    /// pool to full size.
    pub fn total_respawns(&self) -> u64 {
        self.rounds.iter().map(|r| r.respawns).sum()
    }

    /// Total machines moved by the rebalance planner across rounds.
    pub fn total_rebalanced_machines(&self) -> u64 {
        self.rounds.iter().map(|r| r.rebalanced_machines).sum()
    }

    /// Total payload bytes resolved from the shard arena across rounds
    /// (`@uds+arena` only; 0 on every wire path).
    pub fn total_mapped_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.mapped_bytes).sum()
    }

    /// Total simulated wall time.
    pub fn total_wall(&self) -> Duration {
        self.rounds.iter().map(|r| r.wall).sum()
    }

    /// The paper's per-machine memory budget `O(√(nk))` with the constant
    /// used in our enforcement (Lemma 2 works with 4√(nk) expected sample
    /// plus the shard; we meter against `c·√(nk)` with c = 8).
    pub fn machine_budget(&self) -> usize {
        8 * ((self.n as f64 * self.k as f64).sqrt().ceil() as usize) + self.k
    }

    /// The central machine's relaxed budget `Õ(√(nk))` — the paper allows a
    /// `(1/ε)·log k` factor; we report against `√(nk)·log₂(k+1)·8`.
    pub fn central_budget(&self) -> usize {
        let base = (self.n as f64 * self.k as f64).sqrt();
        (8.0 * base * ((self.k + 1) as f64).log2().max(1.0)).ceil() as usize
    }
}

impl MrMetrics {
    /// JSON form for reports.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("n", Json::Num(self.n as f64)),
            ("k", Json::Num(self.k as f64)),
            ("machines", Json::Num(self.machines as f64)),
            ("sample_size", Json::Num(self.sample_size as f64)),
            ("rounds", Json::Arr(self.rounds.iter().map(RoundStat::to_json).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(name: &str, resident: usize, sent: usize, recv: usize) -> RoundStat {
        RoundStat {
            name: name.into(),
            machines: 4,
            max_resident: resident,
            total_sent: sent,
            central_recv: recv,
            oracle_calls: 10,
            batched_calls: 6,
            oracle_batches: 2,
            ipc_bytes_out: 100,
            ipc_bytes_in: 50,
            recoveries: 1,
            reshipped_bytes: 40,
            respawns: 1,
            rebalanced_machines: 3,
            mapped_bytes: 16,
            wall: Duration::from_micros(100),
        }
    }

    #[test]
    fn aggregates() {
        let m = MrMetrics {
            rounds: vec![stat("r1", 100, 50, 0), stat("r2", 80, 30, 30)],
            n: 1000,
            k: 10,
            machines: 10,
            sample_size: 40,
        };
        assert_eq!(m.num_rounds(), 2);
        assert_eq!(m.peak_machine_memory(), 100);
        assert_eq!(m.peak_central_recv(), 30);
        assert_eq!(m.total_communication(), 80);
        assert_eq!(m.total_oracle_calls(), 20);
        assert_eq!(m.total_batched_calls(), 12);
        assert_eq!(m.total_oracle_batches(), 4);
        assert_eq!(m.total_ipc_bytes(), (200, 100));
        assert_eq!(m.total_recoveries(), 2);
        assert_eq!(m.total_reshipped_bytes(), 80);
        assert_eq!(m.total_respawns(), 2);
        assert_eq!(m.total_rebalanced_machines(), 6);
        assert_eq!(m.total_mapped_bytes(), 32);
        assert_eq!(m.total_wall(), Duration::from_micros(200));
        assert!(m.machine_budget() >= (1000f64 * 10.0).sqrt() as usize);
    }

    #[test]
    fn round_stat_json_form() {
        let r = stat("x", 1, 2, 3);
        let j = r.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("wall_us").unwrap().as_usize(), Some(100));
        // parses back as valid JSON text.
        let text = j.to_string_pretty();
        assert!(Json::parse(&text).is_ok());
    }
}
