//! Facility-location workloads: exemplar selection over random planar point
//! clouds. Default kernel `sim(i,j) = exp(−γ·‖x_i − y_j‖²)` (RBF), the
//! standard choice in the distributed-submodular evaluation literature.

use super::{Instance, WorkloadGen};
use crate::core::derive_seed;
use crate::oracle::facility::FacilityOracle;
use crate::util::rng::Rng;

/// Similarity kernel between candidate and demand points.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Kernel {
    /// `exp(−γ·dist²)`.
    Rbf {
        /// Kernel bandwidth γ.
        gamma: f64,
    },
    /// `1 / (1 + γ·dist)`.
    Inverse {
        /// Kernel decay γ.
        gamma: f64,
    },
}

/// `n` candidate points and `d` demand points uniform in the unit square.
#[derive(Debug, Clone)]
pub struct FacilityGen {
    /// Number of candidate elements.
    pub n: usize,
    /// Number of demand points (universe columns).
    pub d: usize,
    /// Similarity kernel.
    pub kernel: Kernel,
    /// Number of planted cluster centers; 0 = fully uniform.
    pub clusters: usize,
}

impl FacilityGen {
    /// Uniform points with the default RBF kernel (γ = 8).
    pub fn new(n: usize, d: usize) -> Self {
        FacilityGen { n, d, kernel: Kernel::Rbf { gamma: 8.0 }, clusters: 0 }
    }

    /// Clustered variant: points drawn around `clusters` random centers,
    /// which makes greedy/threshold selections strongly diminishing.
    pub fn clustered(n: usize, d: usize, clusters: usize) -> Self {
        FacilityGen { n, d, kernel: Kernel::Rbf { gamma: 8.0 }, clusters }
    }

    /// Deterministically build the dense similarity matrix oracle.
    pub fn build(&self, seed: u64) -> FacilityOracle {
        let mut rng = Rng::seed_from_u64(derive_seed(seed, 0xFAC));
        let centers: Vec<(f64, f64)> = (0..self.clusters.max(1))
            .map(|_| (rng.gen_f64(), rng.gen_f64()))
            .collect();
        let point = |rng: &mut Rng| -> (f64, f64) {
            if self.clusters == 0 {
                (rng.gen_f64(), rng.gen_f64())
            } else {
                let (cx, cy) = centers[rng.gen_range(0..centers.len())];
                (
                    (cx + rng.gen_range_f64(-0.08, 0.08)).clamp(0.0, 1.0),
                    (cy + rng.gen_range_f64(-0.08, 0.08)).clamp(0.0, 1.0),
                )
            }
        };
        let cands: Vec<(f64, f64)> = (0..self.n).map(|_| point(&mut rng)).collect();
        let demands: Vec<(f64, f64)> = (0..self.d).map(|_| point(&mut rng)).collect();
        let mut sim = vec![0.0f32; self.n * self.d];
        for (i, &(xi, yi)) in cands.iter().enumerate() {
            for (j, &(xj, yj)) in demands.iter().enumerate() {
                let d2 = (xi - xj).powi(2) + (yi - yj).powi(2);
                let s = match self.kernel {
                    Kernel::Rbf { gamma } => (-gamma * d2).exp(),
                    Kernel::Inverse { gamma } => 1.0 / (1.0 + gamma * d2.sqrt()),
                };
                sim[i * self.d + j] = s as f32;
            }
        }
        FacilityOracle::new(self.n, self.d, sim)
    }

    /// The raw similarity matrix (used to construct the HLO-backed twin).
    pub fn build_matrix(&self, seed: u64) -> (usize, usize, Vec<f32>) {
        let o = self.build(seed);
        let mut sim = Vec::with_capacity(self.n * self.d);
        for e in 0..self.n as u32 {
            sim.extend_from_slice(o.row(e));
        }
        (self.n, self.d, sim)
    }
}

impl WorkloadGen for FacilityGen {
    fn generate(&self, seed: u64) -> Instance {
        let name = format!(
            "facility(n={},d={},clusters={},seed={seed})",
            self.n, self.d, self.clusters
        );
        let (rbf, gamma) = match self.kernel {
            Kernel::Rbf { gamma } => (true, gamma),
            Kernel::Inverse { gamma } => (false, gamma),
        };
        Instance::new(name, std::sync::Arc::new(self.build(seed))).with_spec(
            crate::oracle::spec::OracleSpec::Facility {
                n: self.n,
                d: self.d,
                rbf,
                gamma,
                clusters: self.clusters,
                seed,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::Oracle;

    #[test]
    fn shapes_and_range() {
        let o = FacilityGen::new(30, 20).build(1);
        assert_eq!(o.ground_size(), 30);
        assert_eq!(o.num_points(), 20);
        for e in 0..30u32 {
            for &s in o.row(e) {
                assert!((0.0..=1.0).contains(&s), "RBF similarity in [0,1]");
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = FacilityGen::new(10, 8).build(3);
        let b = FacilityGen::new(10, 8).build(3);
        for e in 0..10u32 {
            assert_eq!(a.row(e), b.row(e));
        }
    }

    #[test]
    fn clustered_has_redundancy() {
        // In a 2-cluster instance, the 3rd selection gains far less than the
        // 1st two (diminishing returns across duplicated mass).
        let o = FacilityGen::clustered(60, 40, 2).build(5);
        let mut st = o.state();
        let g1 = {
            let (mut be, mut bv) = (0u32, -1.0);
            for e in 0..60u32 {
                let m = st.marginal(e);
                if m > bv {
                    bv = m;
                    be = e;
                }
            }
            st.insert(be);
            bv
        };
        let g3 = {
            // greedy two more
            for _ in 0..2 {
                let (mut be, mut bv) = (0u32, -1.0);
                for e in 0..60u32 {
                    let m = st.marginal(e);
                    if m > bv {
                        bv = m;
                        be = e;
                    }
                }
                st.insert(be);
            }
            let (mut bv2, mut _be) = (-1.0, 0u32);
            for e in 0..60u32 {
                let m = st.marginal(e);
                if m > bv2 {
                    bv2 = m;
                    _be = e;
                }
            }
            bv2
        };
        assert!(g3 < g1 * 0.8, "4th-best marginal {g3} should be well below 1st {g1}");
    }
}
