//! The paper's theorems as executable tests — the reproduction's core
//! correctness contract. Each test cites the claim it checks.

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::dense::DenseTwoRound;
use mrsub::algorithms::multi_round::MultiRound;
use mrsub::algorithms::sparse::SparseTwoRound;
use mrsub::algorithms::two_round::{lemma1_invariant, TwoRoundKnownOpt};
use mrsub::algorithms::MrAlgorithm;
use mrsub::core::{threshold_bound, ONE_MINUS_1_E};
use mrsub::mapreduce::ClusterConfig;
use mrsub::oracle::adversarial::AdversarialOracle;
use mrsub::oracle::Oracle;
use mrsub::workload::adversarial::AdversarialGen;
use mrsub::workload::planted::PlantedCoverageGen;
use mrsub::workload::WorkloadGen;

fn cfg(seed: u64) -> ClusterConfig {
    ClusterConfig { seed, ..ClusterConfig::default() }
}

/// Lemma 1: Algorithm 4 with exact OPT is a 1/2-approximation, and its
/// output G satisfies: |G| = k, or ∀e: f_G(e) < OPT/(2k).
#[test]
fn lemma_1_two_round_half_approximation() {
    for seed in 0..8 {
        let inst = PlantedCoverageGen::dense(12, 1200, 2400).generate(seed);
        let opt = inst.known_opt.unwrap();
        let res = TwoRoundKnownOpt::new(opt).run(&inst.oracle, 12, &cfg(seed)).unwrap();
        assert!(
            res.solution.value >= 0.5 * opt - 1e-9,
            "seed {seed}: {} < OPT/2 = {}",
            res.solution.value,
            opt / 2.0
        );
        assert!(lemma1_invariant(
            &*inst.oracle,
            &res.solution,
            opt / 24.0,
            12
        ));
    }
}

/// Lemma 2: w.h.p. the number of elements sent to the central machine is
/// at most √(nk) (we allow the paper's constants: sample 4√(nk) + filter
/// survivors ≤ √(nk) ⇒ total received ≤ ~5-8·√(nk)).
#[test]
fn lemma_2_central_memory() {
    let n = 40_000usize;
    let k = 40usize;
    let bound = (n as f64 * k as f64).sqrt();
    for seed in 0..5 {
        let inst =
            mrsub::workload::coverage::CoverageGen::new(n, 16_000, 10).generate(seed);
        let opt_est = mrsub::algorithms::greedy::lazy_greedy(&inst.oracle, k).value;
        let res = TwoRoundKnownOpt::new(opt_est).run(&inst.oracle, k, &cfg(seed)).unwrap();
        assert!(
            (res.metrics.peak_central_recv() as f64) < 8.0 * bound,
            "seed {seed}: {} ≥ 8√(nk)",
            res.metrics.peak_central_recv()
        );
    }
}

/// Lemma 3: Algorithm 5 with t thresholds achieves 1 − (1 − 1/(t+1))^t.
#[test]
fn lemma_3_multi_round_bound() {
    let inst = PlantedCoverageGen::dense(12, 1800, 3600).generate(3);
    let opt = inst.known_opt.unwrap();
    for t in 1..=6 {
        let res = MultiRound::known(t, opt).run(&inst.oracle, 12, &cfg(5)).unwrap();
        let ratio = res.solution.value / opt;
        assert!(
            ratio >= threshold_bound(t) - 1e-9,
            "t={t}: {ratio} < {}",
            threshold_bound(t)
        );
    }
}

/// Lemma 3 (limit): the bound converges to 1 − 1/e from below, so for
/// large t the measured ratio must exceed 1 − 1/e − ε.
#[test]
fn lemma_3_limit_one_minus_1_over_e() {
    let inst = PlantedCoverageGen::dense(16, 1600, 3200).generate(4);
    let opt = inst.known_opt.unwrap();
    let t = 12; // bound(12) ≈ 0.6321… within 0.02 of 1−1/e
    let res = MultiRound::known(t, opt).run(&inst.oracle, 16, &cfg(6)).unwrap();
    assert!(res.solution.value / opt >= ONE_MINUS_1_E - 0.02);
}

/// Theorem 4: on the adversarial instance, the t-threshold algorithm gets
/// *exactly* the cap (to within the δ tie-break slack) — tightness.
#[test]
fn theorem_4_tightness() {
    for t in 1..=5 {
        let k = 60;
        let inst = AdversarialGen::new(t, k).generate(0);
        let opt = inst.known_opt.unwrap();
        let res = MultiRound::known(t, opt).run(&inst.oracle, k, &cfg(1)).unwrap();
        let ratio = res.solution.value / opt;
        let cap = threshold_bound(t);
        assert!(
            (ratio - cap).abs() < 0.02,
            "t={t}: measured {ratio} should pin the cap {cap}"
        );
    }
}

/// Theorem 4 (construction sanity): the optimal block alone achieves OPT
/// and the distractor mass devalues it exactly as the proof computes.
#[test]
fn theorem_4_instance_structure() {
    let t = 3;
    let k = 30;
    let o = AdversarialOracle::hard_instance(t, k);
    let opt_ids: Vec<u32> = o.optimal_ids().collect();
    assert_eq!(opt_ids.len(), k);
    assert!((o.value(&opt_ids) - o.known_opt()).abs() < 1e-9);
    // selecting ALL distractors leaves the o-marginal at α_t = (t/(t+1))^t·v*.
    let mut st = o.state();
    for e in 0..(o.ground_size() as u32 - k as u32) {
        st.insert(e);
    }
    let alpha_t = (t as f64 / (t as f64 + 1.0)).powi(t as i32);
    let margin = st.marginal(opt_ids[0]);
    assert!(
        (margin - alpha_t).abs() < 1e-3,
        "o-marginal {margin} should be ≈ α_t = {alpha_t}"
    );
}

/// Lemma 5 / Lemma 7 / Theorem 8: the OPT-free 2-round algorithms achieve
/// 1/2 − ε on their respective regimes, and the combination on both.
#[test]
fn theorem_8_dense_sparse_combined() {
    let eps = 0.1;
    let dense_inst = PlantedCoverageGen::dense(10, 1000, 2000).generate(11);
    let sparse_inst = PlantedCoverageGen::sparse(10, 1000, 2000).generate(12);

    let d = DenseTwoRound::new(eps).run(&dense_inst.oracle, 10, &cfg(13)).unwrap();
    assert!(d.solution.value / dense_inst.known_opt.unwrap() >= 0.5 - eps);

    let s = SparseTwoRound::new(eps).run(&sparse_inst.oracle, 10, &cfg(14)).unwrap();
    assert!(s.solution.value / sparse_inst.known_opt.unwrap() >= 0.5 - eps);

    for inst in [&dense_inst, &sparse_inst] {
        let c = CombinedTwoRound::new(eps).run(&inst.oracle, 10, &cfg(15)).unwrap();
        assert!(
            c.solution.value / inst.known_opt.unwrap() >= 0.5 - eps,
            "{}",
            inst.name
        );
        let rounds = c.metrics.rounds.iter().filter(|r| !r.name.starts_with("r0:")).count();
        assert_eq!(rounds, 2, "Theorem 8 is a 2-round result");
    }
}

/// Lemma 1 across *every* execution backend: on seeded randomized
/// instances, Algorithm 4 fed greedy-as-OPT stays ≥ ½·greedy whether the
/// machines are simulated serially, on the thread pool, or — via the
/// typed shard rounds the algorithms now run on — any backend that
/// executes the same tasks. (The process backend itself is asserted
/// bit-identical to `Serial` in `backend_conformance.rs`; here we pin the
/// *theorem* on the in-process matrix so a future backend regression
/// trips a paper bound, not just an equality check.)
#[test]
fn lemma_1_bound_holds_on_all_in_process_backends() {
    use mrsub::algorithms::greedy::lazy_greedy;
    use mrsub::mapreduce::backend::BackendKind;
    use mrsub::workload::coverage::CoverageGen;

    for seed in [1u64, 17, 40, 91] {
        let inst = CoverageGen::new(400, 200, 4).generate(seed);
        let k = 8 + (seed as usize % 7);
        let g = lazy_greedy(&inst.oracle, k).value;
        for backend in [
            BackendKind::Serial,
            BackendKind::Rayon { chunk: 1 },
            BackendKind::Rayon { chunk: 3 },
        ] {
            let cfg = ClusterConfig {
                seed,
                backend: Some(backend.clone()),
                ..ClusterConfig::default()
            };
            let res = TwoRoundKnownOpt::new(g).run(&inst.oracle, k, &cfg).unwrap();
            assert!(
                res.solution.value >= 0.5 * g - 1e-9,
                "seed {seed} [{}]: {} < greedy/2 = {}",
                backend.label(),
                res.solution.value,
                g / 2.0
            );
        }
    }
}

/// Lemma 3 across backends: the t-threshold scheme's
/// `1 − (1 − 1/(t+1))^t` bound (and its 1−1/e−ε limit reading) holds on
/// seeded randomized planted instances for every in-process backend.
#[test]
fn lemma_3_bound_holds_on_all_in_process_backends() {
    use mrsub::mapreduce::backend::BackendKind;

    for seed in [2u64, 23, 77] {
        let inst = PlantedCoverageGen::dense(10, 900, 1800).generate(seed);
        let opt = inst.known_opt.unwrap();
        for t in [1usize, 3] {
            for backend in [BackendKind::Serial, BackendKind::Rayon { chunk: 2 }] {
                let cfg = ClusterConfig {
                    seed,
                    backend: Some(backend.clone()),
                    ..ClusterConfig::default()
                };
                let res = MultiRound::known(t, opt).run(&inst.oracle, 10, &cfg).unwrap();
                let ratio = res.solution.value / opt;
                assert!(
                    ratio >= threshold_bound(t) - 1e-9,
                    "seed {seed} t={t} [{}]: {ratio} < {}",
                    backend.label(),
                    threshold_bound(t)
                );
                // the threshold scheme also clears 1 − 1/e − ε for the ε
                // implied by its own bound gap (sanity on the limit form).
                let eps_t = ONE_MINUS_1_E - threshold_bound(t);
                assert!(ratio >= ONE_MINUS_1_E - eps_t - 1e-9);
            }
        }
    }
}

/// §2.2: ε (the OPT-guess resolution) does not affect the number of
/// rounds — only memory. Verify rounds are identical across ε.
#[test]
fn eps_does_not_change_round_count() {
    let inst = PlantedCoverageGen::dense(10, 1000, 2000).generate(21);
    let mut rounds = Vec::new();
    let mut memory = Vec::new();
    for eps in [0.5, 0.2, 0.05] {
        let res = CombinedTwoRound::new(eps).run(&inst.oracle, 10, &cfg(22)).unwrap();
        rounds.push(res.metrics.rounds.len());
        memory.push(res.metrics.peak_central_recv());
    }
    assert_eq!(rounds[0], rounds[1]);
    assert_eq!(rounds[1], rounds[2]);
    assert!(memory[2] >= memory[0], "smaller ε must cost (weakly) more memory");
}

// --- the related-work frameworks the repo now carries as first-class ---------
// Barbosa–Ene–Nguyen–Ward (arXiv 1502.02606): randomized-partition
// distributed greedy for non-monotone objectives and matroid constraints.
// DASH (arXiv 2206.09563): low-adaptivity threshold sweeps.

/// Barbosa et al., non-monotone case: the randomized-partition framework
/// keeps a constant factor on a planted directed-cut instance (the clean
/// non-monotone family — OPT is the full arc weight, achieved by the
/// source set, and supersets only lose value).
#[test]
fn nonmonotone_randomized_partition_keeps_a_constant_factor() {
    use mrsub::algorithms::randgreedi::RandGreeDi;
    use mrsub::core::Constraint;
    use mrsub::workload::dicut::PlantedDicutGen;

    for seed in [5u64, 19, 42] {
        let g = PlantedDicutGen::new(10, 120, 4);
        let inst = g.generate(seed);
        let opt = inst.known_opt.unwrap();
        let res = RandGreeDi::constrained(Constraint::cardinality(10), 1)
            .run(inst.oracle.as_ref(), 10, &cfg(seed))
            .unwrap();
        let ratio = res.solution.value / opt;
        assert!(ratio >= 0.5, "seed {seed}: non-monotone ratio {ratio} below 1/2");
        // non-monotonicity is real here: the full ground set cuts nothing,
        // so the constant factor cannot come from monotone slack.
        let everything: Vec<u32> = (0..inst.n as u32).collect();
        assert_eq!(inst.oracle.value(&everything), 0.0);
    }
}

/// Barbosa et al., matroid case: every round's local solutions and the
/// final output are independent in the partition matroid — feasibility is
/// an invariant of the whole pipeline, not a final clamp — and the
/// planted-cover value stays competitive.
#[test]
fn matroid_feasibility_is_an_invariant_of_the_constrained_pipeline() {
    use mrsub::algorithms::randgreedi::RandGreeDi;
    use mrsub::workload::planted::PlantedMatroidGen;

    let g = PlantedMatroidGen::new(8, 400, 100, 1);
    let inst = g.generate(31);
    let c = g.constraint(inst.n);
    let res =
        RandGreeDi::constrained(c.clone(), 2).run(inst.oracle.as_ref(), 8, &cfg(32)).unwrap();
    assert!(c.is_feasible(&res.solution.elements), "output violates the partition matroid");
    // every prefix of the greedy selection is feasible too (downward
    // closure plus the cursor's admit-before-insert discipline).
    for i in 0..=res.solution.elements.len() {
        assert!(c.is_feasible(&res.solution.elements[..i]));
    }
    let ratio = res.solution.value / inst.known_opt.unwrap();
    assert!(ratio >= 0.4, "matroid-constrained ratio {ratio} below the framework constant");
}

/// A single-partition matroid with capacity k IS the cardinality
/// constraint: the constrained pipeline must produce the identical
/// selection sequence under both spellings (bit-for-bit, same seeds).
#[test]
fn single_partition_matroid_degenerates_to_cardinality() {
    use mrsub::algorithms::randgreedi::RandGreeDi;
    use mrsub::core::Constraint;
    use mrsub::workload::coverage::CoverageGen;

    let inst = CoverageGen::new(300, 150, 4).generate(9);
    let k = 8;
    let single = Constraint::partition_matroid(vec![0u32; 300], vec![k]);
    let card = Constraint::cardinality(k);
    let a = RandGreeDi::constrained(single, 1).run(inst.oracle.as_ref(), k, &cfg(10)).unwrap();
    let b = RandGreeDi::constrained(card, 1).run(inst.oracle.as_ref(), k, &cfg(10)).unwrap();
    assert_eq!(a.solution.elements, b.solution.elements);
    assert_eq!(a.solution.value.to_bits(), b.solution.value.to_bits());
}

/// DASH's defining property: adaptivity O(log(k/ε)/ε) — the executed MR
/// round count obeys the closed-form bound and, for the k used here, is
/// strictly below k (the adaptivity of sequential greedy).
#[test]
fn dash_round_count_is_low_adaptivity() {
    use mrsub::algorithms::dash::{dash_round_bound, Dash};

    let k = 32;
    let eps = 0.3;
    let inst = PlantedCoverageGen::dense(k, 2000, 4000).generate(41);
    let res = Dash::new(eps).run(inst.oracle.as_ref(), k, &cfg(42)).unwrap();
    let rounds = res.metrics.rounds.iter().filter(|r| !r.name.starts_with("r0:")).count();
    assert!(
        rounds <= dash_round_bound(k, eps),
        "{rounds} rounds exceed the O(log(k/ε)/ε) bound {}",
        dash_round_bound(k, eps)
    );
    assert!(rounds < k, "low adaptivity means fewer rounds ({rounds}) than greedy's k = {k}");
    // and the sweep still clears the 1/2 − ε quality target on the
    // planted cover.
    let ratio = res.solution.value / inst.known_opt.unwrap();
    assert!(ratio >= 0.5 - eps, "DASH ratio {ratio} below 1/2 − ε");
}
