//! E8 — ablations over the constants the paper fixes but does not sweep:
//!
//! * the sampling constant `c` in `p = c·√(k/n)` (Algorithm 3 uses 4):
//!   smaller c shrinks the broadcast sample (memory) but weakens `G₀` and
//!   the dense-regime OPT guess (Lemma 2's martingale needs enough sample
//!   blocks);
//! * the sparse ship factor (`c·k` top elements per machine, Lemma 7's
//!   O(k)): smaller factors risk dropping large elements when the
//!   balls-in-bins load is skewed.
//!
//! Both sweeps report quality (ratio vs planted OPT) against the memory
//! they buy, on the regime that stresses them.

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::sparse::SparseTwoRound;
use mrsub::algorithms::MrAlgorithm;
use mrsub::coordinator::run_experiment;
use mrsub::mapreduce::ClusterConfig;
use mrsub::workload::planted::PlantedCoverageGen;
use mrsub::workload::WorkloadGen;

fn main() {
    let k = 30;
    let seeds = [1u64, 2, 3, 4, 5];

    println!("== E8a: sampling constant c (paper: 4) — combined on planted-dense, k={k} ==");
    println!("{:>6} {:>10} {:>12} {:>12}", "c", "ratio", "sample", "central");
    for c in [0.5, 1.0, 2.0, 4.0, 8.0] {
        let mut ratio = 0.0;
        let mut sample = 0usize;
        let mut central = 0usize;
        for &seed in &seeds {
            let inst = PlantedCoverageGen::dense(k, 5_000, 12_000).generate(seed);
            let cfg =
                ClusterConfig { seed, sample_factor: c, ..ClusterConfig::default() };
            let rec = run_experiment(&inst, &CombinedTwoRound::new(0.1), k, &cfg).unwrap();
            ratio += rec.ratio / seeds.len() as f64;
            sample += rec.metrics.sample_size / seeds.len();
            central = central.max(rec.peak_central_recv);
        }
        println!("{:>6} {:>10.4} {:>12} {:>12}", c, ratio, sample, central);
    }
    println!("expected: ratio degrades below c ≈ 1–2 (sample too thin for G0/OPT");
    println!("guessing); memory scales linearly with c — the paper's c = 4 buys");
    println!("the w.h.p. guarantees at 4√(nk) broadcast cost.\n");

    println!("== E8b: sparse ship factor c·k (paper: O(k)) — sparse alg on planted-sparse ==");
    println!("{:>6} {:>10} {:>12}", "c", "ratio", "central");
    for c in [1usize, 2, 4, 8] {
        let mut ratio = 0.0;
        let mut central = 0usize;
        for &seed in &seeds {
            let inst = PlantedCoverageGen::sparse(k, 5_000, 12_000).generate(seed);
            let cfg = ClusterConfig { seed, ..ClusterConfig::default() };
            let mut alg = SparseTwoRound::new(0.1);
            alg.c = c;
            let rec = run_experiment(&inst, &alg, k, &cfg).unwrap();
            ratio += rec.ratio / seeds.len() as f64;
            central = central.max(rec.peak_central_recv);
        }
        println!("{:>6} {:>10.4} {:>12}", c, ratio, central);
    }
    println!("expected: ratio stable for c ≥ ~2 (all large elements reach the");
    println!("central machine, balls-in-bins), degrading only at c = 1 when a");
    println!("machine's share of large elements exceeds k.");
}
