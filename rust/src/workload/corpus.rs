//! Synthetic Zipf document corpus → coverage instance.
//!
//! Stand-in for the real text corpora used in empirical max-coverage work
//! (the paper itself is theory-only; DESIGN.md §2 documents this
//! substitution): documents are elements, the words they contain are the
//! covered items, and word frequencies follow a Zipf law — which produces
//! the realistic structure (few stop-words covered by everyone, a long tail
//! of rare words) that makes document selection non-trivial.

use super::{Instance, WorkloadGen};
use crate::core::derive_seed;
use crate::oracle::coverage::CoverageOracle;
use crate::util::rng::Rng;

/// Zipf-corpus coverage generator.
#[derive(Debug, Clone)]
pub struct ZipfCorpusGen {
    /// Number of documents (elements).
    pub docs: usize,
    /// Vocabulary size (universe).
    pub vocab: usize,
    /// Words per document (pre-dedup).
    pub doc_len: usize,
    /// Zipf exponent (≈1.0 for natural language).
    pub s: f64,
    /// Weight items by inverse document frequency instead of 1.
    pub idf_weighted: bool,
}

impl ZipfCorpusGen {
    /// Plain coverage corpus.
    pub fn new(docs: usize, vocab: usize, doc_len: usize) -> Self {
        ZipfCorpusGen { docs, vocab, doc_len, s: 1.05, idf_weighted: false }
    }

    /// IDF-weighted variant: covering rare words is worth more.
    pub fn idf(docs: usize, vocab: usize, doc_len: usize) -> Self {
        ZipfCorpusGen { docs, vocab, doc_len, s: 1.05, idf_weighted: true }
    }

    /// Deterministically build the oracle.
    pub fn build(&self, seed: u64) -> CoverageOracle {
        let mut rng = Rng::seed_from_u64(derive_seed(seed, 0x21F));
        // Zipf CDF via inverse-transform on precomputed cumulative weights.
        let mut cum = Vec::with_capacity(self.vocab);
        let mut total = 0.0f64;
        for r in 1..=self.vocab {
            total += (r as f64).powf(-self.s);
            cum.push(total);
        }
        let draw = |rng: &mut Rng| -> u32 {
            let x = rng.gen_range_f64(0.0, total);
            cum.partition_point(|&c| c < x) as u32
        };
        let mut doc_count = vec![0u32; self.vocab];
        let sets: Vec<Vec<u32>> = (0..self.docs)
            .map(|_| {
                let mut words: Vec<u32> = (0..self.doc_len).map(|_| draw(&mut rng)).collect();
                words.sort_unstable();
                words.dedup();
                for &w in &words {
                    doc_count[w as usize] += 1;
                }
                words
            })
            .collect();
        let weights = if self.idf_weighted {
            doc_count
                .iter()
                .map(|&c| ((self.docs as f64 + 1.0) / (c as f64 + 1.0)).ln().max(0.0) + 1e-9)
                .collect()
        } else {
            vec![1.0; self.vocab]
        };
        CoverageOracle::new(sets, weights)
    }
}

impl WorkloadGen for ZipfCorpusGen {
    fn generate(&self, seed: u64) -> Instance {
        let tag = if self.idf_weighted { "zipf-idf" } else { "zipf" };
        let name = format!(
            "{tag}(docs={},vocab={},len={},s={},seed={seed})",
            self.docs, self.vocab, self.doc_len, self.s
        );
        Instance::new(name, std::sync::Arc::new(self.build(seed))).with_spec(
            crate::oracle::spec::OracleSpec::Zipf {
                docs: self.docs,
                vocab: self.vocab,
                doc_len: self.doc_len,
                s: self.s,
                idf: self.idf_weighted,
                seed,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::Oracle;

    #[test]
    fn zipf_head_is_common() {
        let o = ZipfCorpusGen::new(200, 500, 30).build(1);
        // word 0 (rank 1) should be covered by many documents; count docs
        // containing it.
        let containing = (0..200u32).filter(|&e| o.items_of(e).contains(&0)).count();
        assert!(containing > 50, "head word in only {containing} docs");
    }

    #[test]
    fn deterministic_and_shaped() {
        let a = ZipfCorpusGen::new(50, 100, 10).build(3);
        let b = ZipfCorpusGen::new(50, 100, 10).build(3);
        assert_eq!(a.ground_size(), 50);
        for e in 0..50u32 {
            assert_eq!(a.items_of(e), b.items_of(e));
        }
    }

    #[test]
    fn idf_weights_make_rare_words_valuable() {
        let o = ZipfCorpusGen::idf(200, 500, 30).build(5);
        assert!(o.total_weight() > 0.0);
        let inst = ZipfCorpusGen::idf(200, 500, 30).generate(5);
        assert!(inst.name.starts_with("zipf-idf"));
    }
}
