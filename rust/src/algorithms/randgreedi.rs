//! RandGreeDi — the two-round distributed greedy of Barbosa et al. (FOCS
//! 2016), the framework the paper positions itself against.
//!
//! Round 1: randomly partition; each machine runs (lazy) greedy on its
//! shard and ships its k-element solution `T_i`. Round 2: the central
//! machine runs greedy over `∪_i T_i` to get `T_c`; the output is the
//! better of `T_c` and the best local `T_i`. On a random partition this is
//! a `1/2`-approximation in expectation *with* the framework's ground-set
//! duplication caveats (the no-duplication form loses a constant factor —
//! exactly the gap the paper's thresholding closes).

use super::greedy::{constrained_greedy_over, lazy_greedy_over};
use super::{AlgResult, MrAlgorithm};
use crate::core::{derive_seed, Constraint, ElementId, Result, Solution};
use crate::mapreduce::wire::{RoundTask, TaskReply};
use crate::mapreduce::{ClusterConfig, MrCluster};
use crate::oracle::Oracle;

/// Barbosa et al.'s RandGreeDi (no duplication).
///
/// The default is the classic two-round cardinality form (physical shards,
/// plain local greedy) — bit-identical to the historical behavior.
/// [`RandGreeDi::constrained`] switches to the randomized-partition form of
/// the non-monotone/matroid framework: each of `rounds` rounds draws a
/// *fresh* random partition of the full ground set (derived machine-side
/// from the round seed, no shuffle — see
/// [`crate::mapreduce::shard::partition_of`]) and runs a constrained local
/// greedy per part; the central machine completes over the pooled locals
/// under the same constraint.
#[derive(Debug, Clone)]
pub struct RandGreeDi {
    /// Independence system for the randomized-partition form; `None` =
    /// the classic cardinality-only two-round algorithm.
    pub constraint: Option<Constraint>,
    /// Randomized-partition rounds (constrained form only; ≥ 1).
    pub rounds: usize,
}

impl Default for RandGreeDi {
    fn default() -> Self {
        RandGreeDi { constraint: None, rounds: 1 }
    }
}

impl RandGreeDi {
    /// The randomized-partition constrained form (see type docs).
    pub fn constrained(constraint: Constraint, rounds: usize) -> Self {
        RandGreeDi { constraint: Some(constraint), rounds: rounds.max(1) }
    }

    fn run_constrained(
        &self,
        oracle: &dyn Oracle,
        k: usize,
        cfg: &ClusterConfig,
        constraint: &Constraint,
    ) -> Result<AlgResult> {
        let n = oracle.ground_size();
        constraint.validate(n)?;
        let mut cluster = MrCluster::new(n, k, cfg)?;
        let parts = cluster.machines() as u32;
        let seed = derive_seed(cluster.seed(), 0x9B0_CAFE);

        let mut best_local = Solution::empty();
        let mut union: Vec<ElementId> = Vec::new();
        for r in 0..self.rounds {
            // machine m derives its logical part of the full ground set
            // from (seed, r, m) — a true random re-partition per round
            // with nothing shuffled over the wire.
            let task = RoundTask::PartitionGreedy {
                k,
                parts,
                constraint: constraint.clone(),
                seed,
                round: r as u32,
            };
            let locals: Vec<Vec<ElementId>> = cluster
                .shard_round(&format!("r{}:partition-greedy", r + 1), 0, oracle, &task)?
                .into_iter()
                .map(TaskReply::into_ids)
                .collect();
            for t in &locals {
                let v = oracle.value(t);
                best_local = best_local.max(Solution { elements: t.clone(), value: v });
            }
            union.extend(locals.iter().flatten().copied());
        }
        union.sort_unstable();
        union.dedup();

        let received = union.len();
        let central = cluster.central_round("rc:union-constrained-greedy", received, || {
            constrained_greedy_over(oracle, &union, k, constraint)
        })?;

        Ok(AlgResult { solution: central.max(best_local), metrics: cluster.into_metrics() })
    }
}

impl MrAlgorithm for RandGreeDi {
    fn name(&self) -> String {
        match &self.constraint {
            None => "randgreedi".into(),
            Some(c) => format!("randgreedi({},r={})", c.label(), self.rounds),
        }
    }

    fn run(&self, oracle: &dyn Oracle, k: usize, cfg: &ClusterConfig) -> Result<AlgResult> {
        if let Some(constraint) = &self.constraint {
            return self.run_constrained(oracle, k, cfg, constraint);
        }
        let n = oracle.ground_size();
        let mut cluster = MrCluster::new(n, k, cfg)?;

        // Round 1: greedy per shard (typed round; worker-side on the
        // process backend, recycled pooled states in-process).
        let locals: Vec<Vec<ElementId>> = cluster
            .shard_round("r1:local-greedy", 0, oracle, &RoundTask::LocalGreedy { k })?
            .into_iter()
            .map(TaskReply::into_ids)
            .collect();

        // Best local solution (its value is recomputed centrally; the ids
        // are already on the central machine as part of the round-1 output).
        let best_local = locals
            .iter()
            .map(|t| {
                let v = oracle.value(t);
                Solution { elements: t.clone(), value: v }
            })
            .fold(Solution::empty(), Solution::max);

        let union: Vec<ElementId> = {
            let mut u: Vec<ElementId> = locals.iter().flatten().copied().collect();
            u.sort_unstable();
            u.dedup();
            u
        };

        // Round 2: greedy over the union of core-sets.
        let received = union.len();
        let central = cluster
            .central_round("r2:union-greedy", received, || lazy_greedy_over(oracle, &union, k))?;

        Ok(AlgResult { solution: central.max(best_local), metrics: cluster.into_metrics() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::lazy_greedy;
    use crate::workload::coverage::CoverageGen;
    use crate::workload::planted::PlantedCoverageGen;
    use crate::workload::WorkloadGen;

    fn cfg(seed: u64) -> ClusterConfig {
        ClusterConfig { seed, parallel: false, ..ClusterConfig::default() }
    }

    #[test]
    fn two_rounds_and_reasonable_quality() {
        let inst = PlantedCoverageGen::dense(10, 1000, 2000).generate(1);
        let opt = inst.known_opt.unwrap();
        let res = RandGreeDi::default().run(inst.oracle.as_ref(), 10, &cfg(2)).unwrap();
        assert_eq!(res.metrics.num_rounds(), 3);
        assert!(res.solution.value / opt >= 0.5, "randgreedi below 1/2 on easy instance");
    }

    #[test]
    fn never_worse_than_best_local() {
        let o = CoverageGen::new(400, 250, 4).build(3);
        let res = RandGreeDi::default().run(&o, 10, &cfg(4)).unwrap();
        // sanity: close to sequential greedy on random coverage.
        let g = lazy_greedy(&o, 10);
        assert!(res.solution.value >= 0.5 * g.value);
        assert!(res.solution.len() <= 10);
    }

    #[test]
    fn constrained_form_is_feasible_and_competitive() {
        let g = crate::workload::planted::PlantedMatroidGen::new(8, 400, 100, 1);
        let inst = g.generate(11);
        let c = g.constraint(inst.n);
        let res = RandGreeDi::constrained(c.clone(), 2)
            .run(inst.oracle.as_ref(), 8, &cfg(12))
            .unwrap();
        assert!(c.is_feasible(&res.solution.elements), "matroid violated");
        let opt = inst.known_opt.unwrap();
        assert!(res.solution.value / opt >= 0.4, "ratio {}", res.solution.value / opt);
        // 2 partition rounds + 1 central round.
        assert_eq!(res.metrics.num_rounds(), 3);
    }

    #[test]
    fn constrained_form_handles_nonmonotone_dicut() {
        let g = crate::workload::dicut::PlantedDicutGen::new(8, 60, 4);
        let inst = g.generate(13);
        let c = crate::core::Constraint::cardinality(8);
        let res = RandGreeDi::constrained(c, 1).run(inst.oracle.as_ref(), 8, &cfg(14)).unwrap();
        assert!(res.solution.value > 0.0, "dicut selection must cut something");
        assert!(res.solution.len() <= 8);
    }
}
