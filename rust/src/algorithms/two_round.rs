//! Algorithm 4 — the simple 2-round 1/2-approximation, assuming OPT is
//! known (or estimated; the guarantee degrades gracefully with the
//! estimate's accuracy, which Algorithms 6/7 exploit).
//!
//! Round 1: every machine runs `G₀ = ThresholdGreedy(S, ∅, OPT/(2k))` over
//! the broadcast sample — the same `G₀` everywhere since the scan order is
//! fixed — then ships `ThresholdFilter(Vᵢ, G₀, OPT/(2k))` to the central
//! machine. Round 2: the central machine completes `G` by running
//! ThresholdGreedy over the surviving elements, starting from `G₀`.
//!
//! In the simulation the identical per-machine `G₀` computation is executed
//! once and shared (its determinism is asserted by a test); per-machine
//! memory accounting still charges the sample residency on every machine.

use super::threshold::{merge_sorted, threshold_greedy};
use super::{finish, AlgResult, MrAlgorithm};
use crate::core::{ElementId, Result, Solution};
use crate::mapreduce::wire::{RoundTask, TaskReply};
use crate::mapreduce::{ClusterConfig, MrCluster};
use crate::oracle::Oracle;

/// Algorithm 4 with a caller-supplied OPT (exact or estimated).
#[derive(Debug, Clone)]
pub struct TwoRoundKnownOpt {
    /// The OPT value the threshold is derived from.
    pub opt: f64,
}

impl TwoRoundKnownOpt {
    /// New instance with known/estimated OPT.
    pub fn new(opt: f64) -> Self {
        assert!(opt > 0.0, "OPT must be positive");
        TwoRoundKnownOpt { opt }
    }
}

impl MrAlgorithm for TwoRoundKnownOpt {
    fn name(&self) -> String {
        format!("two-round(opt={:.3})", self.opt)
    }

    fn run(&self, oracle: &dyn Oracle, k: usize, cfg: &ClusterConfig) -> Result<AlgResult> {
        let n = oracle.ground_size();
        let mut cluster = MrCluster::new(n, k, cfg)?;
        let tau = self.opt / (2.0 * k as f64);

        // Identical on every machine (fixed ascending scan of S).
        let mut g0 = oracle.state();
        threshold_greedy(g0.as_mut(), cluster.sample(), tau, k);

        // Round 1: filter each shard against G0; ship survivors. If G0 is
        // already full, the completion cannot extend it — nothing is sent
        // (Lemma 2's "we are done" case). The filter is a typed shard
        // round: on the process backend it executes inside the worker
        // processes against their spec-rebuilt oracles.
        let survivors_per_machine: Vec<Vec<ElementId>> = if g0.len() >= k {
            cluster.worker_round("r1:filter", g0.len(), |_ctx| Vec::new())?
        } else {
            let task = RoundTask::Filter { base: g0.selected().to_vec(), tau };
            cluster
                .shard_round("r1:filter", g0.len(), oracle, &task)?
                .into_iter()
                .map(TaskReply::into_ids)
                .collect()
        };
        let survivors = merge_sorted(&survivors_per_machine);

        // Round 2: central completion from G0 over the survivors.
        let received = survivors.len() + cluster.sample().len();
        let solution = cluster.central_round("r2:complete", received, || {
            let mut g = g0.clone_state();
            threshold_greedy(g.as_mut(), &survivors, tau, k);
            finish(oracle, g.selected().to_vec())
        })?;

        Ok(AlgResult { solution, metrics: cluster.into_metrics() })
    }
}

/// Postcondition check used by tests and benches: Lemma 1's invariant —
/// either `|G| = k`, or no element of the ground set has marginal ≥ τ.
pub fn lemma1_invariant(oracle: &dyn Oracle, solution: &Solution, tau: f64, k: usize) -> bool {
    if solution.len() >= k {
        return true;
    }
    let mut st = oracle.state();
    for &e in &solution.elements {
        st.insert(e);
    }
    (0..oracle.ground_size() as u32).all(|e| st.marginal(e) < tau)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::lazy_greedy;
    use crate::workload::coverage::CoverageGen;
    use crate::util::check::forall;
    use crate::workload::planted::PlantedCoverageGen;
    use crate::workload::WorkloadGen;

    fn cfg(seed: u64) -> ClusterConfig {
        ClusterConfig { seed, parallel: false, ..ClusterConfig::default() }
    }

    #[test]
    fn achieves_half_of_planted_opt() {
        let gen = PlantedCoverageGen::dense(10, 1000, 2000);
        let inst = gen.generate(1);
        let opt = inst.known_opt.unwrap();
        let res = TwoRoundKnownOpt::new(opt).run(inst.oracle.as_ref(), 10, &cfg(2)).unwrap();
        let ratio = res.solution.value / opt;
        assert!(ratio >= 0.5 - 1e-9, "ratio {ratio} below 1/2 with exact OPT");
        assert_eq!(res.metrics.num_rounds(), 3, "partition + 2 compute rounds");
    }

    #[test]
    fn lemma1_invariant_holds() {
        let o = CoverageGen::new(500, 300, 5).build(3);
        let greedy_val = lazy_greedy(&o, 20).value;
        let res = TwoRoundKnownOpt::new(greedy_val).run(&o, 20, &cfg(4)).unwrap();
        let tau = greedy_val / 40.0;
        assert!(lemma1_invariant(&o, &res.solution, tau, 20));
    }

    #[test]
    fn deterministic_under_seed() {
        let o = CoverageGen::new(400, 200, 4).build(5);
        let a = TwoRoundKnownOpt::new(100.0).run(&o, 10, &cfg(6)).unwrap();
        let b = TwoRoundKnownOpt::new(100.0).run(&o, 10, &cfg(6)).unwrap();
        assert_eq!(a.solution, b.solution);
    }

    #[test]
    fn prop_half_approx_vs_greedy() {
        forall(0x42, 12, |gen| {
            // greedy ≤ OPT, so feeding greedy-as-OPT keeps τ ≤ OPT/(2k) and
            // the Lemma-1 argument gives value ≥ greedy/2 — the measured
            // contract the experiments use.
            let seed = gen.u64_in(40);
            let k = gen.usize_in(3, 15);
            let o = CoverageGen::new(300, 150, 4).build(seed);
            let g = lazy_greedy(&o, k);
            let res = TwoRoundKnownOpt::new(g.value).run(&o, k, &cfg(seed)).unwrap();
            assert!(
                res.solution.value >= 0.5 * g.value - 1e-9,
                "value {} < half of greedy {}",
                res.solution.value,
                g.value
            );
        });
    }
}
