//! Seeded chaos harness for the elastic process backend.
//!
//! Each *schedule* is a deterministic program drawn from an LCG stream:
//! a sequence of rounds, each pairing a typed [`RoundTask`] with a
//! pre-round chaos event — kill a worker (the pool respawns a
//! replacement in-round), disable respawn and kill (orphans pile onto
//! survivors, manufacturing the imbalance the rebalance planner must
//! later correct), or re-enable respawn (the next heal back-fills the
//! dead slots and *steals* machines back onto them). Every schedule is
//! run against a live [`ProcessPool`] on every transport and compared
//! round-by-round against the `Serial` reference executed in-process
//! over the same shards and stores: the replies must be **bit-identical
//! regardless of what the chaos did**, and the pool must end the
//! schedule back at full `process:N` size.
//!
//! A second matrix drives the external-TCP topology, where dead slots
//! are never respawned by the pool — they are back-filled by late
//! `mrsub worker --connect` joins launched mid-schedule.
//!
//! Reproducibility contract: every failure message carries the schedule
//! seed and transport, failing seeds are appended to
//! `target/chaos-failures.txt` (override with `MRSUB_CHAOS_ARTIFACT`)
//! for CI artifact upload, and `MRSUB_CHAOS_SCHEDULES` narrows the run
//! to a comma-separated seed list for replay, e.g.
//! `MRSUB_CHAOS_SCHEDULES=11 cargo test --test elastic_chaos`.
//!
//! Run with `--test-threads=1` (the `./verify.sh chaos` mode) for
//! deterministic worker-process lifecycles.

use std::collections::BTreeSet;
use std::io::Write as _;
use std::path::PathBuf;

use mrsub::core::ElementId;
use mrsub::mapreduce::backend::Serial;
use mrsub::mapreduce::process::{PoolOptions, ProcessPool, RecoveryPolicy};
use mrsub::mapreduce::shard::{run_task_all_cached, GuessStore, StateCache};
use mrsub::mapreduce::transport::Transport;
use mrsub::mapreduce::wire::RoundTask;
use mrsub::oracle::spec::OracleSpec;

/// The built `mrsub` binary — worker executable for pool spawns.
fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_mrsub"))
}

// --- deterministic schedule generation ---------------------------------------

/// Knuth MMIX LCG; the whole schedule derives from one u64 seed.
struct Lcg(u64);

impl Lcg {
    fn new(seed: u64) -> Self {
        // avoid the all-zeros fixpoint and decorrelate small seeds.
        Lcg(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 11
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// Pre-round chaos event. `Kill` relies on the in-round respawn to keep
/// the pool whole; `StealKill` turns respawn off first so the orphans
/// land on survivors and the dead slot lingers; `Reenable` turns respawn
/// back on so the next heal back-fills the slots and the planner steals
/// machines back onto the fresh (empty) workers.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Chaos {
    None,
    Kill(usize),
    StealKill(usize),
    Reenable,
}

#[derive(Debug)]
struct Step {
    chaos: Chaos,
    task: RoundTask,
}

/// Workers/machines in the chaos fixture (machine i ⇔ shard i at spawn).
const POOL: usize = 3;
/// Deaths allowed per schedule; the pool budget leaves headroom above it.
const MAX_KILLS: u64 = 5;

/// Draw one schedule: 5–7 rounds of (event, task), never killing the
/// last survivor and never exceeding `MAX_KILLS` deaths.
fn generate_schedule(seed: u64) -> Vec<Step> {
    let mut rng = Lcg::new(seed);
    let rounds = 5 + rng.below(3) as u32;
    let mut steps = Vec::new();
    let mut respawn_on = true;
    // slots dead *right now* (only grows while respawn is off; a heal
    // with respawn on refills every slot before the round runs).
    let mut dead: BTreeSet<usize> = BTreeSet::new();
    let mut kills = 0u64;

    for round in 1..=rounds {
        let alive: Vec<usize> = (0..POOL).filter(|w| !dead.contains(w)).collect();
        let chaos = match rng.below(10) {
            // kill with respawn on: replacement spawned in-round.
            0 | 1 if respawn_on && kills < MAX_KILLS => {
                let w = alive[rng.below(alive.len() as u64) as usize];
                kills += 1;
                Chaos::Kill(w)
            }
            // kill with respawn off: orphans pile onto survivors. Keep
            // at least one survivor so the round stays recoverable.
            2 | 3 if kills < MAX_KILLS && alive.len() >= 2 => {
                let w = alive[rng.below(alive.len() as u64) as usize];
                kills += 1;
                dead.insert(w);
                respawn_on = false;
                Chaos::StealKill(w)
            }
            4 | 5 if !respawn_on => {
                respawn_on = true;
                dead.clear(); // the next heal back-fills every slot.
                Chaos::Reenable
            }
            _ => Chaos::None,
        };
        let task = match rng.below(5) {
            0 => RoundTask::MaxSingleton,
            1 => RoundTask::LocalGreedy { k: 2 + rng.below(4) as usize },
            2 => RoundTask::TopSingletons { k: 3, c: 2 },
            3 => RoundTask::Filter {
                base: distinct_pair(&mut rng),
                tau: (1 + rng.below(3)) as f64,
            },
            _ => RoundTask::PruneSample {
                base: distinct_pair(&mut rng),
                floor: 0.5,
                tau: 1.5,
                per_share: 4 + rng.below(8) as usize,
                seed: rng.next(),
                round,
            },
        };
        steps.push(Step { chaos, task });
    }
    // close the loop: whatever the chaos left behind, the final heal
    // must return the pool to full size.
    steps.push(Step { chaos: Chaos::Reenable, task: RoundTask::MaxSingleton });
    steps
}

/// Two distinct element ids from the instance universe — a broadcast
/// partial solution for `Filter`/`PruneSample` rounds.
fn distinct_pair(rng: &mut Lcg) -> Vec<ElementId> {
    let a = rng.below(120) as ElementId;
    let mut b = rng.below(120) as ElementId;
    if b == a {
        b = (b + 1) % 120;
    }
    vec![a, b]
}

// --- fixture -----------------------------------------------------------------

fn chaos_spec() -> OracleSpec {
    OracleSpec::Coverage { n: 120, universe: 80, avg_degree: 3, weighted: false, seed: 5 }
}

fn chaos_shards() -> Vec<Vec<ElementId>> {
    vec![(0..40).collect(), (40..80).collect(), (80..120).collect()]
}

fn chaos_sample() -> Vec<ElementId> {
    (0..120).step_by(7).collect()
}

fn spawn_pool(transport: Transport) -> ProcessPool {
    ProcessPool::spawn(&chaos_spec(), &chaos_shards(), &chaos_sample(), &PoolOptions {
        workers: POOL,
        transport,
        timeout: std::time::Duration::from_secs(60),
        connect_timeout: std::time::Duration::from_secs(60),
        max_frame: 64 << 20,
        exe: Some(worker_exe()),
        env: Vec::new(),
        recovery: RecoveryPolicy::Requeue { budget: (MAX_KILLS + 3) as usize },
        elastic: false,
    })
    .expect("clean spawn")
}

/// The `Serial` reference: the same task sequence executed in-process
/// over the same shards, with persistent per-machine stores and the
/// coordinator-side state cache — the ground truth every chaotic pool
/// run must match bit-for-bit.
struct SerialRef {
    oracle: std::sync::Arc<dyn mrsub::oracle::Oracle>,
    shards: Vec<Vec<ElementId>>,
    stores: Vec<GuessStore>,
    cache: StateCache,
}

impl SerialRef {
    fn new() -> Self {
        SerialRef {
            oracle: chaos_spec().build().expect("reference oracle"),
            shards: chaos_shards(),
            stores: vec![GuessStore::default(); POOL],
            cache: StateCache::default(),
        }
    }
    fn round(&mut self, task: &RoundTask) -> Vec<mrsub::mapreduce::wire::TaskReply> {
        run_task_all_cached(
            self.oracle.as_ref(),
            &self.shards,
            &mut self.stores,
            &[0, 1, 2],
            task,
            &Serial,
            &mut self.cache,
        )
    }
}

// --- harness plumbing --------------------------------------------------------

/// Seeds to run: 1..=16 by default (× 4 transports = 64 schedules),
/// overridable via `MRSUB_CHAOS_SCHEDULES` as a comma-separated list
/// for replaying a failure.
fn schedule_seeds() -> Vec<u64> {
    match std::env::var("MRSUB_CHAOS_SCHEDULES") {
        // an empty/whitespace value (e.g. a CI matrix leg that leaves the
        // variable unset-but-exported) means "default", not "no schedules" —
        // zero schedules would green-light the suite without running it.
        Ok(s) if !s.trim().is_empty() => s
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| t.trim().parse().expect("MRSUB_CHAOS_SCHEDULES: u64 seeds"))
            .collect(),
        _ => (1..=16).collect(),
    }
}

/// Append failing seeds to the CI artifact file (best-effort).
fn record_failures(failures: &[String]) {
    if failures.is_empty() {
        return;
    }
    let path = std::env::var("MRSUB_CHAOS_ARTIFACT")
        .unwrap_or_else(|_| "target/chaos-failures.txt".to_string());
    if let Some(dir) = std::path::Path::new(&path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    if let Ok(mut f) =
        std::fs::OpenOptions::new().create(true).append(true).open(&path)
    {
        for line in failures {
            let _ = writeln!(f, "{line}");
        }
    }
}

/// Run one schedule against a live pool and the serial reference;
/// `Err` carries a replayable description (seed, transport, round).
fn run_schedule(seed: u64, transport: Transport) -> Result<(), String> {
    let label = format!("seed={seed} transport={transport}");
    let steps = generate_schedule(seed);
    let mut pool = spawn_pool(transport);
    let mut serial = SerialRef::new();
    let mut kills = 0u64;
    let mut respawns = 0u64;
    let mut steals = 0u64;

    for (i, step) in steps.iter().enumerate() {
        match step.chaos {
            Chaos::None => {}
            Chaos::Kill(w) => {
                pool.kill_worker(w);
                kills += 1;
            }
            Chaos::StealKill(w) => {
                pool.set_respawn(false);
                pool.kill_worker(w);
                kills += 1;
                steals += 1;
            }
            Chaos::Reenable => pool.set_respawn(true),
        }
        let want = serial.round(&step.task);
        let (got, stats) = pool.round(&step.task).map_err(|e| {
            format!("{label}: round {i} ({:?} then {:?}) failed: {e}", step.chaos, step.task)
        })?;
        if got != want {
            return Err(format!(
                "{label}: round {i} ({:?} then {:?}) diverged from Serial",
                step.chaos, step.task
            ));
        }
        respawns += stats.respawns;
    }
    // acceptance: the loop is closed — every kill's slot was eventually
    // refilled and the pool is back at `process:N` size.
    if pool.alive_workers() != POOL {
        return Err(format!(
            "{label}: pool ended at {}/{POOL} workers",
            pool.alive_workers()
        ));
    }
    if respawns < kills {
        return Err(format!(
            "{label}: {kills} kills but only {respawns} respawns metered"
        ));
    }
    if steals > 0 && pool.rebalanced_machines() == 0 {
        return Err(format!(
            "{label}: {steals} steal-kills but the planner never moved a machine"
        ));
    }
    Ok(())
}

// --- the matrices ------------------------------------------------------------

/// Kill / respawn / steal chaos × every pool-spawned transport. 16 seeds
/// × 4 transports = 64 schedules by default, each bit-identical to
/// `Serial` round-by-round.
#[test]
fn seeded_chaos_schedules_stay_bit_identical_on_every_transport() {
    let seeds = schedule_seeds();
    let mut failures = Vec::new();
    for transport in
        [Transport::Pipe, Transport::Uds, Transport::UdsArena, Transport::Tcp { bind: None }]
    {
        for &seed in &seeds {
            if let Err(msg) = run_schedule(seed, transport.clone()) {
                failures.push(msg);
            }
        }
    }
    record_failures(&failures);
    assert!(
        failures.is_empty(),
        "{} chaos schedule(s) failed — replay with \
         MRSUB_CHAOS_SCHEDULES=<seed> cargo test --test elastic_chaos:\n{}",
        failures.len(),
        failures.join("\n")
    );
}

/// Late-join chaos on the external TCP topology: killed external workers
/// are never respawned by the pool — a late `mrsub worker --connect`
/// back-fills the dead slot at the next round boundary and the planner
/// rebalances onto it. Replies stay bit-identical to `Serial` no matter
/// when (relative to rounds) the joiner lands.
#[test]
fn seeded_late_join_schedules_stay_bit_identical_on_external_tcp() {
    let seeds: Vec<u64> = schedule_seeds().into_iter().take(4).collect();
    let mut failures = Vec::new();
    for &seed in &seeds {
        if let Err(msg) = run_late_join_schedule(seed) {
            failures.push(msg);
        }
    }
    record_failures(&failures);
    assert!(
        failures.is_empty(),
        "{} late-join schedule(s) failed — replay with \
         MRSUB_CHAOS_SCHEDULES=<seed> cargo test --test elastic_chaos:\n{}",
        failures.len(),
        failures.join("\n")
    );
}

fn run_late_join_schedule(seed: u64) -> Result<(), String> {
    let label = format!("seed={seed} transport=tcp(external)");
    let mut rng = Lcg::new(seed ^ 0xC0FFEE);
    // reserve a port, then release it for the pool to bind.
    let port = {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").expect("probe bind");
        probe.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");
    let spawn_worker = |id: usize| {
        std::process::Command::new(worker_exe())
            .args(["worker", "--connect", &addr, "--id", &id.to_string()])
            .stdin(std::process::Stdio::null())
            .spawn()
            .expect("spawn external worker")
    };
    const WORKERS: usize = 2;
    let mut children = vec![spawn_worker(0), spawn_worker(1)];

    let mut pool = ProcessPool::spawn(&chaos_spec(), &chaos_shards(), &chaos_sample(), &PoolOptions {
        workers: WORKERS,
        transport: Transport::Tcp { bind: Some(addr.clone()) },
        timeout: std::time::Duration::from_secs(60),
        connect_timeout: std::time::Duration::from_secs(60),
        max_frame: 64 << 20,
        exe: Some(worker_exe()),
        env: Vec::new(),
        recovery: RecoveryPolicy::Requeue { budget: 4 },
        elastic: false,
    })
    .map_err(|e| format!("{label}: external spawn failed: {e}"))?;
    let mut serial = SerialRef::new();

    // one clean round, then two kill→late-join cycles at rng-chosen
    // rounds; the joiner may land mid-round (parked) or between rounds
    // (integrated at the heal) — replies must not depend on which.
    let rounds = 6;
    let mut kill_rounds: Vec<u32> = vec![2, 2 + 1 + rng.below(2) as u32 * 2];
    kill_rounds.dedup();
    let mut victim = 1usize;
    for round in 1..=rounds {
        if kill_rounds.contains(&round) {
            pool.kill_worker(victim);
            children.push(spawn_worker(victim));
            victim = (victim + 1) % WORKERS;
            if rng.below(2) == 0 {
                // sometimes let the joiner settle into the listener
                // backlog before the round; sometimes race it.
                std::thread::sleep(std::time::Duration::from_millis(300));
            }
        }
        let task = match rng.below(3) {
            0 => RoundTask::MaxSingleton,
            1 => RoundTask::LocalGreedy { k: 2 + rng.below(3) as usize },
            _ => RoundTask::PruneSample {
                base: distinct_pair(&mut rng),
                floor: 0.5,
                tau: 1.5,
                per_share: 6,
                seed: rng.next(),
                round,
            },
        };
        let want = serial.round(&task);
        let (got, _) = pool
            .round(&task)
            .map_err(|e| format!("{label}: round {round} ({task:?}) failed: {e}"))?;
        if got != want {
            return Err(format!("{label}: round {round} ({task:?}) diverged from Serial"));
        }
    }
    // the joins must have closed the loop by the final boundary: run one
    // last quiet round so any still-parked joiner integrates, then check.
    std::thread::sleep(std::time::Duration::from_millis(500));
    let want = serial.round(&RoundTask::MaxSingleton);
    let (got, _) = pool
        .round(&RoundTask::MaxSingleton)
        .map_err(|e| format!("{label}: settling round failed: {e}"))?;
    if got != want {
        return Err(format!("{label}: settling round diverged from Serial"));
    }
    if pool.alive_workers() != WORKERS {
        return Err(format!(
            "{label}: late joins never back-filled — {}/{WORKERS} workers alive",
            pool.alive_workers()
        ));
    }
    if pool.respawns() < kill_rounds.len() as u64 {
        return Err(format!(
            "{label}: {} kills but only {} back-fills metered",
            kill_rounds.len(),
            pool.respawns()
        ));
    }
    drop(pool); // shutdown: surviving externals exit on their own.
    for child in &mut children {
        let _ = child.wait(); // killed workers exit nonzero; ignore.
    }
    Ok(())
}
