//! Pluggable execution substrate for the cluster simulator.
//!
//! Every simulated worker round is "run this closure once per machine";
//! [`ExecBackend`] abstracts *how* those per-machine executions are
//! scheduled, replacing the hard-coded rayon-or-serial switch that used to
//! live inside `MrCluster::worker_round`. Three backends ship today:
//!
//! * [`Serial`] — in-order execution on the calling thread. The reference
//!   semantics; also the right choice for tiny rounds where dispatch
//!   overhead dominates.
//! * [`Rayon`] — the persistent thread pool of [`crate::util::pool`]
//!   (the in-repo rayon substitute), with a configurable work-claim
//!   `chunk`: machines are claimed `chunk` at a time from an atomic
//!   cursor, trading load balancing (chunk = 1) against dispatch cost on
//!   many cheap machines (chunk > 1).
//! * [`BackendKind::Process`] — shared-nothing OS worker processes
//!   ([`crate::mapreduce::process`]): shards and oracle specs are
//!   serialized over pipes ([`crate::mapreduce::wire`]) and typed shard
//!   rounds execute worker-side; see [`ProcessCtl`] for how the closure
//!   interface degrades for control-plane work.
//!
//! The contract every backend must satisfy — and which
//! `tests/batch_equivalence.rs` asserts pairwise — is *output
//! determinism*: `map_indexed(backend, n, f)[i] == f(i)` regardless of
//! scheduling, so `Serial` and `Rayon` runs of the same algorithm produce
//! identical per-machine outputs and identical metrics.
//!
//! Room is deliberately left for heavier substrates (a multi-process
//! backend shelling out to worker processes, an async round scheduler
//! overlapping communication with compute): implement [`ExecBackend`] and
//! add a [`BackendKind`] variant — nothing above this module changes.

use std::fmt;
use std::sync::Arc;

use crate::mapreduce::transport::Transport;
use crate::util::pool;

/// How per-machine closures of a worker round are executed.
///
/// Implementations must run `work(i)` exactly once for every `i < n`
/// before returning, and may use any parallelism; callers rely only on
/// completion, never on ordering.
pub trait ExecBackend: Send + Sync + fmt::Debug {
    /// Stable human-readable name (used in metrics and bench reports).
    fn name(&self) -> &'static str;

    /// Execute `work(i)` for every `i < n`; blocks until all are done.
    fn for_each(&self, n: usize, work: &(dyn Fn(usize) + Sync));
}

/// In-order execution on the calling thread.
#[derive(Debug, Clone, Copy, Default)]
pub struct Serial;

impl ExecBackend for Serial {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn for_each(&self, n: usize, work: &(dyn Fn(usize) + Sync)) {
        for i in 0..n {
            work(i);
        }
    }
}

/// Persistent-thread-pool execution with `chunk`-granular work claiming.
#[derive(Debug, Clone, Copy)]
pub struct Rayon {
    /// Indices claimed per atomic cursor bump. `0` = auto: derive the
    /// chunk from the machine count and the pool width per round (see
    /// [`auto_chunk`]). Explicit `chunk=N` (N ≥ 1) pins it.
    pub chunk: usize,
}

impl Default for Rayon {
    fn default() -> Self {
        Rayon { chunk: 0 }
    }
}

impl ExecBackend for Rayon {
    fn name(&self) -> &'static str {
        "rayon"
    }

    fn for_each(&self, n: usize, work: &(dyn Fn(usize) + Sync)) {
        let chunk = if self.chunk == 0 { auto_chunk(n) } else { self.chunk };
        pool::run_indexed(n, chunk, work);
    }
}

/// The auto work-claim chunk for an `n`-machine round: one cursor bump
/// per ~4 claims per thread, clamped to `[1, 64]`. The bench sweeps show
/// chunk=1 is right up to a few machines per thread (per-machine oracle
/// work dwarfs the dispatch), while many cheap machines per thread want
/// coarser claims to amortize the atomic cursor; 4 claims/thread keeps
/// enough slack for load balancing on skewed shards.
pub fn auto_chunk(n: usize) -> usize {
    let threads = pool::num_threads().max(1);
    (n / (threads * 4)).clamp(1, 64)
}

/// Control-plane stand-in for the shared-nothing process backend.
///
/// [`ExecBackend`] is the *in-address-space* scheduling interface; a
/// shared-nothing backend cannot ship arbitrary closures to another
/// process. Under [`BackendKind::Process`], the data plane — oracle
/// evaluation over shards — runs in worker processes through the typed
/// round API ([`crate::mapreduce::MrCluster::shard_round`] +
/// [`crate::mapreduce::process::ProcessPool`]); whatever closure-based
/// coordination remains (sample-side planning, legacy rounds) executes
/// serially in the coordinator through this stand-in.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProcessCtl;

impl ExecBackend for ProcessCtl {
    fn name(&self) -> &'static str {
        "process"
    }

    fn for_each(&self, n: usize, work: &(dyn Fn(usize) + Sync)) {
        for i in 0..n {
            work(i);
        }
    }
}

/// Serializable backend selector — what configs, the CLI, and
/// [`super::ClusterConfig`] carry; [`BackendKind::build`] instantiates the
/// actual backend.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendKind {
    /// [`Serial`].
    Serial,
    /// [`Rayon`] with the given work-claim chunk.
    Rayon {
        /// Indices claimed per cursor bump.
        chunk: usize,
    },
    /// Shared-nothing worker processes
    /// ([`crate::mapreduce::process::ProcessPool`]); simulated machines
    /// are assigned round-robin across `workers` OS processes, reached
    /// over `transport` (pipes, a Unix-domain socket, or TCP).
    Process {
        /// Worker processes (≥ 1; capped at the machine count).
        workers: usize,
        /// Byte-stream transport coordinator ↔ workers.
        transport: Transport,
    },
}

impl BackendKind {
    /// Instantiate the in-process scheduling backend. For
    /// [`BackendKind::Process`] this is the [`ProcessCtl`] control-plane
    /// stand-in — the worker pool itself is owned by the cluster, which
    /// consults [`BackendKind::process_workers`] to spawn it.
    pub fn build(&self) -> Arc<dyn ExecBackend> {
        match self {
            BackendKind::Serial => Arc::new(Serial),
            BackendKind::Rayon { chunk } => Arc::new(Rayon { chunk: *chunk }),
            BackendKind::Process { .. } => Arc::new(ProcessCtl),
        }
    }

    /// The valid backend names, for error messages — kept next to the
    /// parser so the two cannot drift.
    pub const NAMES: &'static str =
        "serial | rayon | rayon(chunk=N) | process:N[@pipe|@uds|@uds+arena|@tcp[:HOST:PORT]] \
         with N >= 1";

    /// Parse a config/CLI backend name: `"serial"`, `"rayon"`,
    /// `"process"`, `"process:N"` (N ≥ 1 worker processes),
    /// `"process:N@pipe"` / `"process:N@uds"` / `"process:N@uds+arena"` /
    /// `"process:N@tcp"` / `"process:N@tcp:HOST:PORT"` (transport
    /// selection; see [`Transport`]), plus the round-trippable
    /// [`BackendKind::label`] forms (`"rayon(chunk=N)"`). `chunk` applies
    /// to the bare `"rayon"`/`"process"` forms (for rayon, `0` = the
    /// [`auto_chunk`] heuristic). Unknown names, `"process:0"`, and bad
    /// transport suffixes return a structured error naming the valid set.
    pub fn parse(name: &str, chunk: usize) -> Result<BackendKind, String> {
        if let Some(rest) = name.strip_prefix("process:") {
            let (workers, transport) = match rest.split_once('@') {
                Some((w, t)) => (w, Transport::parse_suffix(t)?),
                None => (rest, Transport::Pipe),
            };
            return workers
                .trim()
                .parse::<usize>()
                .ok()
                .filter(|&w| w > 0)
                .map(|workers| BackendKind::Process { workers, transport })
                .ok_or_else(|| {
                    format!(
                        "bad worker count in backend {name:?} (valid backends: {})",
                        BackendKind::NAMES
                    )
                });
        }
        if let Some(rest) = name.strip_prefix("rayon(chunk=") {
            return rest
                .strip_suffix(')')
                .and_then(|inner| inner.parse::<usize>().ok())
                .map(|c| BackendKind::Rayon { chunk: c })
                .ok_or_else(|| {
                    format!(
                        "bad chunk in backend {name:?} (valid backends: {})",
                        BackendKind::NAMES
                    )
                });
        }
        match name {
            "serial" => Ok(BackendKind::Serial),
            "rayon" => Ok(BackendKind::Rayon { chunk }),
            "process" => Ok(BackendKind::Process {
                workers: chunk.max(1),
                transport: Transport::Pipe,
            }),
            _ => Err(format!(
                "unknown backend {name:?} (valid backends: {})",
                BackendKind::NAMES
            )),
        }
    }

    /// Display label; every label round-trips through
    /// [`BackendKind::parse`] (asserted by tests), so labels written into
    /// bench reports and TOML configs can be read back verbatim. The
    /// default pipe transport is elided (`process:N`, not
    /// `process:N@pipe`) and the auto chunk is elided (`rayon`, not
    /// `rayon(chunk=0)`) so default labels stay stable.
    pub fn label(&self) -> String {
        match self {
            BackendKind::Serial => "serial".into(),
            BackendKind::Rayon { chunk: 0 } => "rayon".into(),
            BackendKind::Rayon { chunk } => format!("rayon(chunk={chunk})"),
            BackendKind::Process { workers, transport } => {
                format!("process:{workers}{}", transport.label_suffix())
            }
        }
    }

    /// Whether this backend executes machines concurrently.
    pub fn is_parallel(&self) -> bool {
        !matches!(self, BackendKind::Serial)
    }

    /// Worker-process count when this is the process backend.
    pub fn process_workers(&self) -> Option<usize> {
        match self {
            BackendKind::Process { workers, .. } => Some(*workers),
            _ => None,
        }
    }

    /// Worker transport when this is the process backend.
    pub fn process_transport(&self) -> Option<&Transport> {
        match self {
            BackendKind::Process { transport, .. } => Some(transport),
            _ => None,
        }
    }
}

/// Order-preserving indexed map over `0..n` through a backend: the result
/// at position `i` is `f(i)` no matter how the backend scheduled the work.
/// (The slot-writer machinery lives in [`pool::map_indexed_with`] so the
/// `unsafe` has a single home.)
pub fn map_indexed<R, F>(backend: &dyn ExecBackend, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    pool::map_indexed_with(n, |work| backend.for_each(n, work), f)
}

/// Order-preserving map over a slice through a backend.
pub fn map_slice<T, R, F>(backend: &dyn ExecBackend, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    map_indexed(backend, items.len(), |i| f(i, &items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_kinds() -> Vec<BackendKind> {
        vec![
            BackendKind::Serial,
            BackendKind::Rayon { chunk: 1 },
            BackendKind::Rayon { chunk: 7 },
        ]
    }

    #[test]
    fn backends_agree_with_serial_reference() {
        let reference: Vec<u64> = (0..129u64).map(|i| i * i + 1).collect();
        for kind in all_kinds() {
            let backend = kind.build();
            let got = map_indexed(backend.as_ref(), 129, |i| (i as u64) * (i as u64) + 1);
            assert_eq!(got, reference, "{}", kind.label());
        }
    }

    #[test]
    fn map_slice_preserves_order() {
        let items: Vec<u32> = (0..64).rev().collect();
        for kind in all_kinds() {
            let backend = kind.build();
            let got = map_slice(backend.as_ref(), &items, |i, &x| (i, x));
            for (i, &(gi, gx)) in got.iter().enumerate() {
                assert_eq!(gi, i);
                assert_eq!(gx, items[i]);
            }
        }
    }

    #[test]
    fn empty_input_is_fine() {
        for kind in all_kinds() {
            let backend = kind.build();
            let got: Vec<u8> = map_indexed(backend.as_ref(), 0, |_| unreachable!());
            assert!(got.is_empty());
        }
    }

    #[test]
    fn kind_parse_and_label_roundtrip() {
        assert_eq!(BackendKind::parse("serial", 9), Ok(BackendKind::Serial));
        assert_eq!(BackendKind::parse("rayon", 4), Ok(BackendKind::Rayon { chunk: 4 }));
        // chunk 0 = the auto heuristic, preserved through parsing.
        assert_eq!(BackendKind::parse("rayon", 0), Ok(BackendKind::Rayon { chunk: 0 }));
        let err = BackendKind::parse("cuda", 1).unwrap_err();
        assert!(err.contains(BackendKind::NAMES), "{err}");
        assert_eq!(BackendKind::Serial.label(), "serial");
        assert_eq!(BackendKind::Rayon { chunk: 0 }.label(), "rayon");
        assert_eq!(BackendKind::Rayon { chunk: 4 }.label(), "rayon(chunk=4)");
        assert!(!BackendKind::Serial.is_parallel());
        assert!(BackendKind::Rayon { chunk: 1 }.is_parallel());
    }

    #[test]
    fn auto_chunk_scales_with_machines_within_bounds() {
        // tiny rounds: max balancing.
        assert_eq!(auto_chunk(0), 1);
        assert_eq!(auto_chunk(1), 1);
        // huge rounds: clamped so balancing never fully disappears.
        assert_eq!(auto_chunk(usize::MAX), 64);
        // monotone in n for a fixed pool width.
        let threads = crate::util::pool::num_threads().max(1);
        assert!(auto_chunk(threads * 4) >= 1);
        assert!(auto_chunk(threads * 512) >= auto_chunk(threads * 4));
        // auto (chunk=0) and explicit chunks agree on outputs.
        let auto = Rayon::default();
        assert_eq!(auto.chunk, 0);
        let got = map_indexed(&auto, 257, |i| i * 3);
        let want: Vec<usize> = (0..257).map(|i| i * 3).collect();
        assert_eq!(got, want);
    }

    fn process_kind(workers: usize, transport: Transport) -> BackendKind {
        BackendKind::Process { workers, transport }
    }

    #[test]
    fn process_kind_parse_label_and_rejections() {
        assert_eq!(
            BackendKind::parse("process:4", 1),
            Ok(process_kind(4, Transport::Pipe))
        );
        assert_eq!(BackendKind::parse("process", 3), Ok(process_kind(3, Transport::Pipe)));
        // process:0 is meaningless and must be rejected, not clamped —
        // and the error names the valid set.
        for bad in ["process:0", "process:", "process:x"] {
            let err = BackendKind::parse(bad, 1).unwrap_err();
            assert!(err.contains(BackendKind::NAMES), "{bad}: {err}");
        }
        assert_eq!(process_kind(4, Transport::Pipe).label(), "process:4");
        assert!(process_kind(1, Transport::Pipe).is_parallel());
        assert_eq!(process_kind(2, Transport::Pipe).process_workers(), Some(2));
        assert_eq!(BackendKind::Serial.process_workers(), None);
        assert_eq!(BackendKind::Serial.process_transport(), None);
        assert_eq!(process_kind(2, Transport::Pipe).build().name(), "process");
    }

    #[test]
    fn process_transport_suffixes_parse() {
        assert_eq!(
            BackendKind::parse("process:2@pipe", 1),
            Ok(process_kind(2, Transport::Pipe))
        );
        assert_eq!(
            BackendKind::parse("process:2@uds", 1),
            Ok(process_kind(2, Transport::Uds))
        );
        assert_eq!(
            BackendKind::parse("process:2@uds+arena", 1),
            Ok(process_kind(2, Transport::UdsArena))
        );
        assert_eq!(
            BackendKind::parse("process:3@tcp", 1),
            Ok(process_kind(3, Transport::Tcp { bind: None }))
        );
        assert_eq!(
            BackendKind::parse("process:3@tcp:0.0.0.0:7070", 1),
            Ok(process_kind(3, Transport::Tcp { bind: Some("0.0.0.0:7070".into()) }))
        );
        // bad worker counts / transports are rejected, not defaulted —
        // with transport errors naming the valid transport set.
        assert!(BackendKind::parse("process:0@uds", 1).is_err());
        let err = BackendKind::parse("process:2@shm", 1).unwrap_err();
        assert!(
            err.contains(crate::mapreduce::transport::TRANSPORT_SUFFIXES),
            "{err}"
        );
        assert!(BackendKind::parse("process:2@tcp:", 1).is_err());
        assert_eq!(
            process_kind(2, Transport::Uds).process_transport(),
            Some(&Transport::Uds)
        );
    }

    #[test]
    fn every_label_roundtrips_through_parse() {
        for kind in [
            BackendKind::Serial,
            BackendKind::Rayon { chunk: 0 },
            BackendKind::Rayon { chunk: 1 },
            BackendKind::Rayon { chunk: 7 },
            process_kind(1, Transport::Pipe),
            process_kind(16, Transport::Pipe),
            process_kind(2, Transport::Uds),
            process_kind(2, Transport::UdsArena),
            process_kind(4, Transport::Tcp { bind: None }),
            process_kind(4, Transport::Tcp { bind: Some("127.0.0.1:9100".into()) }),
        ] {
            // the chunk context param only applies to the bare "rayon"
            // form; 0 keeps the auto label ("rayon") a fixed point.
            assert_eq!(
                BackendKind::parse(&kind.label(), 0),
                Ok(kind.clone()),
                "label {:?} must parse back to its kind",
                kind.label()
            );
        }
    }

    #[test]
    fn rayon_backend_handles_nested_fanout() {
        let backend = BackendKind::Rayon { chunk: 1 }.build();
        let outer = map_indexed(backend.as_ref(), 4, |i| {
            let inner = map_indexed(backend.as_ref(), 8, |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        assert_eq!(outer.len(), 4);
        assert_eq!(outer[0], (0..8).sum::<usize>());
    }
}
