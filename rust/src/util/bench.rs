//! Tiny timing harness for the `harness = false` benches (the criterion
//! substitute): warmup + N timed iterations, reporting min/median/mean.

use std::time::{Duration, Instant};

/// Timing summary over iterations.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Samples measured.
    pub iters: usize,
    /// Fastest sample.
    pub min: Duration,
    /// Median sample.
    pub median: Duration,
    /// Mean over all samples.
    pub mean: Duration,
}

impl Timing {
    /// `"min 1.234ms  med 1.301ms  mean 1.310ms  (n=20)"`
    pub fn display(&self) -> String {
        format!(
            "min {:>9}  med {:>9}  mean {:>9}  (n={})",
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.mean),
            self.iters
        )
    }
}

/// Human duration: ns/µs/ms/s with 3 significant places.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs. The
/// closure's return value is consumed with `std::hint::black_box`.
pub fn time<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    Timing { iters: samples.len(), min, median, mean }
}

/// Throughput helper: items per second at a given duration.
pub fn throughput(items: usize, d: Duration) -> f64 {
    items as f64 / d.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_reports_ordered_stats() {
        let t = time(1, 9, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.min <= t.median);
        assert_eq!(t.iters, 9);
        assert!(!t.display().is_empty());
    }

    #[test]
    fn fmt_covers_ranges() {
        assert!(fmt_dur(Duration::from_nanos(5)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }

    #[test]
    fn throughput_math() {
        assert_eq!(throughput(1000, Duration::from_secs(1)), 1000.0);
    }
}
