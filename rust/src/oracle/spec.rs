//! Serializable oracle construction recipes.
//!
//! The process backend's workers are shared-nothing: they cannot borrow
//! the coordinator's oracle, so every oracle family gains a wire-codable
//! *spec* — the deterministic generator parameters plus the seed — from
//! which a worker rebuilds a bit-identical oracle on its side of the pipe.
//! All in-repo generators are pure functions of `(params, seed)` (SplitMix
//! seed derivation, no platform-dependent floating point), so rebuilding
//! from the spec is exact: every marginal a worker computes matches the
//! coordinator's to the last bit, which is what lets
//! `tests/backend_conformance.rs` assert bit-identical selections across
//! `Serial`/`Rayon`/`Process`.
//!
//! [`crate::workload`] generators attach their spec to the [`Instance`]s
//! they produce; data-defined oracles (explicit modular weights) serialize
//! their data outright.
//!
//! [`Instance`]: crate::workload::Instance

use std::sync::Arc;

use crate::core::{Error, Result};
use crate::mapreduce::wire::{Dec, Enc, WireError};
use crate::oracle::concave::{ConcaveOverModularOracle, Phi};
use crate::oracle::modular::ModularOracle;
use crate::oracle::Oracle;
use crate::util::rng::Rng;
use crate::workload::adversarial::AdversarialGen;
use crate::workload::corpus::ZipfCorpusGen;
use crate::workload::coverage::CoverageGen;
use crate::workload::dicut::PlantedDicutGen;
use crate::workload::facility::{FacilityGen, Kernel};
use crate::workload::graph::GraphGen;
use crate::workload::planted::PlantedCoverageGen;

/// A deterministic oracle construction recipe (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub enum OracleSpec {
    /// [`CoverageGen`].
    Coverage {
        /// Elements.
        n: usize,
        /// Universe size.
        universe: usize,
        /// Average element degree.
        avg_degree: usize,
        /// Heavy-tailed item weights.
        weighted: bool,
        /// Generator seed.
        seed: u64,
    },
    /// [`ZipfCorpusGen`].
    Zipf {
        /// Documents (elements).
        docs: usize,
        /// Vocabulary (universe).
        vocab: usize,
        /// Words per document.
        doc_len: usize,
        /// Zipf exponent.
        s: f64,
        /// IDF-weighted items.
        idf: bool,
        /// Generator seed.
        seed: u64,
    },
    /// [`PlantedCoverageGen`].
    Planted {
        /// Golden elements (= planted optimal k).
        k: usize,
        /// Universe size.
        universe: usize,
        /// Noise elements.
        noise_n: usize,
        /// Items per noise element.
        noise_deg: usize,
        /// Generator seed.
        seed: u64,
    },
    /// [`FacilityGen`].
    Facility {
        /// Candidate elements.
        n: usize,
        /// Demand points.
        d: usize,
        /// RBF kernel (`true`) vs inverse kernel.
        rbf: bool,
        /// Kernel bandwidth γ.
        gamma: f64,
        /// Planted cluster centers (0 = uniform).
        clusters: usize,
        /// Generator seed.
        seed: u64,
    },
    /// [`GraphGen::erdos_renyi`] edge coverage.
    ErdosRenyi {
        /// Vertices.
        n: usize,
        /// Edge probability.
        p: f64,
        /// Generator seed.
        seed: u64,
    },
    /// [`GraphGen::barabasi_albert`] edge coverage.
    BarabasiAlbert {
        /// Vertices.
        n: usize,
        /// Edges per arriving vertex.
        attach: usize,
        /// Generator seed.
        seed: u64,
    },
    /// [`AdversarialGen`] (deterministic; no seed).
    Adversarial {
        /// Thresholds the instance is hard for.
        t: usize,
        /// Cardinality constraint.
        k: usize,
    },
    /// Explicit modular weights (data-defined; shipped outright).
    Modular {
        /// Per-element weights.
        weights: Vec<f64>,
    },
    /// The `mrsub bench` concave-over-modular family: `n` elements with 4
    /// random (group, weight) incidences each over `groups` groups,
    /// `φ = √`, derived from `seed` exactly as the bench builds it.
    ConcaveBench {
        /// Elements.
        n: usize,
        /// Groups.
        groups: usize,
        /// Generator seed.
        seed: u64,
    },
    /// [`PlantedDicutGen`] — the *non-monotone* directed-cut workload
    /// (sources `0..sources` fan weighted arcs into sinks; OPT is all
    /// sources).
    Dicut {
        /// Source vertices (= planted optimal k).
        sources: usize,
        /// Sink vertices.
        sinks: usize,
        /// Out-arcs per source.
        deg: usize,
        /// Generator seed.
        seed: u64,
    },
}

impl OracleSpec {
    /// Rebuild the oracle deterministically.
    pub fn build(&self) -> Result<Arc<dyn Oracle>> {
        Ok(match self {
            OracleSpec::Coverage { n, universe, avg_degree, weighted, seed } => {
                let g = if *weighted {
                    CoverageGen::weighted(*n, *universe, *avg_degree)
                } else {
                    CoverageGen::new(*n, *universe, *avg_degree)
                };
                Arc::new(g.build(*seed))
            }
            OracleSpec::Zipf { docs, vocab, doc_len, s, idf, seed } => {
                let mut g = if *idf {
                    ZipfCorpusGen::idf(*docs, *vocab, *doc_len)
                } else {
                    ZipfCorpusGen::new(*docs, *vocab, *doc_len)
                };
                g.s = *s;
                Arc::new(g.build(*seed))
            }
            OracleSpec::Planted { k, universe, noise_n, noise_deg, seed } => {
                let g = PlantedCoverageGen {
                    k: *k,
                    universe: *universe,
                    noise_n: *noise_n,
                    noise_deg: *noise_deg,
                };
                Arc::new(g.build(*seed))
            }
            OracleSpec::Facility { n, d, rbf, gamma, clusters, seed } => {
                let kernel = if *rbf {
                    Kernel::Rbf { gamma: *gamma }
                } else {
                    Kernel::Inverse { gamma: *gamma }
                };
                let g = FacilityGen { n: *n, d: *d, kernel, clusters: *clusters };
                Arc::new(g.build(*seed))
            }
            OracleSpec::ErdosRenyi { n, p, seed } => {
                Arc::new(GraphGen::erdos_renyi(*n, *p).build(*seed))
            }
            OracleSpec::BarabasiAlbert { n, attach, seed } => {
                Arc::new(GraphGen::barabasi_albert(*n, *attach).build(*seed))
            }
            OracleSpec::Adversarial { t, k } => Arc::new(AdversarialGen::new(*t, *k).build()),
            OracleSpec::Modular { weights } => Arc::new(ModularOracle::new(weights.clone())),
            OracleSpec::ConcaveBench { n, groups, seed } => {
                Arc::new(build_concave_bench(*n, *groups, *seed))
            }
            OracleSpec::Dicut { sources, sinks, deg, seed } => {
                let g = PlantedDicutGen { sources: *sources, sinks: *sinks, deg: *deg };
                Arc::new(g.build(*seed))
            }
        })
    }

    /// Short family label (errors / reports).
    pub fn family(&self) -> &'static str {
        match self {
            OracleSpec::Coverage { .. } => "coverage",
            OracleSpec::Zipf { .. } => "zipf",
            OracleSpec::Planted { .. } => "planted",
            OracleSpec::Facility { .. } => "facility",
            OracleSpec::ErdosRenyi { .. } => "erdos-renyi",
            OracleSpec::BarabasiAlbert { .. } => "barabasi-albert",
            OracleSpec::Adversarial { .. } => "adversarial",
            OracleSpec::Modular { .. } => "modular",
            OracleSpec::ConcaveBench { .. } => "concave",
            OracleSpec::Dicut { .. } => "dicut",
        }
    }

    /// Encode into a wire payload.
    pub fn encode(&self, enc: &mut Enc) {
        match self {
            OracleSpec::Coverage { n, universe, avg_degree, weighted, seed } => {
                enc.u8(1);
                enc.usize(*n);
                enc.usize(*universe);
                enc.usize(*avg_degree);
                enc.bool(*weighted);
                enc.u64(*seed);
            }
            OracleSpec::Zipf { docs, vocab, doc_len, s, idf, seed } => {
                enc.u8(2);
                enc.usize(*docs);
                enc.usize(*vocab);
                enc.usize(*doc_len);
                enc.f64(*s);
                enc.bool(*idf);
                enc.u64(*seed);
            }
            OracleSpec::Planted { k, universe, noise_n, noise_deg, seed } => {
                enc.u8(3);
                enc.usize(*k);
                enc.usize(*universe);
                enc.usize(*noise_n);
                enc.usize(*noise_deg);
                enc.u64(*seed);
            }
            OracleSpec::Facility { n, d, rbf, gamma, clusters, seed } => {
                enc.u8(4);
                enc.usize(*n);
                enc.usize(*d);
                enc.bool(*rbf);
                enc.f64(*gamma);
                enc.usize(*clusters);
                enc.u64(*seed);
            }
            OracleSpec::ErdosRenyi { n, p, seed } => {
                enc.u8(5);
                enc.usize(*n);
                enc.f64(*p);
                enc.u64(*seed);
            }
            OracleSpec::BarabasiAlbert { n, attach, seed } => {
                enc.u8(6);
                enc.usize(*n);
                enc.usize(*attach);
                enc.u64(*seed);
            }
            OracleSpec::Adversarial { t, k } => {
                enc.u8(7);
                enc.usize(*t);
                enc.usize(*k);
            }
            OracleSpec::Modular { weights } => {
                enc.u8(8);
                enc.f64s(weights);
            }
            OracleSpec::ConcaveBench { n, groups, seed } => {
                enc.u8(9);
                enc.usize(*n);
                enc.usize(*groups);
                enc.u64(*seed);
            }
            OracleSpec::Dicut { sources, sinks, deg, seed } => {
                enc.u8(10);
                enc.usize(*sources);
                enc.usize(*sinks);
                enc.usize(*deg);
                enc.u64(*seed);
            }
        }
    }

    /// Decode from a wire payload.
    pub fn decode(dec: &mut Dec<'_>) -> std::result::Result<OracleSpec, WireError> {
        Ok(match dec.u8()? {
            1 => OracleSpec::Coverage {
                n: dec.usize()?,
                universe: dec.usize()?,
                avg_degree: dec.usize()?,
                weighted: dec.bool()?,
                seed: dec.u64()?,
            },
            2 => OracleSpec::Zipf {
                docs: dec.usize()?,
                vocab: dec.usize()?,
                doc_len: dec.usize()?,
                s: dec.f64()?,
                idf: dec.bool()?,
                seed: dec.u64()?,
            },
            3 => OracleSpec::Planted {
                k: dec.usize()?,
                universe: dec.usize()?,
                noise_n: dec.usize()?,
                noise_deg: dec.usize()?,
                seed: dec.u64()?,
            },
            4 => OracleSpec::Facility {
                n: dec.usize()?,
                d: dec.usize()?,
                rbf: dec.bool()?,
                gamma: dec.f64()?,
                clusters: dec.usize()?,
                seed: dec.u64()?,
            },
            5 => OracleSpec::ErdosRenyi { n: dec.usize()?, p: dec.f64()?, seed: dec.u64()? },
            6 => OracleSpec::BarabasiAlbert {
                n: dec.usize()?,
                attach: dec.usize()?,
                seed: dec.u64()?,
            },
            7 => OracleSpec::Adversarial { t: dec.usize()?, k: dec.usize()? },
            8 => OracleSpec::Modular { weights: dec.f64s()? },
            9 => OracleSpec::ConcaveBench {
                n: dec.usize()?,
                groups: dec.usize()?,
                seed: dec.u64()?,
            },
            10 => OracleSpec::Dicut {
                sources: dec.usize()?,
                sinks: dec.usize()?,
                deg: dec.usize()?,
                seed: dec.u64()?,
            },
            t => return Err(WireError::Malformed(format!("unknown OracleSpec tag {t}"))),
        })
    }

    /// Helper for callers holding a [`crate::core::Result`] context.
    pub fn decode_payload(payload: &[u8]) -> Result<OracleSpec> {
        let mut dec = Dec::new(payload);
        OracleSpec::decode(&mut dec).map_err(|e| Error::Config(format!("bad oracle spec: {e}")))
    }
}

/// The bench concave-over-modular construction, shared by `mrsub bench`
/// and [`OracleSpec::build`] so coordinator and workers derive the exact
/// same incidence from `(n, groups, seed)`.
pub fn build_concave_bench(n: usize, groups: usize, seed: u64) -> ConcaveOverModularOracle {
    let mut rng = Rng::seed_from_u64(seed);
    let incidence: Vec<Vec<(u32, f64)>> = (0..n)
        .map(|_| {
            (0..4)
                .map(|_| (rng.gen_range(0..groups) as u32, rng.gen_range_f64(0.1, 2.0)))
                .collect()
        })
        .collect();
    ConcaveOverModularOracle::new(n, groups, incidence, Phi::Sqrt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn arb_spec(g: &mut crate::util::check::Gen) -> OracleSpec {
        match g.usize_in(1, 11) {
            1 => OracleSpec::Coverage {
                n: g.usize_in(1, 200),
                universe: g.usize_in(1, 100),
                avg_degree: g.usize_in(1, 8),
                weighted: g.bool_with(0.5),
                seed: g.u64_in(1 << 40),
            },
            2 => OracleSpec::Zipf {
                docs: g.usize_in(1, 100),
                vocab: g.usize_in(1, 100),
                doc_len: g.usize_in(1, 10),
                s: g.f64_in(0.8, 1.4),
                idf: g.bool_with(0.5),
                seed: g.u64_in(1 << 40),
            },
            3 => OracleSpec::Planted {
                k: g.usize_in(1, 10),
                universe: g.usize_in(10, 100),
                noise_n: g.usize_in(1, 100),
                noise_deg: g.usize_in(1, 6),
                seed: g.u64_in(1 << 40),
            },
            4 => OracleSpec::Facility {
                n: g.usize_in(1, 60),
                d: g.usize_in(1, 30),
                rbf: g.bool_with(0.5),
                gamma: g.f64_in(0.5, 16.0),
                clusters: g.usize_in(0, 5),
                seed: g.u64_in(1 << 40),
            },
            5 => OracleSpec::ErdosRenyi {
                n: g.usize_in(2, 50),
                p: g.f64_in(0.01, 0.9),
                seed: g.u64_in(1 << 40),
            },
            6 => OracleSpec::BarabasiAlbert {
                n: g.usize_in(3, 50),
                attach: g.usize_in(1, 4),
                seed: g.u64_in(1 << 40),
            },
            7 => OracleSpec::Adversarial { t: g.usize_in(1, 4), k: g.usize_in(2, 20) },
            8 => OracleSpec::Modular {
                weights: (0..g.usize_in(0, 40)).map(|_| g.f64_in(0.0, 10.0)).collect(),
            },
            9 => OracleSpec::ConcaveBench {
                n: g.usize_in(1, 80),
                groups: g.usize_in(1, 32),
                seed: g.u64_in(1 << 40),
            },
            _ => OracleSpec::Dicut {
                sources: g.usize_in(1, 12),
                sinks: g.usize_in(2, 60),
                deg: g.usize_in(1, 6),
                seed: g.u64_in(1 << 40),
            },
        }
    }

    #[test]
    fn prop_spec_roundtrip() {
        forall(0x5EC, 80, |g| {
            let spec = arb_spec(g);
            let mut enc = Enc::new();
            spec.encode(&mut enc);
            let mut dec = Dec::new(&enc.buf);
            let back = OracleSpec::decode(&mut dec).expect("decode");
            dec.finish().expect("fully consumed");
            assert_eq!(spec, back);
        });
    }

    #[test]
    fn rebuilt_oracles_are_bit_identical() {
        // The shared-nothing contract: build twice from the same spec and
        // compare marginals bit for bit — on every family.
        forall(0x5ED, 12, |g| {
            let spec = arb_spec(g);
            let a = spec.build().expect("build a");
            let b = spec.build().expect("build b");
            assert_eq!(a.ground_size(), b.ground_size(), "{}", spec.family());
            let n = a.ground_size();
            if n == 0 {
                return;
            }
            let mut st_a = a.state();
            let mut st_b = b.state();
            st_a.insert(0);
            st_b.insert(0);
            for e in 0..(n as u32).min(40) {
                assert_eq!(
                    st_a.marginal(e).to_bits(),
                    st_b.marginal(e).to_bits(),
                    "{} marginal({e})",
                    spec.family()
                );
            }
            assert_eq!(st_a.value().to_bits(), st_b.value().to_bits());
        });
    }

    #[test]
    fn truncated_spec_errors_cleanly() {
        let spec = OracleSpec::Modular { weights: vec![1.0, 2.0, 3.0] };
        let mut enc = Enc::new();
        spec.encode(&mut enc);
        for cut in 0..enc.buf.len() {
            let mut dec = Dec::new(&enc.buf[..cut]);
            // must error (or decode a shorter-but-valid prefix never, since
            // lengths are prefixed) — and never panic.
            assert!(OracleSpec::decode(&mut dec).is_err(), "cut at {cut}");
        }
    }
}
