//! Algorithms 1 and 2 — the building blocks of everything in the paper.
//!
//! * **ThresholdGreedy(S, G, τ):** scan `S` in *fixed order*, adding any
//!   element whose marginal w.r.t. the growing solution is ≥ τ, until
//!   `|G| = k`. Postcondition (Alg 1): every `e ∈ S` has `f_{G'}(e) < τ`,
//!   or `|G'| = k` (in which case `f(G') ≥ τ·k` if it started empty).
//! * **ThresholdFilter(S, G, τ):** keep exactly the elements of `S` whose
//!   marginal w.r.t. the *fixed* `G` is ≥ τ.
//!
//! The fixed scan order matters twice: Lemma 1 needs every machine to
//! compute the *same* `G₀` from the broadcast sample, and the Theorem-4
//! lower bound is realized only when distractors precede the optimal
//! elements in the scan. All callers pass ascending-id inputs.
//!
//! Both building blocks drive the oracle through the block-marginal path
//! ([`OracleState::marginals`]): the filter evaluates whole blocks against
//! a fixed state, and the greedy scans blocks *lazily* — a block is
//! evaluated once against the state at block entry, and because marginals
//! only shrink as the solution grows (submodularity), any candidate whose
//! block-entry marginal is already `< τ` is skipped without a fresh query.
//! Only candidates still at `≥ τ` after an insertion are re-evaluated, so
//! the selection sequence is **exactly** the scalar algorithm's (asserted
//! by `prop_greedy_matches_scalar_reference` and
//! `tests/batch_equivalence.rs`) while the bulk of the marginal traffic
//! flows through the batched backends.

use crate::core::ElementId;
use crate::oracle::{OracleState, MARGINAL_BLOCK};

/// Batch size for block marginal evaluation; matches the AOT block size of
/// the PJRT engine so accelerated oracles get full tiles.
pub const FILTER_BLOCK: usize = MARGINAL_BLOCK;

/// Algorithm 1. Extends `state` in place; returns the elements added.
///
/// `k` bounds the *total* solution size (`state.len() + added ≤ k`).
///
/// Block-lazy scan, selection-identical to the scalar reference (see the
/// module docs for the submodularity argument). Oracle-call count is
/// slightly above the scalar scan's: whole blocks are evaluated up front
/// (so a mid-block `k`-stop still charges the full block) and candidates
/// invalidated by an insertion are re-queried once — the price of routing
/// the scan through the batched backends.
pub fn threshold_greedy(
    state: &mut dyn OracleState,
    input: &[ElementId],
    tau: f64,
    k: usize,
) -> Vec<ElementId> {
    let mut added = Vec::new();
    if state.len() >= k {
        return added;
    }
    let mut buf = [0.0f64; FILTER_BLOCK];
    for chunk in input.chunks(FILTER_BLOCK) {
        let m = &mut buf[..chunk.len()];
        state.marginals(chunk, m);
        // Inserts invalidate the block's cached marginals — but only
        // downward, so `cached < τ` remains a sound (and exact) skip.
        let mut stale = false;
        for (i, &e) in chunk.iter().enumerate() {
            if m[i] < tau {
                continue;
            }
            let gain = if stale { state.marginal(e) } else { m[i] };
            if gain >= tau {
                state.insert(e);
                added.push(e);
                stale = true;
                if state.len() >= k {
                    return added;
                }
            }
        }
    }
    added
}

/// Scalar reference implementation of Algorithm 1 (one marginal per scan
/// step). Kept for the equivalence tests and the `mrsub bench`
/// batched-vs-scalar comparison; not used by the algorithms.
pub fn threshold_greedy_scalar(
    state: &mut dyn OracleState,
    input: &[ElementId],
    tau: f64,
    k: usize,
) -> Vec<ElementId> {
    let mut added = Vec::new();
    if state.len() >= k {
        return added;
    }
    for &e in input {
        if state.marginal(e) >= tau {
            state.insert(e);
            added.push(e);
            if state.len() >= k {
                break;
            }
        }
    }
    added
}

/// Max singleton/marginal over `input` w.r.t. `state`, evaluated through
/// the block path (`0.0` for empty input — the identity the scalar folds
/// used).
pub fn block_max_marginal(state: &dyn OracleState, input: &[ElementId]) -> f64 {
    let mut buf = [0.0f64; FILTER_BLOCK];
    let mut best = 0.0f64;
    for chunk in input.chunks(FILTER_BLOCK) {
        let m = &mut buf[..chunk.len()];
        state.marginals(chunk, m);
        for &v in m.iter() {
            best = best.max(v);
        }
    }
    best
}

/// Evaluate marginals of `input` w.r.t. `state` into a fresh vec, block by
/// block — the SoA scoring step of the sparse worker and stochastic
/// sampling.
pub fn block_marginals(state: &dyn OracleState, input: &[ElementId]) -> Vec<f64> {
    let mut out = vec![0.0f64; input.len()];
    for (chunk, o) in input.chunks(FILTER_BLOCK).zip(out.chunks_mut(FILTER_BLOCK)) {
        state.marginals(chunk, o);
    }
    out
}

/// Algorithm 2. Returns the elements of `input` with `f_G(e) ≥ τ` for the
/// *fixed* state `G` (the state is not mutated).
pub fn threshold_filter(
    state: &dyn OracleState,
    input: &[ElementId],
    tau: f64,
) -> Vec<ElementId> {
    let mut out = Vec::new();
    let mut buf = [0.0f64; FILTER_BLOCK];
    for chunk in input.chunks(FILTER_BLOCK) {
        let m = &mut buf[..chunk.len()];
        state.marginals(chunk, m);
        for (i, &e) in chunk.iter().enumerate() {
            if m[i] >= tau {
                out.push(e);
            }
        }
    }
    out
}

/// Merge per-machine filtered shards into a single ascending-id list (the
/// fixed processing order for central completions).
pub fn merge_sorted(parts: &[Vec<ElementId>]) -> Vec<ElementId> {
    let mut all: Vec<ElementId> = parts.iter().flatten().copied().collect();
    all.sort_unstable();
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::coverage::CoverageOracle;
    use crate::oracle::modular::ModularOracle;
    use crate::oracle::Oracle;
    use crate::util::check::forall;

    #[test]
    fn greedy_respects_threshold_and_k() {
        let o = ModularOracle::new(vec![5.0, 1.0, 4.0, 3.0, 2.0]);
        let mut st = o.state();
        let added = threshold_greedy(st.as_mut(), &[0, 1, 2, 3, 4], 3.0, 2);
        // scan order: 0 (5.0 ≥ 3 ✓), 1 (1 < 3 ✗), 2 (4 ≥ 3 ✓) -> k reached.
        assert_eq!(added, vec![0, 2]);
        assert_eq!(st.value(), 9.0);
    }

    #[test]
    fn greedy_continues_from_partial_solution() {
        let o = ModularOracle::new(vec![5.0, 4.0, 3.0]);
        let mut st = o.state();
        st.insert(0);
        let added = threshold_greedy(st.as_mut(), &[1, 2], 3.5, 2);
        assert_eq!(added, vec![1], "k counts the pre-existing element");
    }

    #[test]
    fn filter_keeps_only_large_marginals() {
        let o = CoverageOracle::unweighted(vec![vec![0, 1], vec![1], vec![2], vec![0, 1]], 3);
        let mut st = o.state();
        st.insert(0); // covers {0,1}
        let kept = threshold_filter(st.as_ref(), &[1, 2, 3], 1.0);
        assert_eq!(kept, vec![2], "only element 2 adds ≥ 1.0");
    }

    #[test]
    fn filter_does_not_mutate_state() {
        let o = ModularOracle::new(vec![1.0; 10]);
        let st = o.state();
        let kept = threshold_filter(st.as_ref(), &(0..10).collect::<Vec<_>>(), 0.5);
        assert_eq!(kept.len(), 10);
        assert!(st.is_empty());
    }

    #[test]
    fn merge_sorted_orders_across_shards() {
        let merged = merge_sorted(&[vec![5, 1], vec![3], vec![], vec![2, 4]]);
        assert_eq!(merged, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn postcondition_alg1() {
        // After ThresholdGreedy, no scanned element has marginal ≥ τ
        // (unless |G| = k).
        let o = crate::workload::coverage::CoverageGen::new(100, 60, 4).build(1);
        let input: Vec<ElementId> = (0..100).collect();
        let mut st = o.state();
        threshold_greedy(st.as_mut(), &input, 2.0, 10);
        if st.len() < 10 {
            for &e in &input {
                assert!(st.marginal(e) < 2.0, "element {e} still above threshold");
            }
        }
    }

    #[test]
    fn prop_greedy_value_lower_bound() {
        forall(0x71, 30, |g| {
            // If |G'| = k starting from empty, f(G') ≥ τ·k (each pick ≥ τ).
            let seed = g.u64_in(200);
            let tau = g.f64_in(0.5, 3.0);
            let k = g.usize_in(1, 15);
            let o = crate::workload::coverage::CoverageGen::new(80, 50, 4).build(seed);
            let input: Vec<ElementId> = (0..80).collect();
            let mut st = o.state();
            let added = threshold_greedy(st.as_mut(), &input, tau, k);
            if added.len() == k {
                assert!(st.value() >= tau * k as f64 - 1e-9);
            } else {
                // postcondition: nothing above τ remains.
                for &e in &input {
                    assert!(st.marginal(e) < tau);
                }
            }
        });
    }

    #[test]
    fn prop_greedy_matches_scalar_reference() {
        // The block-lazy greedy must reproduce the scalar scan's selection
        // sequence element for element, on every family shape.
        forall(0x74, 30, |g| {
            let seed = g.u64_in(300);
            let tau = g.f64_in(0.2, 4.0);
            let k = g.usize_in(1, 20);
            let o = crate::workload::coverage::CoverageGen::new(400, 150, 4).build(seed);
            let input: Vec<ElementId> = (0..400).collect();
            let mut st_block = o.state();
            let mut st_scalar = o.state();
            let a = threshold_greedy(st_block.as_mut(), &input, tau, k);
            let b = threshold_greedy_scalar(st_scalar.as_mut(), &input, tau, k);
            assert_eq!(a, b, "seed {seed} tau {tau} k {k}");
            assert_eq!(st_block.value().to_bits(), st_scalar.value().to_bits());
        });
    }

    #[test]
    fn block_helpers_match_scalar_folds() {
        let o = crate::workload::coverage::CoverageGen::new(600, 200, 5).build(9);
        let mut st = o.state();
        st.insert(1);
        let input: Vec<ElementId> = (0..600).collect();
        let best = block_max_marginal(st.as_ref(), &input);
        let best_scalar = input.iter().map(|&e| st.marginal(e)).fold(0.0f64, f64::max);
        assert_eq!(best.to_bits(), best_scalar.to_bits());
        let all = block_marginals(st.as_ref(), &input);
        assert_eq!(all.len(), 600);
        for (&e, &m) in input.iter().zip(&all) {
            assert_eq!(m.to_bits(), st.marginal(e).to_bits());
        }
        assert_eq!(block_max_marginal(st.as_ref(), &[]), 0.0);
    }

    #[test]
    fn prop_filter_matches_scalar_definition() {
        forall(0x72, 20, |g| {
            let seed = g.u64_in(100);
            let tau = g.f64_in(0.1, 4.0);
            let o = crate::workload::coverage::CoverageGen::new(300, 100, 4).build(seed);
            let mut st = o.state();
            st.insert(0);
            st.insert(5);
            let input: Vec<ElementId> = (0..300).collect();
            let kept = threshold_filter(st.as_ref(), &input, tau);
            let expect: Vec<ElementId> =
                input.iter().copied().filter(|&e| st.marginal(e) >= tau).collect();
            assert_eq!(kept, expect);
        });
    }

    #[test]
    fn prop_filter_sound_under_growth() {
        forall(0x73, 20, |g| {
            // Submodularity: anything the filter drops w.r.t. G stays
            // droppable w.r.t. any G' ⊇ G — the property Alg 5 relies on to
            // filter shards persistently.
            let seed = g.u64_in(100);
            let o = crate::workload::coverage::CoverageGen::new(100, 60, 4).build(seed);
            let mut st = o.state();
            st.insert(3);
            let input: Vec<ElementId> = (0..100).collect();
            let tau = 2.0;
            let kept = threshold_filter(st.as_ref(), &input, tau);
            let dropped: Vec<ElementId> =
                input.iter().copied().filter(|e| !kept.contains(e)).collect();
            let mut grown = st.clone_state();
            grown.insert(7);
            grown.insert(11);
            for &e in &dropped {
                assert!(grown.marginal(e) < tau, "dropped element {e} resurfaced");
            }
        });
    }
}
