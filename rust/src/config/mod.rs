//! TOML-backed configuration for the `mrsub` launcher (parsed by the
//! in-repo TOML-subset parser, [`crate::util::minitoml`]).
//!
//! A run config names an instance (workload generator + parameters), an
//! algorithm, the cluster shape, and where to write the JSON report:
//!
//! ```toml
//! k = 50
//! seed = 7
//! output = "report.json"   # optional
//!
//! [instance]
//! kind = "coverage"        # coverage | zipf | planted | facility |
//!                          # erdos-renyi | barabasi-albert | adversarial
//! n = 100000
//! universe = 40000
//! avg_degree = 12
//!
//! [algorithm]
//! kind = "combined"        # two-round | multi-round | dense | sparse |
//!                          # combined | greedy | stochastic | randgreedi |
//!                          # mz-coreset | sample-prune | dash
//! eps = 0.1
//! # randgreedi / dash accept `matroid-parts = p` to run under an
//! # `e mod p` unit-capacity partition matroid (randgreedi additionally
//! # takes `rounds = r` randomized-partition rounds)
//!
//! [cluster]
//! sample_factor = 4.0
//! parallel = true          # legacy switch; superseded by `backend`
//! backend = "rayon"        # serial | rayon |
//!                          # process:N[@pipe|@uds|@uds+arena|@tcp[:addr]]
//!                          # (execution substrate; @-suffix picks the
//!                          # process-backend transport, pipe by default —
//!                          # @uds+arena adds zero-copy shard mapping, an
//!                          # explicit @tcp:HOST:PORT listens there and
//!                          # waits for external `mrsub worker --connect`s)
//! chunk = 0                # rayon work-claim granularity; 0 = auto
//!                          # (machines / (threads*4), clamped to 1..=64)
//! worker_timeout_ms = 30000  # process backend: per-round reply bound
//! connect_timeout_ms = 5000  # process backend: connection-establishment
//!                          # bound (default min(worker_timeout_ms, 30s))
//! recovery = "fail"        # process backend worker-death policy:
//!                          # fail | requeue[:R] — requeue re-places a dead
//!                          # worker's machines on survivors, tolerating R
//!                          # worker deaths per run (default 1)
//! elastic = false          # process backend under requeue: allow the pool
//!                          # to grow past process:N (late joins / serve
//!                          # load); dead-slot replacement is always on

//! max_frame_mb = 64        # process backend: wire frame payload cap
//! enforce_memory = false
//! machines = 0             # 0 = paper default ceil(sqrt(n/k))
//! ```

use std::path::Path;

use crate::algorithms::combined::CombinedTwoRound;
use crate::algorithms::dash::Dash;
use crate::algorithms::dense::DenseTwoRound;
use crate::algorithms::greedy;
use crate::algorithms::multi_round::MultiRound;
use crate::algorithms::mz_coreset::MzCoreset;
use crate::algorithms::randgreedi::RandGreeDi;
use crate::algorithms::sample_prune::SamplePrune;
use crate::algorithms::sparse::SparseTwoRound;
use crate::algorithms::stochastic::StochasticGreedy;
use crate::algorithms::two_round::TwoRoundKnownOpt;
use crate::algorithms::{AlgResult, MrAlgorithm};
use crate::core::{Constraint, Error, Result};
use crate::mapreduce::backend::BackendKind;
use crate::mapreduce::process::RecoveryPolicy;
use crate::mapreduce::ClusterConfig;
use crate::util::minitoml::{Document, Table};
use crate::workload::adversarial::AdversarialGen;
use crate::workload::corpus::ZipfCorpusGen;
use crate::workload::coverage::CoverageGen;
use crate::workload::facility::FacilityGen;
use crate::workload::graph::GraphGen;
use crate::workload::planted::PlantedCoverageGen;
use crate::workload::{Instance, WorkloadGen};

/// Top-level run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Cardinality constraint.
    pub k: usize,
    /// Master seed for instance + cluster randomness.
    pub seed: u64,
    /// Instance to generate.
    pub instance: InstanceConfig,
    /// Algorithm to run.
    pub algorithm: AlgorithmConfig,
    /// Cluster shape (defaults to the paper's parameters).
    pub cluster: ClusterConfig,
    /// Optional JSON report path.
    pub output: Option<String>,
}

// --- small table accessors -------------------------------------------------

fn req_usize(t: &Table, key: &str, ctx: &str) -> Result<usize> {
    t.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| Error::Config(format!("{ctx}: missing/invalid integer {key:?}")))
}

fn opt_usize(t: &Table, key: &str, default: usize) -> usize {
    t.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
}

fn req_f64(t: &Table, key: &str, ctx: &str) -> Result<f64> {
    t.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| Error::Config(format!("{ctx}: missing/invalid number {key:?}")))
}

fn opt_f64(t: &Table, key: &str) -> Option<f64> {
    t.get(key).and_then(|v| v.as_f64())
}

fn opt_bool(t: &Table, key: &str, default: bool) -> bool {
    t.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
}

fn req_str<'a>(t: &'a Table, key: &str, ctx: &str) -> Result<&'a str> {
    t.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| Error::Config(format!("{ctx}: missing/invalid string {key:?}")))
}

impl RunConfig {
    /// Parse from a TOML file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::Config(format!("read {}: {e}", path.as_ref().display())))?;
        Self::parse(&text)
    }

    /// Parse from TOML text.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = Document::parse(text).map_err(Error::Config)?;
        let k = req_usize(&doc.root, "k", "root")?;
        let seed = doc.root.get("seed").and_then(|v| v.as_u64()).unwrap_or(0);
        let output = doc.root.get("output").and_then(|v| v.as_str()).map(String::from);
        let instance = InstanceConfig::from_table(
            doc.table("instance")
                .ok_or_else(|| Error::Config("missing [instance] table".into()))?,
        )?;
        let algorithm = AlgorithmConfig::from_table(
            doc.table("algorithm")
                .ok_or_else(|| Error::Config("missing [algorithm] table".into()))?,
        )?;
        let mut cluster = ClusterConfig { seed, ..ClusterConfig::default() };
        if let Some(t) = doc.table("cluster") {
            let machines = opt_usize(t, "machines", 0);
            cluster.machines = if machines == 0 { None } else { Some(machines) };
            cluster.sample_factor = opt_f64(t, "sample_factor").unwrap_or(4.0);
            cluster.enforce_memory = opt_bool(t, "enforce_memory", false);
            cluster.parallel = opt_bool(t, "parallel", true);
            if let Some(name) = t.get("backend").and_then(|v| v.as_str()) {
                // chunk 0 = the auto work-claim heuristic (machines/threads);
                // an explicit `chunk = N` stays an override.
                let chunk = opt_usize(t, "chunk", 0);
                cluster.backend = Some(
                    BackendKind::parse(name, chunk)
                        .map_err(|e| Error::Config(format!("[cluster]: {e}")))?,
                );
            }
            if let Some(v) = t.get("worker_timeout_ms") {
                let ms = v.as_u64().ok_or_else(|| {
                    Error::Config("[cluster]: invalid integer \"worker_timeout_ms\"".into())
                })?;
                cluster.worker_timeout_ms = ClusterConfig::validate_worker_timeout_ms(ms)
                    .map_err(|e| Error::Config(format!("[cluster]: {e}")))?;
            }
            if let Some(v) = t.get("connect_timeout_ms") {
                let ms = v.as_u64().ok_or_else(|| {
                    Error::Config("[cluster]: invalid integer \"connect_timeout_ms\"".into())
                })?;
                cluster.connect_timeout_ms = Some(
                    ClusterConfig::validate_connect_timeout_ms(ms)
                        .map_err(|e| Error::Config(format!("[cluster]: {e}")))?,
                );
            }
            if let Some(v) = t.get("recovery") {
                let name = v.as_str().ok_or_else(|| {
                    Error::Config("[cluster]: invalid string \"recovery\"".into())
                })?;
                cluster.recovery = RecoveryPolicy::parse(name).ok_or_else(|| {
                    Error::Config(format!(
                        "unknown recovery policy {name:?} \
                         (fail | requeue[:R] with R >= 1)"
                    ))
                })?;
            }
            cluster.elastic = opt_bool(t, "elastic", false);
            if let Some(v) = t.get("max_frame_mb") {
                let mb = v.as_usize().ok_or_else(|| {
                    Error::Config("[cluster]: invalid integer \"max_frame_mb\"".into())
                })?;
                cluster.max_frame_bytes = ClusterConfig::validate_max_frame_mb(mb)
                    .map_err(|e| Error::Config(format!("[cluster]: {e}")))?
                    << 20;
            }
        }
        Ok(RunConfig { k, seed, instance, algorithm, cluster, output })
    }
}

/// Workload selection.
#[derive(Debug, Clone)]
pub enum InstanceConfig {
    /// Random (optionally weighted) coverage.
    Coverage {
        /// Elements.
        n: usize,
        /// Universe size.
        universe: usize,
        /// Average element degree.
        avg_degree: usize,
        /// Heavy-tailed item weights.
        weighted: bool,
    },
    /// Zipf document corpus (optionally IDF-weighted).
    Zipf {
        /// Documents (elements).
        docs: usize,
        /// Vocabulary size.
        vocab: usize,
        /// Words per document.
        doc_len: usize,
        /// IDF-weight the items.
        idf: bool,
    },
    /// Planted-optimum coverage, `regime` ∈ {"dense", "sparse"}.
    Planted {
        /// Planted optimal size.
        k: usize,
        /// Universe size.
        universe: usize,
        /// Noise elements.
        noise_n: usize,
        /// Dense (vs sparse) regime.
        dense: bool,
    },
    /// Facility location over random planar points.
    Facility {
        /// Candidate elements.
        n: usize,
        /// Demand points.
        d: usize,
        /// Planted cluster centers; 0 = uniform.
        clusters: usize,
    },
    /// Erdős–Rényi edge coverage.
    ErdosRenyi {
        /// Vertices.
        n: usize,
        /// Edge probability.
        p: f64,
    },
    /// Barabási–Albert edge coverage.
    BarabasiAlbert {
        /// Vertices.
        n: usize,
        /// Edges attached per new vertex.
        attach: usize,
    },
    /// Theorem-4 adversarial instance.
    Adversarial {
        /// Threshold-round parameter t.
        t: usize,
        /// Cardinality bound.
        k: usize,
    },
}

impl InstanceConfig {
    /// Parse from an `[instance]` table.
    pub fn from_table(t: &Table) -> Result<Self> {
        let ctx = "[instance]";
        Ok(match req_str(t, "kind", ctx)? {
            "coverage" => InstanceConfig::Coverage {
                n: req_usize(t, "n", ctx)?,
                universe: req_usize(t, "universe", ctx)?,
                avg_degree: req_usize(t, "avg_degree", ctx)?,
                weighted: opt_bool(t, "weighted", false),
            },
            "zipf" => InstanceConfig::Zipf {
                docs: req_usize(t, "docs", ctx)?,
                vocab: req_usize(t, "vocab", ctx)?,
                doc_len: req_usize(t, "doc_len", ctx)?,
                idf: opt_bool(t, "idf", false),
            },
            "planted" => InstanceConfig::Planted {
                k: req_usize(t, "k", ctx)?,
                universe: req_usize(t, "universe", ctx)?,
                noise_n: req_usize(t, "noise_n", ctx)?,
                dense: match req_str(t, "regime", ctx)? {
                    "dense" => true,
                    "sparse" => false,
                    other => {
                        return Err(Error::Config(format!("unknown planted regime {other:?}")))
                    }
                },
            },
            "facility" => InstanceConfig::Facility {
                n: req_usize(t, "n", ctx)?,
                d: req_usize(t, "d", ctx)?,
                clusters: opt_usize(t, "clusters", 0),
            },
            "erdos-renyi" => InstanceConfig::ErdosRenyi {
                n: req_usize(t, "n", ctx)?,
                p: req_f64(t, "p", ctx)?,
            },
            "barabasi-albert" => InstanceConfig::BarabasiAlbert {
                n: req_usize(t, "n", ctx)?,
                attach: req_usize(t, "attach", ctx)?,
            },
            "adversarial" => InstanceConfig::Adversarial {
                t: req_usize(t, "t", ctx)?,
                k: req_usize(t, "k", ctx)?,
            },
            other => return Err(Error::Config(format!("unknown instance kind {other:?}"))),
        })
    }

    /// Generate the instance.
    pub fn build(&self, seed: u64) -> Result<Instance> {
        Ok(match self {
            InstanceConfig::Coverage { n, universe, avg_degree, weighted } => {
                let g = if *weighted {
                    CoverageGen::weighted(*n, *universe, *avg_degree)
                } else {
                    CoverageGen::new(*n, *universe, *avg_degree)
                };
                g.generate(seed)
            }
            InstanceConfig::Zipf { docs, vocab, doc_len, idf } => {
                let g = if *idf {
                    ZipfCorpusGen::idf(*docs, *vocab, *doc_len)
                } else {
                    ZipfCorpusGen::new(*docs, *vocab, *doc_len)
                };
                g.generate(seed)
            }
            InstanceConfig::Planted { k, universe, noise_n, dense } => {
                let g = if *dense {
                    PlantedCoverageGen::dense(*k, *universe, *noise_n)
                } else {
                    PlantedCoverageGen::sparse(*k, *universe, *noise_n)
                };
                g.generate(seed)
            }
            InstanceConfig::Facility { n, d, clusters } => {
                let g = if *clusters > 0 {
                    FacilityGen::clustered(*n, *d, *clusters)
                } else {
                    FacilityGen::new(*n, *d)
                };
                g.generate(seed)
            }
            InstanceConfig::ErdosRenyi { n, p } => GraphGen::erdos_renyi(*n, *p).generate(seed),
            InstanceConfig::BarabasiAlbert { n, attach } => {
                GraphGen::barabasi_albert(*n, *attach).generate(seed)
            }
            InstanceConfig::Adversarial { t, k } => AdversarialGen::new(*t, *k).generate(seed),
        })
    }
}

/// Algorithm selection.
#[derive(Debug, Clone)]
pub enum AlgorithmConfig {
    /// Algorithm 4 (needs OPT; falls back to the instance's planted OPT,
    /// then to lazy greedy's value as the estimate).
    TwoRound {
        /// Explicit OPT; `None` = planted/greedy fallback.
        opt: Option<f64>,
    },
    /// Algorithm 5 with t thresholds; OPT known (planted / given) or
    /// guessed with `eps`.
    MultiRound {
        /// Threshold count.
        t: usize,
        /// Explicit OPT; `None` = planted/greedy fallback or guessing.
        opt: Option<f64>,
        /// Guessing granularity (enables OPT-guessing when `opt` absent).
        eps: Option<f64>,
    },
    /// Algorithm 6.
    Dense {
        /// Guess granularity ε.
        eps: f64,
    },
    /// Algorithm 7.
    Sparse {
        /// Guess granularity ε.
        eps: f64,
    },
    /// Theorem 8 (the paper's headline 2-round algorithm).
    Combined {
        /// Guess granularity ε.
        eps: f64,
    },
    /// Sequential lazy greedy (reference).
    Greedy,
    /// Sequential stochastic greedy.
    Stochastic {
        /// Failure probability δ.
        delta: f64,
    },
    /// Barbosa et al. RandGreeDi baseline; with `matroid_parts` set it
    /// runs the randomized-partition constrained form under an `e mod p`
    /// unit-capacity partition matroid.
    Randgreedi {
        /// `Some(p)` selects the constrained randomized-partition form.
        matroid_parts: Option<usize>,
        /// Randomized-partition rounds (constrained form only).
        rounds: usize,
    },
    /// Mirrokni–Zadimoghaddam core-set baseline.
    MzCoreset,
    /// Kumar et al. Sample&Prune baseline.
    SamplePrune {
        /// Threshold decay ε.
        eps: f64,
    },
    /// DASH low-adaptivity threshold sweep (cardinality by default,
    /// `e mod p` unit-cap partition matroid with `matroid_parts`).
    Dash {
        /// Threshold decay ε.
        eps: f64,
        /// `Some(p)` runs under a partition matroid instead of cardinality.
        matroid_parts: Option<usize>,
    },
}

/// The algorithm kinds [`AlgorithmConfig::from_table`] accepts, quoted in
/// its unknown-kind error so a typo'd config names the valid set.
pub const ALGORITHM_KINDS: &[&str] = &[
    "two-round",
    "multi-round",
    "dense",
    "sparse",
    "combined",
    "greedy",
    "stochastic",
    "randgreedi",
    "mz-coreset",
    "sample-prune",
    "dash",
];

/// The `e mod p` unit-capacity partition matroid over `n` elements — the
/// config-file spelling of a matroid constraint (matches
/// [`crate::workload::planted::PlantedMatroidGen`]).
fn modular_partition_matroid(n: usize, parts: usize) -> Constraint {
    Constraint::partition_matroid((0..n).map(|e| (e % parts.max(1)) as u32).collect(), vec![
        1;
        parts.max(1)
    ])
}

impl AlgorithmConfig {
    /// Parse from an `[algorithm]` table.
    pub fn from_table(t: &Table) -> Result<Self> {
        let ctx = "[algorithm]";
        Ok(match req_str(t, "kind", ctx)? {
            "two-round" => AlgorithmConfig::TwoRound { opt: opt_f64(t, "opt") },
            "multi-round" => AlgorithmConfig::MultiRound {
                t: req_usize(t, "t", ctx)?,
                opt: opt_f64(t, "opt"),
                eps: opt_f64(t, "eps"),
            },
            "dense" => AlgorithmConfig::Dense { eps: req_f64(t, "eps", ctx)? },
            "sparse" => AlgorithmConfig::Sparse { eps: req_f64(t, "eps", ctx)? },
            "combined" => AlgorithmConfig::Combined { eps: req_f64(t, "eps", ctx)? },
            "greedy" => AlgorithmConfig::Greedy,
            "stochastic" => AlgorithmConfig::Stochastic { delta: req_f64(t, "delta", ctx)? },
            "randgreedi" => AlgorithmConfig::Randgreedi {
                matroid_parts: t.get("matroid-parts").and_then(|v| v.as_usize()),
                rounds: opt_usize(t, "rounds", 1),
            },
            "mz-coreset" => AlgorithmConfig::MzCoreset,
            "sample-prune" => AlgorithmConfig::SamplePrune { eps: req_f64(t, "eps", ctx)? },
            "dash" => AlgorithmConfig::Dash {
                eps: req_f64(t, "eps", ctx)?,
                matroid_parts: t.get("matroid-parts").and_then(|v| v.as_usize()),
            },
            other => {
                return Err(Error::Config(format!(
                    "unknown algorithm kind {other:?} (valid kinds: {})",
                    ALGORITHM_KINDS.join(", ")
                )))
            }
        })
    }

    /// Instantiate the algorithm; `instance` provides planted OPT / a greedy
    /// fallback estimate for the known-OPT variants.
    pub fn build(&self, instance: &Instance, k: usize) -> Box<dyn MrAlgorithm> {
        let resolve_opt = |explicit: Option<f64>| -> f64 {
            explicit
                .or(instance.known_opt)
                .unwrap_or_else(|| greedy::lazy_greedy(&instance.oracle, k).value)
        };
        match self {
            AlgorithmConfig::TwoRound { opt } => Box::new(TwoRoundKnownOpt::new(resolve_opt(*opt))),
            AlgorithmConfig::MultiRound { t, opt, eps } => match (opt, eps) {
                (Some(o), _) => Box::new(MultiRound::known(*t, *o)),
                (None, Some(e)) => Box::new(MultiRound::guessing(*t, *e)),
                (None, None) => Box::new(MultiRound::known(*t, resolve_opt(None))),
            },
            AlgorithmConfig::Dense { eps } => Box::new(DenseTwoRound::new(*eps)),
            AlgorithmConfig::Sparse { eps } => Box::new(SparseTwoRound::new(*eps)),
            AlgorithmConfig::Combined { eps } => Box::new(CombinedTwoRound::new(*eps)),
            AlgorithmConfig::Greedy => Box::new(GreedyAlg),
            AlgorithmConfig::Stochastic { delta } => Box::new(StochasticGreedy::new(*delta)),
            AlgorithmConfig::Randgreedi { matroid_parts, rounds } => match matroid_parts {
                None => Box::new(RandGreeDi::default()),
                Some(p) => Box::new(RandGreeDi::constrained(
                    modular_partition_matroid(instance.n, *p),
                    *rounds,
                )),
            },
            AlgorithmConfig::MzCoreset => Box::new(MzCoreset),
            AlgorithmConfig::SamplePrune { eps } => Box::new(SamplePrune::new(*eps)),
            AlgorithmConfig::Dash { eps, matroid_parts } => match matroid_parts {
                None => Box::new(Dash::new(*eps)),
                Some(p) => Box::new(Dash::constrained(
                    *eps,
                    modular_partition_matroid(instance.n, *p),
                )),
            },
        }
    }
}

/// Wrapper making sequential lazy greedy an [`MrAlgorithm`].
#[derive(Debug, Clone, Copy)]
pub struct GreedyAlg;

impl MrAlgorithm for GreedyAlg {
    fn name(&self) -> String {
        "greedy".into()
    }

    fn run(
        &self,
        oracle: &dyn crate::oracle::Oracle,
        k: usize,
        _cfg: &ClusterConfig,
    ) -> Result<AlgResult> {
        let n = oracle.ground_size();
        Ok(AlgResult::sequential(greedy::lazy_greedy(&oracle, k), n, k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::transport::Transport;

    #[test]
    fn toml_roundtrip() {
        let toml_text = r#"
            k = 10
            seed = 3
            [instance]
            kind = "coverage"
            n = 100
            universe = 50
            avg_degree = 4
            [algorithm]
            kind = "combined"
            eps = 0.1
        "#;
        let cfg = RunConfig::parse(toml_text).unwrap();
        assert_eq!(cfg.k, 10);
        assert_eq!(cfg.seed, 3);
        let inst = cfg.instance.build(cfg.seed).unwrap();
        assert_eq!(inst.n, 100);
        let alg = cfg.algorithm.build(&inst, cfg.k);
        assert!(alg.name().starts_with("combined"));
    }

    #[test]
    fn cluster_table_parsed() {
        let cfg = RunConfig::parse(
            r#"
            k = 5
            [instance]
            kind = "facility"
            n = 40
            d = 20
            [algorithm]
            kind = "greedy"
            [cluster]
            machines = 3
            sample_factor = 2.0
            parallel = false
            enforce_memory = true
        "#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.machines, Some(3));
        assert_eq!(cfg.cluster.sample_factor, 2.0);
        assert!(!cfg.cluster.parallel);
        assert!(cfg.cluster.enforce_memory);
        assert_eq!(cfg.cluster.backend_kind(), BackendKind::Serial, "legacy flag maps to serial");
    }

    #[test]
    fn cluster_backend_parsed() {
        let text = |backend: &str| {
            format!(
                r#"
                k = 5
                [instance]
                kind = "coverage"
                n = 40
                universe = 30
                avg_degree = 3
                [algorithm]
                kind = "greedy"
                [cluster]
                {backend}
            "#
            )
        };
        let cfg = RunConfig::parse(&text("backend = \"serial\"")).unwrap();
        assert_eq!(cfg.cluster.backend, Some(BackendKind::Serial));
        let cfg = RunConfig::parse(&text("backend = \"rayon\"\nchunk = 4")).unwrap();
        assert_eq!(cfg.cluster.backend, Some(BackendKind::Rayon { chunk: 4 }));
        // bare "rayon" without a chunk = the auto heuristic sentinel.
        let cfg = RunConfig::parse(&text("backend = \"rayon\"")).unwrap();
        assert_eq!(cfg.cluster.backend, Some(BackendKind::Rayon { chunk: 0 }));
        // explicit backend beats the legacy flag.
        let cfg = RunConfig::parse(&text("parallel = true\nbackend = \"serial\"")).unwrap();
        assert_eq!(cfg.cluster.backend_kind(), BackendKind::Serial);
        // unknown backends are structured errors naming the valid set.
        match RunConfig::parse(&text("backend = \"gpu\"")) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("gpu"), "{msg}");
                assert!(msg.contains("serial | rayon"), "{msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    #[test]
    fn cluster_process_backend_parsed_and_validated() {
        let text = |cluster: &str| {
            format!(
                r#"
                k = 5
                [instance]
                kind = "coverage"
                n = 40
                universe = 30
                avg_degree = 3
                [algorithm]
                kind = "greedy"
                [cluster]
                {cluster}
            "#
            )
        };
        let pipe = |workers| BackendKind::Process { workers, transport: Transport::Pipe };
        let cfg = RunConfig::parse(&text("backend = \"process:4\"")).unwrap();
        assert_eq!(cfg.cluster.backend, Some(pipe(4)));
        assert_eq!(cfg.cluster.worker_timeout_ms, 30_000, "default timeout");
        // bare "process" takes the worker count from `chunk`.
        let cfg = RunConfig::parse(&text("backend = \"process\"\nchunk = 3")).unwrap();
        assert_eq!(cfg.cluster.backend, Some(pipe(3)));
        // process:0 must be rejected, not clamped.
        assert!(RunConfig::parse(&text("backend = \"process:0\"")).is_err());

        // timeout bounds: 0 and absurd values rejected, sane ones kept.
        let cfg =
            RunConfig::parse(&text("backend = \"process:2\"\nworker_timeout_ms = 5000")).unwrap();
        assert_eq!(cfg.cluster.worker_timeout_ms, 5000);
        assert!(RunConfig::parse(&text("worker_timeout_ms = 0")).is_err());
        assert!(RunConfig::parse(&text("worker_timeout_ms = 99999999")).is_err());

        // the connect bound is its own knob with the same bounds discipline.
        let cfg = RunConfig::parse(&text(
            "backend = \"process:2\"\nworker_timeout_ms = 600000\nconnect_timeout_ms = 2000",
        ))
        .unwrap();
        assert_eq!(cfg.cluster.connect_timeout_ms, Some(2000));
        assert_eq!(cfg.cluster.effective_connect_timeout_ms(), 2000);
        assert!(RunConfig::parse(&text("connect_timeout_ms = 0")).is_err());
        assert!(RunConfig::parse(&text("connect_timeout_ms = 99999999")).is_err());
        assert!(RunConfig::parse(&text("connect_timeout_ms = \"fast\"")).is_err());

        // unset: derived from worker_timeout_ms, capped at the 30s default
        // so a compute-sized round timeout doesn't grant sloppy connects.
        let cfg = RunConfig::parse(&text("worker_timeout_ms = 5000")).unwrap();
        assert_eq!(cfg.cluster.connect_timeout_ms, None);
        assert_eq!(cfg.cluster.effective_connect_timeout_ms(), 5000);
        let cfg = RunConfig::parse(&text("worker_timeout_ms = 600000")).unwrap();
        assert_eq!(cfg.cluster.effective_connect_timeout_ms(), 30_000);

        // frame cap in MiB, same bounds discipline.
        let cfg = RunConfig::parse(&text("max_frame_mb = 8")).unwrap();
        assert_eq!(cfg.cluster.max_frame_bytes, 8 << 20);
        assert!(RunConfig::parse(&text("max_frame_mb = 0")).is_err());
        assert!(RunConfig::parse(&text("max_frame_mb = 100000")).is_err());
    }

    #[test]
    fn cluster_recovery_policy_parsed() {
        let text = |cluster: &str| {
            format!(
                r#"
                k = 5
                [instance]
                kind = "coverage"
                n = 40
                universe = 30
                avg_degree = 3
                [algorithm]
                kind = "greedy"
                [cluster]
                {cluster}
            "#
            )
        };
        let cfg = RunConfig::parse(&text("backend = \"process:2\"")).unwrap();
        assert_eq!(cfg.cluster.recovery, RecoveryPolicy::Fail, "fail-fast is the default");
        let cfg = RunConfig::parse(&text("recovery = \"fail\"")).unwrap();
        assert_eq!(cfg.cluster.recovery, RecoveryPolicy::Fail);
        let cfg = RunConfig::parse(&text("recovery = \"requeue\"")).unwrap();
        assert_eq!(cfg.cluster.recovery, RecoveryPolicy::Requeue { budget: 1 });
        let cfg = RunConfig::parse(&text("recovery = \"requeue:4\"")).unwrap();
        assert_eq!(cfg.cluster.recovery, RecoveryPolicy::Requeue { budget: 4 });
        assert!(!cfg.cluster.elastic, "elastic growth is opt-in");
        let cfg = RunConfig::parse(&text("recovery = \"requeue\"\nelastic = true")).unwrap();
        assert!(cfg.cluster.elastic);
        // bad policies are config errors, not silent defaults.
        assert!(RunConfig::parse(&text("recovery = \"requeue:0\"")).is_err());
        assert!(RunConfig::parse(&text("recovery = \"retry\"")).is_err());
        assert!(RunConfig::parse(&text("recovery = 3")).is_err(), "non-string rejected");
    }

    #[test]
    fn cluster_process_transports_parsed() {
        let text = |cluster: &str| {
            format!(
                r#"
                k = 5
                [instance]
                kind = "coverage"
                n = 40
                universe = 30
                avg_degree = 3
                [algorithm]
                kind = "greedy"
                [cluster]
                {cluster}
            "#
            )
        };
        let cfg = RunConfig::parse(&text("backend = \"process:2@uds\"")).unwrap();
        assert_eq!(
            cfg.cluster.backend,
            Some(BackendKind::Process { workers: 2, transport: Transport::Uds })
        );
        let cfg = RunConfig::parse(&text("backend = \"process:2@tcp\"")).unwrap();
        assert_eq!(
            cfg.cluster.backend,
            Some(BackendKind::Process { workers: 2, transport: Transport::Tcp { bind: None } })
        );
        let cfg = RunConfig::parse(&text("backend = \"process:2@tcp:0.0.0.0:7070\"")).unwrap();
        assert_eq!(
            cfg.cluster.backend,
            Some(BackendKind::Process {
                workers: 2,
                transport: Transport::Tcp { bind: Some("0.0.0.0:7070".into()) },
            })
        );
        let cfg = RunConfig::parse(&text("backend = \"process:2@uds+arena\"")).unwrap();
        assert_eq!(
            cfg.cluster.backend,
            Some(BackendKind::Process { workers: 2, transport: Transport::UdsArena })
        );
        // unknown / malformed transports are config errors naming the
        // valid transport set, not silent defaults.
        match RunConfig::parse(&text("backend = \"process:2@shm\"")) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("shm"), "{msg}");
                assert!(msg.contains("uds+arena"), "{msg}");
            }
            other => panic!("expected Config error, got {other:?}"),
        }
        assert!(RunConfig::parse(&text("backend = \"process:2@tcp:\"")).is_err());
        assert!(RunConfig::parse(&text("backend = \"process:0@uds\"")).is_err());
    }

    #[test]
    fn bench_report_backend_labels_roundtrip_into_configs() {
        // `mrsub bench` writes backend *labels* into its JSON report; a
        // config citing such a label verbatim must parse back to the same
        // backend — the report → config round-trip.
        for kind in [
            BackendKind::Serial,
            BackendKind::Rayon { chunk: 4 },
            BackendKind::Process { workers: 2, transport: Transport::Pipe },
            BackendKind::Process { workers: 2, transport: Transport::Uds },
            BackendKind::Process { workers: 2, transport: Transport::UdsArena },
            BackendKind::Process { workers: 3, transport: Transport::Tcp { bind: None } },
            BackendKind::Process {
                workers: 3,
                transport: Transport::Tcp { bind: Some("10.0.0.5:7070".into()) },
            },
        ] {
            let text = format!(
                r#"
                k = 5
                [instance]
                kind = "coverage"
                n = 40
                universe = 30
                avg_degree = 3
                [algorithm]
                kind = "greedy"
                [cluster]
                backend = "{}"
            "#,
                kind.label()
            );
            let cfg = RunConfig::parse(&text).unwrap();
            assert_eq!(cfg.cluster.backend, Some(kind.clone()), "label {:?}", kind.label());
        }
    }

    #[test]
    fn all_algorithm_kinds_build_and_run() {
        let inst = CoverageGen::new(60, 40, 3).generate(1);
        let kinds = [
            "kind = \"two-round\"",
            "kind = \"multi-round\"\nt = 2",
            "kind = \"multi-round\"\nt = 2\neps = 0.2",
            "kind = \"dense\"\neps = 0.1",
            "kind = \"sparse\"\neps = 0.1",
            "kind = \"combined\"\neps = 0.1",
            "kind = \"greedy\"",
            "kind = \"stochastic\"\ndelta = 0.1",
            "kind = \"randgreedi\"",
            "kind = \"randgreedi\"\nmatroid-parts = 5\nrounds = 2",
            "kind = \"mz-coreset\"",
            "kind = \"sample-prune\"\neps = 0.2",
            "kind = \"dash\"\neps = 0.2",
            "kind = \"dash\"\neps = 0.2\nmatroid-parts = 5",
        ];
        for text in kinds {
            let doc = Document::parse(text).unwrap();
            let cfg = AlgorithmConfig::from_table(&doc.root).unwrap();
            let alg = cfg.build(&inst, 5);
            let res = alg
                .run(
                    &inst.oracle,
                    5,
                    &ClusterConfig { parallel: false, ..ClusterConfig::default() },
                )
                .unwrap();
            assert!(res.solution.len() <= 5, "{text}");
        }
    }

    #[test]
    fn unknown_algorithm_kind_names_the_valid_set() {
        let doc = Document::parse("kind = \"gredy\"").unwrap();
        match AlgorithmConfig::from_table(&doc.root) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("gredy"), "{msg}");
                for kind in ALGORITHM_KINDS {
                    assert!(msg.contains(kind), "error must name {kind:?}: {msg}");
                }
            }
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    #[test]
    fn planted_regime_validation() {
        let doc = Document::parse(
            "kind = \"planted\"\nk = 3\nuniverse = 30\nnoise_n = 10\nregime = \"weird\"",
        )
        .unwrap();
        assert!(InstanceConfig::from_table(&doc.root).is_err());
    }

    #[test]
    fn all_instance_kinds_build() {
        let texts = [
            "kind = \"coverage\"\nn = 50\nuniverse = 30\navg_degree = 3",
            "kind = \"zipf\"\ndocs = 40\nvocab = 60\ndoc_len = 5",
            "kind = \"planted\"\nk = 4\nuniverse = 40\nnoise_n = 20\nregime = \"sparse\"",
            "kind = \"facility\"\nn = 30\nd = 20",
            "kind = \"erdos-renyi\"\nn = 30\np = 0.2",
            "kind = \"barabasi-albert\"\nn = 30\nattach = 2",
            "kind = \"adversarial\"\nt = 2\nk = 8",
        ];
        for text in texts {
            let doc = Document::parse(text).unwrap();
            let cfg = InstanceConfig::from_table(&doc.root).unwrap();
            let inst = cfg.build(1).unwrap();
            assert!(inst.n > 0, "{text}");
        }
    }

    #[test]
    fn missing_tables_rejected() {
        assert!(RunConfig::parse("k = 5").is_err());
        assert!(RunConfig::parse("[instance]\nkind = \"greedy\"").is_err());
    }
}
