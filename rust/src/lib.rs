//! # mrsub — Submodular Optimization in the MapReduce Model
//!
//! A reproduction of Liu & Vondrák, *"Submodular Optimization in the
//! MapReduce Model"* (SOSA 2019): distributed thresholding algorithms for
//! monotone submodular maximization under a cardinality constraint, built on
//! a faithful simulator of the MRC model of Karloff–Suri–Vassilvitskii.
//!
//! ## Layout
//!
//! * [`core`] — element ids, solutions, shared numeric helpers.
//! * [`oracle`] — the value-oracle abstraction with **block-marginal
//!   evaluation as the primary interface** (every family implements a real
//!   SoA/block `marginals`, bit-identical to its scalar path), a reusable
//!   state pool, seven concrete monotone submodular families (coverage,
//!   weighted coverage, facility location, graph cut-coverage, modular,
//!   concave-over-modular, and the adversarial instance of the paper's
//!   Theorem 4), and a call-counting decorator with batched-vs-scalar
//!   accounting. The XLA/PJRT-accelerated facility oracle rides the same
//!   block path behind the `xla` feature.
//! * [`mapreduce`] — the MRC cluster simulator: random partitioning and
//!   sampling (Algorithm 3), synchronous rounds scheduled on a pluggable
//!   execution substrate ([`mapreduce::backend::ExecBackend`]: serial /
//!   thread-pool / shared-nothing worker *processes* with shards and
//!   oracle specs serialized over a checksummed wire protocol
//!   ([`mapreduce::wire`], [`mapreduce::process`]) riding pluggable byte
//!   streams — pipes, Unix-domain sockets, or TCP
//!   ([`mapreduce::transport`])), per-machine memory, communication, and
//!   IPC-byte metering.
//! * [`algorithms`] — the paper's Algorithms 1–7 and the Theorem 8
//!   combination, plus sequential and distributed baselines
//!   (greedy/lazy/stochastic greedy, RandGreeDi, Mirrokni–Zadimoghaddam
//!   core-sets, Sample&Prune) — hot loops drive the oracle in blocks.
//! * [`workload`] — instance generators used by the experiment suite.
//! * `runtime` (feature `xla`) — PJRT client wrapper that loads the
//!   AOT-compiled JAX/Pallas artifacts (`artifacts/*.hlo.txt`) and serves
//!   batched marginal evaluations to the Rust hot path.
//! * [`coordinator`] — experiment driver: runs algorithms over workloads,
//!   collects [`metrics`], writes JSON reports.
//! * [`serve`] — the `mrsub serve` multi-tenant daemon: accepts jobs over
//!   the wire codec's client frames and runs them through the same
//!   coordinator path against **one warm worker pool** shared across jobs
//!   (job-keyed attach instead of per-job spawn), so serving results stay
//!   bit-identical to standalone runs.
//! * [`config`] — TOML-backed configuration for the `mrsub` launcher.
//! * [`analysis`] — the `mrsub check-invariants` static-analysis engine:
//!   wire-drift fingerprinting, determinism-hazard and unsafe-hygiene
//!   lints over this very tree (see `docs/ARCHITECTURE.md`, "Enforced
//!   invariants").
//!
//! ## Quickstart
//!
//! ```no_run
//! use mrsub::algorithms::combined::CombinedTwoRound;
//! use mrsub::algorithms::MrAlgorithm;
//! use mrsub::mapreduce::ClusterConfig;
//! use mrsub::workload::{coverage::CoverageGen, WorkloadGen};
//!
//! let inst = CoverageGen::new(10_000, 4_000, 12).generate(7);
//! let alg = CombinedTwoRound::new(0.1);
//! let out = alg.run(inst.oracle.as_ref(), 50, &ClusterConfig::default()).unwrap();
//! println!("f(S) = {}", out.solution.value);
//! ```

#![warn(missing_docs)]
// Enforced by the `unsafe-safety` lint (`mrsub check-invariants`): every
// `unsafe fn` body must spell out its interior unsafe blocks, so each one
// can carry its own `// SAFETY:` proof.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod algorithms;
pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod mapreduce;
pub mod metrics;
pub mod oracle;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
pub mod util;
pub mod workload;

pub use crate::core::{ElementId, Solution};
pub use oracle::{Oracle, OracleState};
