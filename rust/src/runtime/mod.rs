//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and serves
//! batched marginal evaluations to the Rust hot path. Python is never on
//! this path — the HLO text is parsed and compiled by the in-process XLA
//! CPU client (`xla` crate over xla_extension 0.5.1).
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that this XLA rejects; the text parser reassigns
//! ids (see /opt/xla-example/README.md and python/compile/aot.py).

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::core::{ElementId, Error, Result};
use crate::util::json::Json;

/// Shape manifest written by `python -m compile.aot` next to the artifacts.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Candidate block size B of the compiled marginals kernel.
    pub b: usize,
    /// Universe tile size D of the compiled kernels.
    pub d: usize,
    /// Element dtype (always "f32" for the shipped artifacts).
    pub dtype: String,
    /// Artifact file names, keyed by entry point.
    pub artifacts: std::collections::HashMap<String, String>,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        let json =
            Json::parse(&text).map_err(|e| Error::Runtime(format!("parse manifest: {e}")))?;
        let field = |k: &str| {
            json.get(k).ok_or_else(|| Error::Runtime(format!("manifest missing {k:?}")))
        };
        let b = field("b")?.as_usize().ok_or_else(|| Error::Runtime("bad b".into()))?;
        let d = field("d")?.as_usize().ok_or_else(|| Error::Runtime("bad d".into()))?;
        let dtype = field("dtype")?
            .as_str()
            .ok_or_else(|| Error::Runtime("bad dtype".into()))?
            .to_string();
        let mut artifacts = std::collections::HashMap::new();
        if let Json::Obj(m) = field("artifacts")? {
            for (k, v) in m {
                artifacts.insert(
                    k.clone(),
                    v.as_str().ok_or_else(|| Error::Runtime("bad artifact path".into()))?.to_string(),
                );
            }
        } else {
            return Err(Error::Runtime("manifest artifacts must be an object".into()));
        }
        Ok(Manifest { b, d, dtype, artifacts })
    }
}

/// Everything PJRT lives here; `PjRtClient` is `Rc`-based so the inner
/// struct is not `Send`. Access is serialized through the surrounding
/// `Mutex` and the CPU device serializes execution anyway, so we assert
/// `Send` for the guarded payload (the PJRT C API itself is thread-safe;
/// the non-atomic `Rc` refcounts are only ever touched under the lock).
struct EngineInner {
    _client: xla::PjRtClient,
    exe_marginals: xla::PjRtLoadedExecutable,
    exe_update: xla::PjRtLoadedExecutable,
    exe_filter: Option<xla::PjRtLoadedExecutable>,
}

// SAFETY: see `EngineInner` doc — all uses go through `Mutex<EngineInner>`,
// so no two threads touch the Rc refcounts or PJRT handles concurrently.
unsafe impl Send for EngineInner {}

/// Compiled marginal-evaluation engine over the AOT artifacts.
///
/// Fixed shapes: candidate blocks of `B` rows × universe tiles of `D`
/// columns (from the manifest). Callers with larger universes tile over D
/// and accumulate; callers with ragged blocks pad to B (padding rows are
/// all-zero and yield marginal 0 under a non-negative coverage vector).
pub struct MarginalsEngine {
    inner: Mutex<EngineInner>,
    b: usize,
    d: usize,
    /// Total PJRT executions served (for perf accounting).
    execs: std::sync::atomic::AtomicU64,
}

impl MarginalsEngine {
    /// Load and compile the artifacts from `dir` (default: `./artifacts`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        if manifest.dtype != "f32" {
            return Err(Error::Runtime(format!("unsupported dtype {}", manifest.dtype)));
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e:?}")))?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let file = manifest
                .artifacts
                .get(name)
                .ok_or_else(|| Error::Runtime(format!("artifact {name} missing from manifest")))?;
            let path: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("artifact path must be utf-8"),
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e:?}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {name}: {e:?}")))
        };
        let exe_marginals = compile("marginals")?;
        let exe_update = compile("update")?;
        let exe_filter = compile("filter").ok();
        Ok(MarginalsEngine {
            inner: Mutex::new(EngineInner { _client: client, exe_marginals, exe_update, exe_filter }),
            b: manifest.b,
            d: manifest.d,
            execs: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Candidate block size B the artifact was compiled for.
    pub fn tile_b(&self) -> usize {
        self.b
    }

    /// Universe tile size D the artifact was compiled for.
    pub fn tile_d(&self) -> usize {
        self.d
    }

    /// Number of PJRT executions served so far.
    pub fn executions(&self) -> u64 {
        self.execs.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Batched marginals for candidates `es`. `rows(e)` must return e's
    /// similarity row, padded to a multiple of `tile_d()`; `cur` is the
    /// coverage vector padded to the same length. Results land in `out`
    /// (f64, one per candidate).
    pub fn batch_marginals<'a, F>(
        &self,
        es: &[ElementId],
        rows: F,
        cur: &[f32],
        out: &mut [f64],
    ) -> Result<()>
    where
        F: Fn(ElementId) -> &'a [f32],
    {
        debug_assert_eq!(es.len(), out.len());
        let d_total = cur.len();
        assert!(d_total % self.d == 0, "cur must be padded to a multiple of tile_d");
        let tiles = d_total / self.d;
        out.iter_mut().for_each(|o| *o = 0.0);

        // Reused per-call buffers: one packed host block and one literal per
        // input, refilled per (chunk, tile) via copy_raw_from — avoids a
        // 2 MiB literal allocation per PJRT call (§Perf).
        let mut sim_block = vec![0.0f32; self.b * self.d];
        let mut sim_lit =
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &[self.b, self.d]);
        let mut cur_lit = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &[self.d]);
        let inner = self.inner.lock().expect("engine poisoned");
        for chunk_start in (0..es.len()).step_by(self.b) {
            let chunk = &es[chunk_start..(chunk_start + self.b).min(es.len())];
            for t in 0..tiles {
                let col0 = t * self.d;
                // pack the (chunk × tile) sim block; unused rows stay zero.
                for (r, &e) in chunk.iter().enumerate() {
                    let row = rows(e);
                    sim_block[r * self.d..(r + 1) * self.d]
                        .copy_from_slice(&row[col0..col0 + self.d]);
                }
                for r in chunk.len()..self.b {
                    sim_block[r * self.d..(r + 1) * self.d].fill(0.0);
                }
                sim_lit
                    .copy_raw_from(&sim_block)
                    .map_err(|e| Error::Runtime(format!("sim copy: {e:?}")))?;
                cur_lit
                    .copy_raw_from(&cur[col0..col0 + self.d])
                    .map_err(|e| Error::Runtime(format!("cur copy: {e:?}")))?;
                let result = inner
                    .exe_marginals
                    .execute::<&xla::Literal>(&[&sim_lit, &cur_lit])
                    .map_err(|e| Error::Runtime(format!("execute marginals: {e:?}")))?[0][0]
                    .to_literal_sync()
                    .map_err(|e| Error::Runtime(format!("sync: {e:?}")))?;
                let partial = result
                    .to_tuple1()
                    .map_err(|e| Error::Runtime(format!("tuple: {e:?}")))?
                    .to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("to_vec: {e:?}")))?;
                self.execs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                for (r, o) in out[chunk_start..chunk_start + chunk.len()].iter_mut().enumerate() {
                    *o += partial[r] as f64;
                }
            }
        }
        Ok(())
    }

    /// Coverage-vector update through the AOT `update` artifact:
    /// `cur <- max(cur, row)`, tile by tile. Used by integration tests and
    /// the e2e example to prove the update path composes; the oracle keeps
    /// a mirrored native update for the scalar path.
    pub fn update_coverage(&self, row: &[f32], cur: &mut [f32]) -> Result<()> {
        assert_eq!(row.len(), cur.len());
        assert!(cur.len() % self.d == 0, "vectors must be padded to tile_d");
        let inner = self.inner.lock().expect("engine poisoned");
        for t in 0..cur.len() / self.d {
            let lo = t * self.d;
            let out = exec_update(&inner.exe_update, &row[lo..lo + self.d], &cur[lo..lo + self.d])?;
            self.execs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            cur[lo..lo + self.d].copy_from_slice(&out);
        }
        Ok(())
    }

    /// Fused filter: marginals + survivor mask at threshold `tau` for one
    /// B×D-padded block. Returns `(marginals, mask)` of length `es.len()`.
    /// Only valid when the universe fits a single tile (`cur.len() == tile_d`);
    /// multi-tile callers use [`Self::batch_marginals`] and threshold on the CPU.
    pub fn filter_threshold<'a, F>(
        &self,
        es: &[ElementId],
        rows: F,
        cur: &[f32],
        tau: f32,
    ) -> Result<(Vec<f64>, Vec<bool>)>
    where
        F: Fn(ElementId) -> &'a [f32],
    {
        let inner = self.inner.lock().expect("engine poisoned");
        let exe = inner
            .exe_filter
            .as_ref()
            .ok_or_else(|| Error::Runtime("filter artifact not loaded".into()))?;
        assert_eq!(cur.len(), self.d, "fused filter requires a single-tile universe");
        let mut sim_block = vec![0.0f32; self.b * self.d];
        let mut marg = Vec::with_capacity(es.len());
        let mut mask = Vec::with_capacity(es.len());
        for chunk in es.chunks(self.b) {
            for (r, &e) in chunk.iter().enumerate() {
                sim_block[r * self.d..(r + 1) * self.d].copy_from_slice(rows(e));
            }
            for r in chunk.len()..self.b {
                sim_block[r * self.d..(r + 1) * self.d].fill(0.0);
            }
            let (m, msk) = exec_filter(exe, &sim_block, cur, tau, self.b, self.d)?;
            self.execs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            for r in 0..chunk.len() {
                marg.push(m[r] as f64);
                mask.push(msk[r] >= 0.5);
            }
        }
        Ok((marg, mask))
    }
}

fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    // Single-copy literal: create at the target shape and copy raw bytes in,
    // instead of vec1 (copy) + reshape (second copy). ~2x less memcpy on the
    // per-call hot path (see EXPERIMENTS.md §Perf).
    let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims_usize);
    lit.copy_raw_from(data)
        .map_err(|e| Error::Runtime(format!("literal copy_raw_from: {e:?}")))?;
    Ok(lit)
}

fn exec_update(
    exe: &xla::PjRtLoadedExecutable,
    row: &[f32],
    cur: &[f32],
) -> Result<Vec<f32>> {
    let d = row.len();
    let row_lit = literal_f32(row, &[d as i64])?;
    let cur_lit = literal_f32(cur, &[d as i64])?;
    let result = exe
        .execute::<xla::Literal>(&[row_lit, cur_lit])
        .map_err(|e| Error::Runtime(format!("execute update: {e:?}")))?[0][0]
        .to_literal_sync()
        .map_err(|e| Error::Runtime(format!("sync: {e:?}")))?;
    let out = result.to_tuple1().map_err(|e| Error::Runtime(format!("tuple: {e:?}")))?;
    out.to_vec::<f32>().map_err(|e| Error::Runtime(format!("to_vec: {e:?}")))
}

fn exec_filter(
    exe: &xla::PjRtLoadedExecutable,
    sim: &[f32],
    cur: &[f32],
    tau: f32,
    b: usize,
    d: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let sim_lit = literal_f32(sim, &[b as i64, d as i64])?;
    let cur_lit = literal_f32(cur, &[d as i64])?;
    let tau_lit = xla::Literal::scalar(tau);
    let result = exe
        .execute::<xla::Literal>(&[sim_lit, cur_lit, tau_lit])
        .map_err(|e| Error::Runtime(format!("execute filter: {e:?}")))?[0][0]
        .to_literal_sync()
        .map_err(|e| Error::Runtime(format!("sync: {e:?}")))?;
    let (m, mask) = result.to_tuple2().map_err(|e| Error::Runtime(format!("tuple2: {e:?}")))?;
    Ok((
        m.to_vec::<f32>().map_err(|e| Error::Runtime(format!("to_vec m: {e:?}")))?,
        mask.to_vec::<f32>().map_err(|e| Error::Runtime(format!("to_vec mask: {e:?}")))?,
    ))
}

/// Locate the artifact directory: `$MRSUB_ARTIFACTS` or `./artifacts`
/// relative to the workspace root (walks up from cwd looking for
/// `artifacts/manifest.json`).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("MRSUB_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
