//! Byte-stream transports for the shared-nothing process backend.
//!
//! The [`crate::mapreduce::wire`] frame codec is transport-agnostic: it
//! only needs a reliable, ordered byte stream in each direction. This
//! module provides three such streams and the machinery to establish
//! them:
//!
//! * [`Transport::Pipe`] — the worker's stdin/stdout pipes, set up by the
//!   coordinator at spawn time. Zero configuration, single host, the
//!   default.
//! * [`Transport::Uds`] — a Unix-domain socket. The coordinator binds a
//!   listener on a private path under the system temp dir; workers
//!   connect back to it. Same-host only, but the workers are free of the
//!   coordinator's stdio and can live in different cgroups/namespaces.
//! * [`Transport::Tcp`] — a TCP listener, loopback (`127.0.0.1:0`) by
//!   default. With an explicit opt-in bind address
//!   (`process:N@tcp:HOST:PORT`) the pool spawns **no** local workers and
//!   instead waits for `N` external `mrsub worker --connect HOST:PORT
//!   --id I` processes to join — this is how workers span hosts.
//!
//! Connection establishment is guarded end to end: the listener accepts
//! with a hard deadline (a worker that never connects degrades into a
//! structured [`crate::core::Error::Worker`], exactly like a
//! connection-refused), and the first frame on every new stream must be a
//! [`crate::mapreduce::wire::FromWorker::Hello`] carrying the worker's
//! slot id and wire version — so a wrong-version binary or a stray
//! connection fails the handshake before any shard data moves.

use std::fmt;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Process-wide listener sequence number, part of every UDS socket path.
/// Combined with the pid it makes each path unique for the life of the
/// filesystem: two listeners can never collide even when callers pass the
/// same `tag` (concurrent pools in one daemon, tests, overlapping `serve`
/// instances), and a path left behind by a crashed coordinator — whose pid
/// is by definition not ours — is never silently unlinked and reused.
static LISTENER_SEQ: AtomicU64 = AtomicU64::new(0);

/// Which byte-stream transport the process backend's coordinator and
/// workers speak [`crate::mapreduce::wire`] over. Parsed from the
/// `process:N@<transport>` backend syntax; [`Transport::Pipe`] when the
/// suffix is omitted.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum Transport {
    /// stdin/stdout pipes of the spawned worker (the default).
    #[default]
    Pipe,
    /// Unix-domain socket under the system temp dir; workers connect back.
    Uds,
    /// [`Transport::Uds`] plus the zero-copy shard arena
    /// ([`crate::mapreduce::arena`]): the coordinator fd-passes a memfd
    /// region over the socket and workers map shards instead of decoding
    /// them. Falls back transparently to plain `@uds` wire semantics when
    /// the arena cannot be built (non-Linux, memfd failure).
    UdsArena,
    /// TCP. `bind: None` = loopback listener + locally spawned workers;
    /// `bind: Some(addr)` = listen on `addr` and wait for external
    /// `mrsub worker --connect` processes instead of spawning any.
    Tcp {
        /// Explicit listen address (`HOST:PORT`); `None` = `127.0.0.1:0`.
        bind: Option<String>,
    },
}

/// The valid transport suffixes, for error messages — kept next to the
/// parser so the two cannot drift.
pub const TRANSPORT_SUFFIXES: &str = "pipe | uds | uds+arena | tcp | tcp:HOST:PORT";

impl Transport {
    /// Parse the `@`-suffix of a `process:N@<suffix>` backend string:
    /// `"pipe"`, `"uds"`, `"uds+arena"`, `"tcp"`, or `"tcp:HOST:PORT"`.
    /// Unknown or malformed suffixes return a structured error naming the
    /// valid set (surfaced verbatim by the CLI and the TOML parser).
    pub fn parse_suffix(s: &str) -> Result<Transport, String> {
        match s {
            "pipe" => Ok(Transport::Pipe),
            "uds" => Ok(Transport::Uds),
            "uds+arena" => Ok(Transport::UdsArena),
            "tcp" => Ok(Transport::Tcp { bind: None }),
            _ => {
                if let Some(addr) = s.strip_prefix("tcp:") {
                    let addr = addr.trim();
                    // require a HOST:PORT shape so `tcp:` alone is
                    // rejected; port 0 (ephemeral) is rejected too —
                    // external workers could never discover the port the
                    // kernel picked.
                    let ok = addr.rsplit_once(':').is_some_and(|(h, p)| {
                        !h.is_empty() && p.parse::<u16>().is_ok_and(|port| port != 0)
                    });
                    if ok {
                        return Ok(Transport::Tcp { bind: Some(addr.to_string()) });
                    }
                    return Err(format!(
                        "bad tcp transport suffix {s:?}: want tcp:HOST:PORT with a \
                         nonzero port (valid transports: {TRANSPORT_SUFFIXES})"
                    ));
                }
                Err(format!(
                    "unknown transport suffix {s:?} (valid transports: {TRANSPORT_SUFFIXES})"
                ))
            }
        }
    }

    /// The `@`-suffix this transport round-trips through
    /// [`Transport::parse_suffix`]; empty for the default pipe transport
    /// (so `process:N` labels stay stable across versions).
    pub fn label_suffix(&self) -> String {
        match self {
            Transport::Pipe => String::new(),
            Transport::Uds => "@uds".into(),
            Transport::UdsArena => "@uds+arena".into(),
            Transport::Tcp { bind: None } => "@tcp".into(),
            Transport::Tcp { bind: Some(addr) } => format!("@tcp:{addr}"),
        }
    }

    /// True iff this transport attempts the zero-copy shard arena.
    pub fn wants_arena(&self) -> bool {
        matches!(self, Transport::UdsArena)
    }

    /// True for the socket transports (worker connects back to a
    /// coordinator listener; pipes are wired at spawn instead).
    pub fn is_socket(&self) -> bool {
        !matches!(self, Transport::Pipe)
    }

    /// True iff the pool should *not* spawn local workers and instead
    /// wait for external `mrsub worker --connect` joins (explicit TCP
    /// bind address).
    pub fn external_workers(&self) -> bool {
        matches!(self, Transport::Tcp { bind: Some(_) })
    }
}

impl fmt::Display for Transport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Transport::Pipe => write!(f, "pipe"),
            Transport::Uds => write!(f, "uds"),
            Transport::UdsArena => write!(f, "uds+arena"),
            Transport::Tcp { bind: None } => write!(f, "tcp"),
            Transport::Tcp { bind: Some(addr) } => write!(f, "tcp:{addr}"),
        }
    }
}

/// One established worker byte stream: a reader and a writer half (for
/// the dedicated per-worker reader/writer threads) plus a control handle
/// that can force-close the stream out from under them.
pub struct WorkerLink {
    /// Read half (frames worker → coordinator).
    pub reader: Box<dyn Read + Send>,
    /// Write half (frames coordinator → worker).
    pub writer: Box<dyn Write + Send>,
    /// Force-close handle (see [`LinkControl`]).
    pub control: LinkControl,
}

/// Transport-specific handle for tearing a live stream down from the
/// coordinator side. Pipes close when their ends drop; sockets need an
/// explicit `shutdown` so a reader thread blocked in `read` (and the
/// worker's own read loop) observe EOF immediately. Streams are
/// `Arc`-shared because socket handles have no `Clone` (only
/// `try_clone`), and `shutdown` needs only `&self`.
#[derive(Clone)]
pub enum LinkControl {
    /// Pipe streams close with their owners; nothing to do.
    Pipe,
    /// Shut down both halves of the TCP stream.
    Tcp(Arc<TcpStream>),
    /// Shut down both halves of the Unix-domain stream.
    Uds(Arc<UnixStream>),
}

impl LinkControl {
    /// Force-close the stream (both directions). Errors are ignored — the
    /// stream may already be gone, which is the desired end state.
    pub fn force_close(&self) {
        match self {
            LinkControl::Pipe => {}
            LinkControl::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            LinkControl::Uds(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl fmt::Debug for LinkControl {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LinkControl::Pipe => write!(f, "LinkControl::Pipe"),
            LinkControl::Tcp(_) => write!(f, "LinkControl::Tcp"),
            LinkControl::Uds(_) => write!(f, "LinkControl::Uds"),
        }
    }
}

/// A bound coordinator listener for the socket transports, plus the
/// endpoint string workers connect back to (the `MRSUB_CONNECT` /
/// `--connect` value).
pub enum Listener {
    /// Unix-domain listener; the path is unlinked on drop.
    Uds {
        /// The bound listener.
        listener: UnixListener,
        /// Socket path (cleaned up on drop).
        path: PathBuf,
    },
    /// TCP listener.
    Tcp {
        /// The bound listener.
        listener: TcpListener,
        /// The resolved local address (real port even when bound to `:0`).
        addr: SocketAddr,
    },
}

impl Listener {
    /// Bind a listener for `transport`; `None` for [`Transport::Pipe`].
    /// The UDS socket path is keyed by pid + a process-wide per-listener
    /// counter (the caller's `tag` rides along for debuggability), so
    /// concurrent pools never collide on a path and a stale socket from a
    /// crashed run — a different pid — can never shadow a live bind. The
    /// path is unlinked in [`Drop`].
    pub fn bind(transport: &Transport, tag: u64) -> std::io::Result<Option<Listener>> {
        match transport {
            Transport::Pipe => Ok(None),
            Transport::Uds | Transport::UdsArena => {
                let seq = LISTENER_SEQ.fetch_add(1, Ordering::Relaxed);
                let path = std::env::temp_dir()
                    .join(format!("mrsub-{}-{tag:x}-{seq:x}.sock", std::process::id()));
                let listener = UnixListener::bind(&path)?;
                listener.set_nonblocking(true)?;
                Ok(Some(Listener::Uds { listener, path }))
            }
            Transport::Tcp { bind } => {
                let addr = bind.as_deref().unwrap_or("127.0.0.1:0");
                let listener = TcpListener::bind(addr)?;
                let addr = listener.local_addr()?;
                listener.set_nonblocking(true)?;
                Ok(Some(Listener::Tcp { listener, addr }))
            }
        }
    }

    /// The endpoint string a worker dials: `uds:<path>` or
    /// `tcp:<host>:<port>` (the scheme [`connect`] parses).
    pub fn endpoint(&self) -> String {
        match self {
            Listener::Uds { path, .. } => format!("uds:{}", path.display()),
            Listener::Tcp { addr, .. } => format!("tcp:{addr}"),
        }
    }

    /// Accept one worker connection, waiting until `deadline`. Returns
    /// `Ok(None)` on deadline expiry (the caller turns that into a
    /// structured worker error naming the missing worker).
    pub fn accept_until(&self, deadline: Instant) -> std::io::Result<Option<WorkerLink>> {
        loop {
            let res = match self {
                Listener::Uds { listener, .. } => listener
                    .accept()
                    .map(|(s, _)| link_from_uds(s)),
                Listener::Tcp { listener, .. } => listener
                    .accept()
                    .map(|(s, _)| link_from_tcp(s)),
            };
            match res {
                Ok(link) => return link.map(Some),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Ok(None);
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Uds { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

fn link_from_tcp(s: TcpStream) -> std::io::Result<WorkerLink> {
    s.set_nonblocking(false)?;
    s.set_nodelay(true)?;
    let reader = s.try_clone()?;
    let writer = s.try_clone()?;
    Ok(WorkerLink {
        reader: Box::new(reader),
        writer: Box::new(writer),
        control: LinkControl::Tcp(Arc::new(s)),
    })
}

fn link_from_uds(s: UnixStream) -> std::io::Result<WorkerLink> {
    s.set_nonblocking(false)?;
    let reader = s.try_clone()?;
    let writer = s.try_clone()?;
    Ok(WorkerLink {
        reader: Box::new(reader),
        writer: Box::new(writer),
        control: LinkControl::Uds(Arc::new(s)),
    })
}

/// Worker side: dial a coordinator endpoint (`uds:<path>` or
/// `tcp:<host>:<port>`, the scheme emitted by [`Listener::endpoint`]).
pub fn connect(endpoint: &str) -> std::io::Result<WorkerLink> {
    if let Some(path) = endpoint.strip_prefix("uds:") {
        return link_from_uds(UnixStream::connect(path)?);
    }
    if let Some(addr) = endpoint.strip_prefix("tcp:") {
        return link_from_tcp(TcpStream::connect(addr)?);
    }
    Err(std::io::Error::new(
        std::io::ErrorKind::InvalidInput,
        format!("bad connect endpoint {endpoint:?} (want uds:<path> or tcp:<host>:<port>)"),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_suffixes_roundtrip() {
        for (s, t) in [
            ("pipe", Transport::Pipe),
            ("uds", Transport::Uds),
            ("uds+arena", Transport::UdsArena),
            ("tcp", Transport::Tcp { bind: None }),
            ("tcp:127.0.0.1:9000", Transport::Tcp { bind: Some("127.0.0.1:9000".into()) }),
        ] {
            let parsed = Transport::parse_suffix(s).unwrap();
            assert_eq!(parsed, t, "{s}");
            let suffix = parsed.label_suffix();
            if !suffix.is_empty() {
                assert_eq!(Transport::parse_suffix(&suffix[1..]), Ok(t));
            }
        }
    }

    #[test]
    fn bad_suffixes_name_the_valid_set() {
        for s in [
            "shm",
            "tcp:",
            "tcp:nohost",
            "tcp::123",
            "tcp:host:notaport",
            // ephemeral port 0 would be undiscoverable by external workers.
            "tcp:host:0",
            "uds+shm",
        ] {
            let err = Transport::parse_suffix(s).unwrap_err();
            assert!(
                err.contains(TRANSPORT_SUFFIXES),
                "error for {s:?} must name the valid transports, got: {err}"
            );
        }
    }

    #[test]
    fn external_worker_semantics() {
        assert!(!Transport::Pipe.external_workers());
        assert!(!Transport::Uds.external_workers());
        assert!(!Transport::UdsArena.external_workers());
        assert!(!Transport::Tcp { bind: None }.external_workers());
        assert!(Transport::Tcp { bind: Some("0.0.0.0:7070".into()) }.external_workers());
        assert!(Transport::Uds.is_socket());
        assert!(Transport::UdsArena.is_socket());
        assert!(!Transport::Pipe.is_socket());
        assert!(Transport::UdsArena.wants_arena());
        assert!(!Transport::Uds.wants_arena());
    }

    #[test]
    fn uds_arena_binds_a_unix_listener() {
        let l = Listener::bind(&Transport::UdsArena, 0xBEEF).unwrap().unwrap();
        assert!(l.endpoint().starts_with("uds:"), "{}", l.endpoint());
    }

    #[test]
    fn uds_listener_accepts_and_moves_bytes() {
        let l = Listener::bind(&Transport::Uds, 0xA11CE).unwrap().unwrap();
        let endpoint = l.endpoint();
        let t = std::thread::spawn(move || {
            let mut link = connect(&endpoint).unwrap();
            link.writer.write_all(b"ping").unwrap();
            link.writer.flush().unwrap();
            let mut buf = [0u8; 4];
            link.reader.read_exact(&mut buf).unwrap();
            buf
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut link = l.accept_until(deadline).unwrap().expect("worker connected");
        let mut buf = [0u8; 4];
        link.reader.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping");
        link.writer.write_all(b"pong").unwrap();
        link.writer.flush().unwrap();
        assert_eq!(&t.join().unwrap(), b"pong");
    }

    #[test]
    fn tcp_listener_loopback_roundtrip_and_force_close() {
        let l = Listener::bind(&Transport::Tcp { bind: None }, 1).unwrap().unwrap();
        let endpoint = l.endpoint();
        assert!(endpoint.starts_with("tcp:127.0.0.1:"));
        let t = std::thread::spawn(move || {
            let mut link = connect(&endpoint).unwrap();
            link.writer.write_all(b"x").unwrap();
            link.writer.flush().unwrap();
            // after force_close on the coordinator side, reads see EOF.
            let mut buf = [0u8; 1];
            link.reader.read(&mut buf).unwrap_or(0)
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut link = l.accept_until(deadline).unwrap().expect("connected");
        let mut buf = [0u8; 1];
        link.reader.read_exact(&mut buf).unwrap();
        link.control.force_close();
        assert_eq!(t.join().unwrap(), 0, "peer observes EOF after force_close");
    }

    #[test]
    fn accept_deadline_expires_to_none() {
        let l = Listener::bind(&Transport::Tcp { bind: None }, 2).unwrap().unwrap();
        let start = Instant::now();
        let got = l.accept_until(Instant::now() + Duration::from_millis(60)).unwrap();
        assert!(got.is_none(), "no connection must time out");
        assert!(start.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn connect_rejects_bad_scheme() {
        assert!(connect("smoke:signals").is_err());
    }

    #[test]
    fn uds_paths_unique_even_with_equal_tags() {
        // two live listeners sharing a tag must get distinct paths — the
        // per-listener counter, not the caller's tag, is what guarantees
        // a daemon's concurrent pools (or overlapping tests) never collide.
        let a = Listener::bind(&Transport::Uds, 0x5A5A).unwrap().unwrap();
        let b = Listener::bind(&Transport::Uds, 0x5A5A).unwrap().unwrap();
        assert_ne!(a.endpoint(), b.endpoint());
        let pid = format!("mrsub-{}-", std::process::id());
        for l in [&a, &b] {
            assert!(l.endpoint().contains(&pid), "path keyed by pid: {}", l.endpoint());
        }
    }

    #[test]
    fn uds_socket_path_cleaned_up_on_drop() {
        let l = Listener::bind(&Transport::Uds, 0xDEAD).unwrap().unwrap();
        let path = match &l {
            Listener::Uds { path, .. } => path.clone(),
            _ => unreachable!(),
        };
        assert!(path.exists());
        drop(l);
        assert!(!path.exists(), "socket path must be unlinked on drop");
    }
}
