//! Planted directed-cut instances — the *non-monotone* workload family.
//!
//! `sources` vertices each fan `deg` weighted arcs into a pool of `sinks`
//! vertices; no other arcs exist. Selecting every source cuts every arc,
//! so `OPT_k = Σ w` exactly at `k = sources`, while adding any sink only
//! un-cuts its incoming arcs — the clean planted setting for the
//! Barbosa–Ene–Nguyen–Ward non-monotone framework and for DASH.

use super::{Instance, WorkloadGen};
use crate::core::derive_seed;
use crate::oracle::dicut::DicutOracle;
use crate::util::rng::Rng;

/// Planted directed-cut generator (see module docs).
#[derive(Debug, Clone)]
pub struct PlantedDicutGen {
    /// Source vertices, ids `0..sources` (= the planted optimal k).
    pub sources: usize,
    /// Sink vertices, ids `sources..sources+sinks`.
    pub sinks: usize,
    /// Arcs leaving each source (heads drawn uniformly from the sinks).
    pub deg: usize,
}

impl PlantedDicutGen {
    /// New generator over `sources + sinks` vertices.
    pub fn new(sources: usize, sinks: usize, deg: usize) -> Self {
        PlantedDicutGen { sources, sinks, deg }
    }

    /// Deterministic arc list for `seed` — shared by [`Self::build`] and
    /// [`Self::opt`] so the planted optimum is the exact total weight.
    fn arcs(&self, seed: u64) -> Vec<(u32, u32, f64)> {
        assert!(self.sinks > 0, "dicut instance needs at least one sink");
        let mut rng = Rng::seed_from_u64(derive_seed(seed, 0xD1C0));
        let mut arcs = Vec::with_capacity(self.sources * self.deg);
        for u in 0..self.sources {
            for _ in 0..self.deg {
                let v = self.sources + rng.gen_range(0..self.sinks);
                let w = 0.5 + 0.25 * rng.gen_range(0..8) as f64;
                arcs.push((u as u32, v as u32, w));
            }
        }
        arcs
    }

    /// Build the oracle (vertices `0..sources+sinks`).
    pub fn build(&self, seed: u64) -> DicutOracle {
        DicutOracle::new(self.sources + self.sinks, &self.arcs(seed))
    }

    /// The planted optimum at `k = sources`: every arc leaves a source, so
    /// the all-sources set cuts the full arc weight and nothing beats it.
    pub fn opt(&self, seed: u64) -> f64 {
        self.arcs(seed).iter().map(|&(_, _, w)| w).sum()
    }
}

impl WorkloadGen for PlantedDicutGen {
    fn generate(&self, seed: u64) -> Instance {
        let name = format!(
            "dicut(src={},sink={},deg={},seed={seed})",
            self.sources, self.sinks, self.deg
        );
        Instance::new(name, std::sync::Arc::new(self.build(seed)))
            .with_opt(self.opt(seed), self.sources)
            .with_spec(crate::oracle::spec::OracleSpec::Dicut {
                sources: self.sources,
                sinks: self.sinks,
                deg: self.deg,
                seed,
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ElementId;
    use crate::oracle::Oracle;

    #[test]
    fn all_sources_achieve_opt() {
        let g = PlantedDicutGen::new(6, 40, 5);
        let o = g.build(1);
        let sources: Vec<ElementId> = (0..6).collect();
        assert_eq!(o.value(&sources), g.opt(1));
        assert_eq!(o.ground_size(), 46);
    }

    #[test]
    fn sinks_only_hurt() {
        let g = PlantedDicutGen::new(6, 40, 5);
        let o = g.build(2);
        let opt = g.opt(2);
        // sources plus a sink is never better than the sources alone.
        let mut with_sink: Vec<ElementId> = (0..6).collect();
        with_sink.push(6);
        assert!(o.value(&with_sink) <= opt);
        // the full ground set cuts nothing at all.
        let everything: Vec<ElementId> = (0..46).collect();
        assert_eq!(o.value(&everything), 0.0);
    }

    #[test]
    fn instance_metadata_and_spec_rebuild() {
        let inst = PlantedDicutGen::new(4, 20, 3).generate(9);
        assert_eq!(inst.n, 24);
        assert_eq!(inst.planted_k, Some(4));
        let spec = inst.spec.clone().expect("dicut attaches a spec");
        let rebuilt = spec.build().expect("spec builds");
        let probe: Vec<ElementId> = (0..8).collect();
        assert_eq!(rebuilt.value(&probe).to_bits(), inst.oracle.value(&probe).to_bits());
        assert_eq!(inst.known_opt, Some(PlantedDicutGen::new(4, 20, 3).opt(9)));
    }
}
