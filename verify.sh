#!/usr/bin/env bash
# Tier-1 verification entry point (documented in ROADMAP.md).
#
#   ./verify.sh              build + test + fmt + clippy
#   ./verify.sh fast         build + test only
#   ./verify.sh conformance  backend-conformance matrix, single-threaded
#                            (stable worker-process counts for the
#                            shared-nothing process backend)
#   ./verify.sh ci           full (superset of fast) + conformance, then
#                            an `mrsub bench` smoke whose JSON report is
#                            validated against the committed bench-report
#                            schema (written to BENCH_smoke.json — the CI
#                            pipeline uploads it as an artifact)
#   ./verify.sh bench-diff   run a bench matching the committed
#                            BENCH_baseline.json axes and gate batched
#                            throughput + per-round IPC bytes against it
#                            (>15% regression fails unless the baseline is
#                            provisional; diff lands in BENCH_diff.json)
#
# The default build is offline-clean (no crates.io deps, `xla` feature off).
set -euo pipefail
cd "$(dirname "$0")"

mode="${1:-full}"

# Fail if #[ignore]d tests silently accumulate: an ignored test is a
# disabled assertion, and disabling one must be a visible, justified act.
# Annotate the same line with `// ALLOW-IGNORE: <reason>` to allow one.
#
# Same discipline for #[allow(dead_code)] in the mapreduce layer: the
# elastic-recovery machinery is easy to strand during refactors, and a
# dead-code allow is exactly how stranded code hides. Justify one with
# `// ALLOW-DEAD: <reason>` on the same line.
check_ignores() {
    local found
    found=$(grep -rn '#\[ignore' rust/ examples/ 2>/dev/null | grep -v 'ALLOW-IGNORE' || true)
    if [ -n "$found" ]; then
        echo "verify: FAIL — #[ignore]d tests without an ALLOW-IGNORE justification:"
        echo "$found"
        exit 1
    fi
    found=$(grep -rn '#\[allow(dead_code' rust/src/mapreduce/ 2>/dev/null | grep -v 'ALLOW-DEAD' || true)
    if [ -n "$found" ]; then
        echo "verify: FAIL — #[allow(dead_code)] in rust/src/mapreduce/ without an ALLOW-DEAD justification:"
        echo "$found"
        exit 1
    fi
}

case "$mode" in
    conformance)
        check_ignores
        cargo build --release
        cargo test --test backend_conformance -- --test-threads=1
        ;;
    fast)
        check_ignores
        cargo build --release
        cargo test -q
        ;;
    full)
        check_ignores
        cargo build --release
        cargo test -q
        cargo fmt --check
        cargo clippy --all-targets -- -D warnings
        # docs are CI-enforced: broken intra-doc links and missing docs
        # (lib.rs carries #![warn(missing_docs)]) fail the build.
        RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
        ;;
    ci)
        # `full` is a strict superset of `fast` (build + tests + lints),
        # so ci = full + conformance + bench smoke.
        "$0" full
        "$0" conformance
        # Bench smoke: tiny sizes, one oracle family, serial vs the
        # shared-nothing process backend — enough to (a) keep the report
        # schema honest against the committed fixture and (b) seed the
        # BENCH_*.json perf trajectory as a per-commit CI artifact.
        echo "verify: ci bench smoke"
        ./target/release/mrsub bench --n 256 --k 8 --iters 2 \
            --families coverage --backends serial,process:2 \
            --sizes 300x6 --output BENCH_smoke.json
        MRSUB_BENCH_REPORT="$PWD/BENCH_smoke.json" \
            cargo test --test bench_report_schema
        ;;
    bench-diff)
        check_ignores
        cargo build --release
        # Match the committed baseline's sweep axes (families × backends ×
        # sizes) so every baseline row finds a current-row partner; rows
        # missing on either side are notes, not gates.
        echo "verify: bench-diff against BENCH_baseline.json"
        ./target/release/mrsub bench --n 4096 --k 32 --iters 3 --seed 11 \
            --families coverage,modular \
            --backends serial,process:2@uds,process:2@uds+arena \
            --sizes 8000x20 --output BENCH_current.json
        ./target/release/mrsub bench-diff \
            --baseline BENCH_baseline.json --current BENCH_current.json \
            --tolerance 0.15 --output BENCH_diff.json
        ;;
    *)
        echo "usage: ./verify.sh [fast|conformance|ci|bench-diff]" >&2
        exit 2
        ;;
esac

echo "verify: OK ($mode)"
