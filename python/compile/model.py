"""L2: the jax compute graph the Rust coordinator calls through PJRT.

Three exported entry points, each lowered to its own HLO artifact by
``aot.py`` (fixed shapes; the Rust runtime pads and tiles around them):

* ``batch_marginals(sim, cur)`` — the hot path of ThresholdGreedy /
  ThresholdFilter: marginal gains of a block of B candidates (Pallas L1
  kernel inside).
* ``select_update(row, cur)`` — coverage-vector update after a selection
  (Pallas L1 kernel inside).
* ``filter_threshold(sim, cur, tau)`` — fused ThresholdFilter step: the
  marginals AND the >= tau survivor mask in one artifact, so the Rust side
  makes a single PJRT call per (block, threshold) instead of two.

Everything is shape-monomorphic on purpose: one compiled executable per
(B, D) variant, loaded once at coordinator startup, zero Python at runtime.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.facility_marginals import coverage_update, facility_marginals

# AOT shapes. The Rust runtime pads candidate blocks to B and tiles the
# universe dimension in chunks of D, summing partial marginals.
AOT_B = 256
AOT_D = 2048

# Tile choice is backend-specific (§Perf / DESIGN.md §Hardware-Adaptation):
# on TPU the kernel streams 128x512 VMEM tiles over the HBM-resident block;
# the CPU artifact uses one full-block tile — interpret-mode grid steps cost
# ~0.5 ms each in dynamic-slice overhead, and a (1,1) grid matches the fused
# pure-jnp roofline (measured 4.3 ms -> 0.64 ms per 256x2048 block).
def _tiles(sim: jnp.ndarray) -> dict:
    return {"block_b": sim.shape[0], "block_d": sim.shape[1]}


def batch_marginals(sim: jnp.ndarray, cur: jnp.ndarray):
    """Marginal gains for a block of candidates. sim (B,D) f32, cur (D,) f32."""
    return (facility_marginals(sim, cur, **_tiles(sim)),)


def select_update(row: jnp.ndarray, cur: jnp.ndarray):
    """Coverage vector update after selecting one element. row, cur (D,) f32."""
    return (coverage_update(row, cur),)


def filter_threshold(sim: jnp.ndarray, cur: jnp.ndarray, tau: jnp.ndarray):
    """Fused filter: marginals plus the survivor mask (marginal >= tau).

    tau is a scalar f32 (shape ()); mask is f32 0.0/1.0 so the whole artifact
    stays single-dtype for the Rust loader.
    """
    m = facility_marginals(sim, cur, **_tiles(sim))
    mask = (m >= tau).astype(jnp.float32)
    return (m, mask)
