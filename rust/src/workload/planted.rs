//! Planted-optimum coverage instances — the workloads where the *exact*
//! OPT is known by construction, so benches can report true approximation
//! ratios (not ratios vs greedy).
//!
//! `k` golden elements partition the universe evenly (together they cover
//! everything); `noise_n` noise elements cover `noise_deg` random items
//! each. Any k-set containing a noise element covers strictly less than the
//! golden k-set, so `OPT_k = universe` exactly.
//!
//! With `noise_deg` small this is also the paper's **sparse** regime: only
//! the k golden elements have singleton value ≥ OPT/(2k) (≪ √(nk) of them),
//! which is precisely the case Algorithm 7 exists for. With `noise_deg`
//! comparable to `universe/k` the instance turns **dense** (Algorithm 6's
//! regime).

use super::{Instance, WorkloadGen};
use crate::core::{derive_seed, Constraint};
use crate::oracle::coverage::CoverageOracle;
use crate::util::rng::Rng;

/// Planted coverage generator.
#[derive(Debug, Clone)]
pub struct PlantedCoverageGen {
    /// Number of golden elements (= the planted optimal k).
    pub k: usize,
    /// Universe size (must be ≥ k).
    pub universe: usize,
    /// Number of noise elements.
    pub noise_n: usize,
    /// Items covered by each noise element.
    pub noise_deg: usize,
}

impl PlantedCoverageGen {
    /// Sparse regime: noise elements cover a single item each.
    pub fn sparse(k: usize, universe: usize, noise_n: usize) -> Self {
        PlantedCoverageGen { k, universe, noise_n, noise_deg: 1 }
    }

    /// Dense regime: noise elements cover ~ `universe/(2k)` items each, so
    /// ≥ √(nk) elements clear the OPT/(2k) singleton bar.
    pub fn dense(k: usize, universe: usize, noise_n: usize) -> Self {
        PlantedCoverageGen { k, universe, noise_n, noise_deg: (universe / (2 * k)).max(2) }
    }

    /// Golden element ids are `0..k`; noise ids are `k..k+noise_n`.
    pub fn build(&self, seed: u64) -> CoverageOracle {
        assert!(self.universe >= self.k, "universe must be >= k");
        let mut rng = Rng::seed_from_u64(derive_seed(seed, 0x91A));
        let mut sets: Vec<Vec<u32>> = Vec::with_capacity(self.k + self.noise_n);
        // golden: contiguous equal slices of the universe.
        for g in 0..self.k {
            let lo = g * self.universe / self.k;
            let hi = (g + 1) * self.universe / self.k;
            sets.push((lo as u32..hi as u32).collect());
        }
        for _ in 0..self.noise_n {
            let mut items: Vec<u32> = (0..self.noise_deg)
                .map(|_| rng.gen_range(0..self.universe) as u32)
                .collect();
            items.sort_unstable();
            items.dedup();
            sets.push(items);
        }
        CoverageOracle::unweighted(sets, self.universe)
    }

    /// The planted optimum value (total universe weight).
    pub fn opt(&self) -> f64 {
        self.universe as f64
    }
}

impl WorkloadGen for PlantedCoverageGen {
    fn generate(&self, seed: u64) -> Instance {
        let name = format!(
            "planted(k={},u={},noise={}x{},seed={seed})",
            self.k, self.universe, self.noise_n, self.noise_deg
        );
        Instance::new(name, std::sync::Arc::new(self.build(seed)))
            .with_opt(self.opt(), self.k)
            .with_spec(crate::oracle::spec::OracleSpec::Planted {
                k: self.k,
                universe: self.universe,
                noise_n: self.noise_n,
                noise_deg: self.noise_deg,
                seed,
            })
    }
}

/// Planted *partition-matroid* workload: the planted coverage instance
/// with `part(e) = e mod k` and unit per-part capacities. The golden set
/// `0..k` holds exactly one element of every part, so it stays feasible
/// and the matroid-constrained optimum is still the full universe — which
/// gives the matroid algorithms an instance with a known constrained OPT.
///
/// The oracle is byte-for-byte the [`PlantedCoverageGen`] one (same
/// [`crate::oracle::spec::OracleSpec::Planted`] recipe, so workers rebuild
/// it bit-identically); only the feasibility system differs.
#[derive(Debug, Clone)]
pub struct PlantedMatroidGen {
    /// The underlying planted coverage construction.
    pub inner: PlantedCoverageGen,
}

impl PlantedMatroidGen {
    /// Sparse planted instance under an `e mod k` unit-cap partition
    /// matroid.
    pub fn new(k: usize, universe: usize, noise_n: usize, noise_deg: usize) -> Self {
        PlantedMatroidGen { inner: PlantedCoverageGen { k, universe, noise_n, noise_deg } }
    }

    /// The partition matroid for a ground set of `n` elements: part
    /// `e mod k`, capacity 1 per part (rank `k` once every part is
    /// inhabited).
    pub fn constraint(&self, n: usize) -> Constraint {
        let k = self.inner.k;
        Constraint::partition_matroid((0..n).map(|e| (e % k) as u32).collect(), vec![1; k])
    }
}

impl WorkloadGen for PlantedMatroidGen {
    fn generate(&self, seed: u64) -> Instance {
        let mut inst = self.inner.generate(seed);
        inst.name = format!("matroid-{}", inst.name);
        inst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ElementId;
    use crate::oracle::Oracle;

    #[test]
    fn golden_set_achieves_opt() {
        let g = PlantedCoverageGen::sparse(5, 100, 50);
        let o = g.build(1);
        let golden: Vec<ElementId> = (0..5).collect();
        assert_eq!(o.value(&golden), 100.0);
        assert_eq!(g.opt(), 100.0);
    }

    #[test]
    fn noise_strictly_worse() {
        let g = PlantedCoverageGen::sparse(5, 100, 50);
        let o = g.build(2);
        // swap one golden for one noise: strictly less coverage.
        let mixed: Vec<ElementId> = vec![0, 1, 2, 3, 7]; // 7 is noise
        assert!(o.value(&mixed) < 100.0);
    }

    #[test]
    fn dense_regime_many_large_singletons() {
        let g = PlantedCoverageGen::dense(10, 1000, 500);
        let o = g.build(3);
        let opt_bar = g.opt() / (2.0 * 10.0); // OPT/(2k) = 50
        // noise_deg = 50 -> noise elements have singleton value ~50 ≥ bar.
        let st = o.state();
        let large = (0..o.ground_size() as ElementId)
            .filter(|&e| st.marginal(e) >= opt_bar * 0.9)
            .count();
        assert!(large > 100, "dense instance should have many large elements, got {large}");
    }

    #[test]
    fn sparse_regime_few_large_singletons() {
        let g = PlantedCoverageGen::sparse(10, 1000, 2000);
        let o = g.build(4);
        let opt_bar = g.opt() / (2.0 * 10.0);
        let st = o.state();
        let large = (0..o.ground_size() as ElementId)
            .filter(|&e| st.marginal(e) >= opt_bar)
            .count();
        assert_eq!(large, 10, "only the golden elements clear OPT/(2k)");
    }

    #[test]
    fn instance_has_known_opt() {
        let inst = PlantedCoverageGen::sparse(5, 50, 20).generate(9);
        assert_eq!(inst.known_opt, Some(50.0));
        assert_eq!(inst.planted_k, Some(5));
        assert_eq!(inst.n, 25);
    }

    #[test]
    fn matroid_golden_set_feasible_and_optimal() {
        let g = PlantedMatroidGen::new(5, 100, 45, 1);
        let inst = g.generate(7);
        assert!(inst.name.starts_with("matroid-planted("));
        assert_eq!(inst.n, 50);
        let c = g.constraint(inst.n);
        c.validate(inst.n).unwrap();
        assert_eq!(c.rank(), 5);
        let golden: Vec<ElementId> = (0..5).collect();
        assert!(c.is_feasible(&golden), "one golden element per part");
        assert_eq!(inst.oracle.value(&golden), 100.0);
        // two elements sharing a part (0 and 5) are jointly infeasible.
        assert!(!c.is_feasible(&[0, 5]));
        // the spec rebuild stays bit-identical (same Planted recipe).
        let rebuilt = g.generate(7).spec.unwrap().build().unwrap();
        assert_eq!(rebuilt.value(&golden).to_bits(), inst.oracle.value(&golden).to_bits());
    }
}
