//! Random (weighted) coverage instances — the "dense" regime of the paper:
//! with i.i.d. element degrees, far more than `√(nk)` elements have
//! singleton value ≥ OPT/(2k), so Algorithm 6's max-sampled-singleton OPT
//! guessing is the binding path.

use super::{Instance, WorkloadGen};
use crate::core::derive_seed;
use crate::oracle::coverage::CoverageOracle;
use crate::util::rng::Rng;

/// Uniform random bipartite coverage: `n` elements over `universe` items,
/// each element covering `1..=2·avg_degree` uniform items.
#[derive(Debug, Clone)]
pub struct CoverageGen {
    /// Number of elements.
    pub n: usize,
    /// Universe size.
    pub universe: usize,
    /// Average element degree.
    pub avg_degree: usize,
    /// If true, items get log-normal-ish weights instead of 1.
    pub weighted: bool,
}

impl CoverageGen {
    /// Unweighted generator.
    pub fn new(n: usize, universe: usize, avg_degree: usize) -> Self {
        CoverageGen { n, universe, avg_degree, weighted: false }
    }

    /// Weighted variant (heavy-tailed item weights).
    pub fn weighted(n: usize, universe: usize, avg_degree: usize) -> Self {
        CoverageGen { n, universe, avg_degree, weighted: true }
    }

    /// Deterministically build the concrete oracle.
    pub fn build(&self, seed: u64) -> CoverageOracle {
        let mut rng = Rng::seed_from_u64(derive_seed(seed, 0xC0F));
        let sets: Vec<Vec<u32>> = (0..self.n)
            .map(|_| {
                let deg = rng.gen_range(1..(2 * self.avg_degree).max(1) + 1);
                let mut items: Vec<u32> =
                    (0..deg).map(|_| rng.gen_range(0..self.universe) as u32).collect();
                items.sort_unstable();
                items.dedup();
                items
            })
            .collect();
        let weights = if self.weighted {
            (0..self.universe)
                .map(|_| {
                    let x = rng.gen_range_f64(f64::MIN_POSITIVE, 1.0);
                    (-x.ln()).max(1e-3) // exp(1)-distributed weights
                })
                .collect()
        } else {
            vec![1.0; self.universe]
        };
        CoverageOracle::new(sets, weights)
    }
}

impl WorkloadGen for CoverageGen {
    fn generate(&self, seed: u64) -> Instance {
        let tag = if self.weighted { "wcoverage" } else { "coverage" };
        let name =
            format!("{tag}(n={},u={},deg={},seed={seed})", self.n, self.universe, self.avg_degree);
        Instance::new(name, std::sync::Arc::new(self.build(seed))).with_spec(
            crate::oracle::spec::OracleSpec::Coverage {
                n: self.n,
                universe: self.universe,
                avg_degree: self.avg_degree,
                weighted: self.weighted,
                seed,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::Oracle;

    #[test]
    fn generates_requested_shape() {
        let o = CoverageGen::new(100, 50, 4).build(1);
        assert_eq!(o.ground_size(), 100);
        assert_eq!(o.universe(), 50);
        // every element covers at least one item (degree >= 1 pre-dedup,
        // dedup can't empty a non-empty list)
        for e in 0..100u32 {
            assert!(!o.items_of(e).is_empty());
        }
    }

    #[test]
    fn deterministic() {
        let a = CoverageGen::new(50, 30, 3).build(7);
        let b = CoverageGen::new(50, 30, 3).build(7);
        for e in 0..50u32 {
            assert_eq!(a.items_of(e), b.items_of(e));
        }
    }

    #[test]
    fn weighted_weights_positive() {
        let o = CoverageGen::weighted(50, 30, 3).build(2);
        assert!(o.total_weight() > 0.0);
        let inst = CoverageGen::weighted(50, 30, 3).generate(2);
        assert!(inst.name.starts_with("wcoverage"));
        assert!(inst.known_opt.is_none());
    }
}
