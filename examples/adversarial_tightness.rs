//! Theorem 4 live: build the adversarial instance and watch the
//! t-threshold algorithm hit its cap exactly — then watch sequential
//! greedy sail past it, showing the gap is about *thresholding*, not the
//! instance being hard per se.
//!
//! ```bash
//! cargo run --release --example adversarial_tightness
//! ```

use mrsub::algorithms::greedy::lazy_greedy;
use mrsub::algorithms::multi_round::MultiRound;
use mrsub::algorithms::MrAlgorithm;
use mrsub::core::threshold_bound;
use mrsub::mapreduce::ClusterConfig;
use mrsub::workload::adversarial::AdversarialGen;
use mrsub::workload::WorkloadGen;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let k = 120;
    println!("Theorem 4: no t-threshold algorithm beats 1 − (1 − 1/(t+1))^t");
    println!(
        "{:>3} {:>8} {:>12} {:>12} {:>12} {:>10}",
        "t", "n", "thresh-alg", "cap", "greedy", "cap hit?"
    );
    for t in 1..=6 {
        let inst = AdversarialGen::new(t, k).generate(0);
        let opt = inst.known_opt.unwrap();
        let cfg = ClusterConfig { seed: 3, ..ClusterConfig::default() };
        let res = MultiRound::known(t, opt).run(&inst.oracle, k, &cfg)?;
        let ratio = res.solution.value / opt;
        let cap = threshold_bound(t);
        let greedy_ratio = lazy_greedy(&inst.oracle, k).value / opt;
        println!(
            "{:>3} {:>8} {:>12.4} {:>12.4} {:>12.4} {:>10}",
            t,
            inst.n,
            ratio,
            cap,
            greedy_ratio,
            if (ratio - cap).abs() < 0.02 { "yes" } else { "NO" }
        );
        if (ratio - cap).abs() >= 0.02 {
            return Err(format!("t={t}: tightness violated").into());
        }
    }
    println!("\nEvery row pins its cap: the thresholds, not the instance, are the bottleneck.");
    Ok(())
}
