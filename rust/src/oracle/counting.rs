//! Call-counting decorator: wraps any [`Oracle`] and counts marginal /
//! value-oracle queries across all states (thread-safe), so experiments can
//! report oracle complexity alongside rounds and memory.
//!
//! Batched marginal calls count as `len` queries — the metric is the
//! *oracle-call complexity* of the algorithm, independent of whether a
//! backend amortizes the batch.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::{Oracle, OracleState};
use crate::core::ElementId;

/// Oracle decorator that counts queries issued through any of its states.
pub struct CountingOracle<O: Oracle> {
    inner: O,
    calls: Arc<AtomicU64>,
}

impl<O: Oracle> CountingOracle<O> {
    /// Wrap an oracle with a fresh counter.
    pub fn new(inner: O) -> Self {
        CountingOracle { inner, calls: Arc::new(AtomicU64::new(0)) }
    }

    /// Total marginal/value queries so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Reset the counter (e.g. between benchmark phases).
    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
    }

    /// Shared handle to the counter (for metrics snapshots inside rounds).
    pub fn counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.calls)
    }

    /// Access the wrapped oracle.
    pub fn inner(&self) -> &O {
        &self.inner
    }
}

impl<O: Oracle> Oracle for CountingOracle<O> {
    fn ground_size(&self) -> usize {
        self.inner.ground_size()
    }

    fn state(&self) -> Box<dyn OracleState> {
        Box::new(CountingState { inner: self.inner.state(), calls: Arc::clone(&self.calls) })
    }

    fn value(&self, set: &[ElementId]) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.value(set)
    }
}

struct CountingState {
    inner: Box<dyn OracleState>,
    calls: Arc<AtomicU64>,
}

impl OracleState for CountingState {
    fn value(&self) -> f64 {
        self.inner.value()
    }

    fn marginal(&self, e: ElementId) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.marginal(e)
    }

    fn insert(&mut self, e: ElementId) {
        self.inner.insert(e);
    }

    fn selected(&self) -> &[ElementId] {
        self.inner.selected()
    }

    fn clone_state(&self) -> Box<dyn OracleState> {
        Box::new(CountingState { inner: self.inner.clone_state(), calls: Arc::clone(&self.calls) })
    }

    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        self.calls.fetch_add(es.len() as u64, Ordering::Relaxed);
        self.inner.marginals(es, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::modular::ModularOracle;

    #[test]
    fn counts_marginals_and_batches() {
        let o = CountingOracle::new(ModularOracle::new(vec![1.0, 2.0, 3.0]));
        assert_eq!(o.calls(), 0);
        let mut st = o.state();
        st.marginal(0);
        st.marginal(1);
        assert_eq!(o.calls(), 2);
        let mut out = [0.0; 3];
        st.marginals(&[0, 1, 2], &mut out);
        assert_eq!(o.calls(), 5);
        st.insert(2);
        assert_eq!(o.calls(), 5, "insert is not a counted query");
        o.value(&[0, 1]);
        assert_eq!(o.calls(), 6);
        o.reset();
        assert_eq!(o.calls(), 0);
    }

    #[test]
    fn cloned_states_share_the_counter() {
        let o = CountingOracle::new(ModularOracle::new(vec![1.0, 2.0]));
        let st = o.state();
        let st2 = st.clone_state();
        st.marginal(0);
        st2.marginal(1);
        assert_eq!(o.calls(), 2);
    }
}
