"""L1 Pallas kernel: batched facility-location / coverage marginal gains.

This is the compute hot-spot of every algorithm in the paper: ThresholdGreedy
(Alg 1) and ThresholdFilter (Alg 2) both evaluate the marginal
f_G(e) = f(G + e) - f(G) for a *batch* of candidate elements against the
current partial solution G. For the dense facility-location family (and for
weighted coverage encoded as a dense matrix) that marginal is

    m[e] = sum_j max(sim[e, j] - cur[j], 0)

where ``cur[j] = max_{i in G} sim[i, j]`` is the running coverage vector.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the kernel is a
bandwidth-bound relu-sum reduction, no MXU work. We tile the (B, D) sim
block into (BLOCK_B, BLOCK_D) VMEM tiles via BlockSpec, keep the cur tile
resident alongside, and accumulate per-element partial sums directly in the
output block across the D-grid dimension. Each sim entry is touched exactly
once — the HBM-roofline optimum. ``interpret=True`` everywhere: the CPU
PJRT plugin cannot run Mosaic custom-calls, so the kernel lowers to plain
HLO; on a real TPU the same BlockSpecs drive the HBM<->VMEM schedule.

Default tile: 128 x 512 f32 = 256 KiB of sim per grid step, well under the
~16 MiB VMEM budget even with double-buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. BLOCK_D is the lane-dim multiple (128) times 4; BLOCK_B is the
# sublane-friendly 128. Both divide the AOT shapes in aot.py.
BLOCK_B = 128
BLOCK_D = 512


def _marginals_kernel(sim_ref, cur_ref, out_ref):
    """One grid step: accumulate relu(sim - cur) over a (BLOCK_B, BLOCK_D) tile."""
    j = pl.program_id(1)
    part = jnp.sum(jnp.maximum(sim_ref[...] - cur_ref[...][None, :], 0.0), axis=1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + part


@functools.partial(jax.jit, static_argnames=("block_b", "block_d"))
def facility_marginals(
    sim: jnp.ndarray,
    cur: jnp.ndarray,
    *,
    block_b: int = BLOCK_B,
    block_d: int = BLOCK_D,
) -> jnp.ndarray:
    """Batched marginal gains via Pallas. sim: (B, D), cur: (D,) -> (B,).

    B must be a multiple of ``block_b`` and D of ``block_d`` (the Rust caller
    pads); use ``facility_marginals_ref`` for arbitrary shapes.
    """
    b, d = sim.shape
    assert b % block_b == 0 and d % block_d == 0, (b, d, block_b, block_d)
    grid = (b // block_b, d // block_d)
    return pl.pallas_call(
        _marginals_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_d,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(sim, cur)


def _update_kernel(row_ref, cur_ref, out_ref):
    """Pointwise max of the selected element's row into the coverage vector."""
    out_ref[...] = jnp.maximum(row_ref[...], cur_ref[...])


@jax.jit
def coverage_update(row: jnp.ndarray, cur: jnp.ndarray) -> jnp.ndarray:
    """New coverage vector after selecting an element. row, cur: (D,) -> (D,).

    Single-tile grid: the op is a trivial element-wise max, so there is no
    reason to pay interpret-mode grid-step overhead.
    """
    (d,) = row.shape
    block_d = d
    assert d % block_d == 0, (d, block_d)
    return pl.pallas_call(
        _update_kernel,
        grid=(d // block_d,),
        in_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(row, cur)
