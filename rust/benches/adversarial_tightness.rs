//! E3 ("Figure 2") — Theorem 4 tightness: on the adversarial instance the
//! t-threshold algorithm achieves *exactly* `1 − (1 − 1/(t+1))^t` (up to
//! the δ tie-break slack and n_ℓ rounding), while sequential greedy —
//! which is not threshold-bucketed — exceeds the cap. Sweeps t and k to
//! show rounding effects vanish as k grows.

use mrsub::algorithms::greedy::lazy_greedy;
use mrsub::algorithms::multi_round::MultiRound;
use mrsub::algorithms::MrAlgorithm;
use mrsub::core::threshold_bound;
use mrsub::mapreduce::ClusterConfig;
use mrsub::workload::adversarial::AdversarialGen;
use mrsub::workload::WorkloadGen;

fn main() {
    println!("== E3: Theorem 4 tightness on the adversarial instance ==\n");
    println!(
        "{:>3} {:>6} {:>7} {:>12} {:>12} {:>10} {:>12}",
        "t", "k", "n", "measured", "cap", "|gap|", "greedy"
    );
    let mut max_gap = 0.0f64;
    for t in 1..=6 {
        for k in [24, 60, 120] {
            let inst = AdversarialGen::new(t, k).generate(0);
            let opt = inst.known_opt.unwrap();
            let cfg = ClusterConfig { seed: 1, ..ClusterConfig::default() };
            let res = MultiRound::known(t, opt).run(&inst.oracle, k, &cfg).unwrap();
            let measured = res.solution.value / opt;
            let cap = threshold_bound(t);
            let gap = (measured - cap).abs();
            max_gap = max_gap.max(gap);
            let greedy_ratio = lazy_greedy(&inst.oracle, k).value / opt;
            println!(
                "{:>3} {:>6} {:>7} {:>12.4} {:>12.4} {:>10.1e} {:>12.4}",
                t, k, inst.n, measured, cap, gap, greedy_ratio
            );
        }
    }
    println!("\nmax |measured − cap| = {max_gap:.2e}");
    println!("expected shape: measured pins the cap for every (t, k) — the adversary");
    println!("forces the thresholding algorithm to its theoretical worst case — while");
    println!("greedy (no threshold bucketing) lands above the cap on the same instance.");
}
