//! Call-counting decorator: wraps any [`Oracle`] and counts marginal /
//! value-oracle queries across all states (thread-safe), so experiments can
//! report oracle complexity alongside rounds and memory.
//!
//! Counting distinguishes the *scalar* path from the *block* path: a
//! batched [`OracleState::marginals`] call counts as `len` queries toward
//! the total (amortization inside a backend is not rewarded) and
//! additionally as `len` **batched** queries in one **batch** — so metrics
//! can report how much of an algorithm's oracle traffic actually flows
//! through the block pipeline.
//!
//! Note that the total is a property of the *scan strategy*, not just the
//! algorithm: the block-lazy ThresholdGreedy
//! ([`crate::algorithms::threshold`]) evaluates whole blocks up front and
//! re-queries candidates invalidated by an insertion, so its count can
//! exceed the element-at-a-time scalar scan's by up to one block (the
//! `k`-stop tail) plus one query per insertion-invalidated survivor —
//! while `Serial`/`Rayon` execution backends of the *same* strategy always
//! report identical counts (asserted in `tests/batch_equivalence.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::{Oracle, OracleState};
use crate::core::ElementId;

/// Shared oracle-query counters: total queries plus the batched-vs-scalar
/// split. Cheap relaxed atomics; snapshot/reset from any thread.
#[derive(Debug, Default)]
pub struct OracleCounters {
    total: AtomicU64,
    batched: AtomicU64,
    batches: AtomicU64,
}

impl OracleCounters {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Total queries (scalar + batched elements).
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Queries served through the block path ([`OracleState::marginals`]).
    pub fn batched(&self) -> u64 {
        self.batched.load(Ordering::Relaxed)
    }

    /// Number of block calls.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Queries served one at a time (`total − batched`).
    pub fn scalar(&self) -> u64 {
        self.total().saturating_sub(self.batched())
    }

    /// Consistent-enough snapshot `(total, batched, batches)` for
    /// per-round deltas.
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (self.total(), self.batched(), self.batches())
    }

    /// Merge externally-counted queries (a process-backend worker's
    /// per-round delta) into these counters, so coordinator metrics see
    /// one coherent total across address spaces.
    pub fn add(&self, total: u64, batched: u64, batches: u64) {
        self.total.fetch_add(total, Ordering::Relaxed);
        self.batched.fetch_add(batched, Ordering::Relaxed);
        self.batches.fetch_add(batches, Ordering::Relaxed);
    }

    /// Zero all counters.
    pub fn reset(&self) {
        self.total.store(0, Ordering::Relaxed);
        self.batched.store(0, Ordering::Relaxed);
        self.batches.store(0, Ordering::Relaxed);
    }

    #[inline]
    fn record_scalar(&self, n: u64) {
        self.total.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    fn record_batch(&self, len: u64) {
        self.total.fetch_add(len, Ordering::Relaxed);
        self.batched.fetch_add(len, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
    }
}

/// Oracle decorator that counts queries issued through any of its states.
pub struct CountingOracle<O: Oracle> {
    inner: O,
    counters: Arc<OracleCounters>,
}

impl<O: Oracle> CountingOracle<O> {
    /// Wrap an oracle with fresh counters.
    pub fn new(inner: O) -> Self {
        CountingOracle { inner, counters: Arc::new(OracleCounters::new()) }
    }

    /// Total marginal/value queries so far.
    pub fn calls(&self) -> u64 {
        self.counters.total()
    }

    /// Reset the counters (e.g. between benchmark phases).
    pub fn reset(&self) {
        self.counters.reset();
    }

    /// Shared handle to the counters (for metrics snapshots inside rounds).
    pub fn counter(&self) -> Arc<OracleCounters> {
        Arc::clone(&self.counters)
    }

    /// Access the wrapped oracle.
    pub fn inner(&self) -> &O {
        &self.inner
    }
}

impl<O: Oracle> Oracle for CountingOracle<O> {
    fn ground_size(&self) -> usize {
        self.inner.ground_size()
    }

    fn state(&self) -> Box<dyn OracleState> {
        Box::new(CountingState { inner: self.inner.state(), counters: Arc::clone(&self.counters) })
    }

    fn value(&self, set: &[ElementId]) -> f64 {
        self.counters.record_scalar(1);
        self.inner.value(set)
    }
}

struct CountingState {
    inner: Box<dyn OracleState>,
    counters: Arc<OracleCounters>,
}

impl OracleState for CountingState {
    fn value(&self) -> f64 {
        self.inner.value()
    }

    fn marginal(&self, e: ElementId) -> f64 {
        self.counters.record_scalar(1);
        self.inner.marginal(e)
    }

    fn insert(&mut self, e: ElementId) {
        self.inner.insert(e);
    }

    fn selected(&self) -> &[ElementId] {
        self.inner.selected()
    }

    fn clone_state(&self) -> Box<dyn OracleState> {
        Box::new(CountingState {
            inner: self.inner.clone_state(),
            counters: Arc::clone(&self.counters),
        })
    }

    fn reset(&mut self) {
        self.inner.reset();
    }

    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        self.counters.record_batch(es.len() as u64);
        self.inner.marginals(es, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::modular::ModularOracle;

    #[test]
    fn counts_marginals_and_batches() {
        let o = CountingOracle::new(ModularOracle::new(vec![1.0, 2.0, 3.0]));
        assert_eq!(o.calls(), 0);
        let mut st = o.state();
        st.marginal(0);
        st.marginal(1);
        assert_eq!(o.calls(), 2);
        let mut out = [0.0; 3];
        st.marginals(&[0, 1, 2], &mut out);
        assert_eq!(o.calls(), 5);
        st.insert(2);
        assert_eq!(o.calls(), 5, "insert is not a counted query");
        o.value(&[0, 1]);
        assert_eq!(o.calls(), 6);
        o.reset();
        assert_eq!(o.calls(), 0);
    }

    #[test]
    fn splits_batched_from_scalar_traffic() {
        let o = CountingOracle::new(ModularOracle::new(vec![1.0; 10]));
        let st = o.state();
        st.marginal(0);
        st.marginal(1);
        let mut out = [0.0; 4];
        st.marginals(&[2, 3, 4, 5], &mut out);
        st.marginals(&[6, 7], &mut out[..2]);
        let c = o.counter();
        assert_eq!(c.total(), 8);
        assert_eq!(c.batched(), 6);
        assert_eq!(c.scalar(), 2);
        assert_eq!(c.batches(), 2);
        assert_eq!(c.snapshot(), (8, 6, 2));
        c.reset();
        assert_eq!(c.snapshot(), (0, 0, 0));
    }

    #[test]
    fn cloned_states_share_the_counter() {
        let o = CountingOracle::new(ModularOracle::new(vec![1.0, 2.0]));
        let st = o.state();
        let st2 = st.clone_state();
        st.marginal(0);
        st2.marginal(1);
        assert_eq!(o.calls(), 2);
    }
}
