//! Sample&Prune — adapted from Kumar, Moseley, Vassilvitskii & Vattani
//! (TOPC 2015), the MapReduce greedy the paper cites as its inspiration.
//!
//! Descending-threshold schedule with τ falling by (1−ε) per step, O(log(k/ε)/ε)
//! rounds in the worst case (vs the paper's *constant* 2): in each round
//! every machine prunes its shard to the elements still above τ w.r.t. the
//! broadcast partial solution; if the surviving mass fits the central
//! machine's √(nk) budget it is shipped whole, otherwise a uniform sample
//! of that budget is shipped; the central machine extends the solution by
//! threshold greedy and broadcasts it back. This reproduces the
//! sample-then-prune structure and round complexity that E6 compares
//! against.

use super::threshold::{merge_sorted, threshold_greedy};
use super::{finish, AlgResult, MrAlgorithm};
use crate::core::{derive_seed, ElementId, Result, Solution};
use crate::mapreduce::wire::{RoundTask, TaskReply};
use crate::mapreduce::{ClusterConfig, MrCluster};
use crate::oracle::Oracle;

/// Kumar et al.-style Sample&Prune threshold greedy.
#[derive(Debug, Clone, Copy)]
pub struct SamplePrune {
    /// Threshold decay per round (τ ← τ·(1−eps)).
    pub eps: f64,
    /// Hard cap on rounds (safety; the schedule terminates well before).
    pub max_rounds: usize,
}

impl SamplePrune {
    /// Default configuration (ε = 0.2).
    pub fn new(eps: f64) -> Self {
        assert!(eps > 0.0 && eps < 1.0);
        SamplePrune { eps, max_rounds: 200 }
    }
}

impl MrAlgorithm for SamplePrune {
    fn name(&self) -> String {
        format!("sample-prune(eps={})", self.eps)
    }

    fn run(&self, oracle: &dyn Oracle, k: usize, cfg: &ClusterConfig) -> Result<AlgResult> {
        let n = oracle.ground_size();
        let mut cluster = MrCluster::new(n, k, cfg)?;
        let budget = ((n as f64 * k as f64).sqrt().ceil() as usize).max(k);

        // Round 1: global max singleton Δ (typed shard round; worker-side
        // on the process backend).
        let maxes = cluster.shard_round("r1:max-singleton", 0, oracle, &RoundTask::MaxSingleton)?;
        let delta = maxes.iter().map(TaskReply::as_scalar).fold(0.0f64, f64::max);
        if delta <= 0.0 {
            return Ok(AlgResult { solution: Solution::empty(), metrics: cluster.into_metrics() });
        }

        let mut g = oracle.state();
        let m = cluster.machines();
        let per_share = (budget / m.max(1)).max(1);
        let mut tau = delta;
        let floor = self.eps * delta / k as f64;
        let mut round = 0usize;
        // residency of round r: the previous round's pruned shards (the
        // original shards before the first prune) + the broadcast G. The
        // pruned shards live machine-side; workers report their sizes in
        // the Pruned replies.
        let mut max_kept = cluster.shards().iter().map(Vec::len).max().unwrap_or(0);
        while tau > floor && g.len() < k && round < self.max_rounds {
            round += 1;
            // Worker half-round (typed; worker-side on every backend):
            // permanently prune the machine-resident shard at the *floor*
            // (safe for every future threshold — marginals only shrink),
            // ship the elements above the current τ, sampled down to the
            // central budget share if oversized. The per-machine RNG seed
            // travels inside the task, so sampling is backend-independent.
            let task = RoundTask::PruneSample {
                base: g.selected().to_vec(),
                floor,
                tau,
                per_share,
                seed: derive_seed(cluster.seed(), round as u64),
                round: round as u32,
            };
            let replies = cluster.shard_round_explicit(
                &format!("r{}a:prune+sample", round + 1),
                max_kept + g.len(),
                oracle,
                &task,
            )?;
            let mut shipped: Vec<Vec<ElementId>> = Vec::with_capacity(replies.len());
            let mut all_fit = true;
            let mut kept_max = 0usize;
            for r in replies {
                let (ship, fit, resident) = r.into_pruned();
                all_fit &= fit;
                kept_max = kept_max.max(resident as usize);
                shipped.push(ship);
            }
            max_kept = kept_max;

            // Central: extend by threshold greedy at τ; broadcast G.
            let pool = merge_sorted(&shipped);
            let mut progressed = false;
            cluster.raw_round(&format!("r{}b:extend", round + 1), 0, g.len() * m, pool.len(), || {
                let added = threshold_greedy(g.as_mut(), &pool, tau, k);
                progressed = !added.is_empty();
            })?;
            // decay once the shipped pool covered every eligible element
            // (nothing left at this level) or no progress was possible.
            if all_fit || !progressed {
                tau *= 1.0 - self.eps;
            }
        }

        let solution = finish(oracle, g.selected().to_vec());
        Ok(AlgResult { solution, metrics: cluster.into_metrics() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::lazy_greedy;
    use crate::workload::coverage::CoverageGen;

    fn cfg(seed: u64) -> ClusterConfig {
        ClusterConfig { seed, parallel: false, ..ClusterConfig::default() }
    }

    #[test]
    fn near_greedy_quality_many_rounds() {
        let o = CoverageGen::new(600, 300, 5).build(1);
        let g = lazy_greedy(&o, 12);
        let res = SamplePrune::new(0.2).run(&o, 12, &cfg(2)).unwrap();
        assert!(
            res.solution.value >= (1.0 - 0.25) * g.value * 0.5_f64.max(0.5),
            "sample-prune {} too far below greedy {}",
            res.solution.value,
            g.value
        );
        // The point of E6: it takes (many) more than 2 compute rounds.
        assert!(res.metrics.num_rounds() > 3, "expected a multi-round schedule");
    }

    #[test]
    fn zero_function_terminates() {
        let o = crate::oracle::modular::ModularOracle::new(vec![0.0; 50]);
        let res = SamplePrune::new(0.3).run(&o, 5, &cfg(3)).unwrap();
        assert!(res.solution.is_empty());
    }

    #[test]
    fn respects_k() {
        let o = CoverageGen::new(200, 100, 4).build(4);
        let res = SamplePrune::new(0.25).run(&o, 6, &cfg(5)).unwrap();
        assert!(res.solution.len() <= 6);
    }
}
