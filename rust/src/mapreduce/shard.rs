//! Machine-local execution of [`RoundTask`]s — the *single* interpreter
//! shared by the in-process backends (`Serial`/`Rayon`, via
//! [`crate::mapreduce::MrCluster::shard_round`]) and the `mrsub worker`
//! subprocess of the process backend.
//!
//! Because every backend funnels through the same `prepare`/`compute`/
//! `apply` code — and oracle reconstruction from an
//! [`crate::oracle::spec::OracleSpec`] is deterministic — bit-identical
//! per-machine outputs across backends hold *by construction*; the
//! conformance suite then re-asserts it end to end.
//!
//! Execution is split into three phases so the read-heavy part can fan out
//! across machines on any [`ExecBackend`] without aliasing the mutable
//! per-machine stores:
//!
//! 1. [`prepare`] — rehydrate the broadcast oracle states (the partial
//!    solutions `G` a filter runs against) **once per round**, exactly as
//!    the lock-step simulation shares its identically-computed `G₀`;
//! 2. [`compute`] — pure per-machine evaluation (parallelizable);
//! 3. [`apply`] — fold persistent effects (Algorithm 5's shrinking
//!    per-guess shards) back into each machine's [`GuessStore`].

use std::collections::HashMap;

use crate::algorithms::greedy::lazy_greedy_extend;
use crate::algorithms::sparse::sparse_worker;
use crate::algorithms::threshold::{block_max_marginal, threshold_filter};
use crate::core::ElementId;
use crate::mapreduce::backend::{self, ExecBackend};
use crate::mapreduce::wire::{RoundTask, TaskReply};
use crate::oracle::{Oracle, OracleState, StatePool};

/// Per-machine persistent state across rounds: the per-OPT-guess filtered
/// shard copies of Algorithm 5 (absent ⇒ the guess still sees the
/// machine's original shard).
#[derive(Debug, Default, Clone)]
pub struct GuessStore {
    shards: HashMap<u32, Vec<ElementId>>,
}

impl GuessStore {
    /// The current shard for guess `id`, falling back to the machine's
    /// base shard before the first persistent filter.
    pub fn shard_for<'a>(&'a self, id: u32, base: &'a [ElementId]) -> &'a [ElementId] {
        self.shards.get(&id).map_or(base, Vec::as_slice)
    }

    /// Number of persisted guess shards (tests/metrics).
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// True iff nothing is persisted.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }
}

/// A round task with its broadcast oracle states rehydrated (one
/// `prepare` per round, shared read-only by every machine).
pub enum Prepared {
    /// See [`RoundTask::Filter`].
    Filter {
        /// Rehydrated base state `G`.
        state: Box<dyn OracleState>,
        /// Threshold.
        tau: f64,
    },
    /// See [`RoundTask::MultiFilter`].
    MultiFilter {
        /// Persist per-guess filtered shards.
        persist: bool,
        /// `(guess id, rehydrated G, τ)` per active guess.
        guesses: Vec<(u32, Box<dyn OracleState>, f64)>,
        /// Guess ids to evict from the stores.
        drop: Vec<u32>,
    },
    /// See [`RoundTask::LocalGreedy`].
    LocalGreedy {
        /// Cardinality bound.
        k: usize,
    },
    /// See [`RoundTask::MaxSingleton`].
    MaxSingleton,
    /// See [`RoundTask::TopSingletons`].
    TopSingletons {
        /// Cardinality bound.
        k: usize,
        /// Ship factor.
        c: usize,
    },
    /// See [`RoundTask::Batch`].
    Batch(Vec<Prepared>),
}

/// Rehydrate a task's broadcast states by replaying each `base` into a
/// fresh oracle state in insertion order — the same replay on every
/// backend, so the resulting marginals are bit-identical everywhere.
pub fn prepare(oracle: &dyn Oracle, task: &RoundTask) -> Prepared {
    let replay = |base: &[ElementId]| -> Box<dyn OracleState> {
        let mut st = oracle.state();
        for &e in base {
            st.insert(e);
        }
        st
    };
    match task {
        RoundTask::Filter { base, tau } => Prepared::Filter { state: replay(base), tau: *tau },
        RoundTask::MultiFilter { persist, guesses, drop } => Prepared::MultiFilter {
            persist: *persist,
            guesses: guesses.iter().map(|g| (g.id, replay(&g.base), g.tau)).collect(),
            drop: drop.clone(),
        },
        RoundTask::LocalGreedy { k } => Prepared::LocalGreedy { k: *k },
        RoundTask::MaxSingleton => Prepared::MaxSingleton,
        RoundTask::TopSingletons { k, c } => Prepared::TopSingletons { k: *k, c: *c },
        RoundTask::Batch(tasks) => {
            Prepared::Batch(tasks.iter().map(|t| prepare(oracle, t)).collect())
        }
    }
}

/// Pure per-machine evaluation (no mutation; parallel-safe).
pub fn compute(
    states: &StatePool<'_>,
    prep: &Prepared,
    shard: &[ElementId],
    store: &GuessStore,
) -> TaskReply {
    match prep {
        Prepared::Filter { state, tau } => {
            TaskReply::Ids(threshold_filter(state.as_ref(), shard, *tau))
        }
        Prepared::MultiFilter { persist, guesses, .. } => TaskReply::Multi(
            guesses
                .iter()
                .map(|(id, state, tau)| {
                    let input = if *persist { store.shard_for(*id, shard) } else { shard };
                    (*id, threshold_filter(state.as_ref(), input, *tau))
                })
                .collect(),
        ),
        Prepared::LocalGreedy { k } => {
            let mut st = states.acquire();
            lazy_greedy_extend(&mut *st, shard, *k);
            TaskReply::Ids(st.selected().to_vec())
        }
        Prepared::MaxSingleton => {
            let st = states.acquire();
            TaskReply::Scalar(block_max_marginal(&*st, shard))
        }
        Prepared::TopSingletons { k, c } => TaskReply::Ids(sparse_worker(states, shard, *k, *c)),
        Prepared::Batch(parts) => {
            TaskReply::Batch(parts.iter().map(|p| compute(states, p, shard, store)).collect())
        }
    }
}

/// Fold a reply's persistent effects into the machine's store.
pub fn apply(prep: &Prepared, reply: &TaskReply, store: &mut GuessStore) {
    match (prep, reply) {
        (Prepared::MultiFilter { persist, drop, .. }, TaskReply::Multi(parts)) => {
            for id in drop {
                store.shards.remove(id);
            }
            if *persist {
                for (id, filtered) in parts {
                    store.shards.insert(*id, filtered.clone());
                }
            }
        }
        (Prepared::Batch(ps), TaskReply::Batch(rs)) => {
            for (p, r) in ps.iter().zip(rs) {
                apply(p, r, store);
            }
        }
        _ => {}
    }
}

/// Execute one task over every machine: prepare once, compute fanned out
/// on `exec`, apply serially. `shards[i]`/`stores[i]` is machine `i`.
pub fn run_task_all(
    oracle: &dyn Oracle,
    shards: &[Vec<ElementId>],
    stores: &mut [GuessStore],
    task: &RoundTask,
    exec: &dyn ExecBackend,
) -> Vec<TaskReply> {
    debug_assert_eq!(shards.len(), stores.len());
    let prep = prepare(oracle, task);
    let states = StatePool::new(oracle);
    let replies = {
        let stores_ro: &[GuessStore] = stores;
        backend::map_indexed(exec, shards.len(), |i| {
            compute(&states, &prep, &shards[i], &stores_ro[i])
        })
    };
    for (i, r) in replies.iter().enumerate() {
        apply(&prep, r, &mut stores[i]);
    }
    replies
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::backend::Serial;
    use crate::mapreduce::wire::GuessFilter;
    use crate::workload::coverage::CoverageGen;

    fn setup() -> (impl Oracle, Vec<Vec<ElementId>>, Vec<GuessStore>) {
        let o = CoverageGen::new(120, 80, 4).build(7);
        let shards: Vec<Vec<ElementId>> =
            vec![(0..40).collect(), (40..80).collect(), (80..120).collect()];
        let stores = vec![GuessStore::default(); 3];
        (o, shards, stores)
    }

    #[test]
    fn filter_task_matches_direct_threshold_filter() {
        let (o, shards, mut stores) = setup();
        let base = vec![3u32, 17];
        let task = RoundTask::Filter { base: base.clone(), tau: 1.5 };
        let replies = run_task_all(&o, &shards, &mut stores, &task, &Serial);
        let mut st = o.state();
        for &e in &base {
            st.insert(e);
        }
        for (shard, reply) in shards.iter().zip(replies) {
            assert_eq!(reply.into_ids(), threshold_filter(st.as_ref(), shard, 1.5));
        }
    }

    #[test]
    fn multifilter_persists_per_guess_shards() {
        let (o, shards, mut stores) = setup();
        let task = RoundTask::MultiFilter {
            persist: true,
            guesses: vec![GuessFilter { id: 9, base: vec![], tau: 1.0 }],
            drop: vec![],
        };
        let first = run_task_all(&o, &shards, &mut stores, &task, &Serial);
        assert!(stores.iter().all(|s| s.len() == 1), "guess shard persisted");
        // second round at a higher tau filters the *persisted* shard.
        let task2 = RoundTask::MultiFilter {
            persist: true,
            guesses: vec![GuessFilter { id: 9, base: vec![0, 1], tau: 2.0 }],
            drop: vec![],
        };
        let second = run_task_all(&o, &shards, &mut stores, &task2, &Serial);
        for (f, s) in first.iter().zip(&second) {
            let f: Vec<_> = f.clone().into_multi();
            let s: Vec<_> = s.clone().into_multi();
            // survivors of round 2 are a subset of round 1's survivors.
            for e in &s[0].1 {
                assert!(f[0].1.contains(e), "round-2 survivor {e} not in round-1 set");
            }
        }
        // drop evicts the persisted shard.
        let task3 = RoundTask::MultiFilter { persist: true, guesses: vec![], drop: vec![9] };
        run_task_all(&o, &shards, &mut stores, &task3, &Serial);
        assert!(stores.iter().all(GuessStore::is_empty));
    }

    #[test]
    fn batch_composes_and_preserves_shapes() {
        let (o, shards, mut stores) = setup();
        let task = RoundTask::Batch(vec![
            RoundTask::MaxSingleton,
            RoundTask::LocalGreedy { k: 4 },
            RoundTask::TopSingletons { k: 3, c: 2 },
        ]);
        let replies = run_task_all(&o, &shards, &mut stores, &task, &Serial);
        for r in replies {
            let parts = r.into_batch();
            assert_eq!(parts.len(), 3);
            assert!(parts[0].as_scalar() > 0.0);
            assert!(matches!(&parts[1], TaskReply::Ids(ids) if ids.len() <= 4));
            assert!(matches!(&parts[2], TaskReply::Ids(ids) if ids.len() <= 6));
        }
    }

    #[test]
    fn serial_and_rayon_compute_identical_replies() {
        let (o, shards, mut stores_a) = setup();
        let mut stores_b = stores_a.clone();
        let task = RoundTask::Batch(vec![
            RoundTask::Filter { base: vec![5], tau: 1.0 },
            RoundTask::LocalGreedy { k: 5 },
        ]);
        let a = run_task_all(&o, &shards, &mut stores_a, &task, &Serial);
        let b = run_task_all(
            &o,
            &shards,
            &mut stores_b,
            &task,
            &crate::mapreduce::backend::Rayon { chunk: 1 },
        );
        assert_eq!(a, b);
    }
}
