#!/usr/bin/env bash
# Tier-1 verification entry point (documented in ROADMAP.md).
#
#   ./verify.sh          build + test + fmt + clippy
#   ./verify.sh fast     build + test only
#
# The default build is offline-clean (no crates.io deps, `xla` feature off).
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q

if [ "${1:-full}" != "fast" ]; then
    cargo fmt --check
    cargo clippy --all-targets -- -D warnings
fi

echo "verify: OK"
