#!/usr/bin/env bash
# Tier-1 verification entry point (documented in ROADMAP.md).
#
#   ./verify.sh              build + test + fmt + clippy
#   ./verify.sh fast         build + test only
#   ./verify.sh conformance  backend-conformance matrix, single-threaded
#                            (stable worker-process counts for the
#                            shared-nothing process backend)
#
# The default build is offline-clean (no crates.io deps, `xla` feature off).
set -euo pipefail
cd "$(dirname "$0")"

mode="${1:-full}"

# Fail if #[ignore]d tests silently accumulate: an ignored test is a
# disabled assertion, and disabling one must be a visible, justified act.
# Annotate the same line with `// ALLOW-IGNORE: <reason>` to allow one.
check_ignores() {
    local found
    found=$(grep -rn '#\[ignore' rust/ examples/ 2>/dev/null | grep -v 'ALLOW-IGNORE' || true)
    if [ -n "$found" ]; then
        echo "verify: FAIL — #[ignore]d tests without an ALLOW-IGNORE justification:"
        echo "$found"
        exit 1
    fi
}

case "$mode" in
    conformance)
        check_ignores
        cargo build --release
        cargo test --test backend_conformance -- --test-threads=1
        ;;
    fast)
        check_ignores
        cargo build --release
        cargo test -q
        ;;
    full)
        check_ignores
        cargo build --release
        cargo test -q
        cargo fmt --check
        cargo clippy --all-targets -- -D warnings
        # docs are CI-enforced: broken intra-doc links and missing docs
        # (lib.rs carries #![warn(missing_docs)]) fail the build.
        RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
        ;;
    *)
        echo "usage: ./verify.sh [fast|conformance]" >&2
        exit 2
        ;;
esac

echo "verify: OK ($mode)"
