//! Core types shared across the library: element identifiers, solutions,
//! feasibility constraints, and small numeric helpers used by the
//! algorithms and the metering code.

pub mod constraint;

pub use constraint::{Constraint, ConstraintCursor};

/// Ground-set element identifier. Instances index elements `0..n`.
pub type ElementId = u32;

/// A feasible solution: the selected elements (in selection order) and the
/// oracle value of the set.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    /// Selected elements, in the order the algorithm picked them.
    pub elements: Vec<ElementId>,
    /// `f(elements)` under the instance oracle.
    pub value: f64,
}

impl Solution {
    /// Empty solution of value zero.
    pub fn empty() -> Self {
        Solution { elements: Vec::new(), value: 0.0 }
    }

    /// Number of selected elements.
    pub fn len(&self) -> usize {
        self.elements.len()
    }

    /// True iff no element has been selected.
    pub fn is_empty(&self) -> bool {
        self.elements.is_empty()
    }

    /// The better (higher-value) of two solutions.
    pub fn max(self, other: Solution) -> Solution {
        if other.value > self.value {
            other
        } else {
            self
        }
    }
}

/// Errors surfaced by algorithms and the cluster simulator.
#[derive(Debug)]
pub enum Error {
    /// Cardinality bound `k` was zero or exceeded the ground-set size.
    InvalidK {
        /// The offending cardinality bound.
        k: usize,
        /// Ground-set size.
        n: usize,
    },
    /// An MRC memory budget was exceeded while `enforce_memory` was on.
    MemoryBudget {
        /// Name of the round that tripped the budget.
        round: String,
        /// Elements actually resident/received.
        used: usize,
        /// The budget in elements.
        budget: usize,
    },
    /// Artifact loading / PJRT execution failure.
    Runtime(String),
    /// Configuration error (bad TOML, unknown workload/algorithm name, ...).
    Config(String),
    /// A process-backend worker failed (died, timed out, sent a bad
    /// frame). Structured so the coordinator degrades cleanly instead of
    /// panicking; `worker` is the pool-local worker index.
    Worker {
        /// Pool-local worker index.
        worker: usize,
        /// What went wrong.
        message: String,
    },
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidK { k, n } => write!(f, "invalid cardinality k={k} for ground set n={n}"),
            Error::MemoryBudget { round, used, budget } => {
                write!(f, "round {round:?} exceeded MRC memory budget: used {used} > budget {budget}")
            }
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Worker { worker, message } => {
                write!(f, "worker {worker}: {message}")
            }
        }
    }
}

impl std::error::Error for Error {}

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Deterministically split a master seed into a per-purpose stream seed.
///
/// SplitMix64 finalizer — cheap, well mixed, and stable across platforms, so
/// every run with the same master seed reproduces bit-identically.
pub fn derive_seed(master: u64, stream: u64) -> u64 {
    let mut z = master ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// `(1 - 1/(t+1))^t` — the paper's approximation factor for the 2t-round
/// algorithm (Lemma 3), exposed so benches/tests compare against the exact
/// bound rather than a re-derived one.
pub fn threshold_bound(t: usize) -> f64 {
    1.0 - (1.0 - 1.0 / (t as f64 + 1.0)).powi(t as i32)
}

/// `1 - 1/e`, the sequential-greedy guarantee used as the reference ratio.
pub const ONE_MINUS_1_E: f64 = 1.0 - std::f64::consts::E.recip();

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_bound_matches_paper_values() {
        // t = 1 -> 1/2 (the 2-round bound); t = 2 -> 5/9 (the 4-round bound).
        assert!((threshold_bound(1) - 0.5).abs() < 1e-12);
        assert!((threshold_bound(2) - 5.0 / 9.0).abs() < 1e-12);
        // monotone increasing in t, converging to 1 - 1/e from below.
        let mut prev = 0.0;
        for t in 1..60 {
            let b = threshold_bound(t);
            assert!(b > prev, "bound must increase with t");
            assert!(b < ONE_MINUS_1_E, "bound stays below 1-1/e");
            prev = b;
        }
        assert!((threshold_bound(4000) - ONE_MINUS_1_E).abs() < 1e-4);
    }

    #[test]
    fn derive_seed_distinct_streams() {
        let s = 42;
        let a = derive_seed(s, 0);
        let b = derive_seed(s, 1);
        let c = derive_seed(s, 2);
        assert_ne!(a, b);
        assert_ne!(b, c);
        // deterministic
        assert_eq!(a, derive_seed(42, 0));
    }

    #[test]
    fn solution_max_prefers_higher_value() {
        let a = Solution { elements: vec![1], value: 1.0 };
        let b = Solution { elements: vec![2], value: 2.0 };
        assert_eq!(a.clone().max(b.clone()).elements, vec![2]);
        assert_eq!(b.clone().max(a).elements, vec![2]);
        assert!(Solution::empty().is_empty());
    }
}
