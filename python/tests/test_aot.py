"""AOT path: lowering produces parseable HLO text with the expected interface.

These tests exercise exactly what the Rust runtime consumes: the HLO text of
each artifact, its parameter count, and (via jax executing the same lowered
module) its numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import lower_artifacts, to_hlo_text
from compile.kernels.ref import facility_marginals_ref

jax.config.update("jax_platform_name", "cpu")

B, D = 256, 1024  # smaller D than prod to keep the test quick


def test_lower_artifacts_produces_all_three():
    texts = lower_artifacts(B, D)
    assert set(texts) == {"marginals", "update", "filter"}
    for name, text in texts.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_marginals_hlo_has_expected_signature():
    text = lower_artifacts(B, D)["marginals"]
    # two parameters, f32[256,1024] and f32[1024]
    assert f"f32[{B},{D}]" in text
    assert f"f32[{D}]" in text


def test_filter_hlo_emits_two_outputs():
    text = lower_artifacts(B, D)["filter"]
    # return_tuple=True: root is a tuple of (marginals, mask), both f32[B]
    assert f"(f32[{B}]{{0}}, f32[{B}]{{0}}) tuple" in text


def test_lowered_module_numerics_match_ref():
    """Execute the very module we serialize (via jax) and compare to ref."""
    rng = np.random.default_rng(0)
    sim = jnp.asarray(rng.uniform(size=(B, D)).astype(np.float32))
    cur = jnp.asarray(rng.uniform(size=(D,)).astype(np.float32))
    compiled = jax.jit(model.batch_marginals).lower(sim, cur).compile()
    (got,) = compiled(sim, cur)
    np.testing.assert_allclose(got, facility_marginals_ref(sim, cur), rtol=1e-5)


def test_hlo_text_is_stable_under_relower():
    """Same input shapes -> same HLO text (idempotent make artifacts)."""
    t1 = lower_artifacts(B, D)["update"]
    t2 = lower_artifacts(B, D)["update"]
    assert t1 == t2
