//! The refactor's equivalence contract, asserted end to end:
//!
//! 1. **Batched ≡ scalar.** Every algorithm must produce element-for-element
//!    identical selections whether the oracle serves marginals through its
//!    real block implementation or through the forced scalar fallback
//!    (`ScalarOnly` below suppresses every family's `marginals` override).
//! 2. **Backend independence.** `Serial` and `Rayon` execution backends
//!    must produce identical per-machine outputs, identical solutions, and
//!    identical `MrMetrics` accounting (memory, communication, oracle-call
//!    totals and the batched/scalar split) — wall time excepted.

use std::sync::Arc;

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::dense::DenseTwoRound;
use mrsub::algorithms::greedy::lazy_greedy;
use mrsub::algorithms::multi_round::MultiRound;
use mrsub::algorithms::mz_coreset::MzCoreset;
use mrsub::algorithms::randgreedi::RandGreeDi;
use mrsub::algorithms::sample_prune::SamplePrune;
use mrsub::algorithms::sparse::SparseTwoRound;
use mrsub::algorithms::stochastic::StochasticGreedy;
use mrsub::algorithms::threshold::{threshold_filter, threshold_greedy, threshold_greedy_scalar};
use mrsub::algorithms::two_round::TwoRoundKnownOpt;
use mrsub::algorithms::MrAlgorithm;
use mrsub::coordinator::run_experiment;
use mrsub::mapreduce::backend::BackendKind;
use mrsub::mapreduce::ClusterConfig;
use mrsub::oracle::concave::{ConcaveOverModularOracle, Phi};
use mrsub::oracle::modular::ModularOracle;
use mrsub::oracle::{Oracle, OracleState};
use mrsub::util::rng::Rng;
use mrsub::workload::adversarial::AdversarialGen;
use mrsub::workload::coverage::CoverageGen;
use mrsub::workload::facility::FacilityGen;
use mrsub::workload::graph::GraphGen;
use mrsub::workload::planted::PlantedCoverageGen;
use mrsub::workload::{Instance, WorkloadGen};

/// Decorator that hides the inner oracle's block `marginals` override, so
/// every batched call falls back to the trait's scalar loop — the
/// reference semantics the block implementations must reproduce.
struct ScalarOnly<O>(O);

impl<O: Oracle> Oracle for ScalarOnly<O> {
    fn ground_size(&self) -> usize {
        self.0.ground_size()
    }

    fn state(&self) -> Box<dyn OracleState> {
        Box::new(ScalarOnlyState(self.0.state()))
    }
}

struct ScalarOnlyState(Box<dyn OracleState>);

impl OracleState for ScalarOnlyState {
    fn value(&self) -> f64 {
        self.0.value()
    }

    fn marginal(&self, e: mrsub::ElementId) -> f64 {
        self.0.marginal(e)
    }

    fn insert(&mut self, e: mrsub::ElementId) {
        self.0.insert(e);
    }

    fn selected(&self) -> &[mrsub::ElementId] {
        self.0.selected()
    }

    fn clone_state(&self) -> Box<dyn OracleState> {
        Box::new(ScalarOnlyState(self.0.clone_state()))
    }

    fn reset(&mut self) {
        self.0.reset();
    }

    // NOTE: no `marginals` override — the default scalar loop applies.
}

/// One small instance per oracle family.
fn family_instances(seed: u64) -> Vec<Instance> {
    let mut rng = Rng::seed_from_u64(seed);
    let concave: Vec<Vec<(u32, f64)>> = (0..300)
        .map(|_| {
            (0..3)
                .map(|_| (rng.gen_range(0..40) as u32, rng.gen_range_f64(0.1, 2.0)))
                .collect()
        })
        .collect();
    let modular: Vec<f64> = (0..300).map(|_| rng.gen_range_f64(0.0, 10.0)).collect();
    vec![
        CoverageGen::new(400, 200, 5).generate(seed),
        FacilityGen::new(200, 60).generate(seed),
        GraphGen::erdos_renyi(250, 0.05).generate(seed),
        Instance::new(
            "concave",
            Arc::new(ConcaveOverModularOracle::new(300, 40, concave, Phi::Sqrt)),
        ),
        Instance::new("modular", Arc::new(ModularOracle::new(modular))),
        AdversarialGen::new(3, 30).generate(seed),
        PlantedCoverageGen::dense(10, 300, 600).generate(seed),
    ]
}

/// Every paper algorithm + baseline under test, with OPT-dependent ones
/// parameterized from `opt_hint`.
fn all_algorithms(opt_hint: f64) -> Vec<Box<dyn MrAlgorithm>> {
    vec![
        Box::new(TwoRoundKnownOpt::new(opt_hint)),
        Box::new(MultiRound::known(2, opt_hint)),
        Box::new(MultiRound::guessing(2, 0.25)),
        Box::new(DenseTwoRound::new(0.15)),
        Box::new(SparseTwoRound::new(0.15)),
        Box::new(CombinedTwoRound::new(0.15)),
        Box::new(RandGreeDi::default()),
        Box::new(MzCoreset),
        Box::new(SamplePrune::new(0.25)),
        Box::new(StochasticGreedy::new(0.1)),
    ]
}

fn cfg(seed: u64, backend: BackendKind) -> ClusterConfig {
    ClusterConfig { seed, backend: Some(backend), ..ClusterConfig::default() }
}

#[test]
fn batched_selections_identical_to_scalar_path() {
    for inst in family_instances(3) {
        let k = 12.min(inst.n);
        let opt_hint = inst
            .known_opt
            .unwrap_or_else(|| lazy_greedy(&inst.oracle, k).value)
            .max(1e-6);
        for alg in all_algorithms(opt_hint) {
            let c = cfg(9, BackendKind::Serial);
            let batched = alg.run(&inst.oracle, k, &c).expect("batched run");
            let scalar_oracle = ScalarOnly(Arc::clone(&inst.oracle));
            let scalar = alg.run(&scalar_oracle, k, &c).expect("scalar run");
            assert_eq!(
                batched.solution.elements, scalar.solution.elements,
                "{} on {}: batched selection diverged from scalar path",
                alg.name(),
                inst.name
            );
            assert_eq!(
                batched.solution.value.to_bits(),
                scalar.solution.value.to_bits(),
                "{} on {}: value bits diverged",
                alg.name(),
                inst.name
            );
        }
    }
}

#[test]
fn building_blocks_identical_to_scalar_path() {
    for inst in family_instances(5) {
        let oracle = &inst.oracle;
        let ids: Vec<mrsub::ElementId> = (0..oracle.ground_size() as mrsub::ElementId).collect();
        let mut st = oracle.state();
        st.insert(ids[ids.len() / 3]);
        st.insert(ids[ids.len() / 2]);
        let tau = st.marginal(ids[0]).max(0.4);

        // filter: block path vs per-element definition.
        let kept = threshold_filter(st.as_ref(), &ids, tau);
        let expect: Vec<_> = ids.iter().copied().filter(|&e| st.marginal(e) >= tau).collect();
        assert_eq!(kept, expect, "filter diverged on {}", inst.name);

        // greedy: block-lazy scan vs scalar reference scan.
        let mut st_a = st.clone_state();
        let mut st_b = st.clone_state();
        let a = threshold_greedy(st_a.as_mut(), &ids, tau, 15);
        let b = threshold_greedy_scalar(st_b.as_mut(), &ids, tau, 15);
        assert_eq!(a, b, "greedy selection diverged on {}", inst.name);
        assert_eq!(st_a.value().to_bits(), st_b.value().to_bits());
    }
}

#[test]
fn serial_and_rayon_backends_agree_on_outputs_and_metrics() {
    let backends =
        [BackendKind::Serial, BackendKind::Rayon { chunk: 1 }, BackendKind::Rayon { chunk: 4 }];
    for inst in family_instances(7).into_iter().take(4) {
        let k = 10.min(inst.n);
        let opt_hint = inst
            .known_opt
            .unwrap_or_else(|| lazy_greedy(&inst.oracle, k).value)
            .max(1e-6);
        for alg in all_algorithms(opt_hint) {
            let mut reference: Option<mrsub::coordinator::ExperimentRecord> = None;
            for backend in &backends {
                let rec = run_experiment(&inst, alg.as_ref(), k, &cfg(13, backend.clone()))
                    .expect("experiment");
                match &reference {
                    None => reference = Some(rec),
                    Some(r) => {
                        let label =
                            format!("{} on {} via {}", alg.name(), inst.name, backend.label());
                        assert_eq!(rec.value.to_bits(), r.value.to_bits(), "{label}: value");
                        assert_eq!(rec.oracle_calls, r.oracle_calls, "{label}: oracle calls");
                        assert_eq!(
                            rec.batched_oracle_calls, r.batched_oracle_calls,
                            "{label}: batched calls"
                        );
                        assert_eq!(rec.oracle_batches, r.oracle_batches, "{label}: batches");
                        assert_eq!(rec.communication, r.communication, "{label}: comm");
                        assert_eq!(
                            rec.peak_machine_memory, r.peak_machine_memory,
                            "{label}: peak mem"
                        );
                        assert_eq!(
                            rec.peak_central_recv, r.peak_central_recv,
                            "{label}: central recv"
                        );
                        assert_eq!(
                            rec.metrics.rounds.len(),
                            r.metrics.rounds.len(),
                            "{label}: round count"
                        );
                        for (a, b) in rec.metrics.rounds.iter().zip(&r.metrics.rounds) {
                            assert_eq!(a.name, b.name, "{label}: round name");
                            assert_eq!(a.machines, b.machines, "{label}: {} machines", a.name);
                            assert_eq!(
                                a.max_resident, b.max_resident,
                                "{label}: {} resident",
                                a.name
                            );
                            assert_eq!(a.total_sent, b.total_sent, "{label}: {} sent", a.name);
                            assert_eq!(
                                a.central_recv, b.central_recv,
                                "{label}: {} central",
                                a.name
                            );
                            assert_eq!(
                                a.oracle_calls, b.oracle_calls,
                                "{label}: {} calls",
                                a.name
                            );
                            assert_eq!(
                                a.batched_calls, b.batched_calls,
                                "{label}: {} batched",
                                a.name
                            );
                            assert_eq!(
                                a.oracle_batches, b.oracle_batches,
                                "{label}: {} batches",
                                a.name
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn block_path_carries_the_oracle_traffic() {
    // The point of the refactor: on the 2-round pipeline the batched share
    // of oracle traffic must dominate.
    let inst = CoverageGen::new(2000, 1000, 6).generate(2);
    let rec = run_experiment(
        &inst,
        &CombinedTwoRound::new(0.1),
        25,
        &cfg(4, BackendKind::Rayon { chunk: 1 }),
    )
    .expect("experiment");
    assert!(rec.oracle_batches > 0);
    assert!(
        rec.batched_oracle_calls * 2 > rec.oracle_calls,
        "batched {} of {} calls — block path must dominate",
        rec.batched_oracle_calls,
        rec.oracle_calls
    );
}
