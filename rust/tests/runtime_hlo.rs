//! PJRT runtime integration: load the AOT artifacts (built by
//! `make artifacts`), execute the compiled JAX/Pallas kernels from Rust,
//! and cross-check the accelerated oracle against the native one — the
//! end-to-end proof that L1 (Pallas) → L2 (jax) → HLO text → L3 (Rust
//! PJRT) compose with correct numerics.
//!
//! These tests require `artifacts/manifest.json`; `make test` builds it
//! first. They are skipped (pass vacuously, with a note) if absent so
//! plain `cargo test` works in a fresh checkout.

use std::sync::Arc;

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::greedy::lazy_greedy;
use mrsub::algorithms::MrAlgorithm;
use mrsub::mapreduce::ClusterConfig;
use mrsub::oracle::hlo::HloFacilityOracle;
use mrsub::oracle::{Oracle, OracleState};
use mrsub::runtime::{default_artifact_dir, MarginalsEngine};
use mrsub::workload::facility::FacilityGen;

fn engine() -> Option<Arc<MarginalsEngine>> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Arc::new(MarginalsEngine::load(&dir).expect("engine load")))
}

fn hlo_oracle(engine: Arc<MarginalsEngine>, n: usize, d: usize, seed: u64) -> HloFacilityOracle {
    let (n, d, sim) = FacilityGen::new(n, d).build_matrix(seed);
    HloFacilityOracle::new(n, d, sim, engine)
}

#[test]
fn engine_loads_and_reports_tiles() {
    let Some(engine) = engine() else { return };
    assert_eq!(engine.tile_b(), 256);
    assert_eq!(engine.tile_d(), 2048);
}

#[test]
fn batch_marginals_match_native_exactly_empty_state() {
    let Some(engine) = engine() else { return };
    let o = hlo_oracle(engine, 600, 400, 1);
    let st_h = o.state();
    let st_n = o.native().state();
    let es: Vec<u32> = (0..600).collect();
    let (mut mh, mut mn) = (vec![0.0; 600], vec![0.0; 600]);
    st_h.marginals(&es, &mut mh);
    st_n.marginals(&es, &mut mn);
    for (i, (a, b)) in mh.iter().zip(&mn).enumerate() {
        assert!((a - b).abs() < 1e-3, "e={i}: hlo {a} vs native {b}");
    }
}

#[test]
fn batch_marginals_match_after_insertions() {
    let Some(engine) = engine() else { return };
    let o = hlo_oracle(engine, 500, 700, 2); // d=700 forces padding to 2048
    let mut st_h = o.state();
    let mut st_n = o.native().state();
    for e in [5u32, 100, 499, 250] {
        st_h.insert(e);
        st_n.insert(e);
    }
    let es: Vec<u32> = (0..500).step_by(3).collect();
    let (mut mh, mut mn) = (vec![0.0; es.len()], vec![0.0; es.len()]);
    st_h.marginals(&es, &mut mh);
    st_n.marginals(&es, &mut mn);
    let max_err = mh.iter().zip(&mn).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
    assert!(max_err < 1e-3, "max err {max_err}");
    // members report zero
    let mut out = [0.0];
    st_h.marginals(&[100], &mut out);
    assert_eq!(out[0], 0.0);
}

#[test]
fn multi_tile_universe_accumulates() {
    let Some(engine) = engine() else { return };
    // d = 3000 > 2048 → two universe tiles.
    let o = hlo_oracle(engine, 300, 3000, 3);
    let st_h = o.state();
    let st_n = o.native().state();
    let es: Vec<u32> = (0..300).step_by(11).collect();
    let (mut mh, mut mn) = (vec![0.0; es.len()], vec![0.0; es.len()]);
    st_h.marginals(&es, &mut mh);
    st_n.marginals(&es, &mut mn);
    for (a, b) in mh.iter().zip(&mn) {
        assert!((a - b).abs() < 2e-3, "{a} vs {b}");
    }
}

#[test]
fn update_artifact_matches_native_update() {
    let Some(engine) = engine() else { return };
    let d = engine.tile_d();
    let mut row = vec![0.0f32; d];
    let mut cur = vec![0.0f32; d];
    for j in 0..d {
        row[j] = ((j * 37) % 100) as f32 / 100.0;
        cur[j] = ((j * 53) % 100) as f32 / 100.0;
    }
    let expect: Vec<f32> = row.iter().zip(&cur).map(|(a, b)| a.max(*b)).collect();
    engine.update_coverage(&row, &mut cur).unwrap();
    assert_eq!(cur, expect);
}

#[test]
fn greedy_through_hlo_oracle_matches_native_greedy() {
    let Some(engine) = engine() else { return };
    let o = hlo_oracle(engine, 400, 300, 4);
    let a = lazy_greedy(&o, 8);
    let b = lazy_greedy(o.native(), 8);
    assert_eq!(a.elements, b.elements, "selection paths must agree");
    assert!((a.value - b.value).abs() < 1e-3);
}

#[test]
fn full_mapreduce_job_over_hlo_oracle() {
    // The paper's headline algorithm running with its filter hot path on
    // the PJRT engine end to end.
    let Some(engine) = engine() else { return };
    let o = hlo_oracle(engine.clone(), 1200, 500, 5);
    let cfg = ClusterConfig { seed: 6, ..ClusterConfig::default() };
    let execs_before = engine.executions();
    let res = CombinedTwoRound::new(0.15).run(&o, 10, &cfg).unwrap();
    let g = lazy_greedy(o.native(), 10);
    assert!(
        res.solution.value >= (0.5 - 0.15) * g.value,
        "hlo-backed combined {} vs greedy {}",
        res.solution.value,
        g.value
    );
    assert!(engine.executions() > execs_before, "the PJRT engine must actually serve the job");
}
