//! Quickstart: generate a coverage instance, run the paper's headline
//! 2-round algorithm (Theorem 8), and compare against sequential greedy.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::greedy::lazy_greedy;
use mrsub::algorithms::MrAlgorithm;
use mrsub::mapreduce::ClusterConfig;
use mrsub::workload::coverage::CoverageGen;
use mrsub::workload::WorkloadGen;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 50k elements covering a 20k-item universe, ~12 items each.
    let inst = CoverageGen::new(50_000, 20_000, 12).generate(42);
    let k = 100;

    // The sequential 1−1/e reference.
    let greedy = lazy_greedy(&inst.oracle, k);
    println!("instance : {}", inst.name);
    println!("greedy   : f = {:.1}", greedy.value);

    // Theorem 8: 2 rounds, no duplication, no knowledge of OPT.
    let cfg = ClusterConfig { seed: 42, ..ClusterConfig::default() };
    let alg = CombinedTwoRound::new(0.1);
    let res = alg.run(&inst.oracle, k, &cfg)?;

    println!("{}  : f = {:.1}", alg.name(), res.solution.value);
    println!("vs greedy: {:.4} (guarantee: ≥ {:.2}·OPT)", res.solution.value / greedy.value, 0.5 - 0.1);
    println!(
        "cluster  : {} machines, {} rounds, sample {} elements",
        res.metrics.machines,
        res.metrics.rounds.len() - 1, // excluding the r0 partition round
        res.metrics.sample_size,
    );
    println!(
        "memory   : peak machine {} / budget {}, central recv {} / budget {}",
        res.metrics.peak_machine_memory(),
        res.metrics.machine_budget(),
        res.metrics.peak_central_recv(),
        res.metrics.central_budget(),
    );
    for r in &res.metrics.rounds {
        println!(
            "  {:<22} resident {:>7}  sent {:>7}  central {:>7}",
            r.name, r.max_resident, r.total_sent, r.central_recv
        );
    }
    Ok(())
}
