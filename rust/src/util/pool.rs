//! Persistent-thread parallel map — the rayon substitute for the cluster
//! simulator. A global pool of parked workers executes index-sharded jobs
//! through an atomic cursor (work-stealing by index), so per-round
//! dispatch costs ~µs instead of thread-spawn ~ms; output order matches
//! input order (the determinism contract the simulator's parallel==serial
//! tests assert). The submitting thread participates in the work, so the
//! pool can never deadlock on nested calls.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of worker threads to use (`MRSUB_THREADS` override, else
/// available parallelism).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("MRSUB_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// A type-erased index job: workers call `run(i)` for indices claimed from
/// the shared cursor in granules of `chunk` (chunked claiming amortizes the
/// atomic per cheap item while index-granular claiming load-balances skewed
/// items). The pointee lives on the submitting thread's stack; it is
/// guaranteed valid until `remaining` hits zero (the submitter spins until
/// then before returning).
struct IndexJob {
    /// Raw (possibly-dangling-after-completion) pointer to the work closure.
    work: *const (dyn Fn(usize) + Sync),
    cursor: AtomicUsize,
    n: usize,
    /// Indices claimed per cursor bump (>= 1).
    chunk: usize,
    /// Helpers still inside `run_all`.
    remaining: AtomicUsize,
}

// SAFETY: `work` points to a `Sync` closure; all dereferences happen while
// the submitting frame is alive (it blocks on `remaining`).
unsafe impl Send for IndexJob {}
unsafe impl Sync for IndexJob {}

impl IndexJob {
    fn run_all(&self) {
        loop {
            let lo = self.cursor.fetch_add(self.chunk, Ordering::Relaxed);
            if lo >= self.n {
                break;
            }
            for i in lo..(lo + self.chunk).min(self.n) {
                // SAFETY: pointer valid per the struct invariant.
                unsafe { (*self.work)(i) };
            }
        }
    }
}

struct PoolState {
    queue: Mutex<VecDeque<Arc<IndexJob>>>,
    available: Condvar,
}

fn pool() -> &'static PoolState {
    static POOL: OnceLock<&'static PoolState> = OnceLock::new();
    POOL.get_or_init(|| {
        let state: &'static PoolState = Box::leak(Box::new(PoolState {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
        }));
        let workers = num_threads().saturating_sub(1).max(1);
        for w in 0..workers {
            std::thread::Builder::new()
                .name(format!("mrsub-pool-{w}"))
                .spawn(move || loop {
                    let job = {
                        let mut q = state.queue.lock().expect("pool poisoned");
                        loop {
                            if let Some(job) = q.pop_front() {
                                break job;
                            }
                            q = state.available.wait(q).expect("pool poisoned");
                        }
                    };
                    job.run_all();
                    // last touch of `work`: release the helper slot.
                    job.remaining.fetch_sub(1, Ordering::Release);
                })
                .expect("spawn pool worker");
        }
        state
    })
}

/// Run `work(i)` for every `i < n`, sharded across the pool plus the
/// calling thread with `chunk`-granular work claiming. Blocks until all
/// indices are done. This is the execution primitive behind
/// [`crate::mapreduce::backend::Rayon`]; use `chunk = 1` for maximal load
/// balancing of skewed items.
pub fn run_indexed(n: usize, chunk: usize, work: &(dyn Fn(usize) + Sync)) {
    let chunk = chunk.max(1);
    let helpers = num_threads().saturating_sub(1).min(n.saturating_sub(1) / chunk);
    if helpers == 0 {
        for i in 0..n {
            work(i);
        }
        return;
    }
    let state = pool();
    // SAFETY: this erases the stack lifetime of `work`, which is sound
    // because the spin-join below never returns until every helper has
    // released its slot — no dereference can outlive the frame.
    let work_ptr: *const (dyn Fn(usize) + Sync + 'static) = unsafe {
        std::mem::transmute::<*const (dyn Fn(usize) + Sync + '_), *const (dyn Fn(usize) + Sync + 'static)>(
            work as *const (dyn Fn(usize) + Sync),
        )
    };
    let job = Arc::new(IndexJob {
        work: work_ptr,
        cursor: AtomicUsize::new(0),
        n,
        chunk,
        remaining: AtomicUsize::new(helpers),
    });
    {
        let mut q = state.queue.lock().expect("pool poisoned");
        for _ in 0..helpers {
            q.push_back(Arc::clone(&job));
        }
    }
    state.available.notify_all();
    // the caller works too — the pool can never starve the submitter.
    job.run_all();
    while job.remaining.load(Ordering::Acquire) != 0 {
        std::hint::spin_loop();
    }
}

/// Order-preserving indexed map over an arbitrary executor: `run` must
/// invoke the passed closure exactly once for every `i < n` (in any order,
/// from any threads) before returning; the result at position `i` is
/// `f(i)`.
///
/// This is the single home of the slot-writer `unsafe` — both
/// [`parallel_map`] and the backend layer
/// ([`crate::mapreduce::backend::map_indexed`]) funnel through it rather
/// than duplicating the raw-pointer write pattern.
pub fn map_indexed_with<R, E, F>(n: usize, run: E, f: F) -> Vec<R>
where
    R: Send,
    E: FnOnce(&(dyn Fn(usize) + Sync)),
    F: Fn(usize) -> R + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let out_ptr = SendPtr(out.as_mut_ptr());
    let out_ref = &out_ptr;
    let work = |i: usize| {
        let r = f(i);
        // SAFETY: the executor contract guarantees each index runs exactly
        // once, so the write is unaliased; `out` outlives `run`.
        unsafe { out_ref.write(i, Some(r)) };
    };
    run(&work);
    out.into_iter().map(|o| o.expect("executor ran every index")).collect()
}

/// Apply `f(index, &item)` to every item, in parallel when `parallel` is
/// true, preserving order. `f` must be `Sync` (shared read-only captures).
pub fn parallel_map<T, R, F>(items: &[T], parallel: bool, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if !parallel || n <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    map_indexed_with(n, |work| run_indexed(n, 1, work), |i| f(i, &items[i]))
}

/// Pointer wrapper asserting cross-thread transferability (see SAFETY in
/// [`map_indexed_with`]).
struct SendPtr<T>(*mut T);
// SAFETY: sharing the wrapper only shares the raw pointer value; every
// dereference goes through `write`, whose caller contract (exactly-once
// per index) makes the concurrent writes unaliased.
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// SAFETY: caller guarantees `i` is in bounds and unaliased.
    unsafe fn write(&self, i: usize, val: T) {
        // SAFETY: bounds and exclusivity are the caller's obligation
        // (documented on the fn); the pointee slot outlives the call.
        unsafe { *self.0.add(i) = val };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<u64> = (0..103).collect();
        let serial = parallel_map(&items, false, |i, &x| x * 2 + i as u64);
        let par = parallel_map(&items, true, |i, &x| x * 2 + i as u64);
        assert_eq!(serial, par);
        assert_eq!(serial[10], 30);
    }

    #[test]
    fn empty_and_singleton() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, true, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], true, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn skewed_workloads_complete() {
        let items: Vec<usize> = (0..64).collect();
        let out = parallel_map(&items, true, |_, &x| {
            // skew: item 0 does 1000x the work.
            let reps = if x == 0 { 100_000 } else { 100 };
            (0..reps).fold(0usize, |a, b| a.wrapping_add(b ^ x))
        });
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn repeated_rounds_reuse_the_pool() {
        // thousands of tiny rounds: spawn-per-call would take seconds.
        let items: Vec<u32> = (0..32).collect();
        let t0 = std::time::Instant::now();
        for _ in 0..2000 {
            let v = parallel_map(&items, true, |_, &x| x + 1);
            assert_eq!(v[31], 32);
        }
        assert!(t0.elapsed().as_secs_f64() < 5.0, "pool dispatch too slow");
    }

    #[test]
    fn nested_calls_do_not_deadlock() {
        let outer: Vec<u32> = (0..4).collect();
        let result = parallel_map(&outer, true, |_, &x| {
            let inner: Vec<u32> = (0..8).collect();
            parallel_map(&inner, true, |_, &y| y + x).iter().sum::<u32>()
        });
        assert_eq!(result.len(), 4);
        assert_eq!(result[1], 28 + 8);
    }

    #[test]
    fn threads_env_override() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn chunked_claiming_covers_every_index() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for chunk in [1usize, 3, 8, 64, 1000] {
            let n = 257;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            let work = |i: usize| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            };
            run_indexed(n, chunk, &work);
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} with chunk {chunk}");
            }
        }
    }
}
