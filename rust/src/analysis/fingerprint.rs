//! Wire-layout fingerprinting for the `wire-drift` lint.
//!
//! The bit-identity contract's versioning rule — any change to the frame
//! header, a message tag, or the byte layout of an existing message bumps
//! [`crate::mapreduce::wire::WIRE_VERSION`] — used to be convention. This
//! module makes it mechanical: the *declarations* that define the wire
//! layout (the [`ANCHORS`] list below) are extracted from the
//! comment-stripped source, whitespace-normalized, and folded through
//! FNV-1a 64 into a single fingerprint that is committed next to the code
//! ([`BLESSED_PATH`]). The lint fails when the fingerprint moves without
//! the version (drift), or the version moves without a re-bless.
//!
//! Comment and whitespace edits inside the declarations do **not** change
//! the fingerprint — only token-level edits do. `WIRE_VERSION`'s own value
//! is deliberately *excluded* from the hash (it is recorded separately in
//! the blessed file), so that bumping it never masks a layout change.
//!
//! `python/tools/wire_fingerprint.py` mirrors this algorithm byte-for-byte
//! so the blessed file can be (re)generated without a Rust toolchain; keep
//! the two implementations in lock-step.

use std::fs;
use std::io;
use std::path::Path;

use crate::analysis::scan;

/// Repo-relative path of the committed blessed fingerprint.
pub const BLESSED_PATH: &str = "rust/src/analysis/wire.blessed";

/// The declarations whose token stream defines the wire layout, in hash
/// order: `(repo-relative file, anchor)`. The anchor must start the item
/// (`pub enum …` / `pub const …`) in the comment-stripped source.
pub const ANCHORS: &[(&str, &str)] = &[
    ("rust/src/mapreduce/wire.rs", "pub const FRAME_MAGIC"),
    ("rust/src/mapreduce/wire.rs", "const HEADER_LEN"),
    ("rust/src/mapreduce/wire.rs", "pub struct GuessFilter"),
    ("rust/src/mapreduce/wire.rs", "pub enum RoundTask"),
    ("rust/src/mapreduce/wire.rs", "pub enum TaskReply"),
    ("rust/src/mapreduce/wire.rs", "pub struct WorkerInit"),
    ("rust/src/mapreduce/wire.rs", "pub enum ToWorker"),
    ("rust/src/mapreduce/wire.rs", "pub enum FromWorker"),
    ("rust/src/mapreduce/wire.rs", "pub enum ClientRequest"),
    ("rust/src/mapreduce/wire.rs", "pub enum ClientResponse"),
    ("rust/src/core/constraint.rs", "pub enum Constraint"),
    ("rust/src/oracle/spec.rs", "pub enum OracleSpec"),
];

/// The committed (version, fingerprint) pair a tree is checked against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blessed {
    /// `WIRE_VERSION` at bless time.
    pub version: u16,
    /// [`tree_fingerprint`] at bless time.
    pub fingerprint: u64,
}

fn inv(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01B3;

fn fnv1a64(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Compute the wire fingerprint of the tree at `root`: for every anchor,
/// extract its item span from the comment-stripped source, remove all
/// whitespace, and fold `anchor + "=" + span + "\n"` through FNV-1a 64.
pub fn tree_fingerprint(root: &Path) -> io::Result<u64> {
    let mut cache: Vec<(&str, String)> = Vec::new();
    let mut h = FNV_OFFSET;
    for &(file, anchor) in ANCHORS {
        if !cache.iter().any(|(f, _)| *f == file) {
            let src = fs::read_to_string(root.join(file))
                .map_err(|e| inv(format!("wire fingerprint: read {file}: {e}")))?;
            cache.push((file, scan::scan(&src).stripped));
        }
        let stripped = &cache.iter().find(|(f, _)| *f == file).expect("just cached").1;
        let span = scan::extract_item(stripped, anchor)
            .ok_or_else(|| inv(format!("wire fingerprint: anchor {anchor:?} not in {file}")))?;
        let normalized: String = span.split_whitespace().collect();
        h = fnv1a64(h, anchor.as_bytes());
        h = fnv1a64(h, b"=");
        h = fnv1a64(h, normalized.as_bytes());
        h = fnv1a64(h, b"\n");
    }
    Ok(h)
}

/// Read the current `WIRE_VERSION` value out of the tree's wire.rs.
pub fn tree_wire_version(root: &Path) -> io::Result<u16> {
    let file = "rust/src/mapreduce/wire.rs";
    let src = fs::read_to_string(root.join(file))
        .map_err(|e| inv(format!("wire version: read {file}: {e}")))?;
    let stripped = scan::scan(&src).stripped;
    let span = scan::extract_item(&stripped, "pub const WIRE_VERSION")
        .ok_or_else(|| inv(format!("wire version: `pub const WIRE_VERSION` not in {file}")))?;
    let normalized: String = span.split_whitespace().collect();
    let value = normalized
        .split('=')
        .nth(1)
        .map(|v| v.trim_end_matches(';'))
        .ok_or_else(|| inv(format!("wire version: malformed declaration {normalized:?}")))?;
    value.parse::<u16>().map_err(|_| inv(format!("wire version: not a u16: {value:?}")))
}

/// Parse the committed blessed file of the tree at `root`.
pub fn read_blessed(root: &Path) -> io::Result<Blessed> {
    let text = fs::read_to_string(root.join(BLESSED_PATH))
        .map_err(|e| inv(format!("no blessed wire fingerprint at {BLESSED_PATH} ({e}); \
                                  run `mrsub check-invariants --bless`")))?;
    let mut version: Option<u16> = None;
    let mut fingerprint: Option<u64> = None;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| inv(format!("{BLESSED_PATH}: malformed line {line:?}")))?;
        match (key.trim(), value.trim()) {
            ("wire_version", v) => {
                version = Some(v.parse().map_err(|_| {
                    inv(format!("{BLESSED_PATH}: bad wire_version {v:?}"))
                })?);
            }
            ("fingerprint", v) => {
                let hex = v.strip_prefix("0x").unwrap_or(v);
                fingerprint = Some(u64::from_str_radix(hex, 16).map_err(|_| {
                    inv(format!("{BLESSED_PATH}: bad fingerprint {v:?}"))
                })?);
            }
            (k, _) => return Err(inv(format!("{BLESSED_PATH}: unknown key {k:?}"))),
        }
    }
    match (version, fingerprint) {
        (Some(version), Some(fingerprint)) => Ok(Blessed { version, fingerprint }),
        _ => Err(inv(format!("{BLESSED_PATH}: missing wire_version or fingerprint"))),
    }
}

/// Write the blessed file for the tree at `root`.
pub fn write_blessed(root: &Path, blessed: Blessed) -> io::Result<()> {
    let text = format!(
        "# Blessed wire-layout fingerprint (`wire-drift` lint, `mrsub check-invariants`).\n\
         # Covers the declarations listed in rust/src/analysis/fingerprint.rs. Do not\n\
         # edit by hand: bump WIRE_VERSION in rust/src/mapreduce/wire.rs, then run\n\
         # `mrsub check-invariants --bless` (refused unless the version moved too).\n\
         wire_version = {}\n\
         fingerprint = {:#018x}\n",
        blessed.version, blessed.fingerprint
    );
    fs::write(root.join(BLESSED_PATH), text)
}

/// Re-record the blessed (version, fingerprint) pair for the tree at
/// `root`. Refused when the fingerprint moved but `WIRE_VERSION` did not —
/// blessing must never be the path of least resistance around a bump.
pub fn bless(root: &Path) -> io::Result<String> {
    let fingerprint = tree_fingerprint(root)?;
    let version = tree_wire_version(root)?;
    if let Ok(old) = read_blessed(root) {
        if old.fingerprint != fingerprint && old.version == version {
            return Err(inv(format!(
                "refusing to bless: wire definitions changed but WIRE_VERSION is still \
                 {version}; bump it in rust/src/mapreduce/wire.rs first"
            )));
        }
        if old.fingerprint == fingerprint && old.version == version {
            return Ok(format!(
                "blessed fingerprint already current (wire_version {version}, {fingerprint:#018x})"
            ));
        }
    }
    write_blessed(root, Blessed { version, fingerprint })?;
    Ok(format!("blessed wire fingerprint {fingerprint:#018x} at wire_version {version}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_vector() {
        // FNV-1a 64 of "a" from the standard offset basis.
        assert_eq!(fnv1a64(FNV_OFFSET, b"a"), 0xAF63_DC4C_8601_EC8C);
    }

    #[test]
    fn repo_anchors_all_resolve() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let fp = tree_fingerprint(root).expect("every anchor resolves in the repo tree");
        assert_ne!(fp, 0);
        let v = tree_wire_version(root).expect("WIRE_VERSION parses");
        assert_eq!(v, crate::mapreduce::wire::WIRE_VERSION);
    }

    #[test]
    fn fingerprint_ignores_comments_and_whitespace_only() {
        let dir = std::env::temp_dir()
            .join(format!("mrsub-fp-{}-{}", std::process::id(), line!()));
        let _ = std::fs::remove_dir_all(&dir);
        let write = |wire: &str| {
            std::fs::create_dir_all(dir.join("rust/src/mapreduce")).unwrap();
            std::fs::create_dir_all(dir.join("rust/src/oracle")).unwrap();
            std::fs::write(dir.join("rust/src/mapreduce/wire.rs"), wire).unwrap();
            std::fs::write(
                dir.join("rust/src/oracle/spec.rs"),
                "pub enum OracleSpec { Modular { weights: Vec<f64> } }\n",
            )
            .unwrap();
        };
        let base = "pub const WIRE_VERSION: u16 = 1;\n\
                    pub const FRAME_MAGIC: [u8; 4] = *b\"MRSB\";\n\
                    const HEADER_LEN: usize = 4 + 2 + 4;\n\
                    pub struct GuessFilter { pub id: u32 }\n\
                    pub enum RoundTask { Filter { tau: f64 } }\n\
                    pub enum TaskReply { Ids(Vec<u32>) }\n\
                    pub struct WorkerInit { pub arena: bool }\n\
                    pub enum ToWorker { Init }\n\
                    pub enum FromWorker { Ready }\n\
                    pub enum ClientRequest { ListJobs }\n\
                    pub enum ClientResponse { ShuttingDown }\n";
        write(base);
        let fp0 = tree_fingerprint(&dir).unwrap();

        // comment + whitespace churn inside the declarations: no drift.
        let churned = base
            .replace(
                "pub enum RoundTask { Filter { tau: f64 } }",
                "pub enum RoundTask {\n    // a filter round\n    Filter {\n        tau: f64,\n    },\n}",
            )
            .replace("const HEADER_LEN: usize = 4 + 2 + 4;", "const HEADER_LEN:usize=4+2+4;");
        write(&churned);
        assert_eq!(tree_fingerprint(&dir).unwrap(), fp0, "comment/whitespace churn drifted");

        // a token-level change (new variant) must drift.
        write(&base.replace("{ Ids(Vec<u32>) }", "{ Ids(Vec<u32>), Ack }"));
        assert_ne!(tree_fingerprint(&dir).unwrap(), fp0, "layout change did not drift");

        // bumping WIRE_VERSION alone must NOT drift (version is excluded).
        write(&base.replace("WIRE_VERSION: u16 = 1", "WIRE_VERSION: u16 = 2"));
        assert_eq!(tree_fingerprint(&dir).unwrap(), fp0, "version value leaked into the hash");
        assert_eq!(tree_wire_version(&dir).unwrap(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn blessed_file_roundtrip_and_refusal() {
        let dir = std::env::temp_dir()
            .join(format!("mrsub-bless-{}-{}", std::process::id(), line!()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("rust/src/analysis")).unwrap();
        let b = Blessed { version: 4, fingerprint: 0xDEAD_BEEF_1234_5678 };
        write_blessed(&dir, b).unwrap();
        assert_eq!(read_blessed(&dir).unwrap(), b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn comment_edits_do_not_change_the_repo_fingerprint_inputs() {
        // the RoundTask declaration in the real tree is comment-heavy;
        // extraction + normalization must give one whitespace-free span.
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let src =
            std::fs::read_to_string(root.join("rust/src/mapreduce/wire.rs")).unwrap();
        let stripped = scan::scan(&src).stripped;
        let span = scan::extract_item(&stripped, "pub enum RoundTask").unwrap();
        let norm: String = span.split_whitespace().collect();
        assert!(norm.starts_with("pubenumRoundTask{"));
        assert!(norm.contains("AdoptMachines{"));
        assert!(!norm.contains("//"), "comments survived stripping");
    }
}
