//! Minimal JSON: a value type, a pretty emitter, and a recursive-descent
//! parser. Serves three needs: writing experiment reports, reading the
//! AOT artifact manifest, and decoding client-supplied job payloads on
//! the serving path. Supports the full JSON grammar, including `\uXXXX`
//! surrogate pairs (a high surrogate must be followed by a low one; a
//! lone or mismatched surrogate is a structured parse error, never a
//! silent U+FFFD).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// Object with stable (insertion-independent) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer accessor (checked truncation).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Pretty-print with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Compact form.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !xs.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing input at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => out.push(self.unicode_escape()?),
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                Some(_) => {
                    // advance one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    /// Four hex digits of a `\uXXXX` escape (the `\u` is already consumed).
    fn hex4(&mut self) -> Result<u32, String> {
        let hex = self
            .bytes
            .get(self.pos..self.pos + 4)
            .ok_or("bad \\u escape")?;
        let code =
            u32::from_str_radix(std::str::from_utf8(hex).map_err(|_| "bad \\u")?, 16)
                .map_err(|_| "bad \\u")?;
        self.pos += 4;
        Ok(code)
    }

    /// Decode one `\uXXXX` escape into a character, pairing UTF-16
    /// surrogates: a high surrogate must be immediately followed by a
    /// `\uXXXX` low surrogate and the pair combines into one supplementary
    /// code point. A lone or mismatched surrogate is a parse error — the
    /// old behavior of emitting U+FFFD silently corrupted every non-BMP
    /// character shipped as an escaped pair.
    fn unicode_escape(&mut self) -> Result<char, String> {
        let at = self.pos - 2; // byte offset of the `\`
        let hi = self.hex4()?;
        if (0xDC00..=0xDFFF).contains(&hi) {
            return Err(format!("lone low surrogate \\u{hi:04x} at byte {at}"));
        }
        if (0xD800..=0xDBFF).contains(&hi) {
            if self.peek() != Some(b'\\') || self.bytes.get(self.pos + 1) != Some(&b'u') {
                return Err(format!(
                    "high surrogate \\u{hi:04x} at byte {at} not followed by a \\u low surrogate"
                ));
            }
            self.pos += 2;
            let lo = self.hex4()?;
            if !(0xDC00..=0xDFFF).contains(&lo) {
                return Err(format!(
                    "high surrogate \\u{hi:04x} at byte {at} followed by \\u{lo:04x}, \
                     which is not a low surrogate"
                ));
            }
            let code = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
            return char::from_u32(code)
                .ok_or_else(|| format!("bad surrogate pair \\u{hi:04x}\\u{lo:04x} at byte {at}"));
        }
        // non-surrogate BMP scalar: always a valid char.
        char::from_u32(hi).ok_or_else(|| format!("bad \\u{hi:04x} at byte {at}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Json::obj([
            ("name", Json::Str("two-round".into())),
            ("ratio", Json::Num(0.5)),
            ("rounds", Json::Num(2.0)),
            ("ok", Json::Bool(true)),
            ("tags", Json::Arr(vec![Json::Str("a\"b".into()), Json::Null])),
        ]);
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, v, "roundtrip failed for {text}");
        }
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{ "b": 256, "d": 2048, "dtype": "f32",
                        "artifacts": {"marginals": "marginals.hlo.txt"} }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("b").unwrap().as_usize(), Some(256));
        assert_eq!(v.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(
            v.get("artifacts").unwrap().get("marginals").unwrap().as_str(),
            Some("marginals.hlo.txt")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""\q""#).is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \n tab\t""#).unwrap();
        assert_eq!(v.as_str(), Some("café \n tab\t"));
        let s = Json::Str("née\u{1}".into()).to_string_compact();
        assert!(s.contains("\\u0001"));
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("née\u{1}"));
    }

    #[test]
    fn surrogate_pairs_decode_to_real_code_points() {
        // U+1D11E MUSICAL SYMBOL G CLEF, escaped as a UTF-16 pair.
        assert_eq!(Json::parse(r#""\ud834\udd1e""#).unwrap().as_str(), Some("𝄞"));
        // U+1F680 ROCKET, upper- and lower-case hex digits both accepted.
        assert_eq!(Json::parse(r#""\uD83D\uDE80""#).unwrap().as_str(), Some("🚀"));
        // pairs mixed with surrounding text and other escapes.
        assert_eq!(
            Json::parse(r#""ok \ud834\udd1e\tend""#).unwrap().as_str(),
            Some("ok 𝄞\tend")
        );
        // raw (unescaped) non-BMP characters still pass through.
        assert_eq!(Json::parse("\"🚀\"").unwrap().as_str(), Some("🚀"));
    }

    #[test]
    fn non_bmp_strings_roundtrip_emit_to_parse() {
        for s in ["𝄞", "🚀 launch", "mix 𝄞 and café", "👩‍🔬"] {
            let v = Json::obj([("s", Json::Str(s.into()))]);
            for text in [v.to_string_pretty(), v.to_string_compact()] {
                let back = Json::parse(&text).unwrap();
                assert_eq!(back.get("s").unwrap().as_str(), Some(s), "roundtrip of {s:?}");
            }
        }
    }

    #[test]
    fn lone_or_mismatched_surrogates_are_structured_errors() {
        // lone high surrogate (end of string, plain char, or non-escape).
        for text in [
            r#""\ud834""#,
            r#""\ud834x""#,
            r#""\ud834\n""#,
            // high followed by a non-surrogate escape.
            r#""\ud834A""#,
            // high followed by another high.
            r#""\ud834\ud834""#,
            // lone low surrogate.
            r#""\udd1e""#,
        ] {
            let err = Json::parse(text).unwrap_err();
            assert!(err.contains("surrogate"), "error for {text} must name the surrogate: {err}");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.25).to_string_compact(), "3.25");
    }
}
