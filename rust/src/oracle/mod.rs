//! Value-oracle abstraction for monotone submodular functions.
//!
//! Every algorithm in the paper interacts with `f` exclusively through
//! marginal queries `f_G(e) = f(G ∪ {e}) − f(G)`, so the central abstraction
//! is an *incremental evaluation state* ([`OracleState`]): it carries the
//! current set `G`, answers marginals in the family's natural incremental
//! complexity (e.g. O(deg) for coverage instead of O(|G|·deg)), and supports
//! O(1)-amortized insertion.
//!
//! [`Oracle`] is the immutable instance: the data defining `f` plus a
//! factory for fresh states. Oracles keep their data behind `Arc` so states
//! are `'static` and cheap to fan out across simulated machines.
//!
//! ## The block-marginal API
//!
//! Batched evaluation ([`OracleState::marginals`]) is the *primary* query
//! interface: every hot loop in `algorithms/` (threshold filter/greedy,
//! stochastic sampling, top-singleton scans) drives the oracle in blocks of
//! [`MARGINAL_BLOCK`] candidates, and every oracle family implements a real
//! SoA/block evaluation rather than the scalar fallback — per-element gain
//! kernels are shared between the scalar and block paths so the two return
//! **bit-identical** f64 values (the contract `tests/batch_equivalence.rs`
//! asserts). Accelerated backends (the PJRT `MarginalsEngine` behind the
//! `xla` feature) slot in as just another implementation of the same block
//! method.
//!
//! [`StatePool`] recycles evaluation states across simulated machines and
//! rounds, so per-round state construction (and its O(universe) allocation)
//! drops out of the round hot path.

use std::sync::Mutex;

use crate::core::ElementId;

/// Preferred candidate-block size for [`OracleState::marginals`] callers.
/// Matches the AOT tile of the PJRT engine so accelerated oracles get full
/// device tiles; the native backends are insensitive to the exact value as
/// long as blocks amortize the virtual dispatch.
pub const MARGINAL_BLOCK: usize = 256;

pub mod adversarial;
pub mod concave;
pub mod counting;
pub mod coverage;
pub mod cut;
pub mod dicut;
pub mod facility;
#[cfg(feature = "xla")]
pub mod hlo;
pub mod modular;
pub mod spec;

pub use counting::{CountingOracle, OracleCounters};

/// A monotone submodular instance `f : 2^V -> R_{>=0}` with `V = 0..n`.
pub trait Oracle: Send + Sync {
    /// Ground-set size `n = |V|`.
    fn ground_size(&self) -> usize;

    /// Fresh evaluation state positioned at `G = ∅`.
    fn state(&self) -> Box<dyn OracleState>;

    /// `f(S)` evaluated from scratch (default: replay into a fresh state).
    fn value(&self, set: &[ElementId]) -> f64 {
        let mut st = self.state();
        for &e in set {
            st.insert(e);
        }
        st.value()
    }

    /// Singleton value `f({e})`.
    fn singleton(&self, e: ElementId) -> f64 {
        self.state().marginal(e)
    }

    /// A cheap upper bound on `OPT_k` used by tests and OPT-guessing:
    /// `k · max_e f({e})` (valid for any monotone submodular `f`).
    ///
    /// Drives the singleton scan through the block-marginal path so
    /// OPT-guessing is served by the batched backends instead of `n`
    /// scalar calls.
    fn opt_upper_bound(&self, k: usize) -> f64 {
        let st = self.state();
        let n = self.ground_size() as ElementId;
        // fixed per-block id/result buffers: no O(n) allocation.
        let mut ids = [0 as ElementId; MARGINAL_BLOCK];
        let mut buf = [0.0f64; MARGINAL_BLOCK];
        let mut best: f64 = 0.0;
        let mut start: ElementId = 0;
        while start < n {
            let len = ((n - start) as usize).min(MARGINAL_BLOCK);
            for (i, slot) in ids[..len].iter_mut().enumerate() {
                *slot = start + i as ElementId;
            }
            st.marginals(&ids[..len], &mut buf[..len]);
            for &v in &buf[..len] {
                best = best.max(v);
            }
            start += len as ElementId;
        }
        best * k as f64
    }
}

/// Incremental evaluation state: the current set `G`, its value, and
/// marginal queries against it.
///
/// `Sync` is required so a single frozen state (e.g. the shared `G₀` of
/// Algorithm 4) can serve read-only marginal queries from all simulated
/// machines in parallel.
pub trait OracleState: Send + Sync {
    /// `f(G)` for the current set.
    fn value(&self) -> f64;

    /// Marginal gain `f_G(e)`. Must return 0 for `e ∈ G` (idempotence).
    fn marginal(&self, e: ElementId) -> f64;

    /// Add `e` to `G`. Inserting an element twice is a no-op.
    fn insert(&mut self, e: ElementId);

    /// The current set `G` in insertion order.
    fn selected(&self) -> &[ElementId];

    /// Deep copy (used when an algorithm forks a partial solution across
    /// guesses or simulated machines).
    fn clone_state(&self) -> Box<dyn OracleState>;

    /// Return to `G = ∅` in place, retaining allocations — the reuse hook
    /// behind [`StatePool`]. Must leave the state indistinguishable from a
    /// fresh [`Oracle::state`].
    fn reset(&mut self);

    /// Batched marginals — the primary query path of every algorithm hot
    /// loop (threshold filter/greedy, stochastic sampling, singleton
    /// scans). The default loops over [`OracleState::marginal`]; every
    /// in-repo family overrides it with a real block evaluation sharing
    /// the scalar path's per-element kernel (bit-identical results), and
    /// accelerated oracles (PJRT) serve one device call per block.
    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(es.len(), out.len());
        for (o, &e) in out.iter_mut().zip(es) {
            *o = self.marginal(e);
        }
    }

    /// Number of selected elements (convenience).
    fn len(&self) -> usize {
        self.selected().len()
    }

    /// True iff `G = ∅`.
    fn is_empty(&self) -> bool {
        self.selected().is_empty()
    }
}

impl<T: Oracle + ?Sized> Oracle for std::sync::Arc<T> {
    fn ground_size(&self) -> usize {
        (**self).ground_size()
    }
    fn state(&self) -> Box<dyn OracleState> {
        (**self).state()
    }
    fn value(&self, set: &[ElementId]) -> f64 {
        (**self).value(set)
    }
    fn singleton(&self, e: ElementId) -> f64 {
        (**self).singleton(e)
    }
    fn opt_upper_bound(&self, k: usize) -> f64 {
        (**self).opt_upper_bound(k)
    }
}

impl<T: Oracle + ?Sized> Oracle for &T {
    fn ground_size(&self) -> usize {
        (**self).ground_size()
    }
    fn state(&self) -> Box<dyn OracleState> {
        (**self).state()
    }
    fn value(&self, set: &[ElementId]) -> f64 {
        (**self).value(set)
    }
    fn singleton(&self, e: ElementId) -> f64 {
        (**self).singleton(e)
    }
    fn opt_upper_bound(&self, k: usize) -> f64 {
        (**self).opt_upper_bound(k)
    }
}

/// Recycles [`OracleState`]s across simulated machines and rounds.
///
/// Worker rounds used to allocate a fresh state (and its O(universe)
/// buffers) per machine per round; the pool hands out reset states
/// instead. [`StatePool::acquire`] returns a guard that releases the state
/// back to the pool on drop, after [`OracleState::reset`] — so a pooled
/// acquire is indistinguishable from `oracle.state()` (asserted by tests)
/// while reusing the covered-bitmap / coverage-vector allocations.
///
/// Thread-safe: acquire/release from any worker thread (the free list is a
/// mutex-guarded stack; contention is one lock op per machine per round,
/// negligible next to the round body).
pub struct StatePool<'a> {
    oracle: &'a dyn Oracle,
    free: Mutex<Vec<Box<dyn OracleState>>>,
}

impl<'a> StatePool<'a> {
    /// New empty pool over `oracle`.
    pub fn new(oracle: &'a dyn Oracle) -> Self {
        StatePool { oracle, free: Mutex::new(Vec::new()) }
    }

    /// Take a state positioned at `G = ∅` (recycled if available).
    pub fn acquire(&self) -> PooledState<'_, 'a> {
        let state = self.free.lock().expect("state pool poisoned").pop();
        let state = state.unwrap_or_else(|| self.oracle.state());
        PooledState { pool: self, state: Some(state) }
    }

    /// States currently parked in the pool (for tests/metrics).
    pub fn idle(&self) -> usize {
        self.free.lock().expect("state pool poisoned").len()
    }
}

/// Guard over a pooled state; derefs to `dyn OracleState` and returns the
/// reset state to the pool on drop.
pub struct PooledState<'p, 'a> {
    pool: &'p StatePool<'a>,
    state: Option<Box<dyn OracleState>>,
}

impl std::ops::Deref for PooledState<'_, '_> {
    type Target = dyn OracleState;

    fn deref(&self) -> &Self::Target {
        self.state.as_deref().expect("pooled state present until drop")
    }
}

impl std::ops::DerefMut for PooledState<'_, '_> {
    fn deref_mut(&mut self) -> &mut Self::Target {
        self.state.as_deref_mut().expect("pooled state present until drop")
    }
}

impl Drop for PooledState<'_, '_> {
    fn drop(&mut self) {
        if let Some(mut state) = self.state.take() {
            state.reset();
            self.pool.free.lock().expect("state pool poisoned").push(state);
        }
    }
}

/// Shared helper: track selection order + membership for states.
#[derive(Debug, Clone, Default)]
pub(crate) struct Selection {
    order: Vec<ElementId>,
    member: Vec<bool>,
}

impl Selection {
    pub fn new(n: usize) -> Self {
        Selection { order: Vec::new(), member: vec![false; n] }
    }

    /// Returns true if `e` was newly inserted.
    pub fn insert(&mut self, e: ElementId) -> bool {
        let i = e as usize;
        if self.member[i] {
            return false;
        }
        self.member[i] = true;
        self.order.push(e);
        true
    }

    pub fn contains(&self, e: ElementId) -> bool {
        self.member[e as usize]
    }

    pub fn order(&self) -> &[ElementId] {
        &self.order
    }

    /// Back to the empty selection, keeping the membership allocation.
    pub fn clear(&mut self) {
        for &e in &self.order {
            self.member[e as usize] = false;
        }
        self.order.clear();
    }
}

#[cfg(test)]
pub(crate) mod axioms {
    //! Reusable oracle-axiom checks shared by per-family tests and proptest
    //! suites: monotonicity, submodularity, idempotence, state/scratch
    //! consistency.

    use super::*;
    use crate::util::rng::Rng;

    /// Check the four oracle axioms on random chains A ⊆ B and probes e.
    pub fn check_axioms(oracle: &dyn Oracle, seed: u64, trials: usize) {
        let n = oracle.ground_size();
        assert!(n >= 3, "axiom check needs n >= 3");
        let mut rng = Rng::seed_from_u64(seed);
        let ids: Vec<ElementId> = (0..n as ElementId).collect();
        for trial in 0..trials {
            let mut perm = ids.clone();
            rng.shuffle(&mut perm);
            let b_len = rng.gen_range(1..n.min(24) + 1);
            let a_len = rng.gen_range(0..b_len);
            let (b_set, rest) = perm.split_at(b_len);
            let a_set = &b_set[..a_len];

            let mut st_a = oracle.state();
            for &e in a_set {
                st_a.insert(e);
            }
            let mut st_b = oracle.state();
            for &e in b_set {
                st_b.insert(e);
            }

            // monotone: values non-negative and non-decreasing along chain.
            assert!(st_a.value() >= -1e-9, "f must be non-negative");
            assert!(
                st_b.value() >= st_a.value() - 1e-9,
                "monotonicity violated: f(B)={} < f(A)={} (trial {trial})",
                st_b.value(),
                st_a.value()
            );

            // probe elements outside B.
            for &e in rest.iter().take(8) {
                let ma = st_a.marginal(e);
                let mb = st_b.marginal(e);
                assert!(mb >= -1e-9, "marginal must be non-negative (monotone f)");
                assert!(
                    ma >= mb - 1e-6 * (1.0 + ma.abs()),
                    "submodularity violated at e={e}: f_A(e)={ma} < f_B(e)={mb} (trial {trial})"
                );
                // marginal consistency: inserting e yields exactly value + marginal.
                let mut st_a2 = st_a.clone_state();
                st_a2.insert(e);
                let err = (st_a2.value() - (st_a.value() + ma)).abs();
                assert!(
                    err <= 1e-6 * (1.0 + st_a2.value().abs()),
                    "insert/marginal mismatch: {err}"
                );
            }

            // idempotence: marginal of a member is 0, re-insert is a no-op.
            if let Some(&e) = b_set.first() {
                assert!(st_b.marginal(e).abs() <= 1e-9, "member marginal must be 0");
                let v = st_b.value();
                st_b.insert(e);
                assert!((st_b.value() - v).abs() <= 1e-12, "re-insert changed value");
            }

            // scratch evaluation agrees with incremental state.
            let direct = oracle.value(b_set);
            let mut st = oracle.state();
            for &e in b_set {
                st.insert(e);
            }
            assert!(
                (direct - st.value()).abs() <= 1e-6 * (1.0 + direct.abs()),
                "value() vs state mismatch: {direct} vs {}",
                st.value()
            );

            // batch marginals are bit-identical to scalar marginals (the
            // block path shares the scalar per-element kernel).
            let probes: Vec<ElementId> = rest.iter().take(8).copied().collect();
            let mut batch = vec![0.0; probes.len()];
            st_a.marginals(&probes, &mut batch);
            for (i, &e) in probes.iter().enumerate() {
                assert_eq!(
                    batch[i].to_bits(),
                    st_a.marginal(e).to_bits(),
                    "batch marginal mismatch at {e} (trial {trial})"
                );
            }

            // reset leaves the state indistinguishable from a fresh one.
            let mut st_r = st_b.clone_state();
            st_r.reset();
            let fresh = oracle.state();
            assert!(st_r.is_empty(), "reset state must be empty");
            assert_eq!(st_r.value().to_bits(), fresh.value().to_bits(), "reset value");
            for &e in b_set.iter().chain(rest.iter()).take(6) {
                assert_eq!(
                    st_r.marginal(e).to_bits(),
                    fresh.marginal(e).to_bits(),
                    "reset marginal mismatch at {e} (trial {trial})"
                );
            }
        }
    }

    /// [`check_axioms`] minus monotonicity: for *non-monotone* families
    /// (e.g. [`crate::oracle::dicut::DicutOracle`]) marginals may be
    /// negative and `f(B)` may drop below `f(A)`, so only non-negativity
    /// of `f`, submodularity, insert/marginal consistency, idempotence,
    /// scratch/incremental agreement, batch bit-identity, and reset
    /// freshness are asserted.
    pub fn check_axioms_nonmono(oracle: &dyn Oracle, seed: u64, trials: usize) {
        let n = oracle.ground_size();
        assert!(n >= 3, "axiom check needs n >= 3");
        let mut rng = Rng::seed_from_u64(seed);
        let ids: Vec<ElementId> = (0..n as ElementId).collect();
        for trial in 0..trials {
            let mut perm = ids.clone();
            rng.shuffle(&mut perm);
            let b_len = rng.gen_range(1..n.min(24) + 1);
            let a_len = rng.gen_range(0..b_len);
            let (b_set, rest) = perm.split_at(b_len);
            let a_set = &b_set[..a_len];

            let mut st_a = oracle.state();
            for &e in a_set {
                st_a.insert(e);
            }
            let mut st_b = oracle.state();
            for &e in b_set {
                st_b.insert(e);
            }

            // non-negative value, but no chain monotonicity.
            assert!(st_a.value() >= -1e-9, "f must be non-negative");
            assert!(st_b.value() >= -1e-9, "f must be non-negative");

            // probe elements outside B.
            for &e in rest.iter().take(8) {
                let ma = st_a.marginal(e);
                let mb = st_b.marginal(e);
                assert!(
                    ma >= mb - 1e-6 * (1.0 + ma.abs()),
                    "submodularity violated at e={e}: f_A(e)={ma} < f_B(e)={mb} (trial {trial})"
                );
                let mut st_a2 = st_a.clone_state();
                st_a2.insert(e);
                let err = (st_a2.value() - (st_a.value() + ma)).abs();
                assert!(
                    err <= 1e-6 * (1.0 + st_a2.value().abs()),
                    "insert/marginal mismatch: {err}"
                );
            }

            // idempotence: marginal of a member is 0, re-insert is a no-op.
            if let Some(&e) = b_set.first() {
                assert!(st_b.marginal(e).abs() <= 1e-9, "member marginal must be 0");
                let v = st_b.value();
                st_b.insert(e);
                assert!((st_b.value() - v).abs() <= 1e-12, "re-insert changed value");
            }

            // scratch evaluation agrees with incremental state.
            let direct = oracle.value(b_set);
            let mut st = oracle.state();
            for &e in b_set {
                st.insert(e);
            }
            assert!(
                (direct - st.value()).abs() <= 1e-6 * (1.0 + direct.abs()),
                "value() vs state mismatch: {direct} vs {}",
                st.value()
            );

            // batch marginals are bit-identical to scalar marginals.
            let probes: Vec<ElementId> = rest.iter().take(8).copied().collect();
            let mut batch = vec![0.0; probes.len()];
            st_a.marginals(&probes, &mut batch);
            for (i, &e) in probes.iter().enumerate() {
                assert_eq!(
                    batch[i].to_bits(),
                    st_a.marginal(e).to_bits(),
                    "batch marginal mismatch at {e} (trial {trial})"
                );
            }

            // reset leaves the state indistinguishable from a fresh one.
            let mut st_r = st_b.clone_state();
            st_r.reset();
            let fresh = oracle.state();
            assert!(st_r.is_empty(), "reset state must be empty");
            assert_eq!(st_r.value().to_bits(), fresh.value().to_bits(), "reset value");
            for &e in b_set.iter().chain(rest.iter()).take(6) {
                assert_eq!(
                    st_r.marginal(e).to_bits(),
                    fresh.marginal(e).to_bits(),
                    "reset marginal mismatch at {e} (trial {trial})"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_insert_dedups_and_orders() {
        let mut s = Selection::new(5);
        assert!(s.insert(3));
        assert!(s.insert(1));
        assert!(!s.insert(3));
        assert!(s.contains(3));
        assert!(!s.contains(0));
        assert_eq!(s.order(), &[3, 1]);
        s.clear();
        assert!(s.order().is_empty());
        assert!(!s.contains(3));
        assert!(s.insert(3), "clear must forget membership");
    }

    #[test]
    fn state_pool_recycles_and_resets() {
        let o = crate::workload::coverage::CoverageGen::new(40, 30, 4).build(1);
        let pool = StatePool::new(&o);
        assert_eq!(pool.idle(), 0);
        {
            let mut st = pool.acquire();
            st.insert(3);
            st.insert(7);
            assert_eq!(st.len(), 2);
        }
        assert_eq!(pool.idle(), 1, "dropped state must return to the pool");
        {
            let st = pool.acquire();
            assert_eq!(pool.idle(), 0, "recycled, not re-allocated");
            assert!(st.is_empty(), "recycled state must be reset");
            let fresh = o.state();
            for e in 0..40u32 {
                assert_eq!(st.marginal(e).to_bits(), fresh.marginal(e).to_bits());
            }
        }
        // concurrent acquire from worker threads is allowed.
        let pool2 = StatePool::new(&o);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..8 {
                        let mut st = pool2.acquire();
                        st.insert(1);
                    }
                });
            }
        });
        assert!(pool2.idle() >= 1 && pool2.idle() <= 4);
    }

    #[test]
    fn opt_upper_bound_uses_batched_path() {
        let o = crate::oracle::modular::ModularOracle::new(vec![1.0, 5.0, 2.0]);
        assert_eq!(o.opt_upper_bound(2), 10.0);
        // counting decorator: the scan must be issued as batches.
        let c = CountingOracle::new(crate::oracle::modular::ModularOracle::new(vec![
            1.0;
            600
        ]));
        c.opt_upper_bound(3);
        let counters = c.counter();
        assert_eq!(counters.batched(), 600, "all singleton scans must be batched");
        assert!(counters.batches() >= 2, "600 elements need >= 3 blocks of 256");
    }
}
