//! Algorithm 7 — the 2-round `1/2 − ε` approximation for **sparse** inputs
//! (fewer than `√(nk)` elements of singleton value ≥ OPT/(2k)).
//!
//! Sparseness means all "large" elements fit on one machine: after the
//! random partition each machine holds O(k) of them in expectation
//! (balls-in-bins, the paper's Lemma 7), so every machine ships its O(k)
//! largest-singleton elements and the central machine — now holding *all*
//! large elements w.h.p. — finds a near-OPT/(2k) threshold from the pooled
//! max singleton and runs the sequential version of Algorithm 4 per guess.

use super::threshold::{block_marginals, block_max_marginal, threshold_greedy};
use super::{finish, AlgResult, MrAlgorithm};
use crate::core::{ElementId, Result, Solution};
use crate::mapreduce::wire::{RoundTask, TaskReply};
use crate::mapreduce::{ClusterConfig, MrCluster};
use crate::oracle::{Oracle, StatePool};

/// Algorithm 7.
#[derive(Debug, Clone, Copy)]
pub struct SparseTwoRound {
    /// Guess resolution ε.
    pub eps: f64,
    /// Elements shipped per machine = `c·k` (the paper's O(k); default 4).
    pub c: usize,
}

impl SparseTwoRound {
    /// New sparse-input algorithm with resolution `eps` and default c = 4.
    pub fn new(eps: f64) -> Self {
        assert!(eps > 0.0);
        SparseTwoRound { eps, c: 4 }
    }
}

/// Worker side: the `c·k` largest-singleton elements of a shard
/// (ties broken toward smaller id; output ascending by id). Singleton
/// scoring runs through the block-marginal path over a pooled state.
pub(crate) fn sparse_worker(
    states: &StatePool<'_>,
    shard: &[ElementId],
    k: usize,
    c: usize,
) -> Vec<ElementId> {
    let st = states.acquire();
    let scores = block_marginals(&*st, shard);
    let mut scored: Vec<(f64, ElementId)> =
        scores.into_iter().zip(shard.iter().copied()).collect();
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let take = (c * k).min(scored.len());
    let mut ids: Vec<ElementId> = scored[..take].iter().map(|&(_, e)| e).collect();
    ids.sort_unstable();
    ids
}

/// Central side: pool all shipped elements, guess OPT/(2k) from the pooled
/// max singleton, run sequential threshold greedy per guess, return best.
pub(crate) fn sparse_central(
    oracle: &dyn Oracle,
    pool: &[ElementId],
    k: usize,
    eps: f64,
) -> Solution {
    let st = oracle.state();
    let v = block_max_marginal(st.as_ref(), pool);
    if v <= 0.0 {
        return Solution::empty();
    }
    let j_max = ((2.0 * k as f64).ln() / (1.0 + eps).ln()).ceil() as usize;
    let mut best = Solution::empty();
    for j in 0..=j_max {
        let tau = v / (1.0 + eps).powi(j as i32);
        let mut g = oracle.state();
        threshold_greedy(g.as_mut(), pool, tau, k);
        best = best.max(finish(oracle, g.selected().to_vec()));
    }
    best
}

impl MrAlgorithm for SparseTwoRound {
    fn name(&self) -> String {
        format!("sparse(eps={},c={})", self.eps, self.c)
    }

    fn run(&self, oracle: &dyn Oracle, k: usize, cfg: &ClusterConfig) -> Result<AlgResult> {
        let n = oracle.ground_size();
        let mut cluster = MrCluster::new(n, k, cfg)?;
        let task = RoundTask::TopSingletons { k, c: self.c };
        let per_machine = cluster.shard_round("r1:top-singletons", 0, oracle, &task)?;
        let mut pool: Vec<ElementId> =
            per_machine.into_iter().flat_map(TaskReply::into_ids).collect();
        pool.sort_unstable();

        let received = pool.len();
        let solution = cluster.central_round("r2:sequential-complete", received, || {
            sparse_central(oracle, &pool, k, self.eps)
        })?;
        Ok(AlgResult { solution, metrics: cluster.into_metrics() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::planted::PlantedCoverageGen;
    use crate::workload::WorkloadGen;

    fn cfg(seed: u64) -> ClusterConfig {
        ClusterConfig { seed, parallel: false, ..ClusterConfig::default() }
    }

    #[test]
    fn half_minus_eps_on_sparse_planted() {
        // Sparse planted: only the 10 golden elements are "large".
        let gen = PlantedCoverageGen::sparse(10, 1000, 3000);
        let inst = gen.generate(1);
        let opt = inst.known_opt.unwrap();
        let eps = 0.1;
        let res = SparseTwoRound::new(eps).run(inst.oracle.as_ref(), 10, &cfg(2)).unwrap();
        let ratio = res.solution.value / opt;
        assert!(ratio >= 0.5 - eps, "sparse ratio {ratio} below 1/2 − ε");
        assert_eq!(res.metrics.num_rounds(), 3);
    }

    #[test]
    fn recovers_all_large_elements() {
        // every golden element must reach the central pool.
        let gen = PlantedCoverageGen::sparse(8, 800, 2000);
        let o = gen.build(3);
        let cluster = MrCluster::new(2008, 8, &cfg(4)).unwrap();
        let states = StatePool::new(&o);
        let mut pool = Vec::new();
        for i in 0..cluster.machines() {
            pool.extend(sparse_worker(&states, cluster.shard(i), 8, 4));
        }
        for golden in 0..8u32 {
            assert!(pool.contains(&golden), "golden element {golden} missing from pool");
        }
    }

    #[test]
    fn worker_respects_ck_cap() {
        let gen = PlantedCoverageGen::sparse(5, 100, 500);
        let o = gen.build(5);
        let shard: Vec<ElementId> = (0..300).collect();
        let states = StatePool::new(&o);
        let out = sparse_worker(&states, &shard, 5, 4);
        assert!(out.len() <= 20);
        assert!(out.windows(2).all(|w| w[0] < w[1]), "ascending ids");
    }

    #[test]
    fn central_handles_empty_pool() {
        let gen = PlantedCoverageGen::sparse(5, 100, 50);
        let o = gen.build(6);
        let sol = sparse_central(&o, &[], 5, 0.1);
        assert!(sol.is_empty());
    }
}
