//! Fixture-tree tests for the `mrsub check-invariants` lint engine.
//!
//! Each test builds a minimal repo-shaped tree in a temp dir (wire.rs with
//! every fingerprint anchor, spec.rs, lib.rs), plants one violation, and
//! asserts the exact lint fires — plus the converse clean/pragma'd cases.
//! Planted violations live in string literals here, never in committed
//! source, so scanning this very file stays clean (literal contents are
//! blanked in the scanner's code view).
//!
//! The final test runs the per-file lints over the real repo tree: the
//! invariants hold on the seed, with no grandfathering. (The `wire-drift`
//! lint is exercised on fixture trees only — the repo-tree comparison
//! against the committed bless belongs to `./verify.sh lint` and its CI
//! job, so `cargo test` never depends on the blessed file being current.)

use std::fs;
use std::path::{Path, PathBuf};

use mrsub::analysis::{self, Finding};

const MINI_WIRE: &str = r#"
pub const WIRE_VERSION: u16 = 1;
pub const FRAME_MAGIC: [u8; 4] = *b"MRSB";
const HEADER_LEN: usize = 4 + 2 + 4;
pub struct GuessFilter { pub id: u32, pub tau: f64 }
pub enum RoundTask { Filter { tau: f64 }, MaxSingleton }
pub enum TaskReply { Ids(Vec<u32>), Scalar(f64) }
pub struct WorkerInit { pub machines: Vec<u32>, pub arena: bool }
pub enum ToWorker { Init, Round, Shutdown }
pub enum FromWorker { Hello, Ready }
"#;

const MINI_SPEC: &str = "pub enum OracleSpec { Modular { weights: Vec<f64> } }\n";

const MINI_LIB: &str = "#![deny(unsafe_op_in_unsafe_fn)]\npub mod mapreduce;\n";

/// A throwaway repo-shaped tree under `$TMPDIR`, pre-populated with the
/// minimal clean fixture files and removed on drop.
struct Tree {
    root: PathBuf,
}

impl Tree {
    fn new(tag: &str) -> Tree {
        let root =
            std::env::temp_dir().join(format!("mrsub-lint-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(root.join("rust/src/analysis")).unwrap();
        let tree = Tree { root };
        tree.write("rust/src/mapreduce/wire.rs", MINI_WIRE);
        tree.write("rust/src/oracle/spec.rs", MINI_SPEC);
        tree.write("rust/src/lib.rs", MINI_LIB);
        tree
    }

    fn write(&self, rel: &str, content: &str) -> &Tree {
        let path = self.root.join(rel);
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(path, content).unwrap();
        self
    }

    fn bless(&self) {
        analysis::bless(&self.root).expect("bless fixture tree");
    }

    fn findings(&self) -> Vec<Finding> {
        analysis::check_tree(&self.root).expect("check_tree").findings
    }

    /// Findings of one lint, as `file:line` strings for compact asserts.
    fn fired(&self, lint: &str) -> Vec<String> {
        self.findings()
            .into_iter()
            .filter(|f| f.lint == lint)
            .map(|f| format!("{}:{}", f.file, f.line))
            .collect()
    }
}

impl Drop for Tree {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

// --- wire-drift --------------------------------------------------------------

#[test]
fn blessed_fixture_tree_is_clean() {
    let tree = Tree::new("clean");
    tree.bless();
    let report = analysis::check_tree(&tree.root).unwrap();
    assert!(report.ok(), "unexpected findings: {:?}", report.findings);
    assert!(report.render().contains("OK"));
    assert!(report.files_scanned >= 3);
}

#[test]
fn wire_drift_without_version_bump_is_caught_and_bless_refuses() {
    let tree = Tree::new("drift");
    tree.bless();
    // token-level layout change, version untouched.
    tree.write(
        "rust/src/mapreduce/wire.rs",
        &MINI_WIRE.replace("Ids(Vec<u32>)", "Ids(Vec<u32>), Ack"),
    );
    let drift = tree.findings();
    assert_eq!(drift.len(), 1, "{drift:?}");
    assert_eq!(drift[0].lint, "wire-drift");
    assert!(drift[0].message.contains("without a WIRE_VERSION bump"), "{}", drift[0].message);
    // blessing must not be an escape hatch around the bump.
    let err = analysis::bless(&tree.root).unwrap_err();
    assert!(err.to_string().contains("refusing to bless"), "{err}");
}

#[test]
fn drift_with_version_bump_wants_a_rebless_and_bless_clears_it() {
    let tree = Tree::new("rebless");
    tree.bless();
    tree.write(
        "rust/src/mapreduce/wire.rs",
        &MINI_WIRE
            .replace("Ids(Vec<u32>)", "Ids(Vec<u32>), Ack")
            .replace("WIRE_VERSION: u16 = 1", "WIRE_VERSION: u16 = 2"),
    );
    let drift = tree.findings();
    assert_eq!(drift.len(), 1, "{drift:?}");
    assert!(drift[0].message.contains("re-record"), "{}", drift[0].message);
    tree.bless();
    assert!(tree.findings().is_empty());
}

#[test]
fn version_bump_without_layout_change_is_flagged() {
    let tree = Tree::new("bump-only");
    tree.bless();
    tree.write(
        "rust/src/mapreduce/wire.rs",
        &MINI_WIRE.replace("WIRE_VERSION: u16 = 1", "WIRE_VERSION: u16 = 2"),
    );
    let drift = tree.findings();
    assert_eq!(drift.len(), 1, "{drift:?}");
    assert!(drift[0].message.contains("did not"), "{}", drift[0].message);
}

#[test]
fn comment_and_whitespace_churn_is_not_drift() {
    let tree = Tree::new("churn");
    tree.bless();
    tree.write(
        "rust/src/mapreduce/wire.rs",
        &MINI_WIRE.replace(
            "pub enum RoundTask { Filter { tau: f64 }, MaxSingleton }",
            "// the round vocabulary\npub enum RoundTask {\n    /* threshold */ Filter { tau: f64 },\n    MaxSingleton, // argmax\n}",
        ),
    );
    assert!(tree.findings().is_empty(), "{:?}", tree.findings());
}

#[test]
fn missing_blessed_file_is_a_wire_drift_finding() {
    let tree = Tree::new("no-bless");
    let drift = tree.fired("wire-drift");
    assert_eq!(drift.len(), 1);
    let all = tree.findings();
    assert!(all[0].message.contains("--bless"), "{}", all[0].message);
}

// --- determinism -------------------------------------------------------------

#[test]
fn hash_container_in_selection_critical_code_is_flagged() {
    let tree = Tree::new("det");
    tree.bless();
    tree.write(
        "rust/src/algorithms/greedy.rs",
        "use std::collections::HashMap;\npub fn f() {}\n",
    );
    assert_eq!(tree.fired("determinism"), vec!["rust/src/algorithms/greedy.rs:1"]);

    // a reasoned pragma on the line above silences exactly that line.
    tree.write(
        "rust/src/algorithms/greedy.rs",
        "// LINT-ALLOW: determinism keyed access only, never iterated\n\
         use std::collections::HashMap;\npub fn f() {}\n",
    );
    assert!(tree.fired("determinism").is_empty());

    // a pragma without a reason does not count.
    tree.write(
        "rust/src/algorithms/greedy.rs",
        "// LINT-ALLOW: determinism\nuse std::collections::HashMap;\npub fn f() {}\n",
    );
    assert_eq!(tree.fired("determinism").len(), 1);
}

#[test]
fn determinism_lint_scope_and_test_code_exemptions() {
    let tree = Tree::new("det-scope");
    tree.bless();
    // outside the selection-critical scope: no finding.
    tree.write(
        "rust/src/workload/gen.rs",
        "use std::collections::HashMap;\npub fn g() {}\n",
    );
    assert!(tree.fired("determinism").is_empty());

    // clock/entropy tokens in scope are findings...
    tree.write(
        "rust/src/oracle/cover.rs",
        "pub fn t() { let _ = std::time::Instant::now(); }\n",
    );
    assert_eq!(tree.fired("determinism"), vec!["rust/src/oracle/cover.rs:1"]);

    // ...but the same token inside a #[cfg(test)] mod is exempt.
    tree.write(
        "rust/src/oracle/cover.rs",
        "pub fn t() {}\n#[cfg(test)]\nmod tests {\n    fn timed() { let _ = std::time::Instant::now(); }\n}\n",
    );
    assert!(tree.fired("determinism").is_empty());

    // identifier boundaries: `random_instance` is not the token `random`.
    tree.write("rust/src/oracle/cover.rs", "pub fn random_instance() {}\n");
    assert!(tree.fired("determinism").is_empty());
}

// --- unsafe hygiene ----------------------------------------------------------

#[test]
fn unsafe_without_safety_comment_and_over_budget_are_flagged() {
    let tree = Tree::new("unsafe");
    tree.bless();
    // one naked unsafe in a file with a zero budget: both lints fire.
    tree.write(
        "rust/src/mapreduce/zap.rs",
        "pub fn z() { unsafe { core::hint::unreachable_unchecked() } }\n",
    );
    assert_eq!(tree.fired("unsafe-safety"), vec!["rust/src/mapreduce/zap.rs:1"]);
    assert_eq!(tree.fired("unsafe-budget"), vec!["rust/src/mapreduce/zap.rs:1"]);

    // a SAFETY comment within 3 lines clears the hygiene lint; the budget
    // finding stays (unsafe outside the audited files is itself the bug).
    tree.write(
        "rust/src/mapreduce/zap.rs",
        "pub fn z(p: *const u32) -> u32 {\n\
         \x20   // SAFETY: caller contract per fixture.\n\
         \x20   unsafe { *p }\n\
         }\n",
    );
    assert!(tree.fired("unsafe-safety").is_empty());
    assert_eq!(tree.fired("unsafe-budget").len(), 1);

    // outside the unsafe scope entirely: no findings.
    tree.write(
        "rust/src/workload/zap.rs",
        "pub fn z(p: *const u32) -> u32 { unsafe { *p } }\n",
    );
    assert!(tree.fired("unsafe-safety").iter().all(|f| !f.contains("workload")));
    assert!(tree.fired("unsafe-budget").iter().all(|f| !f.contains("workload")));
}

#[test]
fn crate_root_must_deny_unsafe_op_in_unsafe_fn() {
    let tree = Tree::new("deny-attr");
    tree.bless();
    tree.write("rust/src/lib.rs", "pub mod mapreduce;\n");
    assert_eq!(tree.fired("unsafe-safety"), vec!["rust/src/lib.rs:1"]);
}

// --- pragma discipline (ignored tests, dead code) ----------------------------

#[test]
fn ignored_tests_and_dead_code_need_reasons() {
    let tree = Tree::new("pragmas");
    tree.bless();
    tree.write(
        "rust/tests/slow.rs",
        "#[test]\n#[ignore]\nfn s() {}\n",
    );
    assert_eq!(tree.fired("ignored-test"), vec!["rust/tests/slow.rs:2"]);
    tree.write(
        "rust/tests/slow.rs",
        "#[test]\n#[ignore] // ALLOW-IGNORE: needs 8 cores, run explicitly\nfn s() {}\n",
    );
    assert!(tree.fired("ignored-test").is_empty());

    tree.write(
        "rust/src/mapreduce/stub.rs",
        "#[allow(dead_code)]\nfn stranded() {}\n",
    );
    assert_eq!(tree.fired("dead-code"), vec!["rust/src/mapreduce/stub.rs:1"]);
    tree.write(
        "rust/src/mapreduce/stub.rs",
        "#[allow(dead_code)] // ALLOW-DEAD: referenced by the next PR's backend\nfn stranded() {}\n",
    );
    assert!(tree.fired("dead-code").is_empty());

    // dead-code is rust/src/-scoped: test support code may carry it.
    tree.write("rust/tests/util.rs", "#[allow(dead_code)]\nfn helper() {}\n");
    assert!(tree.fired("dead-code").is_empty());
}

// --- reports -----------------------------------------------------------------

#[test]
fn reports_render_findings_and_json_schema() {
    let tree = Tree::new("report");
    tree.bless();
    tree.write(
        "rust/src/algorithms/bad.rs",
        "use std::collections::HashSet;\npub fn f() {}\n",
    );
    let report = analysis::check_tree(&tree.root).unwrap();
    assert!(!report.ok());
    let text = report.render();
    assert!(text.contains("[determinism] rust/src/algorithms/bad.rs:1"), "{text}");
    let json = report.to_json().to_string();
    assert!(json.contains("\"ok\""), "{json}");
    assert!(json.contains("determinism"), "{json}");
    assert!(json.contains("\"findings\""), "{json}");
}

// --- the repo tree itself ----------------------------------------------------

/// The per-file invariants hold on the committed tree — nothing is
/// grandfathered. `wire-drift` is excluded here (see module docs): this
/// test must not couple `cargo test` to the committed bless, which the
/// lint CI job checks instead.
#[test]
fn repo_tree_passes_static_lints() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analysis::check_tree(root).expect("scan repo tree");
    let findings: Vec<&Finding> =
        report.findings.iter().filter(|f| f.lint != "wire-drift").collect();
    assert!(
        findings.is_empty(),
        "the committed tree violates its own invariants:\n{:#?}",
        findings
    );
    assert!(report.files_scanned > 40, "suspiciously few files: {}", report.files_scanned);
}
