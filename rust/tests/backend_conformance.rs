//! Cross-backend conformance suite for the shared-nothing process
//! backend (and the in-process backends it must match), across every
//! transport.
//!
//! **Conformance half:** every algorithm × oracle family × backend triple
//! must produce bit-identical selections and objective values against the
//! `Serial` reference — with the process backend exercised over every
//! transport (`process:N@pipe`, `process:N@uds`, `process:N@uds+arena`,
//! `process:N@tcp`). This covers the whole shared-nothing path end to
//! end: shards and oracle specs serialized over the byte stream, the
//! connect-time `Hello` handshake, worker-side oracle reconstruction,
//! typed round dispatch (including Sample&Prune's seeded `PruneSample`
//! round), and reply collection.
//!
//! **Arena half:** `@uds+arena` runs resolve `Init`/`AdoptMachines` shard
//! payloads from the fd-passed mmap'd arena instead of wire frames. The
//! matrix below asserts the zero-copy path is *observationally identical*
//! to the wire path (same replies, same round frames, same recovery
//! behaviour) while the byte meters tell them apart: mapped bytes are
//! metered separately and shipped `Init`/adoption bytes shrink. Off
//! Linux the arena build falls back to the plain `@uds` wire path
//! transparently, so every arena test also passes there — the
//! Linux-only assertions key off `ProcessPool::arena_active`.
//!
//! **Fault-injection half:** a worker killed mid-round, a truncated reply
//! frame, a corrupted checksum, an oversized shard/frame, a hung worker,
//! a wire-version mismatch, and a worker that never connects must each
//! surface as a *structured* [`Error::Worker`]/[`Error::Config`] — never
//! a panic — and must not poison subsequent clean runs. The matrix runs
//! per transport.
//!
//! **Recovery half:** under `--recovery requeue:R` the same worker kills
//! must instead be *absorbed* — orphaned machines re-queued onto
//! survivors, machine-resident state replayed, the in-flight round
//! re-run — with final selections still bit-identical to `Serial`
//! ("kill ⇒ recover ⇒ identical output"), including kills that land
//! mid-`PruneSample` and two sequential deaths. Exhausting the budget or
//! losing the last worker stays a structured [`Error::Worker`].
//!
//! Process-count stability: run with `--test-threads=1` (the
//! `./verify.sh conformance` mode) for deterministic worker-process
//! lifecycles; the assertions themselves are scheduling-independent.

use std::path::PathBuf;

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::dash::Dash;
use mrsub::algorithms::dense::DenseTwoRound;
use mrsub::algorithms::greedy::lazy_greedy;
use mrsub::algorithms::multi_round::MultiRound;
use mrsub::algorithms::mz_coreset::MzCoreset;
use mrsub::algorithms::randgreedi::RandGreeDi;
use mrsub::algorithms::sample_prune::SamplePrune;
use mrsub::algorithms::sparse::SparseTwoRound;
use mrsub::algorithms::stochastic::StochasticGreedy;
use mrsub::algorithms::two_round::TwoRoundKnownOpt;
use mrsub::algorithms::MrAlgorithm;
use mrsub::coordinator::run_experiment;
use mrsub::core::{Constraint, Error};
use mrsub::mapreduce::backend::BackendKind;
use mrsub::mapreduce::process::{PoolOptions, ProcessPool, RecoveryPolicy};
use mrsub::mapreduce::transport::Transport;
use mrsub::mapreduce::wire::{ClientRequest, ClientResponse, RoundTask, DEFAULT_MAX_FRAME};
use mrsub::mapreduce::ClusterConfig;
use mrsub::oracle::spec::OracleSpec;
use mrsub::serve::{request as serve_request, Daemon, ServeOptions};
use mrsub::workload::adversarial::AdversarialGen;
use mrsub::workload::corpus::ZipfCorpusGen;
use mrsub::workload::coverage::CoverageGen;
use mrsub::workload::dicut::PlantedDicutGen;
use mrsub::workload::facility::FacilityGen;
use mrsub::workload::graph::GraphGen;
use mrsub::workload::planted::PlantedCoverageGen;
use mrsub::workload::{Instance, WorkloadGen};

/// The built `mrsub` binary — the worker executable for process-backend
/// runs (the test harness binary itself has no `worker` subcommand).
fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_mrsub"))
}

fn process(workers: usize, transport: Transport) -> BackendKind {
    BackendKind::Process { workers, transport }
}

/// Canonical shard name of a transport — the value the CI matrix passes
/// via `MRSUB_CONFORMANCE_TRANSPORT`.
fn transport_key(t: &Transport) -> &'static str {
    match t {
        Transport::Pipe => "pipe",
        Transport::Uds => "uds",
        Transport::UdsArena => "uds+arena",
        Transport::Tcp { .. } => "tcp",
    }
}

/// CI sharding hook: `MRSUB_CONFORMANCE_TRANSPORT=pipe|uds|uds+arena|tcp`
/// collapses every process-backend transport loop to that one transport,
/// so `.github/workflows/ci.yml` can fan the conformance job out as a
/// `strategy.matrix` over transports. Unset (or empty/whitespace) runs the
/// full matrix; an unknown value fails loudly instead of silently running
/// nothing. The in-process `Serial`/`Rayon` references are never filtered.
fn transport_shard() -> Option<String> {
    let v = std::env::var("MRSUB_CONFORMANCE_TRANSPORT").ok()?;
    let v = v.trim().to_string();
    if v.is_empty() {
        return None;
    }
    assert!(
        ["pipe", "uds", "uds+arena", "tcp"].contains(&v.as_str()),
        "MRSUB_CONFORMANCE_TRANSPORT={v:?} is not one of pipe|uds|uds+arena|tcp"
    );
    Some(v)
}

fn shard_keeps(t: &Transport) -> bool {
    transport_shard().map_or(true, |shard| shard == transport_key(t))
}

/// The wire-only transports: shard payloads always cross the stream, so
/// their byte meters must agree with each other exactly. Subject to the
/// [`transport_shard`] CI filter.
fn wire_transports() -> Vec<Transport> {
    let all = vec![Transport::Pipe, Transport::Uds, Transport::Tcp { bind: None }];
    all.into_iter().filter(shard_keeps).collect()
}

/// Every transport the pool itself can establish (the external-join TCP
/// mode is exercised separately — it needs hand-launched workers),
/// including the zero-copy `@uds+arena` variant, which transparently
/// falls back to the plain `@uds` wire path off Linux — so this matrix
/// stays portable. Subject to the [`transport_shard`] CI filter.
fn transports() -> Vec<Transport> {
    let all =
        vec![Transport::Pipe, Transport::Uds, Transport::Tcp { bind: None }, Transport::UdsArena];
    all.into_iter().filter(shard_keeps).collect()
}

fn cfg(seed: u64, backend: BackendKind) -> ClusterConfig {
    ClusterConfig {
        seed,
        backend: Some(backend),
        worker_exe: Some(worker_exe()),
        worker_timeout_ms: 60_000,
        ..ClusterConfig::default()
    }
}

fn families(seed: u64) -> Vec<Instance> {
    let mut out = vec![
        PlantedCoverageGen::dense(6, 200, 400).generate(seed),
        CoverageGen::new(240, 120, 4).generate(seed),
        ZipfCorpusGen::new(160, 120, 6).generate(seed),
        FacilityGen::clustered(120, 40, 4).generate(seed),
        GraphGen::barabasi_albert(150, 3).generate(seed),
        AdversarialGen::new(2, 8).generate(seed),
    ];
    // data-defined families round-trip through explicit specs.
    let weights: Vec<f64> = (0..150).map(|i| ((i * 37) % 101) as f64 / 7.0).collect();
    let spec = OracleSpec::Modular { weights };
    out.push(Instance::new("modular(test)", spec.build().unwrap()).with_spec(spec));
    let spec = OracleSpec::ConcaveBench { n: 140, groups: 24, seed };
    out.push(Instance::new("concave(test)", spec.build().unwrap()).with_spec(spec));
    // the non-monotone family: workers rebuild the arc list from the spec.
    out.push(PlantedDicutGen::new(6, 80, 3).generate(seed));
    out
}

/// The `e mod parts` unit-capacity partition matroid the constrained
/// conformance cells run under (rank = `parts`).
fn matroid(n: usize, parts: usize) -> Constraint {
    let ids: Vec<u32> = (0..n).map(|e| (e % parts.max(1)) as u32).collect();
    Constraint::partition_matroid(ids, vec![1; parts.max(1)])
}

fn algorithms(inst: &Instance, k: usize) -> Vec<Box<dyn MrAlgorithm>> {
    let opt = inst
        .known_opt
        .unwrap_or_else(|| lazy_greedy(&inst.oracle, k).value)
        .max(1e-9);
    vec![
        Box::new(TwoRoundKnownOpt::new(opt)),
        Box::new(MultiRound::known(2, opt)),
        Box::new(MultiRound::guessing(2, 0.25)),
        Box::new(DenseTwoRound::new(0.15)),
        Box::new(SparseTwoRound::new(0.2)),
        Box::new(CombinedTwoRound::new(0.15)),
        Box::new(RandGreeDi::default()),
        Box::new(RandGreeDi::constrained(matroid(inst.n, k), 2)),
        Box::new(Dash::new(0.2)),
        Box::new(Dash::constrained(0.2, matroid(inst.n, k))),
        Box::new(MzCoreset),
        Box::new(SamplePrune::new(0.25)),
        Box::new(StochasticGreedy::new(0.2)),
    ]
}

/// The tentpole contract: every algorithm × family × backend produces
/// **bit-identical selections** (element for element, in order) and
/// objective values against `Serial` — the process backend over every
/// transport, zero-copy arena included.
#[test]
fn every_algorithm_family_backend_triple_matches_serial() {
    let k = 6;
    let seed = 0xC0DE;
    // Serial (the reference) and Rayon always run; the process backends
    // honor the MRSUB_CONFORMANCE_TRANSPORT CI shard filter.
    let mut backends = vec![BackendKind::Serial, BackendKind::Rayon { chunk: 2 }];
    backends.extend(transports().into_iter().map(|t| process(2, t)));
    for inst in families(seed) {
        for alg in algorithms(&inst, k) {
            let run_on = |backend: &BackendKind| {
                let mut c = cfg(seed, backend.clone());
                c.oracle_spec = inst.spec.clone();
                alg.run(inst.oracle.as_ref(), k, &c).unwrap_or_else(|e| {
                    panic!("{} on {} [{}]: {e}", alg.name(), inst.name, backend.label())
                })
            };
            let reference = run_on(&backends[0]);
            for backend in &backends[1..] {
                let got = run_on(backend);
                assert_eq!(
                    got.metrics.rounds.len(),
                    reference.metrics.rounds.len(),
                    "{} on {} [{}]: round count",
                    alg.name(),
                    inst.name,
                    backend.label()
                );
                assert_eq!(
                    got.solution.elements,
                    reference.solution.elements,
                    "{} on {} [{}]: selection sequence diverged",
                    alg.name(),
                    inst.name,
                    backend.label()
                );
                assert_eq!(
                    got.solution.value.to_bits(),
                    reference.solution.value.to_bits(),
                    "{} on {} [{}]: objective value diverged ({} vs {})",
                    alg.name(),
                    inst.name,
                    backend.label(),
                    got.solution.value,
                    reference.solution.value
                );
            }
        }
    }
}

/// Selections (not just values) are element-for-element identical, and
/// process-backend runs actually move bytes over the wire — on every
/// transport, metered identically.
#[test]
fn process_backend_selections_identical_and_ipc_metered_per_transport() {
    let k = 6;
    let seed = 7;
    let inst = PlantedCoverageGen::dense(6, 300, 600).generate(seed);
    // RandGreeDi round 1 is unconditionally a typed shard round, so the
    // wire path is guaranteed to carry the greedy work.
    let alg = RandGreeDi::default();
    let serial = alg.run(inst.oracle.as_ref(), k, &cfg(seed, BackendKind::Serial)).unwrap();
    assert_eq!(serial.metrics.total_ipc_bytes(), (0, 0), "serial runs move no IPC bytes");

    let mut ipc_per_transport = Vec::new();
    for transport in transports() {
        let label = format!("process:3{}", transport.label_suffix());
        let arena = transport.wants_arena();
        let mut pcfg = cfg(seed, process(3, transport));
        pcfg.oracle_spec = inst.spec.clone();
        let run = alg.run(inst.oracle.as_ref(), k, &pcfg).unwrap();

        assert_eq!(
            run.solution.elements, serial.solution.elements,
            "[{label}] must reproduce the serial selection sequence"
        );
        assert_eq!(run.solution.value.to_bits(), serial.solution.value.to_bits());
        let (out_bytes, in_bytes) = run.metrics.total_ipc_bytes();
        assert!(out_bytes > 0, "[{label}] the round task must ship over the wire");
        assert!(in_bytes > 0, "[{label}] selections must come back over the wire");
        // the mapped meter is the arena's signature: zero on every wire
        // transport, positive exactly when the arena actually engaged
        // (its spawn-time Init elision is attributed to the spawning
        // round's metrics).
        let mapped = run.metrics.total_mapped_bytes();
        if arena && cfg!(target_os = "linux") {
            assert!(mapped > 0, "[{label}] Init payload must resolve from the arena mapping");
        } else if !arena {
            assert_eq!(mapped, 0, "[{label}] wire transports resolve nothing from an arena");
        }
        // the round's oracle traffic happened worker-side but is still
        // visible in the coordinator's per-round metrics.
        let greedy_round = run
            .metrics
            .rounds
            .iter()
            .find(|r| r.name == "r1:local-greedy")
            .expect("local-greedy round recorded");
        assert!(greedy_round.oracle_calls > 0, "[{label}] worker-side calls merged");
        assert!(greedy_round.ipc_bytes_out > 0);
        assert!(greedy_round.ipc_bytes_in > 0);
        ipc_per_transport.push((label, out_bytes, in_bytes));
    }
    // identical frames cross every transport: the byte meters must agree
    // (the wire layer is transport-agnostic by construction). The arena
    // transport is held to the same equality — its Init elision happens
    // at spawn, before round metering starts, so per-round task/reply
    // frames are byte-identical to the wire transports.
    let (_, out0, in0) = &ipc_per_transport[0];
    for (label, out_b, in_b) in &ipc_per_transport[1..] {
        assert_eq!((out_b, in_b), (out0, in0), "[{label}] IPC meter diverged across transports");
    }
}

/// Worker reuse across rounds: Algorithm 5 with t thresholds runs all its
/// typed rounds against one pool (spawn once, not per round).
#[test]
fn multi_round_reuses_workers_across_thresholds() {
    let seed = 3;
    let inst = PlantedCoverageGen::dense(6, 240, 480).generate(seed);
    let opt = inst.known_opt.unwrap();
    let t = 3;
    let mut pcfg = cfg(seed, process(2, Transport::Pipe));
    pcfg.oracle_spec = inst.spec.clone();
    let res = MultiRound::known(t, opt).run(inst.oracle.as_ref(), 6, &pcfg).unwrap();
    // every threshold's worker half-round carried IPC traffic.
    let ipc_rounds = res
        .metrics
        .rounds
        .iter()
        .filter(|r| r.name.ends_with("a:sample-greedy+filter"))
        .count();
    assert_eq!(ipc_rounds, t);
    for r in &res.metrics.rounds {
        if r.name.ends_with("a:sample-greedy+filter") {
            assert!(r.ipc_bytes_out > 0, "round {} shipped no task", r.name);
        }
    }
    let serial = MultiRound::known(t, opt)
        .run(inst.oracle.as_ref(), 6, &cfg(seed, BackendKind::Serial))
        .unwrap();
    assert_eq!(res.solution.elements, serial.solution.elements);
}

/// The PR-3 ROADMAP gap, closed: Sample&Prune's seeded pruning rounds run
/// worker-side (the per-machine RNG seed travels inside the task), carry
/// IPC bytes on the process backend, and stay bit-identical to `Serial`
/// on every transport.
#[test]
fn sample_prune_prune_rounds_run_worker_side_on_every_transport() {
    let k = 8;
    let seed = 21;
    let inst = CoverageGen::new(400, 200, 4).generate(seed);
    let alg = SamplePrune::new(0.25);
    let serial = alg.run(inst.oracle.as_ref(), k, &cfg(seed, BackendKind::Serial)).unwrap();
    let prune_rounds =
        serial.metrics.rounds.iter().filter(|r| r.name.ends_with("a:prune+sample")).count();
    assert!(prune_rounds > 0, "instance must exercise the pruning schedule");

    for transport in transports() {
        let label = format!("process:2{}", transport.label_suffix());
        let mut pcfg = cfg(seed, process(2, transport));
        pcfg.oracle_spec = inst.spec.clone();
        let run = alg.run(inst.oracle.as_ref(), k, &pcfg).unwrap();
        assert_eq!(
            run.solution.elements, serial.solution.elements,
            "[{label}] seeded sampling must be backend-independent"
        );
        assert_eq!(run.solution.value.to_bits(), serial.solution.value.to_bits());
        for r in &run.metrics.rounds {
            if r.name.ends_with("a:prune+sample") {
                assert!(
                    r.ipc_bytes_out > 0 && r.ipc_bytes_in > 0,
                    "[{label}] prune round {} must execute worker-side",
                    r.name
                );
            }
        }
    }
}

// --- fault injection --------------------------------------------------------

fn pool_for_faults(
    fault: Option<&str>,
    transport: Transport,
    max_frame: usize,
    timeout_ms: u64,
) -> mrsub::core::Result<ProcessPool> {
    let spec =
        OracleSpec::Coverage { n: 120, universe: 80, avg_degree: 3, weighted: false, seed: 5 };
    let shards: Vec<Vec<u32>> = vec![(0..40).collect(), (40..80).collect(), (80..120).collect()];
    let sample: Vec<u32> = (0..120).step_by(7).collect();
    let mut env = Vec::new();
    if let Some(f) = fault {
        env.push(("MRSUB_FAULT".to_string(), f.to_string()));
    }
    ProcessPool::spawn(&spec, &shards, &sample, &PoolOptions {
        workers: 2,
        transport,
        timeout: std::time::Duration::from_millis(timeout_ms),
        connect_timeout: std::time::Duration::from_millis(timeout_ms),
        max_frame,
        exe: Some(worker_exe()),
        env,
        ..PoolOptions::default()
    })
}

/// A 3-worker pool (one simulated machine each) under the given recovery
/// policy — the fixture for the elastic-recovery matrix.
fn recovery_pool(recovery: RecoveryPolicy, transport: Transport) -> ProcessPool {
    let spec =
        OracleSpec::Coverage { n: 120, universe: 80, avg_degree: 3, weighted: false, seed: 5 };
    let shards: Vec<Vec<u32>> = vec![(0..40).collect(), (40..80).collect(), (80..120).collect()];
    let sample: Vec<u32> = (0..120).step_by(7).collect();
    ProcessPool::spawn(&spec, &shards, &sample, &PoolOptions {
        workers: 3,
        transport,
        timeout: std::time::Duration::from_secs(60),
        connect_timeout: std::time::Duration::from_secs(60),
        max_frame: 64 << 20,
        exe: Some(worker_exe()),
        env: Vec::new(),
        recovery,
        elastic: false,
    })
    .expect("clean spawn")
}

fn assert_worker_error<T: std::fmt::Debug>(res: mrsub::core::Result<T>, needle: &str) {
    match res {
        Err(Error::Worker { message, .. }) => assert!(
            message.to_lowercase().contains(needle),
            "worker error {message:?} does not mention {needle:?}"
        ),
        other => panic!("expected structured worker error about {needle:?}, got {other:?}"),
    }
}

#[test]
fn killed_worker_mid_round_degrades_cleanly_on_every_transport() {
    for transport in transports() {
        let label = transport.to_string();
        let mut pool =
            pool_for_faults(None, transport, 64 << 20, 60_000).expect("clean spawn");
        // sanity: a round works before the kill.
        let (replies, stats) = pool.round(&RoundTask::MaxSingleton).unwrap();
        assert_eq!(replies.len(), 3, "[{label}]");
        assert!(stats.bytes_out > 0 && stats.bytes_in > 0, "[{label}]");
        // kill one worker out from under the pool; the next round must
        // fail with a structured error, not a panic or a hang.
        pool.kill_worker(1);
        let res = pool.round(&RoundTask::MaxSingleton);
        assert!(
            matches!(res, Err(Error::Worker { .. })),
            "[{label}] expected Err(Worker), got {res:?}"
        );
    }
}

#[test]
fn die_mid_round_fault_is_a_structured_error_on_every_transport() {
    for transport in transports() {
        let mut pool = pool_for_faults(Some("die-mid-round"), transport, 64 << 20, 60_000)
            .expect("init is clean");
        assert_worker_error(pool.round(&RoundTask::MaxSingleton), "stream");
    }
}

#[test]
fn truncated_reply_frame_is_a_structured_error_on_every_transport() {
    for transport in transports() {
        let mut pool = pool_for_faults(Some("truncate-frame"), transport, 64 << 20, 60_000)
            .expect("init is clean");
        assert_worker_error(pool.round(&RoundTask::MaxSingleton), "truncated");
    }
}

#[test]
fn corrupt_checksum_is_a_structured_error_on_every_transport() {
    for transport in transports() {
        let mut pool = pool_for_faults(Some("corrupt-checksum"), transport, 64 << 20, 60_000)
            .expect("init is clean");
        assert_worker_error(pool.round(&RoundTask::MaxSingleton), "checksum");
    }
}

#[test]
fn hung_worker_is_bounded_by_timeout_on_every_transport() {
    for transport in transports() {
        // init handshake is fast, so a 1.5s timeout is comfortably above
        // spawn cost yet far below the injected 20s hang — if the timeout
        // machinery failed, the round would take ~20s and trip the bound.
        let mut pool = pool_for_faults(Some("hang-round"), transport, 64 << 20, 1_500)
            .expect("init is clean");
        let start = std::time::Instant::now();
        assert_worker_error(pool.round(&RoundTask::MaxSingleton), "no reply");
        assert!(
            start.elapsed() < std::time::Duration::from_secs(15),
            "timeout must bound the wait, took {:?}",
            start.elapsed()
        );
    }
}

#[test]
fn version_mismatch_fails_the_handshake_on_every_transport() {
    for transport in transports() {
        let res = pool_for_faults(Some("bad-version"), transport, 64 << 20, 60_000);
        assert_worker_error(res.map(|_| ()), "version");
    }
}

#[test]
fn oversized_shard_rejected_by_frame_cap_on_every_wire_transport() {
    // wire transports only: under `@uds+arena` the shard payload never
    // crosses the stream, so the cap legitimately does not trip — that
    // flip side is pinned by `frame_cap_applies_to_shipped_bytes_only`.
    for transport in wire_transports() {
        // a 120-element init shard cannot fit a 64-byte frame cap: the
        // spawn fails with a structured send error before any round runs.
        let res = pool_for_faults(None, transport, 64, 60_000);
        assert_worker_error(res.map(|_| ()), "max-frame");
    }
}

/// A worker that dies before ever joining: on the socket transports the
/// accept deadline expires into a structured connection error; on pipes
/// the closed stream fails the `Hello`.
#[test]
fn worker_that_never_connects_is_a_structured_error() {
    for transport in [Transport::Uds, Transport::UdsArena, Transport::Tcp { bind: None }] {
        let res = pool_for_faults(Some("no-connect"), transport, 64 << 20, 1_500);
        assert_worker_error(res.map(|_| ()), "connect");
    }
    let res = pool_for_faults(Some("no-connect"), Transport::Pipe, 64 << 20, 1_500);
    assert_worker_error(res.map(|_| ()), "stream");
}

/// `mrsub worker --connect` against a dead endpoint exits nonzero with a
/// connection-refused style error instead of hanging (the README
/// troubleshooting flow).
#[test]
fn worker_connect_to_dead_endpoint_fails_fast() {
    // reserve a port and release it so nothing is listening there —
    // unlike a fixed well-known port, this cannot collide with a local
    // service that would accept the dial and hang the worker.
    let addr = {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").expect("probe bind");
        probe.local_addr().unwrap().to_string()
    };
    let status = std::process::Command::new(worker_exe())
        .args(["worker", "--connect", &addr])
        .stdin(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .status()
        .expect("spawn worker");
    assert!(!status.success(), "dialing a dead endpoint must fail");
}

/// The remote-join flow end to end: an explicit TCP bind address makes
/// the pool spawn nothing and wait for external `mrsub worker --connect
/// HOST:PORT --id I` processes — exactly what a multi-host deployment
/// runs by hand.
#[test]
fn external_tcp_workers_join_by_hand() {
    // reserve a port, then release it for the pool to bind.
    let port = {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").expect("probe bind");
        probe.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");
    // launch the "remote" workers first; their connect retries cover the
    // window until the coordinator binds.
    let mut external: Vec<std::process::Child> = (0..2)
        .map(|id| {
            std::process::Command::new(worker_exe())
                .args(["worker", "--connect", &addr, "--id", &id.to_string()])
                .stdin(std::process::Stdio::null())
                .spawn()
                .expect("spawn external worker")
        })
        .collect();

    let spec =
        OracleSpec::Coverage { n: 120, universe: 80, avg_degree: 3, weighted: false, seed: 5 };
    let shards: Vec<Vec<u32>> = vec![(0..60).collect(), (60..120).collect()];
    let sample: Vec<u32> = (0..120).step_by(9).collect();
    let pool = ProcessPool::spawn(&spec, &shards, &sample, &PoolOptions {
        workers: 2,
        transport: Transport::Tcp { bind: Some(addr) },
        timeout: std::time::Duration::from_secs(30),
        connect_timeout: std::time::Duration::from_secs(30),
        max_frame: 64 << 20,
        exe: Some(worker_exe()),
        env: Vec::new(),
        ..PoolOptions::default()
    });
    let mut pool = pool.expect("external workers must join the pool");
    assert_eq!(pool.workers(), 2);
    let (replies, stats) = pool.round(&RoundTask::LocalGreedy { k: 4 }).unwrap();
    assert_eq!(replies.len(), 2);
    assert!(stats.bytes_in > 0);
    drop(pool); // shutdown: external workers exit on their own.
    for child in &mut external {
        let code = child.wait().expect("external worker reaped");
        assert!(code.success(), "external worker must exit cleanly, got {code:?}");
    }
}

// --- elastic recovery (requeue policy) --------------------------------------

/// The recovery half of the fault matrix, end to end: a worker killed
/// mid-run under `--recovery requeue:R` is **recovered from** — its
/// machines are adopted by survivors (shards + store replay reshipped,
/// the in-flight round re-run) and the final selections are bit-identical
/// to `Serial`, on every transport. This upgrades the fault contract from
/// "kill ⇒ structured error" to "kill ⇒ recover ⇒ identical output".
#[test]
fn killed_worker_recovers_bit_identical_on_every_transport() {
    let k = 6;
    let seed = 0xE1A5;
    let inst = PlantedCoverageGen::dense(6, 300, 600).generate(seed);
    // (algorithm, fault): RandGreeDi dies on its one typed round;
    // multi-round guessing dies on its *second* typed round, after a
    // persistent MultiFilter landed in the replay history.
    let cases: Vec<(Box<dyn MrAlgorithm>, &str)> = vec![
        (Box::new(RandGreeDi::default()), "die-mid-round@1"),
        (Box::new(MultiRound::guessing(2, 0.25)), "die-mid-round:2@1"),
    ];
    for (alg, fault) in cases {
        let serial = alg.run(inst.oracle.as_ref(), k, &cfg(seed, BackendKind::Serial)).unwrap();
        for transport in transports() {
            let label = format!("{} [{}] {fault}", alg.name(), transport);
            let mut pcfg = cfg(seed, process(3, transport));
            pcfg.oracle_spec = inst.spec.clone();
            pcfg.recovery = RecoveryPolicy::Requeue { budget: 2 };
            pcfg.worker_env = vec![("MRSUB_FAULT".to_string(), fault.to_string())];
            let run = alg.run(inst.oracle.as_ref(), k, &pcfg).unwrap_or_else(|e| {
                panic!("[{label}] recovery must absorb the kill: {e}")
            });
            assert_eq!(
                run.solution.elements, serial.solution.elements,
                "[{label}] selections must survive recovery bit for bit"
            );
            assert_eq!(run.solution.value.to_bits(), serial.solution.value.to_bits());
            assert_eq!(
                run.metrics.total_recoveries(),
                1,
                "[{label}] exactly one worker death should be metered"
            );
            assert!(
                run.metrics.total_reshipped_bytes() > 0,
                "[{label}] adoption must ship a reship frame (shards on the wire \
                 path; replay history + framing under the arena)"
            );
        }
    }
}

/// Kill during a seeded `PruneSample` round — the hardest case: the dead
/// worker held machine-resident *pruned* shards that never crossed the
/// wire. Recovery must rebuild them by replaying the earlier pruning
/// round (same seeds, same global machine ids) before re-running the
/// in-flight one, and still match `Serial` exactly.
#[test]
fn kill_during_prune_sample_recovers_bit_identical() {
    let k = 8;
    let seed = 21;
    let inst = CoverageGen::new(400, 200, 4).generate(seed);
    let alg = SamplePrune::new(0.25);
    let serial = alg.run(inst.oracle.as_ref(), k, &cfg(seed, BackendKind::Serial)).unwrap();
    let prune_rounds =
        serial.metrics.rounds.iter().filter(|r| r.name.ends_with("a:prune+sample")).count();
    assert!(
        prune_rounds >= 2,
        "instance must run >= 2 pruning rounds so the kill lands after \
         machine-resident state exists (got {prune_rounds})"
    );

    for transport in transports() {
        let label = format!("process:3{}", transport.label_suffix());
        let mut pcfg = cfg(seed, process(3, transport));
        pcfg.oracle_spec = inst.spec.clone();
        pcfg.recovery = RecoveryPolicy::Requeue { budget: 1 };
        // worker 1 dies on its second pruning round: its pruned shards
        // exist only in its memory and must be reconstructed by replay.
        pcfg.worker_env = vec![("MRSUB_FAULT".to_string(), "die-on-prune:2@1".to_string())];
        let run = alg
            .run(inst.oracle.as_ref(), k, &pcfg)
            .unwrap_or_else(|e| panic!("[{label}] recovery must absorb the kill: {e}"));
        assert_eq!(
            run.solution.elements, serial.solution.elements,
            "[{label}] replayed pruned shards must reproduce the serial selections"
        );
        assert_eq!(run.solution.value.to_bits(), serial.solution.value.to_bits());
        assert_eq!(run.metrics.total_recoveries(), 1, "[{label}]");
        assert!(run.metrics.total_reshipped_bytes() > 0, "[{label}]");
    }
}

/// Two sequential worker deaths in different rounds are both absorbed
/// under `requeue:2`, with replies (including machine-resident prune
/// state carried across the deaths) identical to an undisturbed pool.
#[test]
fn two_sequential_worker_deaths_recover_under_budget() {
    let prune = |round: u32| RoundTask::PruneSample {
        base: vec![3, 50],
        floor: 0.1,
        tau: 0.4,
        per_share: 8,
        seed: 77,
        round,
    };
    for transport in transports() {
        let label = transport.to_string();
        let mut elastic = recovery_pool(RecoveryPolicy::Requeue { budget: 2 }, transport.clone());
        let mut reference = recovery_pool(RecoveryPolicy::Fail, transport);

        let (r1e, _) = elastic.round(&prune(1)).unwrap();
        let (r1r, _) = reference.round(&prune(1)).unwrap();
        assert_eq!(r1e, r1r, "[{label}] clean round agrees");

        elastic.kill_worker(0);
        let (r2e, s2) = elastic.round(&prune(2)).expect("first death recovered");
        let (r2r, _) = reference.round(&prune(2)).unwrap();
        assert_eq!(r2e, r2r, "[{label}] round 2 replies survive death #1");
        assert_eq!(s2.recoveries, 1, "[{label}]");
        assert!(s2.reshipped_bytes > 0, "[{label}]");

        elastic.kill_worker(1);
        let (r3e, s3) = elastic.round(&prune(3)).expect("second death recovered");
        let (r3r, _) = reference.round(&prune(3)).unwrap();
        assert_eq!(r3e, r3r, "[{label}] round 3 replies survive death #2");
        assert_eq!(s3.recoveries, 1, "[{label}]");
    }
}

/// Exhausting the `requeue:R` budget still fails structurally — the
/// (R+1)-th death is an [`Error::Worker`] naming the exhausted budget.
#[test]
fn recovery_budget_exhaustion_is_a_structured_error() {
    for transport in transports() {
        let label = transport.to_string();
        let mut pool = recovery_pool(RecoveryPolicy::Requeue { budget: 1 }, transport);
        let (replies, _) = pool.round(&RoundTask::MaxSingleton).unwrap();
        assert_eq!(replies.len(), 3, "[{label}]");
        pool.kill_worker(0);
        let (replies, stats) =
            pool.round(&RoundTask::MaxSingleton).expect("first death is within budget");
        assert_eq!(replies.len(), 3, "[{label}] recovered round still answers all machines");
        assert_eq!(stats.recoveries, 1, "[{label}]");
        pool.kill_worker(1);
        assert_worker_error(pool.round(&RoundTask::MaxSingleton), "budget");
        // a pool poisoned by the unrecovered failure stays a structured
        // error on reuse — never a panic on the stranded machines.
        assert_worker_error(pool.round(&RoundTask::MaxSingleton), "dead");
    }
}

/// With replacement spawning disabled (the pre-elastic degraded mode),
/// losing the last worker is unrecoverable regardless of budget: there is
/// nobody left to adopt the machines. (With respawn on — the default —
/// the same total loss is absorbed; see
/// `total_worker_loss_recovers_when_respawn_closes_the_loop`.)
#[test]
fn last_worker_death_is_structured_even_under_requeue() {
    for transport in transports() {
        let mut pool = recovery_pool(RecoveryPolicy::Requeue { budget: 5 }, transport);
        pool.set_respawn(false);
        for wi in 0..3 {
            pool.kill_worker(wi);
        }
        assert_worker_error(pool.round(&RoundTask::MaxSingleton), "surviving");
    }
}

/// Replacement spawning closes the recovery loop even under **total**
/// worker loss: with budget >= N every dead slot is refilled by a fresh
/// process within the same round, the re-queued machines land on the
/// replacements (store state rebuilt by replay), and the replies stay
/// bit-identical to an undisturbed pool — "last worker died" is no longer
/// terminal when the pool may spawn its own survivors. The flip side
/// stays bounded: a budget below the death count is still a structured
/// budget error, never an infinite respawn loop.
#[test]
fn total_worker_loss_recovers_when_respawn_closes_the_loop() {
    let prune = |round: u32| RoundTask::PruneSample {
        base: vec![3, 50],
        floor: 0.1,
        tau: 0.4,
        per_share: 8,
        seed: 77,
        round,
    };
    for transport in transports() {
        let label = transport.to_string();
        let mut elastic = recovery_pool(RecoveryPolicy::Requeue { budget: 5 }, transport.clone());
        let mut reference = recovery_pool(RecoveryPolicy::Fail, transport);

        let (r1e, _) = elastic.round(&prune(1)).unwrap();
        let (r1r, _) = reference.round(&prune(1)).unwrap();
        assert_eq!(r1e, r1r, "[{label}] clean round agrees");

        for wi in 0..3 {
            elastic.kill_worker(wi);
        }
        let (r2e, s2) = elastic
            .round(&prune(2))
            .unwrap_or_else(|e| panic!("[{label}] total loss must be absorbed: {e}"));
        let (r2r, _) = reference.round(&prune(2)).unwrap();
        assert_eq!(r2e, r2r, "[{label}] replies survive losing every worker");
        assert_eq!(s2.recoveries, 3, "[{label}] every death is metered");
        assert_eq!(s2.respawns, 3, "[{label}] every slot is replaced within the round");
        assert_eq!(elastic.alive_workers(), 3, "[{label}] pool back to process:N size");
    }
    // under-provisioned: the 3rd death exceeds requeue:2 and stays a
    // structured budget error.
    let mut pool = recovery_pool(RecoveryPolicy::Requeue { budget: 2 }, Transport::Uds);
    for wi in 0..3 {
        pool.kill_worker(wi);
    }
    assert_worker_error(pool.round(&RoundTask::MaxSingleton), "budget");
}

/// Late-join elasticity on the external TCP topology, plus the parking
/// regression: a `mrsub worker --connect` that dials in while a recovery
/// round (and its `AdoptMachines` replay) is in flight must NOT be
/// spliced into the running round — it is parked until the round closes,
/// then back-fills the dead slot at the next boundary, where the
/// rebalance planner sheds a machine (with full store replay) onto it.
/// The replies of every round stay bit-identical to an undisturbed pool.
#[test]
fn late_join_is_parked_mid_round_then_backfills_the_dead_slot() {
    let prune = |round: u32| RoundTask::PruneSample {
        base: vec![3, 50],
        floor: 0.1,
        tau: 0.4,
        per_share: 8,
        seed: 77,
        round,
    };
    // reserve a port, then release it for the pool to bind.
    let port = {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").expect("probe bind");
        probe.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");
    let spawn_worker = |id: usize| {
        std::process::Command::new(worker_exe())
            .args(["worker", "--connect", &addr, "--id", &id.to_string()])
            .stdin(std::process::Stdio::null())
            .spawn()
            .expect("spawn external worker")
    };
    // workers launched first; connect retries cover the bind window.
    let mut w0 = spawn_worker(0);
    let mut w1 = spawn_worker(1);

    // same instance as `recovery_pool`, but 3 machines over 2 external
    // workers (w0 hosts machines 0 and 2, w1 hosts machine 1) so the
    // reference pool's per-machine replies are directly comparable.
    let spec =
        OracleSpec::Coverage { n: 120, universe: 80, avg_degree: 3, weighted: false, seed: 5 };
    let shards: Vec<Vec<u32>> = vec![(0..40).collect(), (40..80).collect(), (80..120).collect()];
    let sample: Vec<u32> = (0..120).step_by(7).collect();
    let mut pool = ProcessPool::spawn(&spec, &shards, &sample, &PoolOptions {
        workers: 2,
        transport: Transport::Tcp { bind: Some(addr.clone()) },
        timeout: std::time::Duration::from_secs(60),
        connect_timeout: std::time::Duration::from_secs(60),
        max_frame: 64 << 20,
        exe: Some(worker_exe()),
        env: Vec::new(),
        recovery: RecoveryPolicy::Requeue { budget: 1 },
        elastic: false,
    })
    .expect("external workers must join the pool");
    let mut reference = recovery_pool(RecoveryPolicy::Fail, Transport::Uds);

    let (r1, _) = pool.round(&prune(1)).unwrap();
    let (r1r, _) = reference.round(&prune(1)).unwrap();
    assert_eq!(r1, r1r, "external clean round agrees with the reference");

    // kill worker 1 and immediately offer a replacement: the joiner dials
    // in while round 2 — the recovery round, replay included — is in
    // flight. Parked or still in the listener backlog, it must not be
    // handed a mid-round partial store.
    pool.kill_worker(1);
    let mut joiner = spawn_worker(1);
    let (r2, s2) = pool.round(&prune(2)).expect("death absorbed by the survivor");
    let (r2r, _) = reference.round(&prune(2)).unwrap();
    assert_eq!(r2, r2r, "recovery replies are joiner-independent (parked, not spliced)");
    assert_eq!(s2.recoveries, 1, "the death is metered");
    assert_eq!(s2.respawns, 0, "external slots are never respawned by the pool itself");
    assert_eq!(pool.alive_workers(), 1, "mid-round the pool is still down a worker");

    // let the joiner surely reach the listener, then cross a round
    // boundary: the parked join back-fills slot 1 and the planner sheds
    // the survivor's highest-id machine (with full replay) onto it.
    std::thread::sleep(std::time::Duration::from_millis(500));
    let (r3, s3) = pool.round(&prune(3)).unwrap();
    let (r3r, _) = reference.round(&prune(3)).unwrap();
    assert_eq!(r3, r3r, "back-fill + rebalance stay bit-identical");
    assert_eq!(s3.respawns, 1, "the back-fill is metered as a respawn");
    assert!(
        s3.rebalanced_machines >= 1,
        "the planner must shed load onto the joiner, got {}",
        s3.rebalanced_machines
    );
    assert_eq!(pool.alive_workers(), 2, "pool back to full size");

    drop(pool); // shutdown: surviving externals exit on their own.
    for (name, child) in [("w0", &mut w0), ("joiner", &mut joiner)] {
        let code = child.wait().expect("external worker reaped");
        assert!(code.success(), "{name} must exit cleanly, got {code:?}");
    }
    let _ = w1.wait(); // killed out from under the pool; status is arbitrary.
}

/// A faulted run must not poison the coordinator: its metrics stay
/// readable and a subsequent clean run on the same instance succeeds.
#[test]
fn fault_does_not_poison_subsequent_runs() {
    let seed = 13;
    let inst = PlantedCoverageGen::dense(6, 200, 400).generate(seed);
    // RandGreeDi's round 1 is unconditionally a typed shard round, so the
    // injected fault is guaranteed to be exercised.
    let alg = RandGreeDi::default();
    for transport in transports() {
        let label = transport.to_string();
        let mut bad = cfg(seed, process(2, transport.clone()));
        bad.oracle_spec = inst.spec.clone();
        bad.worker_env = vec![("MRSUB_FAULT".to_string(), "die-mid-round".to_string())];
        let res = alg.run(inst.oracle.as_ref(), 6, &bad);
        assert!(
            matches!(res, Err(Error::Worker { .. })),
            "[{label}] faulted run must error: {res:?}"
        );

        // clean run right after: identical to serial, as if nothing happened.
        let mut good = cfg(seed, process(2, transport));
        good.oracle_spec = inst.spec.clone();
        let clean = alg.run(inst.oracle.as_ref(), 6, &good).unwrap();
        let serial = alg.run(inst.oracle.as_ref(), 6, &cfg(seed, BackendKind::Serial)).unwrap();
        assert_eq!(clean.solution.elements, serial.solution.elements, "[{label}]");
        assert_eq!(clean.solution.value.to_bits(), serial.solution.value.to_bits());
    }
}

// --- zero-copy arena (@uds+arena) -------------------------------------------

/// Cross-transport meter equality, arena-aware: an identically configured
/// pool on `@uds` and `@uds+arena` must produce byte-identical replies,
/// while the spawn meters split the same payload differently — the wire
/// pool ships every shard/sample word as `Init` frames, the arena pool
/// elides exactly those words into `mapped_bytes` (plus the per-shard
/// length prefixes that vanish with the payload). Subsequent rounds ship
/// byte-identical frames on both, so the relation between the lifetime
/// meters is stable, not a spawn-only accident.
#[test]
fn arena_init_elides_shard_payloads_into_the_mapping() {
    let mut uds = pool_for_faults(None, Transport::Uds, 64 << 20, 60_000).expect("clean spawn");
    let mut arena =
        pool_for_faults(None, Transport::UdsArena, 64 << 20, 60_000).expect("clean spawn");
    let (uds_out, uds_in) = uds.total_ipc_bytes();
    let (arena_out, arena_in) = arena.total_ipc_bytes();
    let mapped = arena.total_mapped_bytes();
    assert_eq!(uds.total_mapped_bytes(), 0, "the wire pool never touches an arena");
    assert_eq!(arena_in, uds_in, "worker Ready replies are arena-independent");
    if arena.arena_active() {
        assert!(mapped > 0, "Init must resolve shard + sample payloads from the mapping");
        assert!(
            arena_out < uds_out,
            "arena Init must ship O(1) framing ({arena_out} vs {uds_out} wire bytes)"
        );
        // the elided wire bytes are the mapped payload words plus the
        // (tiny) per-shard length prefixes that disappeared with them:
        // 3 machines ⇒ at most a few dozen bytes of slack.
        let elided = uds_out - arena_out;
        assert!(
            elided >= mapped && elided <= mapped + 16 * 3,
            "elided Init bytes ({elided}) must account for the mapped payload ({mapped})"
        );
    } else {
        // non-Linux fallback: metered exactly like plain `@uds`.
        assert_eq!(mapped, 0, "fallback pools must not report mapped bytes");
        assert_eq!(arena_out, uds_out, "fallback Init ships the same frames as @uds");
    }

    // compute on mapped shards is observationally identical to shipped
    // shards, and per-round frames stay byte-identical either way.
    let (ru, su) = uds.round(&RoundTask::LocalGreedy { k: 4 }).unwrap();
    let (ra, sa) = arena.round(&RoundTask::LocalGreedy { k: 4 }).unwrap();
    assert_eq!(ra, ru, "mapped shards must compute identically to shipped ones");
    assert_eq!(
        (sa.bytes_out, sa.bytes_in),
        (su.bytes_out, su.bytes_in),
        "round frames are arena-independent"
    );
    assert_eq!(sa.mapped_bytes, 0, "a plain round resolves nothing new from the arena");
}

/// Kill during an mmap'd adoption — the arena recovery path end to end: a
/// worker dies mid-round while the pool holds an arena, the survivor's
/// `AdoptMachines` ships replay history + framing only (the orphaned
/// shards resolve from its mapping), and the recovered replies stay
/// bit-identical to both an undisturbed pool and the wire recovery path.
#[test]
fn kill_during_arena_adoption_recovers_bit_identical() {
    let prune = |round: u32| RoundTask::PruneSample {
        base: vec![3, 50],
        floor: 0.1,
        tau: 0.4,
        per_share: 8,
        seed: 77,
        round,
    };
    let mut arena = recovery_pool(RecoveryPolicy::Requeue { budget: 1 }, Transport::UdsArena);
    let mut wire = recovery_pool(RecoveryPolicy::Requeue { budget: 1 }, Transport::Uds);
    let mut reference = recovery_pool(RecoveryPolicy::Fail, Transport::Uds);

    let (r1a, _) = arena.round(&prune(1)).unwrap();
    let (r1w, _) = wire.round(&prune(1)).unwrap();
    let (r1r, _) = reference.round(&prune(1)).unwrap();
    assert_eq!(r1a, r1r, "clean arena round agrees with the wire reference");
    assert_eq!(r1w, r1r);

    // same kill under both elastic pools: worker 0's machine is adopted
    // mid-round, with its machine-resident pruned state rebuilt by replay.
    arena.kill_worker(0);
    wire.kill_worker(0);
    let (r2a, sa) = arena.round(&prune(2)).expect("arena adoption must recover");
    let (r2w, sw) = wire.round(&prune(2)).expect("wire adoption must recover");
    let (r2r, _) = reference.round(&prune(2)).unwrap();
    assert_eq!(r2a, r2r, "adoption through the arena mapping must stay bit-identical");
    assert_eq!(r2w, r2r);
    assert_eq!((sa.recoveries, sw.recoveries), (1, 1));
    assert!(sa.reshipped_bytes > 0, "arena adoption still ships replay + framing");
    if arena.arena_active() {
        assert!(sa.mapped_bytes > 0, "adopted shards must resolve from the mapping");
        assert!(
            sa.reshipped_bytes < sw.reshipped_bytes,
            "arena adoption ({} bytes) must reship less than the wire path ({} bytes)",
            sa.reshipped_bytes,
            sw.reshipped_bytes
        );
    } else {
        assert_eq!(sa.mapped_bytes, 0);
        assert_eq!(sa.reshipped_bytes, sw.reshipped_bytes, "fallback adoption matches @uds");
    }
}

// --- serving daemon (mrsub serve) -------------------------------------------

/// A serving daemon over the given backend, inheriting the conformance
/// worker executable and generous timeouts. Port 0 picks a free port.
fn serve_daemon(
    backend: BackendKind,
    recovery: RecoveryPolicy,
    env: Vec<(String, String)>,
    elastic: bool,
) -> Daemon {
    let mut c = cfg(0, backend);
    c.recovery = recovery;
    c.worker_env = env;
    c.elastic = elastic;
    Daemon::start(ServeOptions { bind: "127.0.0.1:0".into(), cfg: c }).expect("daemon must bind")
}

/// The shared serving dataset family (parameterized by generator seed).
fn serve_spec(seed: u64) -> OracleSpec {
    OracleSpec::Coverage { n: 240, universe: 120, avg_degree: 4, weighted: false, seed }
}

/// Submit one job over the client wire path and unwrap its result.
fn serve_submit(
    addr: &str,
    algorithm: &str,
    k: usize,
    seed: u64,
    spec: &OracleSpec,
) -> (Vec<u32>, f64) {
    let req = ClientRequest::SubmitJob {
        algorithm: algorithm.to_string(),
        k,
        seed,
        machines: 0,
        spec: spec.clone(),
    };
    match serve_request(addr, &req, DEFAULT_MAX_FRAME).expect("client request") {
        ClientResponse::JobResult { selection, value, .. } => (selection, value),
        other => panic!("expected JobResult, got {other:?}"),
    }
}

/// Submit every job concurrently — one client connection per job, each
/// served by its own daemon thread — and collect results in submission
/// order.
fn serve_submit_all(
    addr: &str,
    k: usize,
    jobs: &[(&'static str, u64, OracleSpec)],
) -> Vec<(Vec<u32>, f64)> {
    let handles: Vec<_> = jobs
        .iter()
        .cloned()
        .map(|(alg, seed, spec)| {
            let addr = addr.to_string();
            std::thread::spawn(move || serve_submit(&addr, alg, k, seed, &spec))
        })
        .collect();
    handles.into_iter().map(|h| h.join().expect("submit thread")).collect()
}

/// The standalone reference for a served job: the same experiment path on
/// the `Serial` backend (what `run_job` would do with no pool at all).
fn standalone_serial(
    alg: &dyn MrAlgorithm,
    k: usize,
    seed: u64,
    spec: &OracleSpec,
) -> (Vec<u32>, f64) {
    let inst = Instance::new("standalone", spec.build().unwrap()).with_spec(spec.clone());
    let mut c = cfg(seed, BackendKind::Serial);
    c.oracle_spec = Some(spec.clone());
    let rec = run_experiment(&inst, alg, k, &c).expect("standalone reference run");
    (rec.selection.clone(), rec.value)
}

/// Stop a daemon the way `mrsub submit --shutdown` does, and make sure the
/// drain actually returns (a hung `wait` would wedge the test).
fn shut_down(daemon: Daemon, addr: &str) {
    let resp = serve_request(addr, &ClientRequest::Shutdown, DEFAULT_MAX_FRAME).unwrap();
    assert!(matches!(resp, ClientResponse::ShuttingDown), "shutdown must be acked");
    daemon.wait();
}

/// The serving tentpole contract: two jobs submitted **concurrently** to
/// one daemon — different algorithms, different datasets, different seeds
/// — come back bit-identical to the same runs standalone on `Serial`,
/// while the warm pool spawns its workers exactly once and shares them
/// across both jobs (rounds interleave at pool-mutex granularity).
#[test]
fn served_concurrent_jobs_are_bit_identical_to_standalone_serial() {
    let k = 6;
    let daemon = serve_daemon(process(2, Transport::Uds), RecoveryPolicy::Fail, Vec::new(), false);
    let addr = daemon.addr().to_string();
    let jobs: Vec<(&'static str, u64, OracleSpec)> =
        vec![("combined:0.15", 41, serve_spec(11)), ("randgreedi", 42, serve_spec(12))];
    let served = serve_submit_all(&addr, k, &jobs);

    let references = [
        standalone_serial(&CombinedTwoRound::new(0.15), k, 41, &serve_spec(11)),
        standalone_serial(&RandGreeDi::default(), k, 42, &serve_spec(12)),
    ];
    for (i, ((sel, val), (rsel, rval))) in served.iter().zip(&references).enumerate() {
        assert_eq!(sel, rsel, "job {i}: served selection diverged from standalone");
        assert_eq!(val.to_bits(), rval.to_bits(), "job {i}: served value diverged");
    }
    let stats = daemon.stats();
    assert_eq!(stats.jobs_completed, 2);
    assert_eq!(stats.workers_spawned, 2, "one warm pool, spawned once, shared by both jobs");
    assert_eq!(stats.workers_alive, 2);
    assert_eq!(stats.workers_respawned, 0, "no deaths, no growth: nothing to replace");
    shut_down(daemon, &addr);
}

/// The serve-under-churn contract: concurrent jobs keep answering
/// bit-identically to standalone `Serial` while the pool churns under
/// them — a worker dies mid-job, a **replacement is spawned into its
/// slot** (so the pool returns to full size instead of limping on the
/// survivors), and under `--elastic` late workers join the pool as the
/// job load exceeds the spawn size. [`ServeStats::workers_respawned`]
/// counts every such activation.
#[test]
fn served_jobs_survive_churn_with_replacement_and_elastic_growth() {
    let k = 6;
    // worker 1 dies on the first typed round it processes — whichever of
    // the concurrent jobs lands it; recovery must absorb either case, the
    // other jobs must cross the same dead worker unharmed, and the
    // replacement (fault stripped) must take the slot back.
    let daemon = serve_daemon(
        process(2, Transport::Uds),
        RecoveryPolicy::Requeue { budget: 2 },
        vec![("MRSUB_FAULT".to_string(), "die-mid-round@1".to_string())],
        true,
    );
    let addr = daemon.addr().to_string();
    let jobs: Vec<(&'static str, u64, OracleSpec)> = vec![
        ("randgreedi", 21, serve_spec(31)),
        ("randgreedi", 22, serve_spec(32)),
        ("combined:0.15", 23, serve_spec(33)),
    ];
    let served = serve_submit_all(&addr, k, &jobs);

    let references = [
        standalone_serial(&RandGreeDi::default(), k, 21, &serve_spec(31)),
        standalone_serial(&RandGreeDi::default(), k, 22, &serve_spec(32)),
        standalone_serial(&CombinedTwoRound::new(0.15), k, 23, &serve_spec(33)),
    ];
    for (i, ((sel, val), (rsel, rval))) in served.iter().zip(&references).enumerate() {
        assert_eq!(sel, rsel, "job {i}: selections must survive the churn bit for bit");
        assert_eq!(val.to_bits(), rval.to_bits(), "job {i}: value diverged under churn");
    }
    let stats = daemon.stats();
    assert_eq!(stats.jobs_completed, 3);
    assert_eq!(stats.workers_spawned, 2, "the initial spawn happens exactly once");
    assert!(
        stats.workers_respawned >= 1,
        "the killed worker's replacement must be counted (stats: {stats:?})"
    );
    assert!(
        stats.workers_alive >= 2,
        "the pool must return to at least its spawn size, got {}",
        stats.workers_alive
    );
    shut_down(daemon, &addr);
}

/// Warm-pool arena caching: the pool's spawn dataset is the first job's
/// deterministic partition, so resubmitting the **same** `(spec, k, seed,
/// machines)` re-derives a byte-identical dataset and attaches with every
/// shard payload resolved from the zero-copy arena — no re-spawned
/// workers, no re-shipped shards. Off Linux the arena build falls back to
/// the wire path: the attach meters flip to misses, but the results and
/// the no-respawn contract are unchanged.
#[test]
fn same_spec_resubmission_is_an_arena_cache_hit() {
    let k = 6;
    let seed = 33;
    let spec = serve_spec(5);
    let daemon =
        serve_daemon(process(2, Transport::UdsArena), RecoveryPolicy::Fail, Vec::new(), false);
    let addr = daemon.addr().to_string();

    let first = serve_submit(&addr, "randgreedi", k, seed, &spec);
    let s1 = daemon.stats();
    assert_eq!(s1.workers_spawned, 2);
    assert_eq!(s1.arena_hits + s1.arena_misses, 1, "one job, one attach");

    let second = serve_submit(&addr, "randgreedi", k, seed, &spec);
    let s2 = daemon.stats();
    assert_eq!(second.0, first.0, "identical submissions must reproduce the selection");
    assert_eq!(second.1.to_bits(), first.1.to_bits());
    assert_eq!(s2.arena_hits + s2.arena_misses, 2, "two jobs, two attaches");
    assert_eq!(s2.workers_spawned, s1.workers_spawned, "the warm pool must not re-spawn");
    assert_eq!(s2.workers_alive, 2);
    if s1.arena_hits == 1 {
        // the arena engaged: the first job's dataset IS the spawn dataset,
        // and the resubmission re-derives it byte for byte.
        assert_eq!(s2.arena_hits, 2, "same-spec resubmission must attach arena-elided");
    } else {
        assert_eq!((s1.arena_misses, s2.arena_misses), (1, 2), "fallback attaches ship shards");
    }

    let reference = standalone_serial(&RandGreeDi::default(), k, seed, &spec);
    assert_eq!(first.0, reference.0, "served result must match standalone Serial");
    assert_eq!(first.1.to_bits(), reference.1.to_bits());
    shut_down(daemon, &addr);
}

/// The flip side of the frame-cap matrix: the cap guards *shipped* bytes,
/// so a cap far too small for the 120-element wire `Init` can legitimately
/// admit the arena `Init` (whose shard payload never crosses the stream).
/// Whenever the arena build fell back to the wire path instead, the same
/// structured cap error as `@uds` must surface.
#[test]
fn frame_cap_applies_to_shipped_bytes_only() {
    match pool_for_faults(None, Transport::UdsArena, 256, 60_000) {
        Ok(pool) => assert!(
            pool.arena_active(),
            "a 256-byte cap only fits an Init whose payload lives in the arena"
        ),
        Err(e) => assert!(
            matches!(e, Error::Worker { .. }),
            "fallback must keep the structured max-frame error, got {e:?}"
        ),
    }
}
