//! Experiment driver: runs an algorithm on an instance, wires the
//! oracle-call counter through the cluster, normalizes values into ratios,
//! and packages everything as a serializable [`ExperimentRecord`] — the
//! unit the benches, examples, and the CLI all print or persist.

use std::sync::Arc;
use std::time::Instant;

use crate::algorithms::greedy::lazy_greedy;
use crate::algorithms::MrAlgorithm;
use crate::core::Result;
use crate::mapreduce::ClusterConfig;
use crate::metrics::MrMetrics;
use crate::oracle::CountingOracle;
use crate::util::json::Json;
use crate::workload::Instance;

/// One algorithm × instance execution, fully accounted.
#[derive(Debug, Clone)]
pub struct ExperimentRecord {
    /// Algorithm display name.
    pub algorithm: String,
    /// Instance display name.
    pub instance: String,
    /// Cardinality constraint.
    pub k: usize,
    /// Cluster seed.
    pub seed: u64,
    /// Objective value achieved.
    pub value: f64,
    /// Reference value (planted OPT if known, else lazy greedy).
    pub reference: f64,
    /// Whether `reference` is the exact optimum.
    pub reference_is_opt: bool,
    /// `value / reference`.
    pub ratio: f64,
    /// MapReduce rounds (compute rounds; excludes the r0 partition round).
    pub rounds: usize,
    /// Peak per-machine resident elements.
    pub peak_machine_memory: usize,
    /// Peak central-machine received elements in one round.
    pub peak_central_recv: usize,
    /// Total elements shipped across all rounds.
    pub communication: usize,
    /// Total oracle calls.
    pub oracle_calls: u64,
    /// End-to-end wall time (ms).
    pub wall_ms: f64,
    /// Full per-round metrics.
    pub metrics: MrMetrics,
}

impl ExperimentRecord {
    /// JSON form for reports.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("algorithm", Json::Str(self.algorithm.clone())),
            ("instance", Json::Str(self.instance.clone())),
            ("k", Json::Num(self.k as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("value", Json::Num(self.value)),
            ("reference", Json::Num(self.reference)),
            ("reference_is_opt", Json::Bool(self.reference_is_opt)),
            ("ratio", Json::Num(self.ratio)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("peak_machine_memory", Json::Num(self.peak_machine_memory as f64)),
            ("peak_central_recv", Json::Num(self.peak_central_recv as f64)),
            ("communication", Json::Num(self.communication as f64)),
            ("oracle_calls", Json::Num(self.oracle_calls as f64)),
            ("wall_ms", Json::Num(self.wall_ms)),
            ("metrics", self.metrics.to_json()),
        ])
    }
}

/// Run `alg` on `inst`, returning the full record.
///
/// The oracle is wrapped in a [`CountingOracle`] and the counter is wired
/// into the cluster config so per-round oracle calls land in the metrics.
pub fn run_experiment(
    inst: &Instance,
    alg: &dyn MrAlgorithm,
    k: usize,
    cfg: &ClusterConfig,
) -> Result<ExperimentRecord> {
    let counting = CountingOracle::new(Arc::clone(&inst.oracle));
    let mut cfg = cfg.clone();
    cfg.call_counter = Some(counting.counter());

    let start = Instant::now();
    let result = alg.run(&counting, k, &cfg)?;
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    let oracle_calls = counting.calls();

    let (reference, reference_is_opt) = match (inst.known_opt, inst.planted_k) {
        (Some(opt), Some(pk)) if pk == k => (opt, true),
        _ => (lazy_greedy(&inst.oracle, k).value, false),
    };
    let ratio = if reference > 0.0 { result.solution.value / reference } else { 0.0 };

    // compute rounds exclude the r0 partition record.
    let rounds = result.metrics.rounds.iter().filter(|r| !r.name.starts_with("r0:")).count();

    Ok(ExperimentRecord {
        algorithm: alg.name(),
        instance: inst.name.clone(),
        k,
        seed: cfg.seed,
        value: result.solution.value,
        reference,
        reference_is_opt,
        ratio,
        rounds,
        peak_machine_memory: result.metrics.peak_machine_memory(),
        peak_central_recv: result.metrics.peak_central_recv(),
        communication: result.metrics.total_communication(),
        oracle_calls,
        wall_ms,
        metrics: result.metrics,
    })
}

/// Render records as an aligned text table (the benches' output format).
pub fn render_table(title: &str, records: &[ExperimentRecord]) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==\n"));
    out.push_str(&format!(
        "{:<28} {:<34} {:>4} {:>9} {:>7} {:>7} {:>10} {:>10} {:>12} {:>9}\n",
        "algorithm", "instance", "k", "value", "ratio", "rounds", "peak-mem", "central", "oracle-calls", "wall-ms"
    ));
    for r in records {
        out.push_str(&format!(
            "{:<28} {:<34} {:>4} {:>9.2} {:>7.4} {:>7} {:>10} {:>10} {:>12} {:>9.1}\n",
            r.algorithm,
            truncate(&r.instance, 34),
            r.k,
            r.value,
            r.ratio,
            r.rounds,
            r.peak_machine_memory,
            r.peak_central_recv,
            r.oracle_calls,
            r.wall_ms
        ));
    }
    out
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..s.char_indices().take(n - 1).last().map(|(i, c)| i + c.len_utf8()).unwrap_or(0)])
    }
}

/// Write records as pretty JSON.
pub fn write_json(path: &str, records: &[ExperimentRecord]) -> Result<()> {
    let arr = Json::Arr(records.iter().map(ExperimentRecord::to_json).collect());
    std::fs::write(path, arr.to_string_pretty())
        .map_err(|e| crate::core::Error::Runtime(format!("write {path}: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::combined::CombinedTwoRound;
    use crate::workload::planted::PlantedCoverageGen;
    use crate::workload::WorkloadGen;

    #[test]
    fn record_is_complete_and_serializable() {
        let inst = PlantedCoverageGen::dense(8, 400, 800).generate(1);
        let cfg = ClusterConfig { parallel: false, ..ClusterConfig::default() };
        let rec = run_experiment(&inst, &CombinedTwoRound::new(0.1), 8, &cfg).unwrap();
        assert!(rec.reference_is_opt);
        assert!(rec.ratio >= 0.4);
        assert_eq!(rec.rounds, 2);
        assert!(rec.oracle_calls > 0);
        let json = rec.to_json();
        assert_eq!(json.get("algorithm").unwrap().as_str(), Some(rec.algorithm.as_str()));
        // JSON text parses back.
        assert!(Json::parse(&json.to_string_pretty()).is_ok());
    }

    #[test]
    fn reference_falls_back_to_greedy_for_mismatched_k() {
        let inst = PlantedCoverageGen::dense(8, 400, 800).generate(2);
        let cfg = ClusterConfig { parallel: false, ..ClusterConfig::default() };
        // k != planted k → greedy reference.
        let rec = run_experiment(&inst, &CombinedTwoRound::new(0.1), 5, &cfg).unwrap();
        assert!(!rec.reference_is_opt);
        assert!(rec.reference > 0.0);
    }

    #[test]
    fn table_renders() {
        let inst = PlantedCoverageGen::sparse(5, 100, 100).generate(3);
        let cfg = ClusterConfig { parallel: false, ..ClusterConfig::default() };
        let rec = run_experiment(&inst, &CombinedTwoRound::new(0.1), 5, &cfg).unwrap();
        let table = render_table("test", &[rec]);
        assert!(table.contains("combined"));
        assert!(table.contains("ratio"));
    }
}
