//! RandGreeDi — the two-round distributed greedy of Barbosa et al. (FOCS
//! 2016), the framework the paper positions itself against.
//!
//! Round 1: randomly partition; each machine runs (lazy) greedy on its
//! shard and ships its k-element solution `T_i`. Round 2: the central
//! machine runs greedy over `∪_i T_i` to get `T_c`; the output is the
//! better of `T_c` and the best local `T_i`. On a random partition this is
//! a `1/2`-approximation in expectation *with* the framework's ground-set
//! duplication caveats (the no-duplication form loses a constant factor —
//! exactly the gap the paper's thresholding closes).

use super::greedy::lazy_greedy_over;
use super::{AlgResult, MrAlgorithm};
use crate::core::{ElementId, Result, Solution};
use crate::mapreduce::wire::{RoundTask, TaskReply};
use crate::mapreduce::{ClusterConfig, MrCluster};
use crate::oracle::Oracle;

/// Barbosa et al.'s RandGreeDi (no duplication).
#[derive(Debug, Clone, Copy, Default)]
pub struct RandGreeDi;

impl MrAlgorithm for RandGreeDi {
    fn name(&self) -> String {
        "randgreedi".into()
    }

    fn run(&self, oracle: &dyn Oracle, k: usize, cfg: &ClusterConfig) -> Result<AlgResult> {
        let n = oracle.ground_size();
        let mut cluster = MrCluster::new(n, k, cfg)?;

        // Round 1: greedy per shard (typed round; worker-side on the
        // process backend, recycled pooled states in-process).
        let locals: Vec<Vec<ElementId>> = cluster
            .shard_round("r1:local-greedy", 0, oracle, &RoundTask::LocalGreedy { k })?
            .into_iter()
            .map(TaskReply::into_ids)
            .collect();

        // Best local solution (its value is recomputed centrally; the ids
        // are already on the central machine as part of the round-1 output).
        let best_local = locals
            .iter()
            .map(|t| {
                let v = oracle.value(t);
                Solution { elements: t.clone(), value: v }
            })
            .fold(Solution::empty(), Solution::max);

        let union: Vec<ElementId> = {
            let mut u: Vec<ElementId> = locals.iter().flatten().copied().collect();
            u.sort_unstable();
            u.dedup();
            u
        };

        // Round 2: greedy over the union of core-sets.
        let received = union.len();
        let central = cluster
            .central_round("r2:union-greedy", received, || lazy_greedy_over(oracle, &union, k))?;

        Ok(AlgResult { solution: central.max(best_local), metrics: cluster.into_metrics() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::lazy_greedy;
    use crate::workload::coverage::CoverageGen;
    use crate::workload::planted::PlantedCoverageGen;
    use crate::workload::WorkloadGen;

    fn cfg(seed: u64) -> ClusterConfig {
        ClusterConfig { seed, parallel: false, ..ClusterConfig::default() }
    }

    #[test]
    fn two_rounds_and_reasonable_quality() {
        let inst = PlantedCoverageGen::dense(10, 1000, 2000).generate(1);
        let opt = inst.known_opt.unwrap();
        let res = RandGreeDi.run(inst.oracle.as_ref(), 10, &cfg(2)).unwrap();
        assert_eq!(res.metrics.num_rounds(), 3);
        assert!(res.solution.value / opt >= 0.5, "randgreedi below 1/2 on easy instance");
    }

    #[test]
    fn never_worse_than_best_local() {
        let o = CoverageGen::new(400, 250, 4).build(3);
        let res = RandGreeDi.run(&o, 10, &cfg(4)).unwrap();
        // sanity: close to sequential greedy on random coverage.
        let g = lazy_greedy(&o, 10);
        assert!(res.solution.value >= 0.5 * g.value);
        assert!(res.solution.len() <= 10);
    }
}
