//! Experiment driver: runs an algorithm on an instance, wires the
//! oracle-call counter through the cluster, normalizes values into ratios,
//! and packages everything as a serializable [`ExperimentRecord`] — the
//! unit the benches, examples, and the CLI all print or persist.

use std::sync::Arc;
use std::time::Instant;

use crate::algorithms::greedy::lazy_greedy;
use crate::algorithms::MrAlgorithm;
use crate::core::Result;
use crate::mapreduce::ClusterConfig;
use crate::metrics::MrMetrics;
use crate::oracle::CountingOracle;
use crate::util::json::Json;
use crate::workload::Instance;

/// Schema version stamped into every `mrsub bench` JSON report
/// (`"schema_version"`). Bump whenever a report field is added, removed,
/// or changes meaning; `tests/bench_report_schema.rs` pins the committed
/// fixture against this so report consumers cannot break silently.
pub const BENCH_SCHEMA_VERSION: u32 = 4;

/// One algorithm × instance execution, fully accounted.
#[derive(Debug, Clone)]
pub struct ExperimentRecord {
    /// Algorithm display name.
    pub algorithm: String,
    /// Instance display name.
    pub instance: String,
    /// Cardinality constraint.
    pub k: usize,
    /// Cluster seed.
    pub seed: u64,
    /// Objective value achieved.
    pub value: f64,
    /// Reference value (planted OPT if known, else lazy greedy).
    pub reference: f64,
    /// Whether `reference` is the exact optimum.
    pub reference_is_opt: bool,
    /// `value / reference`.
    pub ratio: f64,
    /// MapReduce rounds (compute rounds; excludes the r0 partition round).
    pub rounds: usize,
    /// Peak per-machine resident elements.
    pub peak_machine_memory: usize,
    /// Peak central-machine received elements in one round.
    pub peak_central_recv: usize,
    /// Total elements shipped across all rounds.
    pub communication: usize,
    /// Total oracle calls.
    pub oracle_calls: u64,
    /// Of `oracle_calls`, queries served through the block-marginal path.
    pub batched_oracle_calls: u64,
    /// Number of block-marginal calls issued.
    pub oracle_batches: u64,
    /// Wire-frame bytes coordinator → workers (0 unless the run used the
    /// shared-nothing process backend).
    pub ipc_bytes_out: u64,
    /// Wire-frame bytes workers → coordinator.
    pub ipc_bytes_in: u64,
    /// Worker deaths recovered from during the run (elastic process
    /// backend under `--recovery requeue:R`).
    pub recoveries: u64,
    /// Frame bytes reshipped to surviving workers for machine adoption.
    pub reshipped_bytes: u64,
    /// Replacement workers spawned into dead slots (or back-filled by
    /// late joins) — together with `recoveries`, the closed elastic
    /// loop: the pool returns to full size after every absorbed death.
    pub respawns: u64,
    /// Machines moved between workers by the deterministic rebalance
    /// planner at round boundaries (elastic process backend).
    pub rebalanced_machines: u64,
    /// Shard/sample payload bytes workers resolved from the mmap'd arena
    /// instead of wire frames (`@uds+arena` runs; 0 on every wire path).
    pub mapped_bytes: u64,
    /// End-to-end wall time (ms).
    pub wall_ms: f64,
    /// The selected elements themselves — the serving daemon returns
    /// these to `mrsub submit` clients alongside the value.
    pub selection: Vec<crate::core::ElementId>,
    /// Full per-round metrics.
    pub metrics: MrMetrics,
}

impl ExperimentRecord {
    /// Queries served one at a time (`oracle_calls − batched_oracle_calls`).
    pub fn scalar_oracle_calls(&self) -> u64 {
        self.oracle_calls.saturating_sub(self.batched_oracle_calls)
    }
}

impl ExperimentRecord {
    /// JSON form for reports.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("algorithm", Json::Str(self.algorithm.clone())),
            ("instance", Json::Str(self.instance.clone())),
            ("k", Json::Num(self.k as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("value", Json::Num(self.value)),
            ("reference", Json::Num(self.reference)),
            ("reference_is_opt", Json::Bool(self.reference_is_opt)),
            ("ratio", Json::Num(self.ratio)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("peak_machine_memory", Json::Num(self.peak_machine_memory as f64)),
            ("peak_central_recv", Json::Num(self.peak_central_recv as f64)),
            ("communication", Json::Num(self.communication as f64)),
            ("oracle_calls", Json::Num(self.oracle_calls as f64)),
            ("batched_oracle_calls", Json::Num(self.batched_oracle_calls as f64)),
            ("scalar_oracle_calls", Json::Num(self.scalar_oracle_calls() as f64)),
            ("oracle_batches", Json::Num(self.oracle_batches as f64)),
            ("ipc_bytes_out", Json::Num(self.ipc_bytes_out as f64)),
            ("ipc_bytes_in", Json::Num(self.ipc_bytes_in as f64)),
            ("recoveries", Json::Num(self.recoveries as f64)),
            ("reshipped_bytes", Json::Num(self.reshipped_bytes as f64)),
            ("respawns", Json::Num(self.respawns as f64)),
            ("rebalanced_machines", Json::Num(self.rebalanced_machines as f64)),
            ("mapped_bytes", Json::Num(self.mapped_bytes as f64)),
            ("wall_ms", Json::Num(self.wall_ms)),
            (
                "selection",
                Json::Arr(self.selection.iter().map(|&e| Json::Num(e as f64)).collect()),
            ),
            ("metrics", self.metrics.to_json()),
        ])
    }
}

/// Run `alg` on `inst`, returning the full record.
///
/// The oracle is wrapped in a [`CountingOracle`] and the counter is wired
/// into the cluster config so per-round oracle calls land in the metrics.
pub fn run_experiment(
    inst: &Instance,
    alg: &dyn MrAlgorithm,
    k: usize,
    cfg: &ClusterConfig,
) -> Result<ExperimentRecord> {
    let counting = CountingOracle::new(Arc::clone(&inst.oracle));
    let counters = counting.counter();
    let mut cfg = cfg.clone();
    cfg.call_counter = Some(Arc::clone(&counters));
    // Hand the instance's construction recipe to the cluster so the
    // process backend can rebuild the oracle in its workers.
    if cfg.oracle_spec.is_none() {
        cfg.oracle_spec = inst.spec.clone();
    }

    let start = Instant::now();
    let result = alg.run(&counting, k, &cfg)?;
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    let (oracle_calls, batched_oracle_calls, oracle_batches) = counters.snapshot();

    let (reference, reference_is_opt) = match (inst.known_opt, inst.planted_k) {
        (Some(opt), Some(pk)) if pk == k => (opt, true),
        _ => (lazy_greedy(&inst.oracle, k).value, false),
    };
    let ratio = if reference > 0.0 { result.solution.value / reference } else { 0.0 };

    // compute rounds exclude the r0 partition record.
    let rounds = result.metrics.rounds.iter().filter(|r| !r.name.starts_with("r0:")).count();
    let (ipc_bytes_out, ipc_bytes_in) = result.metrics.total_ipc_bytes();
    let recoveries = result.metrics.total_recoveries();
    let reshipped_bytes = result.metrics.total_reshipped_bytes();
    let respawns = result.metrics.total_respawns();
    let rebalanced_machines = result.metrics.total_rebalanced_machines();
    let mapped_bytes = result.metrics.total_mapped_bytes();

    Ok(ExperimentRecord {
        algorithm: alg.name(),
        instance: inst.name.clone(),
        k,
        seed: cfg.seed,
        value: result.solution.value,
        reference,
        reference_is_opt,
        ratio,
        rounds,
        peak_machine_memory: result.metrics.peak_machine_memory(),
        peak_central_recv: result.metrics.peak_central_recv(),
        communication: result.metrics.total_communication(),
        oracle_calls,
        batched_oracle_calls,
        oracle_batches,
        ipc_bytes_out,
        ipc_bytes_in,
        recoveries,
        reshipped_bytes,
        respawns,
        rebalanced_machines,
        mapped_bytes,
        wall_ms,
        selection: result.solution.elements.clone(),
        metrics: result.metrics,
    })
}

/// Render records as an aligned text table (the benches' output format).
pub fn render_table(title: &str, records: &[ExperimentRecord]) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==\n"));
    out.push_str(&format!(
        "{:<28} {:<34} {:>4} {:>9} {:>7} {:>7} {:>10} {:>10} {:>12} {:>9} {:>9}\n",
        "algorithm", "instance", "k", "value", "ratio", "rounds", "peak-mem", "central", "oracle-calls", "batched%", "wall-ms"
    ));
    for r in records {
        let batched_pct = if r.oracle_calls > 0 {
            100.0 * r.batched_oracle_calls as f64 / r.oracle_calls as f64
        } else {
            0.0
        };
        out.push_str(&format!(
            "{:<28} {:<34} {:>4} {:>9.2} {:>7.4} {:>7} {:>10} {:>10} {:>12} {:>8.1}% {:>9.1}\n",
            r.algorithm,
            truncate(&r.instance, 34),
            r.k,
            r.value,
            r.ratio,
            r.rounds,
            r.peak_machine_memory,
            r.peak_central_recv,
            r.oracle_calls,
            batched_pct,
            r.wall_ms
        ));
    }
    out
}

/// Char-aware truncation to at most `n` characters, appending `…` when the
/// input is longer. Counts chars on both sides of the decision (the old
/// byte-length test over-truncated any multibyte instance name).
fn truncate(s: &str, n: usize) -> String {
    let mut chars = s.char_indices();
    match chars.nth(n) {
        None => s.to_string(),
        Some(_) => {
            let cut = s
                .char_indices()
                .nth(n.saturating_sub(1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            format!("{}…", &s[..cut])
        }
    }
}

/// Write records as pretty JSON.
pub fn write_json(path: &str, records: &[ExperimentRecord]) -> Result<()> {
    let arr = Json::Arr(records.iter().map(ExperimentRecord::to_json).collect());
    std::fs::write(path, arr.to_string_pretty())
        .map_err(|e| crate::core::Error::Runtime(format!("write {path}: {e}")))
}

/// Outcome of comparing a fresh `mrsub bench` report against a committed
/// baseline (`mrsub bench-diff`, `./verify.sh bench-diff`).
#[derive(Debug, Clone)]
pub struct BenchDiff {
    /// Gated metrics that regressed beyond tolerance (human-readable,
    /// one per metric × row).
    pub regressions: Vec<String>,
    /// Non-gating observations: rows present on one side only, improved
    /// metrics, and the within-tolerance summary.
    pub notes: Vec<String>,
    /// The baseline declared itself `"provisional": true` — e.g. it was
    /// hand-seeded before a machine-measured baseline existed — so
    /// regressions are reported but do not gate.
    pub provisional: bool,
    /// Relative tolerance the comparison ran with.
    pub tolerance: f64,
}

impl BenchDiff {
    /// Whether this diff should fail a gate: at least one regression and
    /// a non-provisional baseline.
    pub fn failed(&self) -> bool {
        !self.provisional && !self.regressions.is_empty()
    }

    /// JSON form (uploaded as a CI artifact).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("tolerance", Json::Num(self.tolerance)),
            ("provisional", Json::Bool(self.provisional)),
            ("failed", Json::Bool(self.failed())),
            (
                "regressions",
                Json::Arr(self.regressions.iter().cloned().map(Json::Str).collect()),
            ),
            ("notes", Json::Arr(self.notes.iter().cloned().map(Json::Str).collect())),
        ])
    }

    /// Render as the text block `bench-diff` prints.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "bench-diff (tolerance {:.0}%{}):\n",
            self.tolerance * 100.0,
            if self.provisional { ", baseline provisional — report-only" } else { "" }
        ));
        if self.regressions.is_empty() {
            out.push_str("  no regressions beyond tolerance\n");
        }
        for r in &self.regressions {
            out.push_str(&format!("  REGRESSION: {r}\n"));
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }
}

/// Identity of a cluster-sweep row: the sweep axes, not the measurements.
fn cluster_row_key(row: &Json) -> String {
    let fam = row.get("family").and_then(Json::as_str).unwrap_or("?");
    let backend = row.get("backend").and_then(Json::as_str).unwrap_or("?");
    let n = row.get("n").and_then(Json::as_f64).unwrap_or(0.0);
    let k = row.get("k").and_then(Json::as_f64).unwrap_or(0.0);
    format!("{fam}/{backend}/n={n}/k={k}")
}

/// Per-round IPC bytes of a cluster row (out + in over compute rounds) —
/// the deterministic communication gate; wall-clock is too noisy to gate
/// across machines.
fn row_ipc_per_round(row: &Json) -> Option<f64> {
    let out = row.get("ipc_bytes_out")?.as_f64()?;
    let inb = row.get("ipc_bytes_in")?.as_f64()?;
    let rounds = row.get("rounds")?.as_f64()?;
    if rounds <= 0.0 {
        return None;
    }
    Some((out + inb) / rounds)
}

/// Compare a fresh bench report against a committed baseline.
///
/// Gates (each at relative `tolerance`, default 15% in the CLI):
/// - **hotpath**: `batched_elems_per_s` per family must not drop;
/// - **cluster**: per-round IPC bytes (`(out+in)/rounds`) per
///   family × backend × size must not grow.
///
/// Rows are matched by identity axes; rows present on only one side are
/// noted, not gated (families and backends are allowed to evolve). A
/// baseline with `"provisional": true` reports but never fails —
/// committing a hand-seeded baseline must not brick CI on machines with
/// different absolute throughput.
pub fn bench_diff(baseline: &Json, current: &Json, tolerance: f64) -> BenchDiff {
    let provisional = matches!(baseline.get("provisional"), Some(Json::Bool(true)));
    let mut diff = BenchDiff {
        regressions: Vec::new(),
        notes: Vec::new(),
        provisional,
        tolerance,
    };

    let rows = |report: &Json, key: &str| -> Vec<Json> {
        match report.get(key) {
            Some(Json::Arr(v)) => v.clone(),
            _ => Vec::new(),
        }
    };

    // hotpath: batched-marginal throughput per family must hold up.
    let base_hot = rows(baseline, "hotpath");
    let cur_hot = rows(current, "hotpath");
    for b in &base_hot {
        let fam = b.get("family").and_then(Json::as_str).unwrap_or("?").to_string();
        let Some(c) = cur_hot
            .iter()
            .find(|c| c.get("family").and_then(Json::as_str) == Some(fam.as_str()))
        else {
            diff.notes.push(format!("hotpath family {fam:?} absent from current report"));
            continue;
        };
        let (Some(bv), Some(cv)) = (
            b.get("batched_elems_per_s").and_then(Json::as_f64),
            c.get("batched_elems_per_s").and_then(Json::as_f64),
        ) else {
            continue;
        };
        if bv > 0.0 && cv < bv * (1.0 - tolerance) {
            diff.regressions.push(format!(
                "hotpath {fam}: batched throughput {cv:.3e} el/s is {:.1}% below baseline {bv:.3e}",
                100.0 * (1.0 - cv / bv)
            ));
        } else if bv > 0.0 && cv > bv * (1.0 + tolerance) {
            diff.notes.push(format!(
                "hotpath {fam}: batched throughput improved {bv:.3e} -> {cv:.3e} el/s"
            ));
        }
    }

    // cluster: per-round IPC bytes per sweep point must not grow.
    let base_cluster = rows(baseline, "cluster");
    let cur_cluster = rows(current, "cluster");
    for b in &base_cluster {
        let key = cluster_row_key(b);
        let Some(c) = cur_cluster.iter().find(|c| cluster_row_key(c) == key) else {
            diff.notes.push(format!("cluster row {key} absent from current report"));
            continue;
        };
        let (Some(bv), Some(cv)) = (row_ipc_per_round(b), row_ipc_per_round(c)) else {
            continue;
        };
        if bv > 0.0 && cv > bv * (1.0 + tolerance) {
            diff.regressions.push(format!(
                "cluster {key}: per-round IPC {cv:.0} B is {:.1}% above baseline {bv:.0} B",
                100.0 * (cv / bv - 1.0)
            ));
        } else if bv > 0.0 && cv < bv * (1.0 - tolerance) {
            diff.notes.push(format!(
                "cluster {key}: per-round IPC improved {bv:.0} -> {cv:.0} B"
            ));
        }
    }

    diff
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::combined::CombinedTwoRound;
    use crate::workload::planted::PlantedCoverageGen;
    use crate::workload::WorkloadGen;

    #[test]
    fn record_is_complete_and_serializable() {
        let inst = PlantedCoverageGen::dense(8, 400, 800).generate(1);
        let cfg = ClusterConfig { parallel: false, ..ClusterConfig::default() };
        let rec = run_experiment(&inst, &CombinedTwoRound::new(0.1), 8, &cfg).unwrap();
        assert!(rec.reference_is_opt);
        assert!(rec.ratio >= 0.4);
        assert_eq!(rec.rounds, 2);
        assert!(rec.oracle_calls > 0);
        let json = rec.to_json();
        assert_eq!(json.get("algorithm").unwrap().as_str(), Some(rec.algorithm.as_str()));
        // JSON text parses back.
        assert!(Json::parse(&json.to_string_pretty()).is_ok());
    }

    #[test]
    fn reference_falls_back_to_greedy_for_mismatched_k() {
        let inst = PlantedCoverageGen::dense(8, 400, 800).generate(2);
        let cfg = ClusterConfig { parallel: false, ..ClusterConfig::default() };
        // k != planted k → greedy reference.
        let rec = run_experiment(&inst, &CombinedTwoRound::new(0.1), 5, &cfg).unwrap();
        assert!(!rec.reference_is_opt);
        assert!(rec.reference > 0.0);
    }

    #[test]
    fn truncate_is_char_aware() {
        // ASCII: unchanged when short, n chars total when long.
        assert_eq!(truncate("short", 10), "short");
        assert_eq!(truncate("abcdefgh", 5), "abcd…");
        assert_eq!(truncate("abcde", 5), "abcde");
        // Multibyte: 7 chars but 14+ bytes — must NOT be truncated at n=10
        // (the old byte-length test split it), and truncation must land on
        // a char boundary, never mid-codepoint.
        let s = "coverage·τ≥α₂"; // 13 chars, >13 bytes
        assert_eq!(truncate(s, 13), s);
        assert_eq!(truncate(s, 20), s);
        let cut = truncate(s, 10);
        assert_eq!(cut.chars().count(), 10);
        assert!(cut.ends_with('…'));
        assert!(s.starts_with(cut.trim_end_matches('…')));
        // Degenerate widths stay safe.
        assert_eq!(truncate("αβγ", 1), "…");
        assert_eq!(truncate("", 4), "");
    }

    #[test]
    fn record_reports_batched_split() {
        let inst = PlantedCoverageGen::dense(8, 400, 800).generate(5);
        let cfg = ClusterConfig { parallel: false, ..ClusterConfig::default() };
        let rec = run_experiment(&inst, &CombinedTwoRound::new(0.1), 8, &cfg).unwrap();
        assert!(rec.batched_oracle_calls > 0, "hot loops must use the block path");
        assert!(rec.oracle_batches > 0);
        assert!(rec.batched_oracle_calls <= rec.oracle_calls);
        assert_eq!(
            rec.scalar_oracle_calls(),
            rec.oracle_calls - rec.batched_oracle_calls
        );
        // the block path dominates the oracle traffic of the 2-round algs.
        assert!(
            rec.batched_oracle_calls * 2 > rec.oracle_calls,
            "expected mostly-batched traffic, got {}/{}",
            rec.batched_oracle_calls,
            rec.oracle_calls
        );
        let json = rec.to_json();
        assert!(json.get("batched_oracle_calls").is_some());
        assert!(json.get("oracle_batches").is_some());
    }

    fn report(batched: f64, ipc_out: f64, provisional: bool) -> Json {
        let mut fields = vec![
            (
                "hotpath",
                Json::Arr(vec![Json::obj([
                    ("family", Json::Str("coverage".into())),
                    ("batched_elems_per_s", Json::Num(batched)),
                ])]),
            ),
            (
                "cluster",
                Json::Arr(vec![Json::obj([
                    ("family", Json::Str("coverage".into())),
                    ("backend", Json::Str("process:2@uds".into())),
                    ("n", Json::Num(8000.0)),
                    ("k", Json::Num(20.0)),
                    ("ipc_bytes_out", Json::Num(ipc_out)),
                    ("ipc_bytes_in", Json::Num(1000.0)),
                    ("rounds", Json::Num(2.0)),
                ])]),
            ),
        ];
        if provisional {
            fields.push(("provisional", Json::Bool(true)));
        }
        Json::obj(fields)
    }

    #[test]
    fn bench_diff_passes_within_tolerance() {
        let base = report(1.0e8, 10_000.0, false);
        let cur = report(0.95e8, 10_500.0, false);
        let d = bench_diff(&base, &cur, 0.15);
        assert!(!d.failed(), "{:?}", d.regressions);
        assert!(d.regressions.is_empty());
    }

    #[test]
    fn bench_diff_gates_throughput_drop_and_ipc_growth() {
        let base = report(1.0e8, 10_000.0, false);
        let cur = report(0.5e8, 20_000.0, false);
        let d = bench_diff(&base, &cur, 0.15);
        assert!(d.failed());
        assert_eq!(d.regressions.len(), 2, "{:?}", d.regressions);
        assert!(d.regressions[0].contains("batched throughput"));
        assert!(d.regressions[1].contains("per-round IPC"));
        // the artifact JSON round-trips.
        let j = d.to_json();
        assert!(Json::parse(&j.to_string_pretty()).is_ok());
        assert!(d.render().contains("REGRESSION"));
    }

    #[test]
    fn bench_diff_provisional_baseline_reports_but_never_fails() {
        let base = report(1.0e8, 10_000.0, true);
        let cur = report(0.5e8, 20_000.0, false);
        let d = bench_diff(&base, &cur, 0.15);
        assert!(d.provisional);
        assert!(!d.failed(), "provisional baselines must be report-only");
        assert_eq!(d.regressions.len(), 2);
        assert!(d.render().contains("report-only"));
    }

    #[test]
    fn bench_diff_missing_rows_are_notes_not_gates() {
        let base = report(1.0e8, 10_000.0, false);
        let cur = Json::obj([
            ("hotpath", Json::Arr(vec![])),
            ("cluster", Json::Arr(vec![])),
        ]);
        let d = bench_diff(&base, &cur, 0.15);
        assert!(!d.failed());
        assert_eq!(d.notes.len(), 2, "{:?}", d.notes);
    }

    #[test]
    fn table_renders() {
        let inst = PlantedCoverageGen::sparse(5, 100, 100).generate(3);
        let cfg = ClusterConfig { parallel: false, ..ClusterConfig::default() };
        let rec = run_experiment(&inst, &CombinedTwoRound::new(0.1), 5, &cfg).unwrap();
        let table = render_table("test", &[rec]);
        assert!(table.contains("combined"));
        assert!(table.contains("ratio"));
    }
}
