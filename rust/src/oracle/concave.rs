//! Concave-over-modular oracle: `f(S) = Σ_g φ(Σ_{e ∈ S} w_{g,e})` with
//! `φ` concave, non-decreasing, `φ(0) = 0` (we use `φ = sqrt` or a
//! saturating `1 − exp(−x)`).
//!
//! A classic "soft coverage" family (feature saturation in summarization /
//! data-subset selection). Unlike hard coverage its marginals decay
//! smoothly, which stresses the threshold bucketing differently: many
//! elements sit just above/below a threshold instead of dropping to zero.

use std::sync::Arc;

use super::{Oracle, OracleState, Selection};
use crate::core::ElementId;

/// The concave link function applied to each group's accumulated mass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phi {
    /// `φ(x) = sqrt(x)`.
    Sqrt,
    /// `φ(x) = 1 − exp(−x)`, saturating at 1.
    Saturate,
}

impl Phi {
    #[inline]
    fn eval(self, x: f64) -> f64 {
        match self {
            Phi::Sqrt => x.sqrt(),
            Phi::Saturate => 1.0 - (-x).exp(),
        }
    }
}

/// Sparse element→(group, weight) incidence with a concave link.
#[derive(Debug)]
pub struct ConcaveOverModularOracle {
    data: Arc<ComData>,
}

#[derive(Debug)]
struct ComData {
    n: usize,
    groups: usize,
    /// CSR offsets per element into `entries`.
    offsets: Vec<u32>,
    /// (group, weight) pairs.
    entries: Vec<(u32, f64)>,
    phi: Phi,
}

impl ConcaveOverModularOracle {
    /// Build from per-element sparse (group, weight >= 0) lists. Duplicate
    /// groups within one element are merged (summed) so a marginal is
    /// well-defined per group.
    pub fn new(n: usize, groups: usize, incidence: Vec<Vec<(u32, f64)>>, phi: Phi) -> Self {
        assert_eq!(incidence.len(), n);
        let mut offsets = Vec::with_capacity(n + 1);
        let mut entries: Vec<(u32, f64)> = Vec::new();
        offsets.push(0u32);
        for row in &incidence {
            let mut merged: Vec<(u32, f64)> = Vec::with_capacity(row.len());
            let mut sorted = row.clone();
            sorted.sort_by_key(|&(g, _)| g);
            for &(g, w) in &sorted {
                assert!((g as usize) < groups, "group {g} out of range");
                debug_assert!(w >= 0.0);
                match merged.last_mut() {
                    Some((lg, lw)) if *lg == g => *lw += w,
                    _ => merged.push((g, w)),
                }
            }
            entries.extend(merged);
            offsets.push(entries.len() as u32);
        }
        ConcaveOverModularOracle { data: Arc::new(ComData { n, groups, offsets, entries, phi }) }
    }
}

impl Oracle for ConcaveOverModularOracle {
    fn ground_size(&self) -> usize {
        self.data.n
    }

    fn state(&self) -> Box<dyn OracleState> {
        Box::new(ComState {
            data: Arc::clone(&self.data),
            mass: vec![0.0; self.data.groups],
            sel: Selection::new(self.data.n),
            value: 0.0,
        })
    }
}

#[derive(Debug, Clone)]
struct ComState {
    data: Arc<ComData>,
    /// Accumulated modular mass per group.
    mass: Vec<f64>,
    sel: Selection,
    value: f64,
}

impl ComState {
    /// Per-element gain kernel shared by the scalar and block paths, so
    /// both return bit-identical values.
    #[inline]
    fn gain_of(&self, e: ElementId) -> f64 {
        let d = &*self.data;
        let (lo, hi) = (d.offsets[e as usize] as usize, d.offsets[e as usize + 1] as usize);
        let phi = d.phi;
        let mut gain = 0.0;
        for &(g, w) in &d.entries[lo..hi] {
            let m = self.mass[g as usize];
            gain += phi.eval(m + w) - phi.eval(m);
        }
        gain
    }
}

impl OracleState for ComState {
    fn value(&self) -> f64 {
        self.value
    }

    fn marginal(&self, e: ElementId) -> f64 {
        if self.sel.contains(e) {
            return 0.0;
        }
        self.gain_of(e)
    }

    /// Block path: one incidence sweep per block with member tests and
    /// data pointers hoisted out of the virtual call.
    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(es.len(), out.len());
        for (o, &e) in out.iter_mut().zip(es) {
            *o = if self.sel.contains(e) { 0.0 } else { self.gain_of(e) };
        }
    }

    fn reset(&mut self) {
        self.mass.fill(0.0);
        self.sel.clear();
        self.value = 0.0;
    }

    fn insert(&mut self, e: ElementId) {
        if !self.sel.insert(e) {
            return;
        }
        let data = Arc::clone(&self.data);
        let (lo, hi) = (data.offsets[e as usize] as usize, data.offsets[e as usize + 1] as usize);
        let phi = data.phi;
        for &(g, w) in &data.entries[lo..hi] {
            let m = self.mass[g as usize];
            self.value += phi.eval(m + w) - phi.eval(m);
            self.mass[g as usize] = m + w;
        }
    }

    fn selected(&self) -> &[ElementId] {
        self.sel.order()
    }

    fn clone_state(&self) -> Box<dyn OracleState> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::axioms::check_axioms;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn random_instance(n: usize, groups: usize, seed: u64, phi: Phi) -> ConcaveOverModularOracle {
        let mut rng = Rng::seed_from_u64(seed);
        let incidence: Vec<Vec<(u32, f64)>> = (0..n)
            .map(|_| {
                let deg = rng.gen_range(1..5);
                (0..deg)
                    .map(|_| {
                        (rng.gen_range(0..groups) as u32, rng.gen_range_f64(0.0, 2.0))
                    })
                    .collect()
            })
            .collect();
        ConcaveOverModularOracle::new(n, groups, incidence, phi)
    }

    #[test]
    fn sqrt_single_group() {
        // two elements each worth 1.0 in group 0: f({a}) = 1, f({a,b}) = sqrt(2).
        let o = ConcaveOverModularOracle::new(
            2,
            1,
            vec![vec![(0, 1.0)], vec![(0, 1.0)]],
            Phi::Sqrt,
        );
        assert!((o.value(&[0]) - 1.0).abs() < 1e-12);
        assert!((o.value(&[0, 1]) - 2f64.sqrt()).abs() < 1e-12);
        let mut st = o.state();
        st.insert(0);
        assert!((st.marginal(1) - (2f64.sqrt() - 1.0)).abs() < 1e-12);
    }

    #[test]
    fn saturate_caps_at_group_count() {
        let o = random_instance(30, 5, 3, Phi::Saturate);
        let all: Vec<ElementId> = (0..30).collect();
        assert!(o.value(&all) <= 5.0 + 1e-9);
    }

    #[test]
    fn prop_com_axioms() {
        forall(0xC0A, 20, |g| {
            let seed = g.u64_in(200);
            let n = g.usize_in(5, 25);
            let groups = g.usize_in(1, 8);
            let phi = if g.bool_with(0.5) { Phi::Saturate } else { Phi::Sqrt };
            let o = random_instance(n, groups, seed, phi);
            check_axioms(&o, seed ^ 0x33, 6);
        });
    }
}
