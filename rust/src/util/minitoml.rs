//! TOML-subset parser for run configs: top-level keys, `[table]` headers
//! (one level), and scalar values (string, integer, float, boolean).
//! Comments (`#`), blank lines, and underscores in numbers are handled.
//! Arrays/dates/nested tables are intentionally out of scope — configs in
//! this repo don't use them.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
}

impl Value {
    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric accessor (ints widen to f64).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer accessor.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    /// u64 accessor.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// One table: key → value.
pub type Table = BTreeMap<String, Value>;

/// A parsed document: the root table plus named tables.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    /// Keys before any `[section]` header.
    pub root: Table,
    /// Named `[section]` tables in declaration order-independent storage.
    pub tables: BTreeMap<String, Table>,
}

impl Document {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Document, String> {
        let mut doc = Document::default();
        let mut current: Option<String> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated table header", lineno + 1))?
                    .trim();
                if name.is_empty() || name.contains('[') || name.contains('.') {
                    return Err(format!("line {}: unsupported table header {name:?}", lineno + 1));
                }
                doc.tables.entry(name.to_string()).or_default();
                current = Some(name.to_string());
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = parse_value(value.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let table = match &current {
                None => &mut doc.root,
                Some(name) => doc.tables.get_mut(name).expect("created on header"),
            };
            table.insert(key.to_string(), value);
        }
        Ok(doc)
    }

    /// Named table accessor.
    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.get(name)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string must not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value, String> {
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        // minimal escapes
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("bad escape \\{other:?}")),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean: String = text.chars().filter(|&c| c != '_').collect();
    if !clean.contains('.') && !clean.contains('e') && !clean.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(x) = clean.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    Err(format!("cannot parse value {text:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_run_config_shape() {
        let text = r#"
            # experiment
            k = 50
            seed = 7

            [instance]
            kind = "coverage"   # dense regime
            n = 100_000
            universe = 40000
            avg_degree = 12
            weighted = false

            [algorithm]
            kind = "combined"
            eps = 0.1

            [cluster]
            sample_factor = 4.0
            parallel = true
        "#;
        let doc = Document::parse(text).unwrap();
        assert_eq!(doc.root["k"], Value::Int(50));
        assert_eq!(doc.table("instance").unwrap()["n"], Value::Int(100_000));
        assert_eq!(doc.table("instance").unwrap()["kind"].as_str(), Some("coverage"));
        assert_eq!(doc.table("algorithm").unwrap()["eps"].as_f64(), Some(0.1));
        assert_eq!(doc.table("cluster").unwrap()["parallel"].as_bool(), Some(true));
    }

    #[test]
    fn strings_with_hash_and_escapes() {
        let doc = Document::parse(r#"name = "a # not comment \n b"  # real comment"#).unwrap();
        assert_eq!(doc.root["name"].as_str(), Some("a # not comment \n b"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Document::parse("[unclosed").is_err());
        assert!(Document::parse("novalue").is_err());
        assert!(Document::parse("x = ").is_err());
        assert!(Document::parse("[a.b]\nx = 1").is_err());
        assert!(Document::parse(r#"s = "unterminated"#).is_err());
    }

    #[test]
    fn numbers_and_accessors() {
        let doc = Document::parse("a = -3\nb = 2.5\nc = 1e3\nd = true").unwrap();
        assert_eq!(doc.root["a"].as_f64(), Some(-3.0));
        assert_eq!(doc.root["a"].as_usize(), None);
        assert_eq!(doc.root["b"].as_f64(), Some(2.5));
        assert_eq!(doc.root["c"].as_f64(), Some(1000.0));
        assert_eq!(doc.root["d"].as_bool(), Some(true));
        assert_eq!(doc.root["d"].as_f64(), None);
    }
}
