//! Facility-location oracle: `f(S) = Σ_j max_{i ∈ S} sim(i, j)` over a dense
//! similarity matrix — the exemplar-selection objective of the distributed
//! submodular-maximization literature (Mirzasoleiman et al., Barbosa et al.).
//!
//! The state keeps the running per-point coverage vector
//! `cur[j] = max_{i∈G} sim(i,j)`, so a marginal is a single row scan:
//! `f_G(e) = Σ_j max(sim(e,j) − cur[j], 0)`. This row scan is exactly the
//! computation the L1 Pallas kernel implements; [`super::hlo::HloFacilityOracle`]
//! is the PJRT-accelerated twin of this oracle and is tested against it.

use std::sync::Arc;

use super::{Oracle, OracleState, Selection};
use crate::core::ElementId;

/// Dense facility-location instance. `sim` is row-major `n × d`, `sim >= 0`.
#[derive(Debug)]
pub struct FacilityOracle {
    data: Arc<FacilityData>,
}

#[derive(Debug)]
pub(crate) struct FacilityData {
    pub n: usize,
    pub d: usize,
    /// Row-major similarities, length `n * d`, all entries `>= 0`.
    pub sim: Vec<f32>,
}

impl FacilityOracle {
    /// Build from a row-major `n × d` similarity matrix (entries must be >= 0).
    pub fn new(n: usize, d: usize, sim: Vec<f32>) -> Self {
        assert_eq!(sim.len(), n * d, "sim must be n*d row-major");
        debug_assert!(sim.iter().all(|&x| x >= 0.0), "similarities must be non-negative");
        FacilityOracle { data: Arc::new(FacilityData { n, d, sim }) }
    }

    /// Number of demand points (columns).
    pub fn num_points(&self) -> usize {
        self.data.d
    }

    /// Similarity row of element `e`.
    pub fn row(&self, e: ElementId) -> &[f32] {
        let d = self.data.d;
        &self.data.sim[e as usize * d..(e as usize + 1) * d]
    }

}

/// Column-tile width of the facility kernels: lane sums fold into f64
/// every `TILE` columns (f32-ulp accuracy regardless of row length), and
/// the block path walks the universe in `TILE`-column stripes so the
/// coverage tile stays L1-resident across a whole candidate block.
const TILE: usize = 1024;

/// One tile of the marginal row scan: `Σ_j max(row[j] − cur[j], 0)` with 8
/// independent f32 lane accumulators (LLVM vectorizes the
/// subtract/max/add chain), folded to f64 at the end. Shared by the scalar
/// and block paths so both produce bit-identical sums.
#[inline]
fn relu_dot_tile(row: &[f32], cur: &[f32]) -> f64 {
    const LANES: usize = 8;
    debug_assert_eq!(row.len(), cur.len());
    let mut acc = [0.0f32; LANES];
    let (mut r, mut c) = (row, cur);
    while r.len() >= LANES {
        for l in 0..LANES {
            acc[l] += (r[l] - c[l]).max(0.0);
        }
        r = &r[LANES..];
        c = &c[LANES..];
    }
    for l in 0..r.len() {
        acc[l] += (r[l] - c[l]).max(0.0);
    }
    acc.iter().map(|&x| x as f64).sum::<f64>()
}

/// The full marginal row scan: `Σ_j max(row[j] − cur[j], 0)`, tile by
/// tile. ~8× faster than the scalar branchy/widening loop it replaced
/// (see EXPERIMENTS.md §Perf).
#[inline]
pub(crate) fn relu_dot_gain(row: &[f32], cur: &[f32]) -> f64 {
    debug_assert_eq!(row.len(), cur.len());
    let mut gain = 0.0f64;
    let mut i = 0;
    while i < row.len() {
        let end = (i + TILE).min(row.len());
        gain += relu_dot_tile(&row[i..end], &cur[i..end]);
        i = end;
    }
    gain
}

impl Oracle for FacilityOracle {
    fn ground_size(&self) -> usize {
        self.data.n
    }

    fn state(&self) -> Box<dyn OracleState> {
        Box::new(FacilityState {
            data: Arc::clone(&self.data),
            cur: vec![0.0; self.data.d],
            sel: Selection::new(self.data.n),
            value: 0.0,
        })
    }
}

#[derive(Debug, Clone)]
struct FacilityState {
    data: Arc<FacilityData>,
    /// cur[j] = max_{i in G} sim(i, j); empty max = 0 (f(∅) = 0).
    cur: Vec<f32>,
    sel: Selection,
    value: f64,
}

impl OracleState for FacilityState {
    fn value(&self) -> f64 {
        self.value
    }

    #[inline]
    fn marginal(&self, e: ElementId) -> f64 {
        if self.sel.contains(e) {
            return 0.0;
        }
        let d = self.data.d;
        let row = &self.data.sim[e as usize * d..(e as usize + 1) * d];
        relu_dot_gain(row, &self.cur)
    }

    fn insert(&mut self, e: ElementId) {
        if !self.sel.insert(e) {
            return;
        }
        let d = self.data.d;
        let data = Arc::clone(&self.data);
        let row = &data.sim[e as usize * d..(e as usize + 1) * d];
        let mut gain = 0.0f64;
        for (c, s) in self.cur.iter_mut().zip(row) {
            if *s > *c {
                gain += (*s - *c) as f64;
                *c = *s;
            }
        }
        self.value += gain;
    }

    fn selected(&self) -> &[ElementId] {
        self.sel.order()
    }

    fn clone_state(&self) -> Box<dyn OracleState> {
        Box::new(self.clone())
    }

    fn reset(&mut self) {
        self.cur.fill(0.0);
        self.sel.clear();
        self.value = 0.0;
    }

    /// Block path, column-tiled: the universe is walked in `TILE`-column
    /// stripes with all candidate rows visited per stripe, so the coverage
    /// tile is read from L1 for the whole block instead of being
    /// re-streamed per row. Per-element sums accumulate in tile order —
    /// exactly [`relu_dot_gain`]'s order — so results are bit-identical to
    /// the scalar path.
    fn marginals(&self, es: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(es.len(), out.len());
        out.fill(0.0);
        let d = self.data.d;
        let sim = &self.data.sim;
        let mut col = 0;
        while col < d {
            let end = (col + TILE).min(d);
            let cur_tile = &self.cur[col..end];
            for (o, &e) in out.iter_mut().zip(es) {
                if self.sel.contains(e) {
                    continue;
                }
                let base = e as usize * d;
                *o += relu_dot_tile(&sim[base + col..base + end], cur_tile);
            }
            col = end;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::axioms::check_axioms;
    use crate::util::check::forall;

    fn tiny() -> FacilityOracle {
        // 3 elements, 2 points.
        FacilityOracle::new(3, 2, vec![1.0, 0.0, 0.0, 2.0, 0.5, 0.5])
    }

    #[test]
    fn values() {
        let o = tiny();
        assert_eq!(o.value(&[0]), 1.0);
        assert_eq!(o.value(&[1]), 2.0);
        assert_eq!(o.value(&[0, 1]), 3.0);
        assert_eq!(o.value(&[0, 1, 2]), 3.0); // element 2 dominated
        let mut st = o.state();
        st.insert(2);
        assert_eq!(st.value(), 1.0);
        assert_eq!(st.marginal(0), 0.5);
        assert_eq!(st.marginal(1), 1.5);
    }

    #[test]
    fn axioms_hold_random_instance() {
        let o = crate::workload::facility::FacilityGen::new(40, 25).build(5);
        check_axioms(&o, 17, 30);
    }

    #[test]
    fn prop_facility_axioms() {
        forall(0xFA1, 20, |g| {
            let seed = g.u64_in(500);
            let n = g.usize_in(6, 30);
            let d = g.usize_in(2, 20);
            let o = crate::workload::facility::FacilityGen::new(n, d).build(seed);
            check_axioms(&o, seed ^ 0x5f5f, 6);
        });
    }

    #[test]
    fn prop_value_bounded_by_colmax_sum() {
        forall(0xFA2, 20, |g| {
            let seed = g.u64_in(100);
            let o = crate::workload::facility::FacilityGen::new(20, 10).build(seed);
            let all: Vec<ElementId> = (0..20).collect();
            let mut bound = 0.0f64;
            for j in 0..10 {
                let mut m = 0.0f32;
                for e in 0..20u32 {
                    m = m.max(o.row(e)[j]);
                }
                bound += m as f64;
            }
            assert!((o.value(&all) - bound).abs() < 1e-6 * (1.0 + bound));
        });
    }
}
