//! E7a ("Table 4") — cluster-runtime throughput: end-to-end wall time and
//! filter throughput (elements/s through ThresholdFilter) of the combined
//! algorithm as the simulated cluster scales, serial vs parallel machine
//! execution, plus thread-pool scaling on a fixed instance.

use std::time::Instant;

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::MrAlgorithm;
use mrsub::mapreduce::ClusterConfig;
use mrsub::util::bench::fmt_dur;
use mrsub::workload::coverage::CoverageGen;
use mrsub::workload::WorkloadGen;

fn main() {
    let k = 50;
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("== E7a: cluster throughput, combined(eps=0.1), k={k} ==");
    println!("(testbed has {cpus} CPU(s) — with 1 CPU the parallel rows measure pool");
    println!("dispatch overhead only; speedups require a multi-core host)\n");
    println!(
        "{:>8} {:>9} {:>10} {:>12} {:>12} {:>14}",
        "n", "machines", "mode", "wall", "speedup", "elems/s"
    );
    for n in [50_000usize, 100_000, 200_000] {
        let inst = CoverageGen::new(n, n / 3, 10).generate(3);
        let mut serial_time = 0.0f64;
        for parallel in [false, true] {
            let cfg = ClusterConfig { seed: 3, parallel, ..ClusterConfig::default() };
            let alg = CombinedTwoRound::new(0.1);
            let t0 = Instant::now();
            let res = alg.run(&inst.oracle, k, &cfg).expect("run");
            let dt = t0.elapsed();
            let secs = dt.as_secs_f64();
            if !parallel {
                serial_time = secs;
            }
            println!(
                "{:>8} {:>9} {:>10} {:>12} {:>12.2} {:>14.0}",
                n,
                res.metrics.machines,
                if parallel { "parallel" } else { "serial" },
                fmt_dur(dt),
                serial_time / secs,
                n as f64 / secs
            );
        }
    }

    println!("\n-- thread scaling (n=200k, MRSUB_THREADS sweep) --");
    println!("{:>8} {:>12} {:>10}", "threads", "wall", "speedup");
    let inst = CoverageGen::new(200_000, 66_000, 10).generate(3);
    let mut t1 = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        std::env::set_var("MRSUB_THREADS", threads.to_string());
        let cfg = ClusterConfig { seed: 3, parallel: true, ..ClusterConfig::default() };
        let t0 = Instant::now();
        CombinedTwoRound::new(0.1).run(&inst.oracle, k, &cfg).expect("run");
        let secs = t0.elapsed().as_secs_f64();
        if threads == 1 {
            t1 = secs;
        }
        println!("{:>8} {:>12} {:>10.2}", threads, fmt_dur(t0.elapsed()), t1 / secs);
    }
    std::env::remove_var("MRSUB_THREADS");
    println!("\nexpected shape: parallel mode speeds up the worker rounds by ~min(threads,");
    println!("machines)× until the (serial) central completion and oracle setup dominate");
    println!("(Amdahl); elements/s grows with n at roughly constant per-element cost.");
}
