//! Document selection over a Zipf corpus — the max-coverage application
//! that motivates the paper's line of work (McGregor–Vu, Assadi–Khanna
//! study exactly distributed max-coverage).
//!
//! Selects k documents maximizing IDF-weighted word coverage from a
//! 60k-document synthetic corpus, comparing the paper's 2-round algorithm
//! against the prior-art baselines at equal round budgets.
//!
//! ```bash
//! cargo run --release --example corpus_selection
//! ```

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::mz_coreset::MzCoreset;
use mrsub::algorithms::randgreedi::RandGreeDi;
use mrsub::algorithms::sample_prune::SamplePrune;
use mrsub::algorithms::MrAlgorithm;
use mrsub::config::GreedyAlg;
use mrsub::coordinator::{render_table, run_experiment};
use mrsub::mapreduce::ClusterConfig;
use mrsub::workload::corpus::ZipfCorpusGen;
use mrsub::workload::WorkloadGen;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let inst = ZipfCorpusGen::idf(60_000, 30_000, 40).generate(2024);
    let k = 50;
    let cfg = ClusterConfig { seed: 2024, ..ClusterConfig::default() };

    let algs: Vec<Box<dyn MrAlgorithm>> = vec![
        Box::new(GreedyAlg),
        Box::new(CombinedTwoRound::new(0.1)),
        Box::new(RandGreeDi),
        Box::new(MzCoreset),
        Box::new(SamplePrune::new(0.2)),
    ];
    let mut records = Vec::new();
    for alg in &algs {
        println!("running {} …", alg.name());
        records.push(run_experiment(&inst, alg.as_ref(), k, &cfg)?);
    }
    println!(
        "{}",
        render_table("corpus selection: 60k docs, 30k vocab, IDF-weighted (ref = greedy)", &records)
    );

    // The paper's claim in this regime: 2 rounds, ≥ 1/2−ε of greedy.
    let combined = &records[1];
    if combined.rounds != 2 {
        return Err("combined must run in 2 rounds".into());
    }
    if combined.ratio < 0.5 - 0.1 {
        return Err(format!("combined ratio {} below guarantee", combined.ratio).into());
    }
    println!("OK: 2 rounds, ratio {:.4} ≥ 1/2 − ε", combined.ratio);
    Ok(())
}
