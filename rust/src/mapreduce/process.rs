//! Shared-nothing process backend: one OS worker process per group of
//! simulated machines, speaking the [`crate::mapreduce::wire`] protocol
//! over stdin/stdout pipes.
//!
//! ## Topology
//!
//! [`ProcessPool::spawn`] re-executes the current binary (or an explicit
//! `worker_exe`) with the hidden `mrsub worker` subcommand, one process
//! per worker, and assigns the `m` simulated machines round-robin across
//! the `N` workers of `--backend process:N`. Each worker receives — once,
//! at init — the oracle *spec* (rebuilt deterministically on its side; no
//! shared memory), its machines' shards, and the broadcast sample. Worker
//! processes then persist across rounds: Algorithm 5's `t` thresholds pay
//! one spawn, not `t`.
//!
//! ## Round protocol
//!
//! A round writes one `Round(task)` frame to every worker (all workers
//! compute concurrently), then joins the replies in worker order. Replies
//! carry per-machine [`TaskReply`]s plus the worker-side oracle-call delta,
//! which the coordinator merges into its [`OracleCounters`] so
//! `MrMetrics` sees one coherent count. All frame traffic is metered —
//! the per-round IPC byte counts land in `RoundStat::ipc_bytes_*`.
//!
//! ## Failure surface
//!
//! Every failure mode — worker killed mid-round, truncated or corrupted
//! reply frame, oversized frame, handshake version mismatch, worker-side
//! error — is a structured [`Error::Worker`] (never a panic, never a
//! poisoned coordinator): the pool marks the worker dead, reaps the child,
//! and the algorithm's `run` surfaces `Err`. Each worker gets a dedicated
//! reader thread *and* writer thread, so the coordinator itself never
//! blocks on a pipe — a worker that stops replying *or* stops reading is
//! bounded by `worker_timeout_ms`, never a coordinator hang. Reply shapes
//! are validated against the task ([`wire::reply_matches`]) before use.
//!
//! The `MRSUB_FAULT` environment variable (set by the conformance suite
//! via `worker_env`) injects worker-side faults: `die-mid-round`,
//! `hang-round`, `truncate-frame`, `corrupt-checksum`, `bad-version`.

use std::io::{Read, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::core::{ElementId, Error, Result};
use crate::mapreduce::shard::{self, GuessStore};
use crate::mapreduce::wire::{
    self, FromWorker, RoundTask, TaskReply, ToWorker, WireError, WorkerInit, DEFAULT_MAX_FRAME,
    WIRE_VERSION,
};
use crate::oracle::spec::OracleSpec;
use crate::oracle::{CountingOracle, Oracle, OracleCounters};

/// Pool construction knobs (derived from `ClusterConfig` by the cluster).
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Worker processes to spawn (capped at the machine count).
    pub workers: usize,
    /// Per-reply wait bound; a worker silent for longer is declared dead.
    pub timeout: Duration,
    /// Hard cap on a single frame's payload.
    pub max_frame: usize,
    /// Worker executable; `None` = `std::env::current_exe()` (the normal
    /// case — coordinator and worker are the same binary). Tests point
    /// this at the built `mrsub` binary.
    pub exe: Option<PathBuf>,
    /// Extra environment for workers (fault injection uses `MRSUB_FAULT`).
    pub env: Vec<(String, String)>,
}

impl Default for PoolOptions {
    fn default() -> Self {
        PoolOptions {
            workers: 1,
            timeout: Duration::from_millis(30_000),
            max_frame: DEFAULT_MAX_FRAME,
            exe: None,
            env: Vec::new(),
        }
    }
}

/// Per-round IPC accounting returned by [`ProcessPool::round`].
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundIpcStats {
    /// Frame bytes coordinator → workers this round.
    pub bytes_out: u64,
    /// Frame bytes workers → coordinator this round.
    pub bytes_in: u64,
    /// Worker-side oracle calls `(total, batched, batches)` this round.
    pub calls: (u64, u64, u64),
}

struct WorkerHandle {
    child: Child,
    /// Payloads to the dedicated writer thread (which owns the pipe and
    /// does the blocking `write`); `None` once closed (shutdown/failure).
    /// Queueing instead of writing inline keeps the coordinator off the
    /// pipe: a worker that stops *reading* cannot wedge the coordinator —
    /// the reply timeout still fires and the worker is declared dead.
    tx: Option<mpsc::Sender<Vec<u8>>>,
    /// Frames from the dedicated reader thread: `(payload, frame_bytes)`.
    rx: mpsc::Receiver<std::result::Result<(Vec<u8>, usize), WireError>>,
    /// Simulated machine ids this worker hosts.
    machines: Vec<usize>,
    alive: bool,
}

/// A running pool of shared-nothing worker processes.
pub struct ProcessPool {
    workers: Vec<WorkerHandle>,
    n_machines: usize,
    timeout: Duration,
    max_frame: usize,
    bytes_out: u64,
    bytes_in: u64,
}

fn worker_error(worker: usize, message: impl Into<String>) -> Error {
    Error::Worker { worker, message: message.into() }
}

impl ProcessPool {
    /// Spawn workers, ship each its shards + spec + sample, and complete
    /// the `Ready` handshake.
    pub fn spawn(
        spec: &OracleSpec,
        shards: &[Vec<ElementId>],
        sample: &[ElementId],
        opts: &PoolOptions,
    ) -> Result<ProcessPool> {
        let m = shards.len();
        if m == 0 {
            return Err(Error::Config("process pool needs at least one machine".into()));
        }
        let w = opts.workers.clamp(1, m);
        let exe = match &opts.exe {
            Some(p) => p.clone(),
            None => std::env::current_exe()
                .map_err(|e| Error::Config(format!("cannot locate worker executable: {e}")))?,
        };
        let mut machines_of: Vec<Vec<usize>> = vec![Vec::new(); w];
        for i in 0..m {
            machines_of[i % w].push(i);
        }
        let mut workers: Vec<WorkerHandle> = Vec::with_capacity(w);
        for (wi, machines) in machines_of.into_iter().enumerate() {
            let mut cmd = Command::new(&exe);
            cmd.arg("worker")
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .env("MRSUB_MAX_FRAME", opts.max_frame.to_string());
            for (key, val) in &opts.env {
                cmd.env(key, val);
            }
            let mut child = match cmd.spawn() {
                Ok(child) => child,
                Err(e) => {
                    // reap the workers already spawned — no zombies on a
                    // partial spawn (process-limit pressure, vanished exe).
                    for mut prev in workers {
                        let _ = prev.child.kill();
                        let _ = prev.child.wait();
                    }
                    return Err(worker_error(wi, format!("spawn {}: {e}", exe.display())));
                }
            };
            let mut stdin = child.stdin.take().expect("stdin piped");
            let mut stdout = child.stdout.take().expect("stdout piped");
            let (reply_tx, rx) = mpsc::channel();
            let (tx, payload_rx) = mpsc::channel::<Vec<u8>>();
            let max_frame = opts.max_frame;
            std::thread::spawn(move || loop {
                let res = wire::read_frame(&mut stdout, max_frame);
                let stop = res.is_err();
                if reply_tx.send(res).is_err() || stop {
                    break;
                }
            });
            std::thread::spawn(move || {
                // exits when the sender is dropped (shutdown/mark_dead) or
                // the pipe breaks; dropping stdin EOFs the worker.
                while let Ok(payload) = payload_rx.recv() {
                    if wire::write_frame(&mut stdin, &payload, max_frame).is_err() {
                        break;
                    }
                }
            });
            workers.push(WorkerHandle { child, tx: Some(tx), rx, machines, alive: true });
        }
        let mut pool = ProcessPool {
            workers,
            n_machines: m,
            timeout: opts.timeout,
            max_frame: opts.max_frame,
            bytes_out: 0,
            bytes_in: 0,
        };
        for wi in 0..pool.workers.len() {
            let init = ToWorker::Init(WorkerInit {
                spec: spec.clone(),
                machines: pool.workers[wi].machines.iter().map(|&i| i as u32).collect(),
                shards: pool.workers[wi].machines.iter().map(|&i| shards[i].clone()).collect(),
                sample: sample.to_vec(),
            });
            pool.send(wi, &init)?;
        }
        for wi in 0..pool.workers.len() {
            match pool.recv(wi)? {
                FromWorker::Ready { version } if version == WIRE_VERSION => {}
                FromWorker::Ready { version } => {
                    return Err(pool.mark_dead(
                        wi,
                        format!(
                            "wire version mismatch: worker speaks v{version}, \
                             coordinator v{WIRE_VERSION}"
                        ),
                    ))
                }
                FromWorker::Fail { message } => {
                    return Err(pool.mark_dead(wi, format!("init failed: {message}")))
                }
                other => {
                    return Err(pool.mark_dead(wi, format!("unexpected init reply: {other:?}")))
                }
            }
        }
        Ok(pool)
    }

    /// Number of worker processes.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Number of simulated machines served.
    pub fn machines(&self) -> usize {
        self.n_machines
    }

    /// Total frame bytes sent/received since spawn.
    pub fn total_ipc_bytes(&self) -> (u64, u64) {
        (self.bytes_out, self.bytes_in)
    }

    /// Execute one round on every worker; returns per-machine replies (in
    /// machine order) plus the round's IPC stats.
    pub fn round(&mut self, task: &RoundTask) -> Result<(Vec<TaskReply>, RoundIpcStats)> {
        let (out0, in0) = (self.bytes_out, self.bytes_in);
        // one encode; every worker receives byte-identical frames.
        let payload = ToWorker::Round(task.clone()).encode();
        for wi in 0..self.workers.len() {
            self.send_payload(wi, &payload)?;
        }
        let mut out: Vec<Option<TaskReply>> = (0..self.n_machines).map(|_| None).collect();
        let mut calls = (0u64, 0u64, 0u64);
        for wi in 0..self.workers.len() {
            match self.recv(wi)? {
                FromWorker::RoundDone { replies, calls: c } => {
                    let hosted = self.workers[wi].machines.len();
                    if replies.len() != hosted {
                        return Err(self.mark_dead(
                            wi,
                            format!("returned {} replies for {hosted} machines", replies.len()),
                        ));
                    }
                    if let Some(bad) =
                        replies.iter().find(|r| !wire::reply_matches(task, r))
                    {
                        let msg = format!(
                            "reply shape mismatch for {} task: {bad:?}",
                            task.label()
                        );
                        return Err(self.mark_dead(wi, msg));
                    }
                    for (slot, reply) in replies.into_iter().enumerate() {
                        out[self.workers[wi].machines[slot]] = Some(reply);
                    }
                    calls.0 += c.0;
                    calls.1 += c.1;
                    calls.2 += c.2;
                }
                FromWorker::Fail { message } => return Err(self.mark_dead(wi, message)),
                FromWorker::Ready { .. } => {
                    return Err(self.mark_dead(wi, "unexpected Ready mid-round"))
                }
            }
        }
        let replies: Vec<TaskReply> =
            out.into_iter().map(|r| r.expect("every machine is assigned a worker")).collect();
        let stats = RoundIpcStats {
            bytes_out: self.bytes_out - out0,
            bytes_in: self.bytes_in - in0,
            calls,
        };
        Ok((replies, stats))
    }

    /// Fault injection (tests): kill worker `wi`'s OS process *without*
    /// telling the pool — the next round must surface a structured error,
    /// exactly as if the process died on its own.
    pub fn kill_worker(&mut self, wi: usize) {
        if let Some(w) = self.workers.get_mut(wi) {
            let _ = w.child.kill();
            let _ = w.child.wait();
        }
    }

    fn send(&mut self, wi: usize, msg: &ToWorker) -> Result<()> {
        self.send_payload(wi, &msg.encode())
    }

    /// Queue one frame for the worker's writer thread. Never blocks on the
    /// pipe; oversized payloads fail here (structured), write failures
    /// surface at the next `recv` (dead pipe / timeout).
    fn send_payload(&mut self, wi: usize, payload: &[u8]) -> Result<()> {
        if !self.workers[wi].alive {
            return Err(worker_error(wi, "worker is dead (earlier failure)"));
        }
        if payload.len() > self.max_frame {
            let e = WireError::FrameTooLarge { len: payload.len(), max: self.max_frame };
            return Err(self.mark_dead(wi, format!("send failed: {e}")));
        }
        let queued = match &self.workers[wi].tx {
            Some(tx) => tx.send(payload.to_vec()).is_ok(),
            None => false,
        };
        if !queued {
            return Err(self.mark_dead(wi, "send failed: writer thread gone (pipe broken)"));
        }
        self.bytes_out += wire::frame_size(payload.len()) as u64;
        Ok(())
    }

    fn recv(&mut self, wi: usize) -> Result<FromWorker> {
        if !self.workers[wi].alive {
            return Err(worker_error(wi, "worker is dead (earlier failure)"));
        }
        match self.workers[wi].rx.recv_timeout(self.timeout) {
            Ok(Ok((payload, nbytes))) => {
                self.bytes_in += nbytes as u64;
                match FromWorker::decode(&payload) {
                    Ok(msg) => Ok(msg),
                    Err(e) => Err(self.mark_dead(wi, format!("undecodable reply: {e}"))),
                }
            }
            Ok(Err(WireError::Truncated { got: 0, .. })) => {
                Err(self.mark_dead(wi, "worker closed its pipe (exited or was killed)"))
            }
            Ok(Err(e)) => Err(self.mark_dead(wi, format!("bad reply frame: {e}"))),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let ms = self.timeout.as_millis();
                Err(self.mark_dead(wi, format!("no reply within {ms} ms (worker hung?)")))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(self.mark_dead(wi, "worker reader disconnected (process gone)"))
            }
        }
    }

    /// Mark `wi` dead, reap the child, and build the structured error.
    fn mark_dead(&mut self, wi: usize, message: impl Into<String>) -> Error {
        let w = &mut self.workers[wi];
        w.alive = false;
        w.tx = None; // writer thread exits, dropping the worker's stdin.
        let _ = w.child.kill();
        let _ = w.child.wait();
        worker_error(wi, message)
    }

    fn shutdown_all(&mut self) {
        for w in &mut self.workers {
            if let Some(tx) = w.tx.take() {
                let _ = tx.send(ToWorker::Shutdown.encode());
            } // dropping tx ends the writer, closing the pipe: EOF is a
              // shutdown too.
        }
        for w in &mut self.workers {
            let deadline = Instant::now() + Duration::from_millis(250);
            loop {
                match w.child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    _ => {
                        let _ = w.child.kill();
                        let _ = w.child.wait();
                        break;
                    }
                }
            }
        }
    }
}

impl Drop for ProcessPool {
    fn drop(&mut self) {
        self.shutdown_all();
    }
}

// --- worker side ------------------------------------------------------------

struct WorkerRuntime {
    oracle: CountingOracle<std::sync::Arc<dyn Oracle>>,
    counters: std::sync::Arc<OracleCounters>,
    shards: Vec<Vec<ElementId>>,
    stores: Vec<GuessStore>,
}

fn send_reply(w: &mut dyn Write, msg: &FromWorker, max_frame: usize) -> bool {
    wire::write_frame(w, &msg.encode(), max_frame).is_ok()
}

/// The worker main loop over arbitrary streams (in-memory in unit tests,
/// the process pipes in production). Returns the process exit code.
pub fn run_worker(r: &mut dyn Read, w: &mut dyn Write, max_frame: usize, fault: Option<&str>) -> i32 {
    let mut rt: Option<WorkerRuntime> = None;
    loop {
        let payload = match wire::read_frame(r, max_frame) {
            Ok((payload, _)) => payload,
            // clean EOF before a header byte: coordinator closed the pipe.
            Err(WireError::Truncated { got: 0, .. }) => return 0,
            Err(e) => {
                send_reply(w, &FromWorker::Fail { message: e.to_string() }, max_frame);
                return 3;
            }
        };
        let msg = match ToWorker::decode(&payload) {
            Ok(msg) => msg,
            Err(e) => {
                send_reply(
                    w,
                    &FromWorker::Fail { message: format!("undecodable message: {e}") },
                    max_frame,
                );
                return 3;
            }
        };
        match msg {
            ToWorker::Init(init) => match init.spec.build() {
                Ok(oracle) => {
                    let counting = CountingOracle::new(oracle);
                    let counters = counting.counter();
                    let n = init.shards.len();
                    rt = Some(WorkerRuntime {
                        oracle: counting,
                        counters,
                        shards: init.shards,
                        stores: vec![GuessStore::default(); n],
                    });
                    let version = if fault == Some("bad-version") {
                        WIRE_VERSION.wrapping_add(1)
                    } else {
                        WIRE_VERSION
                    };
                    if !send_reply(w, &FromWorker::Ready { version }, max_frame) {
                        return 3;
                    }
                }
                Err(e) => {
                    send_reply(
                        w,
                        &FromWorker::Fail { message: format!("cannot build oracle: {e}") },
                        max_frame,
                    );
                    return 3;
                }
            },
            ToWorker::Round(task) => {
                match fault {
                    // vanish without a reply: the coordinator sees a
                    // closed pipe, exactly like an OOM-killed worker.
                    Some("die-mid-round") => return 3,
                    // go silent: the coordinator's worker_timeout_ms must
                    // bound the wait and declare the worker dead.
                    Some("hang-round") => {
                        std::thread::sleep(Duration::from_secs(20));
                        return 3;
                    }
                    Some("truncate-frame") => {
                        let reply =
                            FromWorker::RoundDone { replies: Vec::new(), calls: (0, 0, 0) };
                        let mut framed = Vec::new();
                        let _ = wire::write_frame(&mut framed, &reply.encode(), max_frame);
                        let half = framed.len() / 2;
                        let _ = w.write_all(&framed[..half]);
                        let _ = w.flush();
                        return 3;
                    }
                    Some("corrupt-checksum") => {
                        let reply =
                            FromWorker::RoundDone { replies: Vec::new(), calls: (0, 0, 0) };
                        let mut framed = Vec::new();
                        let _ = wire::write_frame(&mut framed, &reply.encode(), max_frame);
                        if let Some(last) = framed.last_mut() {
                            *last ^= 0xFF;
                        }
                        let _ = w.write_all(&framed);
                        let _ = w.flush();
                        return 3;
                    }
                    _ => {}
                }
                let Some(rt) = rt.as_mut() else {
                    send_reply(
                        w,
                        &FromWorker::Fail { message: "round before init".into() },
                        max_frame,
                    );
                    return 3;
                };
                let before = rt.counters.snapshot();
                let replies = shard::run_task_all(
                    &rt.oracle,
                    &rt.shards,
                    &mut rt.stores,
                    &task,
                    &crate::mapreduce::backend::Serial,
                );
                let after = rt.counters.snapshot();
                let calls = (
                    after.0.saturating_sub(before.0),
                    after.1.saturating_sub(before.1),
                    after.2.saturating_sub(before.2),
                );
                if !send_reply(w, &FromWorker::RoundDone { replies, calls }, max_frame) {
                    return 3;
                }
            }
            ToWorker::Shutdown => return 0,
        }
    }
}

/// Entry point for the hidden `mrsub worker` subcommand: serve the wire
/// protocol on stdin/stdout until shutdown; returns the exit code.
pub fn worker_main() -> i32 {
    let max_frame = std::env::var("MRSUB_MAX_FRAME")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(DEFAULT_MAX_FRAME);
    let fault = std::env::var("MRSUB_FAULT").ok();
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut r = stdin.lock();
    let mut w = stdout.lock();
    run_worker(&mut r, &mut w, max_frame, fault.as_deref())
}

#[cfg(test)]
mod tests {
    //! In-memory worker-loop tests (no process spawning — the spawning
    //! path is exercised by `tests/backend_conformance.rs`, which can see
    //! the built `mrsub` binary).

    use super::*;
    use crate::mapreduce::wire::{Dec, Enc};

    fn spec() -> OracleSpec {
        OracleSpec::Coverage { n: 60, universe: 40, avg_degree: 3, weighted: false, seed: 5 }
    }

    fn framed(msgs: &[ToWorker]) -> Vec<u8> {
        let mut buf = Vec::new();
        for m in msgs {
            wire::write_frame(&mut buf, &m.encode(), DEFAULT_MAX_FRAME).unwrap();
        }
        buf
    }

    fn read_replies(buf: &[u8]) -> Vec<FromWorker> {
        let mut cursor = std::io::Cursor::new(buf.to_vec());
        let mut out = Vec::new();
        while let Ok((payload, _)) = wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME) {
            out.push(FromWorker::decode(&payload).unwrap());
        }
        out
    }

    #[test]
    fn worker_loop_serves_init_round_shutdown() {
        let init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0, 1],
            shards: vec![(0..30).collect(), (30..60).collect()],
            sample: vec![1, 2, 3],
        });
        let round = ToWorker::Round(RoundTask::LocalGreedy { k: 3 });
        let input = framed(&[init, round, ToWorker::Shutdown]);
        let mut r = std::io::Cursor::new(input);
        let mut out = Vec::new();
        let code = run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, None);
        assert_eq!(code, 0);
        let replies = read_replies(&out);
        assert_eq!(replies.len(), 2);
        assert!(matches!(replies[0], FromWorker::Ready { version: WIRE_VERSION }));
        match &replies[1] {
            FromWorker::RoundDone { replies, calls } => {
                assert_eq!(replies.len(), 2, "one reply per hosted machine");
                assert!(calls.0 > 0, "worker-side oracle calls reported");
                assert!(calls.1 > 0, "greedy heap fill runs the block path");
            }
            other => panic!("expected RoundDone, got {other:?}"),
        }
    }

    #[test]
    fn worker_eof_is_clean_exit() {
        let mut r = std::io::Cursor::new(Vec::new());
        let mut out = Vec::new();
        assert_eq!(run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, None), 0);
        assert!(out.is_empty());
    }

    #[test]
    fn worker_round_before_init_fails_structurally() {
        let input = framed(&[ToWorker::Round(RoundTask::MaxSingleton)]);
        let mut r = std::io::Cursor::new(input);
        let mut out = Vec::new();
        assert_ne!(run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, None), 0);
        match &read_replies(&out)[0] {
            FromWorker::Fail { message } => assert!(message.contains("before init")),
            other => panic!("expected Fail, got {other:?}"),
        }
    }

    #[test]
    fn worker_rejects_corrupted_input_frame() {
        let mut input = framed(&[ToWorker::Round(RoundTask::MaxSingleton)]);
        let len = input.len();
        input[len - 1] ^= 0x55; // corrupt the checksum
        let mut r = std::io::Cursor::new(input);
        let mut out = Vec::new();
        assert_ne!(run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, None), 0);
        match &read_replies(&out)[0] {
            FromWorker::Fail { message } => assert!(message.contains("checksum")),
            other => panic!("expected Fail, got {other:?}"),
        }
    }

    #[test]
    fn fault_injection_shapes_are_detectable() {
        // truncate-frame: the emitted bytes must NOT parse as a frame.
        let init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0],
            shards: vec![(0..60).collect()],
            sample: vec![],
        });
        let round = ToWorker::Round(RoundTask::MaxSingleton);
        let input = framed(&[init.clone(), round.clone()]);
        let mut out = Vec::new();
        let code = run_worker(
            &mut std::io::Cursor::new(input.clone()),
            &mut out,
            DEFAULT_MAX_FRAME,
            Some("truncate-frame"),
        );
        assert_ne!(code, 0);
        // first frame (Ready) parses, second is truncated.
        let mut cursor = std::io::Cursor::new(out);
        assert!(wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_ok());
        assert!(matches!(
            wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME),
            Err(WireError::Truncated { .. })
        ));

        // corrupt-checksum: second frame fails the checksum.
        let mut out = Vec::new();
        run_worker(
            &mut std::io::Cursor::new(input),
            &mut out,
            DEFAULT_MAX_FRAME,
            Some("corrupt-checksum"),
        );
        let mut cursor = std::io::Cursor::new(out);
        assert!(wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_ok());
        assert!(matches!(
            wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME),
            Err(WireError::BadChecksum { .. })
        ));
    }

    #[test]
    fn spec_is_wire_codable_inside_init() {
        // Init round-trips through encode/decode with the spec intact.
        let init = WorkerInit {
            spec: spec(),
            machines: vec![3, 7],
            shards: vec![vec![1, 2], vec![3]],
            sample: vec![9],
        };
        let msg = ToWorker::Init(init.clone());
        match ToWorker::decode(&msg.encode()).unwrap() {
            ToWorker::Init(back) => assert_eq!(back, init),
            other => panic!("expected Init, got {other:?}"),
        }
        // Enc/Dec are also usable standalone for specs.
        let mut enc = Enc::new();
        init.spec.encode(&mut enc);
        let mut dec = Dec::new(&enc.buf);
        assert_eq!(OracleSpec::decode(&mut dec).unwrap(), init.spec);
    }
}
