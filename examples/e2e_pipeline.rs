//! End-to-end driver — proves every layer composes on a real workload.
//!
//! Pipeline: a ~100k-point exemplar-selection workload (facility location
//! over a clustered planar point cloud — the paper's motivating "summarize
//! a large dataset" setting) is solved on the simulated MRC cluster with
//! the marginal hot path served by the **AOT-compiled JAX/Pallas kernel
//! through PJRT** (L1→L2→artifacts→L3), alongside the native-Rust oracle
//! for cross-validation, plus sequential greedy and the distributed
//! baselines. Reports values, ratios, rounds, memory, oracle calls, PJRT
//! executions, and wall time. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use std::sync::Arc;
use std::time::Instant;

use mrsub::algorithms::combined::CombinedTwoRound;
use mrsub::algorithms::greedy::lazy_greedy;
use mrsub::algorithms::multi_round::MultiRound;
use mrsub::algorithms::randgreedi::RandGreeDi;
use mrsub::coordinator::{render_table, run_experiment, write_json};
use mrsub::mapreduce::ClusterConfig;
use mrsub::oracle::hlo::HloFacilityOracle;
use mrsub::runtime::{default_artifact_dir, MarginalsEngine};
use mrsub::workload::facility::FacilityGen;
use mrsub::workload::{Instance, WorkloadGen};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let t0 = Instant::now();
    // ---- workload: 40k candidate exemplars, 2048 demand points ----------
    // (n·d = 82M f32 similarities ≈ 330 MB — a real, memory-resident
    // dataset; d matches one engine tile so the PJRT path runs unpadded.)
    let n = 40_000;
    let d = 2048;
    let k = 64;
    let seed = 7;
    println!("generating facility-location workload: n={n}, d={d}, k={k} …");
    let gen = FacilityGen::clustered(n, d, 24);
    let (n_, d_, sim) = gen.build_matrix(seed);

    // ---- the three-layer stack -------------------------------------------
    let dir = default_artifact_dir();
    println!("loading PJRT engine from {} …", dir.display());
    let engine = Arc::new(MarginalsEngine::load(&dir)?);
    let hlo_oracle = Arc::new(HloFacilityOracle::new(n_, d_, sim, Arc::clone(&engine)));
    let inst_hlo = Instance::new(format!("facility-hlo(n={n},d={d})"), hlo_oracle.clone());
    let inst_native = gen.generate(seed);

    let cfg = ClusterConfig { seed, ..ClusterConfig::default() };

    // ---- reference + runs -------------------------------------------------
    println!("sequential greedy reference …");
    let greedy = lazy_greedy(&inst_native.oracle, k);
    println!("greedy: f = {:.2} ({:.1?})", greedy.value, t0.elapsed());

    let mut records = Vec::new();
    println!("combined (Theorem 8) on the PJRT-backed oracle …");
    records.push(run_experiment(&inst_hlo, &CombinedTwoRound::new(0.1), k, &cfg)?);
    println!("combined (Theorem 8) on the native oracle …");
    records.push(run_experiment(&inst_native, &CombinedTwoRound::new(0.1), k, &cfg)?);
    println!("multi-round t=3 (Algorithm 5) on the native oracle …");
    records.push(run_experiment(&inst_native, &MultiRound::guessing(3, 0.2), k, &cfg)?);
    println!("randgreedi baseline …");
    records.push(run_experiment(&inst_native, &RandGreeDi, k, &cfg)?);

    println!("{}", render_table("E2E: exemplar selection, 40k×2048 (ref = lazy greedy)", &records));

    // cross-check: PJRT-backed and native runs of the same algorithm must
    // select identically (same seed, same numerics to f32 rounding).
    let (hlo_run, native_run) = (&records[0], &records[1]);
    println!(
        "hlo-vs-native value delta: {:.3e} (identical selection: {})",
        (hlo_run.value - native_run.value).abs(),
        hlo_run.value == native_run.value
    );
    println!("PJRT executions served: {}", engine.executions());
    println!("total e2e wall time: {:.1?}", t0.elapsed());

    write_json("e2e_report.json", &records)?;
    println!("report written to e2e_report.json");
    if hlo_run.value < 0.4 * greedy.value {
        return Err("PJRT-backed run quality regression".into());
    }
    Ok(())
}
