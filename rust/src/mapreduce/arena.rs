//! Zero-copy shard arena for same-host workers (`process:N@uds+arena`).
//!
//! The coordinator serializes every machine's spawn-time shard plus the
//! broadcast sample **once** into an anonymous `memfd` region, then passes
//! the file descriptor over the Unix-domain socket (`SCM_RIGHTS`) to each
//! worker right after it connects. Workers `mmap` the region read-only and
//! hand out `&'static [ElementId]` slices straight into the mapping — so
//! `Init` and `AdoptMachines` stop reshipping shard bytes over the wire
//! entirely (they carry machine *ids*; the data is already mapped). The
//! elided bytes are metered separately as `mapped_bytes` in
//! [`crate::mapreduce::process::RoundIpcStats`].
//!
//! Layout (little about it is clever on purpose — both sides are the same
//! binary on the same host, so native-endian `u32` words are exact):
//!
//! ```text
//! word 0   ARENA_MAGIC ("MRSA")
//! word 1   ARENA_VERSION
//! word 2   n_machines
//! word 3   sample_off   (u32-word offset from file start)
//! word 4   sample_len   (elements)
//! word 5.. per-machine (off, len) pairs, machine id order, 2·n words
//! ...      payload: sample ids, then each machine's shard ids
//! ```
//!
//! Failure is never fatal to the pool: if the arena cannot be built or a
//! descriptor cannot be passed, the coordinator transparently falls back
//! to the ordinary wire path (shards inside `Init`), identical to plain
//! `@uds`. A worker that was *told* the arena is active
//! (`MRSUB_ARENA=1`) but cannot receive or validate the mapping fails
//! structurally instead — a half-configured pool must not limp along.
//!
//! The worker-side mapping is intentionally leaked (`&'static`): it lives
//! exactly as long as the worker process, and unmapping would invalidate
//! shard slices held by the interpreter.
//!
//! **Layering for Miri/sanitizers:** the word layout and [`ArenaMap`]'s
//! validation are platform-independent (a mapping is just a
//! `&'static [u32]`; [`ArenaMap::from_words`] builds a view over any
//! leaked slice), while the memfd/mmap/`SCM_RIGHTS` FFI lives in a
//! `cfg(all(target_os = "linux", not(miri)))` module. Under Miri — which
//! cannot execute foreign functions — the FFI side degrades to the same
//! `Unsupported` facade as non-Linux hosts, and the layout/validation
//! tests still run (`./verify.sh miri`).

use std::io;

use crate::core::ElementId;

/// First arena word: `"MRSA"` read as a native-endian u32 on x86-64.
pub const ARENA_MAGIC: u32 = 0x4153_524D;

/// Arena layout version; bump on any layout change (validated at map time).
pub const ARENA_VERSION: u32 = 1;

/// Header words before the per-machine table.
const HEADER_WORDS: usize = 5;

/// Serialize shards + sample into the word layout above.
fn layout_words(shards: &[Vec<ElementId>], sample: &[ElementId]) -> Vec<u32> {
    let table = 2 * shards.len();
    let payload: usize = sample.len() + shards.iter().map(Vec::len).sum::<usize>();
    let mut words = Vec::with_capacity(HEADER_WORDS + table + payload);
    words.extend_from_slice(&[
        ARENA_MAGIC,
        ARENA_VERSION,
        shards.len() as u32,
        (HEADER_WORDS + table) as u32,
        sample.len() as u32,
    ]);
    // machine table, then payload: sample first, shards in machine order.
    let mut off = HEADER_WORDS + table + sample.len();
    for s in shards {
        words.push(off as u32);
        words.push(s.len() as u32);
        off += s.len();
    }
    words.extend_from_slice(sample);
    for s in shards {
        words.extend_from_slice(s);
    }
    words
}

fn bad_arena(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("arena map: {msg}"))
}

/// A validated read-only view of a mapped arena. `Copy` because the
/// backing words are leaked for the process lifetime — slices are
/// `'static`. Construction goes through [`ArenaMap::from_fd`] (mmap an
/// `SCM_RIGHTS`-received memfd; Linux, not Miri) or
/// [`ArenaMap::from_words`] (any leaked slice; every platform, and the
/// Miri-clean path the layout tests drive).
#[derive(Clone, Copy, Debug)]
pub struct ArenaMap {
    words: &'static [u32],
    n_machines: usize,
}

impl ArenaMap {
    /// Build a view over an already-leaked word region and validate the
    /// layout (magic, version, span bounds). The slice must live for the
    /// process lifetime — callers leak it exactly once.
    pub fn from_words(words: &'static [u32]) -> io::Result<ArenaMap> {
        if words.len() < HEADER_WORDS {
            return Err(bad_arena("region smaller than the arena header"));
        }
        let map = ArenaMap { words, n_machines: words[2] as usize };
        map.validate()?;
        Ok(map)
    }

    fn validate(&self) -> io::Result<()> {
        let w = self.words;
        if w[0] != ARENA_MAGIC {
            return Err(bad_arena("bad arena magic"));
        }
        if w[1] != ARENA_VERSION {
            return Err(bad_arena("arena layout version mismatch"));
        }
        let table_end = HEADER_WORDS + 2 * self.n_machines;
        if table_end > w.len() {
            return Err(bad_arena("machine table exceeds the region"));
        }
        let span = |off: u32, len: u32| {
            let (off, len) = (off as usize, len as usize);
            off >= table_end && off.checked_add(len).is_some_and(|end| end <= w.len())
        };
        if !span(w[3], w[4]) {
            return Err(bad_arena("sample span exceeds the region"));
        }
        for m in 0..self.n_machines {
            let at = HEADER_WORDS + 2 * m;
            if !span(w[at], w[at + 1]) {
                return Err(bad_arena("shard span exceeds the region"));
            }
        }
        Ok(())
    }

    /// Spawn-time shard of global machine `machine`; `None` when the
    /// id is out of range (a coordinator bug surfaced structurally).
    pub fn shard(&self, machine: u32) -> Option<&'static [ElementId]> {
        let m = machine as usize;
        if m >= self.n_machines {
            return None;
        }
        let at = HEADER_WORDS + 2 * m;
        let (off, len) = (self.words[at] as usize, self.words[at + 1] as usize);
        Some(&self.words[off..off + len])
    }

    /// The broadcast sample `S`.
    pub fn sample(&self) -> &'static [ElementId] {
        let (off, len) = (self.words[3] as usize, self.words[4] as usize);
        &self.words[off..off + len]
    }

    /// Number of machines the arena carries shards for.
    pub fn machines(&self) -> usize {
        self.n_machines
    }
}

#[cfg(all(target_os = "linux", not(miri)))]
mod fdimp {
    use super::*;
    use std::fs::File;
    use std::io::{Seek, SeekFrom, Write};
    use std::os::fd::{AsRawFd, FromRawFd, OwnedFd};
    use std::os::unix::net::UnixStream;
    use std::time::Duration;

    // Hand-declared glibc symbols — the workspace is offline-clean (no
    // libc crate). Layouts below are the x86-64/aarch64 Linux ABI.
    const MFD_CLOEXEC: u32 = 1;
    const PROT_READ: i32 = 1;
    const MAP_SHARED: i32 = 1;
    const SOL_SOCKET: i32 = 1;
    const SCM_RIGHTS: i32 = 1;

    #[repr(C)]
    struct IoVec {
        base: *mut u8,
        len: usize,
    }

    /// `struct msghdr` (64-bit Linux): `msg_namelen` is 32-bit, so
    /// `repr(C)` inserts the ABI's 4 pad bytes before `iov` itself.
    #[repr(C)]
    struct MsgHdr {
        name: *mut u8,
        namelen: u32,
        iov: *mut IoVec,
        iovlen: usize,
        control: *mut u8,
        controllen: usize,
        flags: i32,
    }

    /// One-fd control message: `cmsghdr` (16 bytes on 64-bit) + the fd,
    /// padded to the 8-byte cmsg alignment (CMSG_SPACE(4) = 24).
    #[repr(C, align(8))]
    struct CmsgOneFd {
        len: usize, // CMSG_LEN(4) = 20
        level: i32,
        ty: i32,
        fd: i32,
        _pad: i32,
    }

    extern "C" {
        fn memfd_create(name: *const u8, flags: u32) -> i32;
        fn mmap(addr: *mut u8, len: usize, prot: i32, flags: i32, fd: i32, off: i64) -> *mut u8;
        fn sendmsg(fd: i32, msg: *const MsgHdr, flags: i32) -> isize;
        fn recvmsg(fd: i32, msg: *mut MsgHdr, flags: i32) -> isize;
    }

    /// Coordinator-side arena: an anonymous memfd holding every machine's
    /// spawn shard plus the broadcast sample. Kept open for the pool's
    /// lifetime; each worker gets a duplicated descriptor via
    /// [`Arena::send_fd`].
    pub struct Arena {
        file: File,
        payload_words: usize,
    }

    impl Arena {
        /// Build the arena region. Any failure here is reported as a plain
        /// I/O error; callers fall back to the wire path.
        pub fn build(shards: &[Vec<ElementId>], sample: &[ElementId]) -> io::Result<Arena> {
            // SAFETY: the name is a NUL-terminated literal that outlives
            // the call; memfd_create touches no other memory of ours.
            let raw = unsafe { memfd_create(b"mrsub-arena\0".as_ptr(), MFD_CLOEXEC) };
            if raw < 0 {
                return Err(io::Error::last_os_error());
            }
            // SAFETY: memfd_create returned a fresh descriptor we own.
            let mut file = unsafe { File::from_raw_fd(raw) };
            let words = layout_words(shards, sample);
            let payload_words: usize = sample.len() + shards.iter().map(Vec::len).sum::<usize>();
            let mut bytes = Vec::with_capacity(words.len() * 4);
            for w in &words {
                bytes.extend_from_slice(&w.to_ne_bytes());
            }
            file.write_all(&bytes)?;
            file.flush()?;
            Ok(Arena { file, payload_words })
        }

        /// Elements (shard + sample ids) stored in the region — the data a
        /// wire `Init` would otherwise reship to every worker.
        pub fn payload_words(&self) -> usize {
            self.payload_words
        }

        /// Pass the arena descriptor over `stream` (`SCM_RIGHTS` with a
        /// 1-byte carrier, the first coordinator→worker byte on the
        /// socket — sent before any wire frame is queued).
        pub fn send_fd(&self, stream: &UnixStream) -> io::Result<()> {
            let mut carrier = [b'A'];
            let mut iov = IoVec { base: carrier.as_mut_ptr(), len: 1 };
            let mut cmsg = CmsgOneFd {
                len: std::mem::size_of::<usize>() + 8 + 4, // CMSG_LEN(4)
                level: SOL_SOCKET,
                ty: SCM_RIGHTS,
                fd: self.file.as_raw_fd(),
                _pad: 0,
            };
            let msg = MsgHdr {
                name: std::ptr::null_mut(),
                namelen: 0,
                iov: &mut iov,
                iovlen: 1,
                control: (&mut cmsg as *mut CmsgOneFd).cast(),
                controllen: std::mem::size_of::<CmsgOneFd>(),
                flags: 0,
            };
            // SAFETY: every pointer in `msg` outlives the call.
            let sent = unsafe { sendmsg(stream.as_raw_fd(), &msg, 0) };
            if sent != 1 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }
    }

    /// Worker side: receive the arena descriptor (the 1-byte
    /// `SCM_RIGHTS` carrier is the first byte the coordinator sends on an
    /// arena-mode socket). `timeout` bounds the wait.
    pub fn recv_fd(stream: &UnixStream, timeout: Duration) -> io::Result<OwnedFd> {
        let old = stream.read_timeout()?;
        stream.set_read_timeout(Some(timeout))?;
        let res = recv_fd_inner(stream);
        stream.set_read_timeout(old)?;
        res
    }

    fn recv_fd_inner(stream: &UnixStream) -> io::Result<OwnedFd> {
        let mut carrier = [0u8; 1];
        let mut iov = IoVec { base: carrier.as_mut_ptr(), len: 1 };
        let mut cmsg = CmsgOneFd {
            len: 0,
            level: 0,
            ty: 0,
            fd: -1,
            _pad: 0,
        };
        let mut msg = MsgHdr {
            name: std::ptr::null_mut(),
            namelen: 0,
            iov: &mut iov,
            iovlen: 1,
            control: (&mut cmsg as *mut CmsgOneFd).cast(),
            controllen: std::mem::size_of::<CmsgOneFd>(),
            flags: 0,
        };
        // SAFETY: every pointer in `msg` outlives the call.
        let got = unsafe { recvmsg(stream.as_raw_fd(), &mut msg, 0) };
        if got != 1 {
            return Err(io::Error::last_os_error());
        }
        let min_len = std::mem::size_of::<usize>() + 8 + 4;
        if cmsg.len < min_len || cmsg.level != SOL_SOCKET || cmsg.ty != SCM_RIGHTS || cmsg.fd < 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "arena handshake carried no SCM_RIGHTS descriptor",
            ));
        }
        // SAFETY: the kernel installed a fresh descriptor for this process.
        Ok(unsafe { OwnedFd::from_raw_fd(cmsg.fd) })
    }

    impl ArenaMap {
        /// `mmap` the received descriptor, leak the mapping, and validate
        /// the layout via [`ArenaMap::from_words`]. The mapping (and the
        /// descriptor's `File`) are leaked on success.
        pub fn from_fd(fd: OwnedFd) -> io::Result<ArenaMap> {
            let mut file = File::from(fd);
            let bytes = file.seek(SeekFrom::End(0))? as usize;
            if bytes < HEADER_WORDS * 4 || bytes % 4 != 0 {
                return Err(bad_arena("region smaller than the arena header"));
            }
            // SAFETY: null addr + MAP_SHARED ask the kernel for a fresh
            // read-only mapping of a descriptor we own; failure is checked
            // below, no memory of ours is touched.
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), bytes, PROT_READ, MAP_SHARED, file.as_raw_fd(), 0)
            };
            if ptr.is_null() || ptr as isize == -1 {
                return Err(io::Error::last_os_error());
            }
            // SAFETY: the mapping is page-aligned (so u32-aligned), `bytes`
            // long, read-only, and never unmapped (leaked below).
            let words: &'static [u32] =
                unsafe { std::slice::from_raw_parts(ptr.cast::<u32>(), bytes / 4) };
            std::mem::forget(file); // keep the fd so the memfd outlives us
            ArenaMap::from_words(words)
        }
    }
}

#[cfg(any(not(target_os = "linux"), miri))]
mod fdimp {
    //! Portable facade: every fd-based entry point reports `Unsupported`,
    //! so the pool's transparent wire-path fallback engages and
    //! `@uds+arena` degrades to plain `@uds` semantics off Linux — and
    //! under Miri, which cannot execute the memfd/mmap/sendmsg FFI.
    //! [`ArenaMap::from_words`] (defined platform-independently above)
    //! still works here, which is what the Miri layout tests drive.
    use super::*;
    use std::os::fd::OwnedFd;
    use std::os::unix::net::UnixStream;
    use std::time::Duration;

    fn unsupported() -> io::Error {
        io::Error::new(io::ErrorKind::Unsupported, "shard arena requires Linux memfd")
    }

    /// Coordinator-side arena (unsupported on this platform).
    pub struct Arena;

    impl Arena {
        /// Always fails here; the pool falls back to the wire path.
        pub fn build(_shards: &[Vec<ElementId>], _sample: &[ElementId]) -> io::Result<Arena> {
            Err(unsupported())
        }

        /// Unreachable here (no `Arena` value can be built).
        pub fn payload_words(&self) -> usize {
            0
        }

        /// Unreachable here (no `Arena` value can be built).
        pub fn send_fd(&self, _stream: &UnixStream) -> io::Result<()> {
            Err(unsupported())
        }
    }

    /// Worker side (unsupported on this platform).
    pub fn recv_fd(_stream: &UnixStream, _timeout: Duration) -> io::Result<OwnedFd> {
        Err(unsupported())
    }

    impl ArenaMap {
        /// Always fails here (no fd-passing / mmap without the FFI).
        pub fn from_fd(_fd: OwnedFd) -> io::Result<ArenaMap> {
            Err(unsupported())
        }
    }
}

pub use fdimp::{recv_fd, Arena};

/// Platform-independent layout + validation tests; these also run under
/// Miri (`./verify.sh miri`), where the fd path is cfg'd out. The backing
/// words are intentionally leaked — exactly like the real mapping — so
/// the Miri job runs with `-Zmiri-ignore-leaks`.
#[cfg(test)]
mod layout_tests {
    use super::*;

    fn leak(words: Vec<u32>) -> &'static [u32] {
        Box::leak(words.into_boxed_slice())
    }

    #[test]
    fn layout_words_roundtrip_through_from_words() {
        let shards = vec![vec![1u32, 5, 9], vec![], vec![2, 4, 6, 8]];
        let sample = vec![3u32, 7];
        let map = ArenaMap::from_words(leak(layout_words(&shards, &sample))).unwrap();
        assert_eq!(map.machines(), 3);
        assert_eq!(map.sample(), &sample[..]);
        for (i, shard) in shards.iter().enumerate() {
            assert_eq!(map.shard(i as u32), Some(&shard[..]), "machine {i}");
        }
        assert_eq!(map.shard(3), None, "out-of-range machine id");
    }

    #[test]
    fn empty_arena_is_valid() {
        let map = ArenaMap::from_words(leak(layout_words(&[], &[]))).unwrap();
        assert_eq!(map.machines(), 0);
        assert_eq!(map.sample(), &[] as &[u32]);
        assert_eq!(map.shard(0), None);
    }

    #[test]
    fn garbage_words_are_rejected_not_trusted() {
        // too short for a header.
        assert!(ArenaMap::from_words(leak(vec![0; 3])).is_err());
        // wrong magic.
        let mut words = layout_words(&[vec![1, 2]], &[9]);
        words[0] ^= 1;
        let err = ArenaMap::from_words(leak(words)).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        // wrong layout version.
        let mut words = layout_words(&[vec![1, 2]], &[9]);
        words[1] += 1;
        let err = ArenaMap::from_words(leak(words)).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        // shard span far past the end of the region.
        let words = vec![ARENA_MAGIC, ARENA_VERSION, 1, 7, 0, 1 << 20, 8];
        let err = ArenaMap::from_words(leak(words)).unwrap_err();
        assert!(err.to_string().contains("span"), "{err}");
        // machine table itself exceeds the region.
        let words = vec![ARENA_MAGIC, ARENA_VERSION, 1 << 24, 5, 0];
        let err = ArenaMap::from_words(leak(words)).unwrap_err();
        assert!(err.to_string().contains("table"), "{err}");
        // spans may not point into the header/table.
        let words = vec![ARENA_MAGIC, ARENA_VERSION, 0, 0, 2];
        let err = ArenaMap::from_words(leak(words)).unwrap_err();
        assert!(err.to_string().contains("sample"), "{err}");
    }
}

#[cfg(all(test, target_os = "linux", not(miri)))]
mod fd_tests {
    use super::*;
    use std::os::unix::net::UnixStream;
    use std::time::Duration;

    #[test]
    fn build_pass_map_roundtrip() {
        let shards = vec![vec![1u32, 5, 9], vec![], vec![2, 4, 6, 8]];
        let sample = vec![3u32, 7];
        let arena = Arena::build(&shards, &sample).expect("memfd arena");
        assert_eq!(arena.payload_words(), 9, "3 + 0 + 4 shard ids plus 2 sample ids");

        let (coord, worker) = UnixStream::pair().unwrap();
        arena.send_fd(&coord).expect("sendmsg");
        let fd = recv_fd(&worker, Duration::from_secs(5)).expect("recvmsg");
        let map = ArenaMap::from_fd(fd).expect("map + validate");

        assert_eq!(map.machines(), 3);
        assert_eq!(map.sample(), &sample[..]);
        for (i, shard) in shards.iter().enumerate() {
            assert_eq!(map.shard(i as u32), Some(&shard[..]), "machine {i}");
        }
        assert_eq!(map.shard(3), None, "out-of-range machine id");
    }

    #[test]
    fn arena_outlives_coordinator_side_drop() {
        // the worker's mapping must stay valid after the coordinator
        // closes its descriptor (memfd is refcounted by open fds + maps).
        let shards = vec![vec![10u32, 20, 30]];
        let arena = Arena::build(&shards, &[42]).unwrap();
        let (coord, worker) = UnixStream::pair().unwrap();
        arena.send_fd(&coord).unwrap();
        drop(arena);
        drop(coord);
        let fd = recv_fd(&worker, Duration::from_secs(5)).unwrap();
        let map = ArenaMap::from_fd(fd).unwrap();
        assert_eq!(map.shard(0), Some(&[10u32, 20, 30][..]));
        assert_eq!(map.sample(), &[42u32]);
    }

    #[test]
    fn garbage_region_is_rejected_not_trusted() {
        // a plain temp file mmaps fine, but fails arena validation: wrong
        // magic, then truncated spans.
        use std::io::Write;
        use std::os::fd::OwnedFd;
        let dir = std::env::temp_dir();
        let path = dir.join(format!("mrsub-arena-garbage-{}", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(&[0u8; 64]).unwrap();
        let f = std::fs::File::open(&path).unwrap();
        let err = ArenaMap::from_fd(OwnedFd::from(f)).unwrap_err();
        assert!(err.to_string().contains("arena"), "{err}");

        // header claims a shard span far past the end of the region.
        let mut words: Vec<u32> = vec![ARENA_MAGIC, ARENA_VERSION, 1, 7, 0, 1 << 20, 8];
        let mut bytes = Vec::new();
        for w in words.drain(..) {
            bytes.extend_from_slice(&w.to_ne_bytes());
        }
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(&bytes).unwrap();
        let f = std::fs::File::open(&path).unwrap();
        let err = ArenaMap::from_fd(OwnedFd::from(f)).unwrap_err();
        assert!(err.to_string().contains("span"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn recv_fd_times_out_without_a_sender() {
        let (_coord, worker) = UnixStream::pair().unwrap();
        let err = recv_fd(&worker, Duration::from_millis(50)).unwrap_err();
        assert!(
            matches!(err.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut),
            "{err:?}"
        );
    }
}
