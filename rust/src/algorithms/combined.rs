//! Theorem 8 — the paper's headline 2-round `1/2 − ε` approximation with
//! no duplication of the ground set and no knowledge of OPT.
//!
//! "Given the input, we can run both in parallel and return the better of
//! the two solutions: each machine simply runs both algorithms at the same
//! time, keeping the number of machines the same." — every machine executes
//! the Algorithm 6 (dense) worker *and* the Algorithm 7 (sparse) worker in
//! the same physical round and ships both outputs; the central machine
//! completes both and returns the better solution. Exactly 2 MapReduce
//! rounds on one random partition.

use super::dense::{
    dense_central, dense_guess_filters, dense_prepare, scatter_guess_reply, transpose_survivors,
};
use super::sparse::sparse_central;
use super::{AlgResult, MrAlgorithm};
use crate::core::{ElementId, Result};
use crate::mapreduce::wire::{RoundTask, TaskReply};
use crate::mapreduce::{ClusterConfig, MrCluster};
use crate::oracle::Oracle;

/// Theorem 8: Algorithm 6 ∥ Algorithm 7.
#[derive(Debug, Clone, Copy)]
pub struct CombinedTwoRound {
    /// Guess resolution ε (both sub-algorithms).
    pub eps: f64,
    /// Sparse ship factor (c·k elements per machine; default 4).
    pub c: usize,
}

impl CombinedTwoRound {
    /// New combined algorithm with resolution `eps`.
    pub fn new(eps: f64) -> Self {
        assert!(eps > 0.0);
        CombinedTwoRound { eps, c: 4 }
    }
}

impl MrAlgorithm for CombinedTwoRound {
    fn name(&self) -> String {
        format!("combined(eps={})", self.eps)
    }

    fn run(&self, oracle: &dyn Oracle, k: usize, cfg: &ClusterConfig) -> Result<AlgResult> {
        let n = oracle.ground_size();
        let mut cluster = MrCluster::new(n, k, cfg)?;
        let exec = std::sync::Arc::clone(cluster.exec());
        let plan = dense_prepare(oracle, cluster.sample(), k, self.eps, exec.as_ref());

        // Round 1: each machine runs both workers — one Batch task, two
        // programs, one synchronous round.
        let task = RoundTask::Batch(vec![
            RoundTask::MultiFilter {
                persist: false,
                guesses: dense_guess_filters(&plan, k),
                drop: Vec::new(),
            },
            RoundTask::TopSingletons { k, c: self.c },
        ]);
        let replies = cluster.shard_round("r1:dense+sparse", plan.resident(), oracle, &task)?;

        let mut dense_parts: Vec<Vec<Vec<ElementId>>> = Vec::with_capacity(replies.len());
        let mut pool: Vec<ElementId> = Vec::new();
        for reply in replies {
            let mut parts = reply.into_batch().into_iter();
            let dense_reply = parts.next().map(TaskReply::into_multi).unwrap_or_default();
            let sparse_reply = parts.next().map(TaskReply::into_ids).unwrap_or_default();
            dense_parts.push(scatter_guess_reply(dense_reply, plan.taus.len()));
            pool.extend(sparse_reply);
        }
        let survivors = transpose_survivors(&dense_parts, plan.taus.len());
        pool.sort_unstable();

        // Round 2: central completes both; keep the better.
        let received = survivors.iter().map(Vec::len).sum::<usize>()
            + pool.len()
            + cluster.sample().len();
        let solution = cluster.central_round("r2:complete-both", received, || {
            let dense_sol = dense_central(oracle, &plan, survivors, k);
            let sparse_sol = sparse_central(oracle, &pool, k, self.eps);
            dense_sol.max(sparse_sol)
        })?;
        Ok(AlgResult { solution, metrics: cluster.into_metrics() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::lazy_greedy;
    use crate::workload::coverage::CoverageGen;
    use crate::workload::planted::PlantedCoverageGen;
    use crate::workload::WorkloadGen;

    fn cfg(seed: u64) -> ClusterConfig {
        ClusterConfig { seed, parallel: false, ..ClusterConfig::default() }
    }

    #[test]
    fn works_on_both_regimes() {
        let eps = 0.1;
        for (label, gen) in [
            ("dense", PlantedCoverageGen::dense(10, 1000, 2000)),
            ("sparse", PlantedCoverageGen::sparse(10, 1000, 2000)),
        ] {
            let inst = gen.generate(7);
            let opt = inst.known_opt.unwrap();
            let res =
                CombinedTwoRound::new(eps).run(inst.oracle.as_ref(), 10, &cfg(8)).unwrap();
            let ratio = res.solution.value / opt;
            assert!(ratio >= 0.5 - eps, "{label}: ratio {ratio} below 1/2 − ε");
            assert_eq!(res.metrics.num_rounds(), 3, "{label}: must stay 2 compute rounds");
        }
    }

    #[test]
    fn beats_half_of_greedy_without_opt() {
        for seed in 0..3 {
            let o = CoverageGen::new(600, 300, 5).build(seed);
            let g = lazy_greedy(&o, 12);
            let res = CombinedTwoRound::new(0.1).run(&o, 12, &cfg(seed)).unwrap();
            assert!(
                res.solution.value >= (0.5 - 0.1) * g.value,
                "seed {seed}: {} vs greedy {}",
                res.solution.value,
                g.value
            );
        }
    }

    #[test]
    fn solution_respects_k() {
        let o = CoverageGen::new(300, 200, 4).build(9);
        let res = CombinedTwoRound::new(0.2).run(&o, 7, &cfg(10)).unwrap();
        assert!(res.solution.len() <= 7);
    }
}
