//! Randomized composable core-sets — Mirrokni & Zadimoghaddam (STOC 2015),
//! the prior state of the art in the paper's regime (2 rounds, no
//! duplication): a 0.27-approximation, improved to 0.545 only *with*
//! Θ((1/ε)·log(1/ε)) duplication.
//!
//! Round 1: each machine runs greedy on its random shard and outputs its
//! k-element solution as a composable core-set. Round 2: the central
//! machine runs greedy on the union of core-sets; the result is the central
//! solution (MZ's analysis bounds exactly this composition — the
//! "return-best-local" strengthening belongs to RandGreeDi, so we keep the
//! two baselines distinct and honest).

use super::greedy::lazy_greedy_over;
use super::{AlgResult, MrAlgorithm};
use crate::core::{ElementId, Result};
use crate::mapreduce::wire::{RoundTask, TaskReply};
use crate::mapreduce::{ClusterConfig, MrCluster};
use crate::oracle::Oracle;

/// MZ randomized composable core-sets (greedy core-set, central greedy).
#[derive(Debug, Clone, Copy, Default)]
pub struct MzCoreset;

impl MrAlgorithm for MzCoreset {
    fn name(&self) -> String {
        "mz-coreset".into()
    }

    fn run(&self, oracle: &dyn Oracle, k: usize, cfg: &ClusterConfig) -> Result<AlgResult> {
        let n = oracle.ground_size();
        let mut cluster = MrCluster::new(n, k, cfg)?;

        let coresets: Vec<Vec<ElementId>> = cluster
            .shard_round("r1:greedy-coreset", 0, oracle, &RoundTask::LocalGreedy { k })?
            .into_iter()
            .map(TaskReply::into_ids)
            .collect();

        let union: Vec<ElementId> = {
            let mut u: Vec<ElementId> = coresets.into_iter().flatten().collect();
            u.sort_unstable();
            u.dedup();
            u
        };
        let received = union.len();
        let solution = cluster
            .central_round("r2:union-greedy", received, || lazy_greedy_over(oracle, &union, k))?;
        Ok(AlgResult { solution, metrics: cluster.into_metrics() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::planted::PlantedCoverageGen;
    use crate::workload::WorkloadGen;

    fn cfg(seed: u64) -> ClusterConfig {
        ClusterConfig { seed, parallel: false, ..ClusterConfig::default() }
    }

    #[test]
    fn clears_its_027_bound_comfortably() {
        let inst = PlantedCoverageGen::dense(10, 1000, 2000).generate(5);
        let opt = inst.known_opt.unwrap();
        let res = MzCoreset.run(inst.oracle.as_ref(), 10, &cfg(6)).unwrap();
        let ratio = res.solution.value / opt;
        assert!(ratio >= 0.27, "mz ratio {ratio} below its own bound");
        assert_eq!(res.metrics.num_rounds(), 3);
        assert!(res.solution.len() <= 10);
    }
}
