//! Shared-nothing process backend: one OS worker process per group of
//! simulated machines, speaking the [`crate::mapreduce::wire`] protocol
//! over a pluggable byte-stream transport
//! ([`crate::mapreduce::transport`]): stdin/stdout pipes (default), a
//! Unix-domain socket, or TCP.
//!
//! ## Topology
//!
//! [`ProcessPool::spawn`] re-executes the current binary (or an explicit
//! `worker_exe`) with the hidden `mrsub worker` subcommand, one process
//! per worker, and assigns the `m` simulated machines round-robin across
//! the `N` workers of `--backend process:N[@transport]`. On the socket
//! transports the coordinator binds a listener first and workers dial
//! back (`MRSUB_CONNECT`); with an explicit TCP bind address
//! (`process:N@tcp:HOST:PORT`) **no** local workers are spawned — the
//! pool waits for `N` external `mrsub worker --connect HOST:PORT --id I`
//! processes, which is how workers span hosts. Each worker receives —
//! once, at init — the oracle *spec* (rebuilt deterministically on its
//! side; no shared memory), its machines' shards, and the broadcast
//! sample. Worker processes then persist across rounds: Algorithm 5's
//! `t` thresholds pay one spawn, not `t`.
//!
//! ## Handshakes
//!
//! The first frame on every new byte stream — any transport — is
//! [`FromWorker::Hello`], carrying the worker's slot id (socket
//! connections arrive in arbitrary order) and its [`WIRE_VERSION`]; a
//! version mismatch or an unknown slot fails here, before any shard data
//! moves. [`ToWorker::Init`] → [`FromWorker::Ready`] then completes setup
//! exactly as on pipes. Connection establishment is bounded by the same
//! `worker_timeout_ms` that bounds round replies: a worker that never
//! connects (crashed, connection refused, wrong endpoint) degrades into a
//! structured [`Error::Worker`] when the accept deadline expires.
//!
//! ## Round protocol
//!
//! A round writes one `Round(task)` frame to every worker (all workers
//! compute concurrently), then joins the replies in worker order. Replies
//! carry per-machine [`TaskReply`]s plus the worker-side oracle-call delta,
//! which the coordinator merges into its [`OracleCounters`] so
//! `MrMetrics` sees one coherent count. All frame traffic is metered
//! identically on every transport — the per-round IPC byte counts land in
//! `RoundStat::ipc_bytes_*`.
//!
//! ## Failure surface
//!
//! Every failure mode — worker killed mid-round, truncated or corrupted
//! reply frame, oversized frame, handshake version mismatch, refused or
//! dropped connection, worker-side error — is a structured
//! [`Error::Worker`] (never a panic, never a poisoned coordinator): the
//! pool marks the worker dead, force-closes its stream, reaps the child
//! (when it spawned one), and the algorithm's `run` surfaces `Err`. Each
//! worker gets a dedicated reader thread *and* writer thread, so the
//! coordinator itself never blocks on a stream — a worker that stops
//! replying *or* stops reading is bounded by `worker_timeout_ms`, never a
//! coordinator hang. Reply shapes are validated against the task
//! ([`wire::reply_matches`]) before use.
//!
//! The `MRSUB_FAULT` environment variable (set by the conformance suite
//! via `worker_env`) injects worker-side faults: `die-mid-round`,
//! `hang-round`, `truncate-frame`, `corrupt-checksum`, `bad-version`,
//! `no-connect`.

use std::io::{Read, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::core::{ElementId, Error, Result};
use crate::mapreduce::shard::{self, GuessStore};
use crate::mapreduce::transport::{self, LinkControl, Listener, Transport};
use crate::mapreduce::wire::{
    self, FromWorker, RoundTask, TaskReply, ToWorker, WireError, WorkerInit, DEFAULT_MAX_FRAME,
    WIRE_VERSION,
};
use crate::oracle::spec::OracleSpec;
use crate::oracle::{CountingOracle, Oracle, OracleCounters};

/// Pool construction knobs (derived from `ClusterConfig` by the cluster).
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Worker processes to spawn (capped at the machine count).
    pub workers: usize,
    /// Coordinator ↔ worker byte-stream transport.
    pub transport: Transport,
    /// Per-reply wait bound; also bounds connection establishment. A
    /// worker silent for longer is declared dead.
    pub timeout: Duration,
    /// Hard cap on a single frame's payload.
    pub max_frame: usize,
    /// Worker executable; `None` = `std::env::current_exe()` (the normal
    /// case — coordinator and worker are the same binary). Tests point
    /// this at the built `mrsub` binary.
    pub exe: Option<PathBuf>,
    /// Extra environment for workers (fault injection uses `MRSUB_FAULT`).
    pub env: Vec<(String, String)>,
}

impl Default for PoolOptions {
    fn default() -> Self {
        PoolOptions {
            workers: 1,
            transport: Transport::Pipe,
            timeout: Duration::from_millis(30_000),
            max_frame: DEFAULT_MAX_FRAME,
            exe: None,
            env: Vec::new(),
        }
    }
}

/// Per-round IPC accounting returned by [`ProcessPool::round`].
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundIpcStats {
    /// Frame bytes coordinator → workers this round.
    pub bytes_out: u64,
    /// Frame bytes workers → coordinator this round.
    pub bytes_in: u64,
    /// Worker-side oracle calls `(total, batched, batches)` this round.
    pub calls: (u64, u64, u64),
}

/// Frames from a reader thread: `(payload, frame_bytes)` or a wire error.
type FrameResult = std::result::Result<(Vec<u8>, usize), WireError>;

struct WorkerHandle {
    /// The spawned OS process; `None` for external workers that joined
    /// over `mrsub worker --connect` (nothing to reap — dropping the
    /// stream is the only lever).
    child: Option<Child>,
    /// Payloads to the dedicated writer thread (which owns the stream and
    /// does the blocking `write`); `None` once closed (shutdown/failure).
    /// Queueing instead of writing inline keeps the coordinator off the
    /// stream: a worker that stops *reading* cannot wedge the coordinator
    /// — the reply timeout still fires and the worker is declared dead.
    tx: Option<mpsc::Sender<Vec<u8>>>,
    /// Frames from the dedicated reader thread.
    rx: mpsc::Receiver<FrameResult>,
    /// Force-close handle for the underlying stream (no-op for pipes).
    control: LinkControl,
    /// Fires when the writer thread has drained its queue and exited —
    /// a bounded flush handshake (the `Shutdown` frame in particular)
    /// consulted at shutdown before the stream is cut.
    writer_done: mpsc::Receiver<()>,
    /// Simulated machine ids this worker hosts.
    machines: Vec<usize>,
    alive: bool,
}

/// A running pool of shared-nothing worker processes.
pub struct ProcessPool {
    workers: Vec<WorkerHandle>,
    n_machines: usize,
    timeout: Duration,
    max_frame: usize,
    bytes_out: u64,
    bytes_in: u64,
}

fn worker_error(worker: usize, message: impl Into<String>) -> Error {
    Error::Worker { worker, message: message.into() }
}

/// The one version-mismatch wording, shared by every handshake site
/// (socket Hello, pipe Hello, Ready) so the transports never drift.
fn version_mismatch(version: u16) -> String {
    format!("wire version mismatch: worker speaks v{version}, coordinator v{WIRE_VERSION}")
}

/// Diversifies UDS socket paths across pools within one process.
static POOL_TAG: AtomicU64 = AtomicU64::new(1);

/// Upper bound on the wait for a `Hello` after a stream connects. A real
/// worker sends it as its very first act, so this only fires for silent
/// strays (port scanners, health checks) — and bounds how long any single
/// stray can stall the (serial) accept loop; several strays in a row
/// still burn the pool deadline, which is why an explicit TCP bind
/// belongs on a trusted network segment (see README).
const HELLO_BUDGET: Duration = Duration::from_secs(2);

/// Start the dedicated reader + writer threads over a worker byte stream;
/// returns the send queue, the receive channel, and a drain signal the
/// writer fires just before exiting (a *bounded* flush handshake for
/// shutdown — never a join that could hang the coordinator).
fn start_io_threads(
    mut reader: Box<dyn Read + Send>,
    mut writer: Box<dyn Write + Send>,
    max_frame: usize,
) -> (mpsc::Sender<Vec<u8>>, mpsc::Receiver<FrameResult>, mpsc::Receiver<()>) {
    let (reply_tx, rx) = mpsc::channel();
    let (tx, payload_rx) = mpsc::channel::<Vec<u8>>();
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || loop {
        let res = wire::read_frame(&mut reader, max_frame);
        let stop = res.is_err();
        if reply_tx.send(res).is_err() || stop {
            break;
        }
    });
    std::thread::spawn(move || {
        // exits when the sender is dropped (shutdown/mark_dead) or the
        // stream breaks; dropping a pipe writer EOFs the worker.
        while let Ok(payload) = payload_rx.recv() {
            if wire::write_frame(&mut writer, &payload, max_frame).is_err() {
                break;
            }
        }
        let _ = done_tx.send(());
    });
    (tx, rx, done_rx)
}

/// A connected-but-not-yet-initialized worker stream (handshake state).
struct Pending {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<FrameResult>,
    control: LinkControl,
    writer_done: mpsc::Receiver<()>,
}

/// Read and decode the connect-time `Hello` from a pending stream;
/// returns `(version, worker id, frame bytes)` for the IPC meter.
fn expect_hello(
    pending: &Pending,
    deadline: Instant,
) -> std::result::Result<(u16, u32, u64), String> {
    let remaining = deadline.saturating_duration_since(Instant::now()).min(HELLO_BUDGET);
    let waited_ms = remaining.as_millis();
    match pending.rx.recv_timeout(remaining) {
        Ok(Ok((payload, nbytes))) => match FromWorker::decode(&payload) {
            Ok(FromWorker::Hello { version, worker }) => Ok((version, worker, nbytes as u64)),
            Ok(other) => Err(format!("expected Hello handshake, got {other:?}")),
            Err(e) => Err(format!("undecodable handshake frame: {e}")),
        },
        Ok(Err(WireError::Truncated { got: 0, .. })) => {
            Err("stream closed before the Hello handshake (worker crashed?)".into())
        }
        Ok(Err(e)) => Err(format!("bad handshake frame: {e}")),
        Err(mpsc::RecvTimeoutError::Timeout) => {
            Err(format!(
                "no Hello within {waited_ms} ms of connecting \
                 (worker connected but went silent)"
            ))
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            Err("stream closed before the Hello handshake".into())
        }
    }
}

impl ProcessPool {
    /// Spawn (or await) workers, complete the `Hello` handshake, ship
    /// each worker its shards + spec + sample, and complete the `Ready`
    /// handshake.
    pub fn spawn(
        spec: &OracleSpec,
        shards: &[Vec<ElementId>],
        sample: &[ElementId],
        opts: &PoolOptions,
    ) -> Result<ProcessPool> {
        let m = shards.len();
        if m == 0 {
            return Err(Error::Config("process pool needs at least one machine".into()));
        }
        let w = opts.workers.clamp(1, m);
        let external = opts.transport.external_workers();
        let listener = Listener::bind(&opts.transport, POOL_TAG.fetch_add(1, Ordering::Relaxed))
            .map_err(|e| {
                Error::Config(format!("bind {} listener: {e}", opts.transport))
            })?;
        let mut machines_of: Vec<Vec<usize>> = vec![Vec::new(); w];
        for i in 0..m {
            machines_of[i % w].push(i);
        }

        // --- process phase: spawn local workers (unless external) --------
        let mut children: Vec<Child> = Vec::new(); // index == worker slot
        let abort = |mut children: Vec<Child>, slots: Vec<Option<Pending>>| {
            for slot in slots.into_iter().flatten() {
                slot.control.force_close();
            }
            for child in &mut children {
                let _ = child.kill();
                let _ = child.wait();
            }
        };
        if !external {
            let exe = match &opts.exe {
                Some(p) => p.clone(),
                None => std::env::current_exe().map_err(|e| {
                    Error::Config(format!("cannot locate worker executable: {e}"))
                })?,
            };
            for wi in 0..w {
                let mut cmd = Command::new(&exe);
                cmd.arg("worker")
                    .stderr(Stdio::inherit())
                    .env("MRSUB_MAX_FRAME", opts.max_frame.to_string())
                    .env("MRSUB_WORKER_ID", wi.to_string());
                match &listener {
                    None => {
                        // a stale MRSUB_CONNECT inherited from the
                        // coordinator's environment would flip a pipe
                        // worker into socket-dial mode; clear it.
                        cmd.stdin(Stdio::piped())
                            .stdout(Stdio::piped())
                            .env_remove("MRSUB_CONNECT");
                    }
                    Some(l) => {
                        // socket workers keep stdio free; they dial back.
                        cmd.stdin(Stdio::null())
                            .stdout(Stdio::inherit())
                            .env("MRSUB_CONNECT", l.endpoint());
                    }
                }
                for (key, val) in &opts.env {
                    cmd.env(key, val);
                }
                match cmd.spawn() {
                    Ok(child) => children.push(child),
                    Err(e) => {
                        // reap the workers already spawned — no zombies on a
                        // partial spawn (process-limit pressure, vanished exe).
                        abort(children, Vec::new());
                        return Err(worker_error(wi, format!("spawn {}: {e}", exe.display())));
                    }
                }
            }
        }

        // --- connection + Hello phase ------------------------------------
        let deadline = Instant::now() + opts.timeout;
        let timeout_ms = opts.timeout.as_millis();
        let mut slots: Vec<Option<Pending>> = (0..w).map(|_| None).collect();
        // socket Hello frames are consumed here, before the pool exists;
        // meter them so all transports account handshake bytes alike
        // (pipe Hellos flow through `recv`, which meters inline).
        let mut hello_bytes_in: u64 = 0;
        match &listener {
            None => {
                // pipes are wired at spawn: stream `wi` IS worker `wi`.
                for (wi, child) in children.iter_mut().enumerate() {
                    let stdin = child.stdin.take().expect("stdin piped");
                    let stdout = child.stdout.take().expect("stdout piped");
                    let (tx, rx, writer_done) =
                        start_io_threads(Box::new(stdout), Box::new(stdin), opts.max_frame);
                    slots[wi] =
                        Some(Pending { tx, rx, control: LinkControl::Pipe, writer_done });
                }
            }
            Some(l) => {
                let mut filled = 0usize;
                // external mode drops bad joins per-connection; the reason
                // for the last rejection is folded into the eventual
                // timeout error so the operator sees *why* a slot stayed
                // empty (e.g. a stale old-version worker retrying).
                let mut last_reject: Option<String> = None;
                while filled < w {
                    let link = match l.accept_until(deadline) {
                        Ok(Some(link)) => link,
                        Ok(None) => {
                            let missing =
                                slots.iter().position(Option::is_none).unwrap_or(0);
                            abort(children, slots);
                            let mut msg = format!(
                                "no worker connection within {timeout_ms} ms \
                                 (connection refused, worker crashed before \
                                 connecting, or wrong --connect endpoint?)"
                            );
                            if let Some(r) = last_reject {
                                msg.push_str(&format!("; last rejected join: {r}"));
                            }
                            return Err(worker_error(missing, msg));
                        }
                        Err(e) => {
                            abort(children, slots);
                            return Err(worker_error(0, format!("accept failed: {e}")));
                        }
                    };
                    let control = link.control.clone();
                    let (tx, rx, writer_done) =
                        start_io_threads(link.reader, link.writer, opts.max_frame);
                    let pending = Pending { tx, rx, control, writer_done };
                    match expect_hello(&pending, deadline) {
                        Ok((version, worker, _)) if version != WIRE_VERSION => {
                            pending.control.force_close();
                            if external {
                                // a stray old-binary join must not tear
                                // down already-joined workers.
                                last_reject = Some(version_mismatch(version));
                                continue;
                            }
                            abort(children, slots);
                            return Err(worker_error(
                                worker as usize,
                                version_mismatch(version),
                            ));
                        }
                        Ok((_, worker, nbytes)) => {
                            let wi = worker as usize;
                            if wi >= w || slots[wi].is_some() {
                                pending.control.force_close();
                                let msg = format!(
                                    "unexpected worker id {wi} in Hello \
                                     (pool has {w} slots; duplicate --id?)"
                                );
                                if external {
                                    last_reject = Some(msg);
                                    continue;
                                }
                                abort(children, slots);
                                return Err(worker_error(wi, msg));
                            }
                            hello_bytes_in += nbytes;
                            slots[wi] = Some(pending);
                            filled += 1;
                        }
                        Err(msg) if external => {
                            // an open listener on a real network attracts
                            // strays (port scanners, health checks): a
                            // stream that dies or garbles before its Hello
                            // is dropped, not a pool-fatal event — a truly
                            // missing worker still trips the accept
                            // deadline above.
                            pending.control.force_close();
                            last_reject = Some(msg);
                        }
                        Err(msg) => {
                            // spawned-worker mode: every stream is one of
                            // ours, so a pre-Hello death is a real worker
                            // failure — fail fast with the cause.
                            pending.control.force_close();
                            let missing =
                                slots.iter().position(Option::is_none).unwrap_or(0);
                            abort(children, slots);
                            return Err(worker_error(missing, msg));
                        }
                    }
                }
            }
        }
        drop(listener); // all workers joined; unlink the UDS path now.

        // --- assemble + pipe-mode Hello + Init/Ready ----------------------
        let mut children = children.into_iter().map(Some).collect::<Vec<_>>();
        children.resize_with(w, || None);
        let workers: Vec<WorkerHandle> = slots
            .into_iter()
            .zip(machines_of)
            .enumerate()
            .map(|(wi, (pending, machines))| {
                let p = pending.expect("every slot filled above");
                WorkerHandle {
                    child: children[wi].take(),
                    tx: Some(p.tx),
                    rx: p.rx,
                    control: p.control,
                    writer_done: p.writer_done,
                    machines,
                    alive: true,
                }
            })
            .collect();
        let mut pool = ProcessPool {
            workers,
            n_machines: m,
            timeout: opts.timeout,
            max_frame: opts.max_frame,
            bytes_out: 0,
            bytes_in: hello_bytes_in,
        };
        if matches!(opts.transport, Transport::Pipe) {
            // socket hellos were consumed during accept; pipe hellos are
            // still queued — same handshake, same validation.
            for wi in 0..pool.workers.len() {
                match pool.recv(wi)? {
                    FromWorker::Hello { version, worker }
                        if version == WIRE_VERSION && worker as usize == wi => {}
                    FromWorker::Hello { version, .. } if version != WIRE_VERSION => {
                        return Err(pool.mark_dead(wi, version_mismatch(version)))
                    }
                    other => {
                        return Err(
                            pool.mark_dead(wi, format!("bad Hello handshake: {other:?}"))
                        )
                    }
                }
            }
        }
        for wi in 0..pool.workers.len() {
            let init = ToWorker::Init(WorkerInit {
                spec: spec.clone(),
                machines: pool.workers[wi].machines.iter().map(|&i| i as u32).collect(),
                shards: pool.workers[wi].machines.iter().map(|&i| shards[i].clone()).collect(),
                sample: sample.to_vec(),
            });
            pool.send(wi, &init)?;
        }
        for wi in 0..pool.workers.len() {
            match pool.recv(wi)? {
                FromWorker::Ready { version } if version == WIRE_VERSION => {}
                FromWorker::Ready { version } => {
                    return Err(pool.mark_dead(wi, version_mismatch(version)))
                }
                FromWorker::Fail { message } => {
                    return Err(pool.mark_dead(wi, format!("init failed: {message}")))
                }
                other => {
                    return Err(pool.mark_dead(wi, format!("unexpected init reply: {other:?}")))
                }
            }
        }
        Ok(pool)
    }

    /// Number of worker processes.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Number of simulated machines served.
    pub fn machines(&self) -> usize {
        self.n_machines
    }

    /// Total frame bytes sent/received since spawn.
    pub fn total_ipc_bytes(&self) -> (u64, u64) {
        (self.bytes_out, self.bytes_in)
    }

    /// Execute one round on every worker; returns per-machine replies (in
    /// machine order) plus the round's IPC stats.
    pub fn round(&mut self, task: &RoundTask) -> Result<(Vec<TaskReply>, RoundIpcStats)> {
        let (out0, in0) = (self.bytes_out, self.bytes_in);
        // one encode; every worker receives byte-identical frames.
        let payload = ToWorker::Round(task.clone()).encode();
        for wi in 0..self.workers.len() {
            self.send_payload(wi, &payload)?;
        }
        let mut out: Vec<Option<TaskReply>> = (0..self.n_machines).map(|_| None).collect();
        let mut calls = (0u64, 0u64, 0u64);
        for wi in 0..self.workers.len() {
            match self.recv(wi)? {
                FromWorker::RoundDone { replies, calls: c } => {
                    let hosted = self.workers[wi].machines.len();
                    if replies.len() != hosted {
                        return Err(self.mark_dead(
                            wi,
                            format!("returned {} replies for {hosted} machines", replies.len()),
                        ));
                    }
                    if let Some(bad) =
                        replies.iter().find(|r| !wire::reply_matches(task, r))
                    {
                        let msg = format!(
                            "reply shape mismatch for {} task: {bad:?}",
                            task.label()
                        );
                        return Err(self.mark_dead(wi, msg));
                    }
                    for (slot, reply) in replies.into_iter().enumerate() {
                        out[self.workers[wi].machines[slot]] = Some(reply);
                    }
                    calls.0 += c.0;
                    calls.1 += c.1;
                    calls.2 += c.2;
                }
                FromWorker::Fail { message } => return Err(self.mark_dead(wi, message)),
                other => {
                    return Err(
                        self.mark_dead(wi, format!("unexpected mid-round message: {other:?}"))
                    )
                }
            }
        }
        let replies: Vec<TaskReply> =
            out.into_iter().map(|r| r.expect("every machine is assigned a worker")).collect();
        let stats = RoundIpcStats {
            bytes_out: self.bytes_out - out0,
            bytes_in: self.bytes_in - in0,
            calls,
        };
        Ok((replies, stats))
    }

    /// Fault injection (tests): kill worker `wi`'s OS process *without*
    /// telling the pool — the next round must surface a structured error,
    /// exactly as if the process died on its own. External workers (no
    /// child handle) get their stream force-closed instead.
    pub fn kill_worker(&mut self, wi: usize) {
        if let Some(w) = self.workers.get_mut(wi) {
            match &mut w.child {
                Some(child) => {
                    let _ = child.kill();
                    let _ = child.wait();
                }
                None => w.control.force_close(),
            }
        }
    }

    fn send(&mut self, wi: usize, msg: &ToWorker) -> Result<()> {
        self.send_payload(wi, &msg.encode())
    }

    /// Queue one frame for the worker's writer thread. Never blocks on the
    /// stream; oversized payloads fail here (structured), write failures
    /// surface at the next `recv` (dead stream / timeout).
    fn send_payload(&mut self, wi: usize, payload: &[u8]) -> Result<()> {
        if !self.workers[wi].alive {
            return Err(worker_error(wi, "worker is dead (earlier failure)"));
        }
        if payload.len() > self.max_frame {
            let e = WireError::FrameTooLarge { len: payload.len(), max: self.max_frame };
            return Err(self.mark_dead(wi, format!("send failed: {e}")));
        }
        let queued = match &self.workers[wi].tx {
            Some(tx) => tx.send(payload.to_vec()).is_ok(),
            None => false,
        };
        if !queued {
            return Err(self.mark_dead(wi, "send failed: writer thread gone (stream broken)"));
        }
        self.bytes_out += wire::frame_size(payload.len()) as u64;
        Ok(())
    }

    fn recv(&mut self, wi: usize) -> Result<FromWorker> {
        if !self.workers[wi].alive {
            return Err(worker_error(wi, "worker is dead (earlier failure)"));
        }
        match self.workers[wi].rx.recv_timeout(self.timeout) {
            Ok(Ok((payload, nbytes))) => {
                self.bytes_in += nbytes as u64;
                match FromWorker::decode(&payload) {
                    Ok(msg) => Ok(msg),
                    Err(e) => Err(self.mark_dead(wi, format!("undecodable reply: {e}"))),
                }
            }
            Ok(Err(WireError::Truncated { got: 0, .. })) => {
                Err(self.mark_dead(wi, "worker closed its stream (exited or was killed)"))
            }
            Ok(Err(e)) => Err(self.mark_dead(wi, format!("bad reply frame: {e}"))),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let ms = self.timeout.as_millis();
                Err(self.mark_dead(wi, format!("no reply within {ms} ms (worker hung?)")))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(self.mark_dead(wi, "worker reader disconnected (process gone)"))
            }
        }
    }

    /// Mark `wi` dead, tear its stream down, reap the child (if any), and
    /// build the structured error.
    fn mark_dead(&mut self, wi: usize, message: impl Into<String>) -> Error {
        let w = &mut self.workers[wi];
        w.alive = false;
        w.tx = None; // writer thread exits; on pipes this drops stdin.
        w.control.force_close();
        if let Some(child) = &mut w.child {
            let _ = child.kill();
            let _ = child.wait();
        }
        worker_error(wi, message)
    }

    fn shutdown_all(&mut self) {
        for w in &mut self.workers {
            if let Some(tx) = w.tx.take() {
                let _ = tx.send(ToWorker::Shutdown.encode());
            } // dropping tx ends the writer; on pipes that also EOFs the
              // worker, which is a shutdown too.
        }
        for w in &mut self.workers {
            let Some(child) = &mut w.child else {
                // external worker, nothing to reap: wait (bounded) for the
                // writer to signal it drained the Shutdown frame, so the
                // close below cannot sever it mid-write — then close our
                // end so a worker that missed it observes EOF and exits.
                // A dead worker's writer has already exited and signaled.
                let _ = w.writer_done.recv_timeout(Duration::from_millis(250));
                w.control.force_close();
                continue;
            };
            let deadline = Instant::now() + Duration::from_millis(250);
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
            // unblock any reader thread still parked on the socket.
            w.control.force_close();
        }
    }
}

impl Drop for ProcessPool {
    fn drop(&mut self) {
        self.shutdown_all();
    }
}

// --- worker side ------------------------------------------------------------

struct WorkerRuntime {
    oracle: CountingOracle<std::sync::Arc<dyn Oracle>>,
    counters: std::sync::Arc<OracleCounters>,
    machines: Vec<usize>,
    shards: Vec<Vec<ElementId>>,
    stores: Vec<GuessStore>,
}

fn send_reply(w: &mut dyn Write, msg: &FromWorker, max_frame: usize) -> bool {
    wire::write_frame(w, &msg.encode(), max_frame).is_ok()
}

/// The worker main loop over arbitrary streams (in-memory in unit tests,
/// pipes or sockets in production). Sends the connect-time `Hello` (as
/// worker slot `worker_id`), then serves frames until shutdown. Returns
/// the process exit code.
pub fn run_worker(
    r: &mut dyn Read,
    w: &mut dyn Write,
    max_frame: usize,
    worker_id: u32,
    fault: Option<&str>,
) -> i32 {
    let hello_version = if fault == Some("bad-version") {
        WIRE_VERSION.wrapping_add(1)
    } else {
        WIRE_VERSION
    };
    if !send_reply(
        w,
        &FromWorker::Hello { version: hello_version, worker: worker_id },
        max_frame,
    ) {
        return 3;
    }
    let mut rt: Option<WorkerRuntime> = None;
    loop {
        let payload = match wire::read_frame(r, max_frame) {
            Ok((payload, _)) => payload,
            // clean EOF before a header byte: coordinator closed the stream.
            Err(WireError::Truncated { got: 0, .. }) => return 0,
            Err(e) => {
                send_reply(w, &FromWorker::Fail { message: e.to_string() }, max_frame);
                return 3;
            }
        };
        let msg = match ToWorker::decode(&payload) {
            Ok(msg) => msg,
            Err(e) => {
                send_reply(
                    w,
                    &FromWorker::Fail { message: format!("undecodable message: {e}") },
                    max_frame,
                );
                return 3;
            }
        };
        match msg {
            ToWorker::Init(init) => match init.spec.build() {
                Ok(oracle) => {
                    let counting = CountingOracle::new(oracle);
                    let counters = counting.counter();
                    let n = init.shards.len();
                    rt = Some(WorkerRuntime {
                        oracle: counting,
                        counters,
                        machines: init.machines.iter().map(|&i| i as usize).collect(),
                        shards: init.shards,
                        stores: vec![GuessStore::default(); n],
                    });
                    let version = if fault == Some("bad-version") {
                        WIRE_VERSION.wrapping_add(1)
                    } else {
                        WIRE_VERSION
                    };
                    if !send_reply(w, &FromWorker::Ready { version }, max_frame) {
                        return 3;
                    }
                }
                Err(e) => {
                    send_reply(
                        w,
                        &FromWorker::Fail { message: format!("cannot build oracle: {e}") },
                        max_frame,
                    );
                    return 3;
                }
            },
            ToWorker::Round(task) => {
                match fault {
                    // vanish without a reply: the coordinator sees a
                    // closed stream, exactly like an OOM-killed worker.
                    Some("die-mid-round") => return 3,
                    // go silent: the coordinator's worker_timeout_ms must
                    // bound the wait and declare the worker dead.
                    Some("hang-round") => {
                        std::thread::sleep(Duration::from_secs(20));
                        return 3;
                    }
                    Some("truncate-frame") => {
                        let reply =
                            FromWorker::RoundDone { replies: Vec::new(), calls: (0, 0, 0) };
                        let mut framed = Vec::new();
                        let _ = wire::write_frame(&mut framed, &reply.encode(), max_frame);
                        let half = framed.len() / 2;
                        let _ = w.write_all(&framed[..half]);
                        let _ = w.flush();
                        return 3;
                    }
                    Some("corrupt-checksum") => {
                        let reply =
                            FromWorker::RoundDone { replies: Vec::new(), calls: (0, 0, 0) };
                        let mut framed = Vec::new();
                        let _ = wire::write_frame(&mut framed, &reply.encode(), max_frame);
                        if let Some(last) = framed.last_mut() {
                            *last ^= 0xFF;
                        }
                        let _ = w.write_all(&framed);
                        let _ = w.flush();
                        return 3;
                    }
                    _ => {}
                }
                let Some(rt) = rt.as_mut() else {
                    send_reply(
                        w,
                        &FromWorker::Fail { message: "round before init".into() },
                        max_frame,
                    );
                    return 3;
                };
                let before = rt.counters.snapshot();
                let replies = shard::run_task_all(
                    &rt.oracle,
                    &rt.shards,
                    &mut rt.stores,
                    &rt.machines,
                    &task,
                    &crate::mapreduce::backend::Serial,
                );
                let after = rt.counters.snapshot();
                let calls = (
                    after.0.saturating_sub(before.0),
                    after.1.saturating_sub(before.1),
                    after.2.saturating_sub(before.2),
                );
                if !send_reply(w, &FromWorker::RoundDone { replies, calls }, max_frame) {
                    return 3;
                }
            }
            ToWorker::Shutdown => return 0,
        }
    }
}

/// Entry point for the hidden `mrsub worker` subcommand: serve the wire
/// protocol on stdin/stdout (default) or on a dialed-back socket
/// (`--connect HOST:PORT` / `--connect-uds PATH` / `MRSUB_CONNECT`),
/// identifying as worker slot `--id N` / `MRSUB_WORKER_ID`. Returns the
/// process exit code.
pub fn worker_main(args: &[String]) -> i32 {
    let max_frame = std::env::var("MRSUB_MAX_FRAME")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(DEFAULT_MAX_FRAME);
    let fault = std::env::var("MRSUB_FAULT").ok();
    let mut endpoint = std::env::var("MRSUB_CONNECT").ok();
    let mut worker_id: u32 = std::env::var("MRSUB_WORKER_ID")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let mut it = args.iter();
    while let Some(flag) = it.next() {
        let mut value = |name: &str| -> Option<String> {
            let v = it.next();
            if v.is_none() {
                eprintln!("mrsub worker: {name} needs a value");
            }
            v.cloned()
        };
        match flag.as_str() {
            "--connect" => match value("--connect") {
                // bare HOST:PORT means TCP; explicit uds:/tcp: pass through.
                Some(v) if v.starts_with("uds:") || v.starts_with("tcp:") => {
                    endpoint = Some(v);
                }
                Some(v) => endpoint = Some(format!("tcp:{v}")),
                None => return 2,
            },
            "--connect-uds" => match value("--connect-uds") {
                Some(v) => endpoint = Some(format!("uds:{v}")),
                None => return 2,
            },
            "--id" => match value("--id").and_then(|v| v.parse().ok()) {
                Some(v) => worker_id = v,
                None => {
                    eprintln!("mrsub worker: --id needs a non-negative integer");
                    return 2;
                }
            },
            other => {
                eprintln!("mrsub worker: unknown flag {other:?}");
                return 2;
            }
        }
    }
    // fault: die before ever connecting — the coordinator's accept
    // deadline must degrade this into a structured connection error.
    if fault.as_deref() == Some("no-connect") {
        return 3;
    }
    match endpoint {
        None => {
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            let mut r = stdin.lock();
            let mut w = stdout.lock();
            run_worker(&mut r, &mut w, max_frame, worker_id, fault.as_deref())
        }
        Some(ep) => {
            // a hand-launched remote worker may beat the coordinator's
            // bind; retry briefly before giving up with a structured
            // connection-refused error on stderr.
            let mut link = None;
            for attempt in 0..10 {
                match transport::connect(&ep) {
                    Ok(l) => {
                        link = Some(l);
                        break;
                    }
                    Err(e) if attempt == 9 => {
                        eprintln!("mrsub worker: connect {ep}: {e} (connection refused?)");
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(150)),
                }
            }
            match link {
                Some(mut link) => run_worker(
                    &mut *link.reader,
                    &mut *link.writer,
                    max_frame,
                    worker_id,
                    fault.as_deref(),
                ),
                None => 3,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! In-memory worker-loop tests (no process spawning — the spawning
    //! path is exercised by `tests/backend_conformance.rs`, which can see
    //! the built `mrsub` binary).

    use super::*;
    use crate::mapreduce::wire::{Dec, Enc};

    fn spec() -> OracleSpec {
        OracleSpec::Coverage { n: 60, universe: 40, avg_degree: 3, weighted: false, seed: 5 }
    }

    fn framed(msgs: &[ToWorker]) -> Vec<u8> {
        let mut buf = Vec::new();
        for m in msgs {
            wire::write_frame(&mut buf, &m.encode(), DEFAULT_MAX_FRAME).unwrap();
        }
        buf
    }

    fn read_replies(buf: &[u8]) -> Vec<FromWorker> {
        let mut cursor = std::io::Cursor::new(buf.to_vec());
        let mut out = Vec::new();
        while let Ok((payload, _)) = wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME) {
            out.push(FromWorker::decode(&payload).unwrap());
        }
        out
    }

    #[test]
    fn worker_loop_serves_hello_init_round_shutdown() {
        let init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0, 1],
            shards: vec![(0..30).collect(), (30..60).collect()],
            sample: vec![1, 2, 3],
        });
        let round = ToWorker::Round(RoundTask::LocalGreedy { k: 3 });
        let input = framed(&[init, round, ToWorker::Shutdown]);
        let mut r = std::io::Cursor::new(input);
        let mut out = Vec::new();
        let code = run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 7, None);
        assert_eq!(code, 0);
        let replies = read_replies(&out);
        assert_eq!(replies.len(), 3);
        assert!(
            matches!(replies[0], FromWorker::Hello { version: WIRE_VERSION, worker: 7 }),
            "first frame must be the connect-time Hello, got {:?}",
            replies[0]
        );
        assert!(matches!(replies[1], FromWorker::Ready { version: WIRE_VERSION }));
        match &replies[2] {
            FromWorker::RoundDone { replies, calls } => {
                assert_eq!(replies.len(), 2, "one reply per hosted machine");
                assert!(calls.0 > 0, "worker-side oracle calls reported");
                assert!(calls.1 > 0, "greedy heap fill runs the block path");
            }
            other => panic!("expected RoundDone, got {other:?}"),
        }
    }

    #[test]
    fn worker_eof_is_clean_exit_after_hello() {
        let mut r = std::io::Cursor::new(Vec::new());
        let mut out = Vec::new();
        assert_eq!(run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 0, None), 0);
        let replies = read_replies(&out);
        assert_eq!(replies.len(), 1, "only the Hello goes out before EOF");
        assert!(matches!(replies[0], FromWorker::Hello { .. }));
    }

    #[test]
    fn worker_round_before_init_fails_structurally() {
        let input = framed(&[ToWorker::Round(RoundTask::MaxSingleton)]);
        let mut r = std::io::Cursor::new(input);
        let mut out = Vec::new();
        assert_ne!(run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 0, None), 0);
        match &read_replies(&out)[1] {
            FromWorker::Fail { message } => assert!(message.contains("before init")),
            other => panic!("expected Fail, got {other:?}"),
        }
    }

    #[test]
    fn worker_rejects_corrupted_input_frame() {
        let mut input = framed(&[ToWorker::Round(RoundTask::MaxSingleton)]);
        let len = input.len();
        input[len - 1] ^= 0x55; // corrupt the checksum
        let mut r = std::io::Cursor::new(input);
        let mut out = Vec::new();
        assert_ne!(run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 0, None), 0);
        match &read_replies(&out)[1] {
            FromWorker::Fail { message } => assert!(message.contains("checksum")),
            other => panic!("expected Fail, got {other:?}"),
        }
    }

    #[test]
    fn bad_version_fault_poisons_the_hello() {
        let mut r = std::io::Cursor::new(Vec::new());
        let mut out = Vec::new();
        run_worker(&mut r, &mut out, DEFAULT_MAX_FRAME, 2, Some("bad-version"));
        match &read_replies(&out)[0] {
            FromWorker::Hello { version, worker: 2 } => {
                assert_ne!(*version, WIRE_VERSION, "faulted Hello must carry a wrong version")
            }
            other => panic!("expected Hello, got {other:?}"),
        }
    }

    #[test]
    fn fault_injection_shapes_are_detectable() {
        // truncate-frame: the emitted bytes must NOT parse as a frame.
        let init = ToWorker::Init(WorkerInit {
            spec: spec(),
            machines: vec![0],
            shards: vec![(0..60).collect()],
            sample: vec![],
        });
        let round = ToWorker::Round(RoundTask::MaxSingleton);
        let input = framed(&[init.clone(), round.clone()]);
        let mut out = Vec::new();
        let code = run_worker(
            &mut std::io::Cursor::new(input.clone()),
            &mut out,
            DEFAULT_MAX_FRAME,
            0,
            Some("truncate-frame"),
        );
        assert_ne!(code, 0);
        // first two frames (Hello, Ready) parse, third is truncated.
        let mut cursor = std::io::Cursor::new(out);
        assert!(wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_ok());
        assert!(wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_ok());
        assert!(matches!(
            wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME),
            Err(WireError::Truncated { .. })
        ));

        // corrupt-checksum: third frame fails the checksum.
        let mut out = Vec::new();
        run_worker(
            &mut std::io::Cursor::new(input),
            &mut out,
            DEFAULT_MAX_FRAME,
            0,
            Some("corrupt-checksum"),
        );
        let mut cursor = std::io::Cursor::new(out);
        assert!(wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_ok());
        assert!(wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME).is_ok());
        assert!(matches!(
            wire::read_frame(&mut cursor, DEFAULT_MAX_FRAME),
            Err(WireError::BadChecksum { .. })
        ));
    }

    #[test]
    fn spec_is_wire_codable_inside_init() {
        // Init round-trips through encode/decode with the spec intact.
        let init = WorkerInit {
            spec: spec(),
            machines: vec![3, 7],
            shards: vec![vec![1, 2], vec![3]],
            sample: vec![9],
        };
        let msg = ToWorker::Init(init.clone());
        match ToWorker::decode(&msg.encode()).unwrap() {
            ToWorker::Init(back) => assert_eq!(back, init),
            other => panic!("expected Init, got {other:?}"),
        }
        // Enc/Dec are also usable standalone for specs.
        let mut enc = Enc::new();
        init.spec.encode(&mut enc);
        let mut dec = Dec::new(&enc.buf);
        assert_eq!(OracleSpec::decode(&mut dec).unwrap(), init.spec);
    }
}
